import functools
import inspect
import random
import sys
import types

import numpy as np
import pytest


def _install_hypothesis_stub():
    """Minimal deterministic stand-in for hypothesis.

    The real dependency is declared in pyproject.toml; in environments where
    it isn't installed (e.g. hermetic CI containers) the property tests fall
    back to a fixed-seed sampler over the same strategies so the suite still
    collects and exercises the invariants.
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(lo, hi):
        return _Strategy(lambda r: r.randint(lo, hi))

    def floats(lo, hi):
        return _Strategy(lambda r: r.uniform(lo, hi))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    def settings(**kw):
        max_examples = kw.get("max_examples", 10)

        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 10))
                rng = random.Random(0)
                for _ in range(n):
                    draw = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **draw)

            # hide strategy-drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            runner.__signature__ = sig.replace(parameters=params)
            return runner

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies_mod = types.ModuleType("hypothesis.strategies")
    strategies_mod.integers = integers
    strategies_mod.floats = floats
    strategies_mod.sampled_from = sampled_from
    mod.strategies = strategies_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies_mod


_install_hypothesis_stub()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
