"""Persistent tuning DB (repro.tune): robustness + warm-start invariants.

The contract under test: a farm-produced DB lets a cold process resolve
measured winners with zero in-process sweeps, while a missing, corrupt,
wrong-schema, or env-mismatched DB degrades to exactly today's in-process
path — never a crash — with the fallback visible in the
``db_hits`` / ``db_misses`` / ``db_stale`` / ``sweeps`` counters.
"""

import json

import numpy as np
import jax.numpy as jnp
import pytest

import repro.ops as ops
import repro.ops.tiling as tiling
from repro.sparse import SparseTensor, wcsr_from_dense
from repro.tune import (TuneDB, TuneJob, run_farm, run_job, smoke_fleet)
from repro.tune.db import (TUNE_DB_SCHEMA, env_fingerprint, key_to_record,
                           problem_key, record_to_key)

SWEEP = dict(impl="kernel_interpret", bns=(32,), chunks_per_task=(4,),
             depths=(1,), warmup=0, iters=1)


@pytest.fixture(autouse=True)
def _isolated_tuning(monkeypatch):
    """Every test starts and ends with no DB installed and clean counters."""
    monkeypatch.delenv("REPRO_TUNE_DB", raising=False)
    monkeypatch.delenv(tiling.ENV_TUNE_ITERS_VAR, raising=False)
    monkeypatch.delenv(tiling.ENV_TUNE_WARMUP_VAR, raising=False)
    ops.set_tune_db(None)
    tiling._ENV_DBS.clear()
    ops.clear_tuning_cache()
    yield
    ops.set_tune_db(None)
    tiling._ENV_DBS.clear()
    ops.clear_tuning_cache()


def _operands(rng, m=64, k=96, n=64):
    d = rng.normal(size=(m, k)).astype(np.float32)
    d[np.abs(d) < 0.8] = 0.0
    st = SparseTensor.wrap(wcsr_from_dense(d, b_row=32, b_col=8))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    return st, b


def _key(st, n):
    return problem_key("spmm", st.format, st.shape, n, st.block, st.dtype)


def _winner(us=10.0, bn=32):
    return {"bn": bn, "chunks_per_task": 4, "pipeline_depth": 1,
            "value_codec": "none", "us": us}


# ---------------------------------------------------------------------------
# TuneDB core: round-trip, merge, quarantine, staleness
# ---------------------------------------------------------------------------


def test_db_roundtrip_and_key_codec(tmp_path, rng):
    st, b = _operands(rng)
    key = _key(st, 64)
    assert record_to_key(key_to_record(key)) == key
    db = TuneDB(tmp_path / "t.jsonl")
    assert db.lookup(key) == ("miss", None)
    db.record(key, _winner(), structure="abc", source="test")
    status, w = db.lookup(key)
    assert status == "hit" and w["bn"] == 32 and w["us"] == 10.0
    # a fresh handle reads the same entry back from disk
    db2 = TuneDB(tmp_path / "t.jsonl")
    assert db2.lookup(key)[0] == "hit"
    assert len(db2) == 1 and db2.quarantined == 0


def test_db_merge_best_us_wins(tmp_path, rng):
    """Duplicate keys fold read-side: lowest measured us wins — exactly the
    concurrent-writer story (appends never clobber, merge at load)."""
    st, _ = _operands(rng)
    key = _key(st, 64)
    path = tmp_path / "t.jsonl"
    # two independent handles on one path = two concurrent workers
    TuneDB(path).record(key, _winner(us=50.0, bn=64))
    TuneDB(path).record(key, _winner(us=10.0, bn=32))
    TuneDB(path).record(key, _winner(us=30.0, bn=128))
    db = TuneDB(path)
    _, w = db.lookup(key)
    assert (w["bn"], w["us"]) == (32, 10.0)
    # compact keeps only the merged winner and stays loadable
    n = db.compact()
    assert n == 1
    with open(path) as f:
        assert len(f.read().splitlines()) == 1
    assert TuneDB(path).lookup(key)[0] == "hit"


def test_db_quarantines_corrupt_and_wrong_schema(tmp_path, rng):
    st, _ = _operands(rng)
    key = _key(st, 64)
    path = tmp_path / "t.jsonl"
    good = TuneDB(path)
    good.record(key, _winner())
    with open(path, "a") as f:
        f.write("{ not json at all\n")                      # corrupt line
        f.write(json.dumps({"schema": "repro-tune/v999",    # wrong schema
                            "key": key_to_record(key),
                            "env": env_fingerprint(),
                            "winner": _winner()}) + "\n")
        f.write(json.dumps({"schema": TUNE_DB_SCHEMA,       # malformed key
                            "key": {"op": "spmm"},
                            "env": env_fingerprint(),
                            "winner": _winner()}) + "\n")
        f.write(json.dumps({"schema": TUNE_DB_SCHEMA,       # malformed winner
                            "key": key_to_record(key),
                            "env": env_fingerprint(),
                            "winner": {"bn": -3}}) + "\n")
    db = TuneDB(path)
    assert db.quarantined == 4
    assert db.lookup(key)[0] == "hit"  # the good record still serves


def test_db_env_mismatch_is_stale_not_served(tmp_path, rng):
    st, _ = _operands(rng)
    key = _key(st, 64)
    path = tmp_path / "t.jsonl"
    other = TuneDB(path, env={"jax": "0.0.1", "backend": "elsewhere"})
    other.record(key, _winner())
    db = TuneDB(path)  # real env
    assert db.lookup(key) == ("stale", None)
    assert len(db.entries) == 0 and len(db.stale) == 1
    # compact keeps stale records for the other fingerprint's deployments
    db.compact()
    assert TuneDB(path, env={"jax": "0.0.1",
                             "backend": "elsewhere"}).lookup(key)[0] == "hit"


def test_db_missing_file_and_unreadable_path_degrade(tmp_path):
    db = TuneDB(tmp_path / "never-written.jsonl")
    assert len(db) == 0 and db.quarantined == 0
    # a directory path can't be read or appended — still no crash on load
    db2 = TuneDB(tmp_path)
    assert len(db2) == 0


# ---------------------------------------------------------------------------
# autotune_spmm wiring: consult-before-sweep, record-after, counters
# ---------------------------------------------------------------------------


def test_autotune_records_then_warm_starts(tmp_path, rng):
    st, b = _operands(rng)
    path = tmp_path / "t.jsonl"
    ops.set_tune_db(TuneDB(path))
    cold = ops.autotune_spmm(st, b, **SWEEP)
    info = ops.tuning_cache_info()
    assert info.sweeps == 1 and info.db_misses == 1 and info.db_hits == 0
    # the winner was committed with the structure digest for provenance
    rec = next(iter(TuneDB(path).entries.values()))
    assert rec["structure"] == st.structure.content_digest()
    assert rec["meta"]["source"] == "autotune"

    # "restart": clean process state, fresh handle on the same file
    ops.clear_tuning_cache()
    ops.set_tune_db(TuneDB(path))
    warm = ops.autotune_spmm(st, b, **SWEEP)
    info = ops.tuning_cache_info()
    assert info.sweeps == 0 and info.db_hits == 1
    assert warm["bn"] == cold["bn"]
    assert warm["value_codec"] == cold["value_codec"]
    # the adopted winner steers "auto" plans exactly like a local tune
    plan = ops.make_plan(st, int(b.shape[1]), ops.current_config())
    assert plan.bn == cold["bn"]


def test_tuned_entry_cold_consult_adopts_from_db(tmp_path, rng):
    """make_plan/resolve_bn reach the DB through tuned_entry without anyone
    calling autotune_spmm in this 'process'."""
    st, b = _operands(rng)
    key = _key(st, 64)
    db = TuneDB(tmp_path / "t.jsonl")
    db.record(key, _winner(bn=32))
    ops.set_tune_db(db)
    entry = ops.tuned_entry("spmm", st.format, st.shape, 64, st.block,
                            st.dtype)
    assert entry is not None and entry["bn"] == 32
    assert ops.tuning_cache_info().db_hits == 1
    # second lookup is an in-process hit: no second DB consult counted
    ops.tuned_entry("spmm", st.format, st.shape, 64, st.block, st.dtype)
    assert ops.tuning_cache_info().db_hits == 1


def test_corrupt_db_falls_back_to_sweep_never_crashes(tmp_path, rng):
    st, b = _operands(rng)
    path = tmp_path / "t.jsonl"
    with open(path, "w") as f:
        f.write("\x00\xff garbage\n{broken\n")
    ops.set_tune_db(str(path))  # path form: engine/env usage
    best = ops.autotune_spmm(st, b, **SWEEP)
    info = ops.tuning_cache_info()
    assert info.sweeps == 1 and info.db_hits == 0 and info.db_misses == 1
    assert best["bn"] == 32
    # ...and the fresh winner was appended after the garbage, readably
    db = TuneDB(path)
    assert len(db) == 1 and db.quarantined == 2


def test_env_mismatched_db_falls_back_and_counts_stale(tmp_path, rng):
    st, b = _operands(rng)
    path = tmp_path / "t.jsonl"
    other = TuneDB(path, env={"jax": "0.0.1", "backend": "elsewhere"})
    other.record(_key(st, 64), _winner(bn=999))
    ops.set_tune_db(TuneDB(path))
    best = ops.autotune_spmm(st, b, **SWEEP)
    info = ops.tuning_cache_info()
    assert info.sweeps == 1 and info.db_stale == 1 and info.db_hits == 0
    assert best["bn"] == 32  # swept locally, never adopted bn=999


def test_no_db_behavior_identical_and_sweep_counted(rng):
    st, b = _operands(rng)
    y_plain = np.asarray(ops.spmm(st, b, impl="kernel_interpret"))
    best = ops.autotune_spmm(st, b, **SWEEP)
    assert ops.tuning_cache_info().sweeps == 1
    assert ops.tuning_cache_info().db_misses == 0  # no DB: nothing consulted
    assert best["bn"] == 32
    y_ref = np.asarray(ops.spmm(st, b, impl="ref"))
    np.testing.assert_allclose(y_plain, y_ref,
                               atol=2e-4 * max(1, np.abs(y_ref).max()))


def test_env_var_db_and_bad_path_degrade(tmp_path, monkeypatch, rng):
    st, b = _operands(rng)
    db = TuneDB(tmp_path / "env.jsonl")
    db.record(_key(st, 64), _winner(bn=32))
    monkeypatch.setenv("REPRO_TUNE_DB", str(tmp_path / "env.jsonl"))
    tiling._ENV_DBS.clear()
    assert ops.active_tune_db() is not None
    entry = ops.tuned_entry("spmm", st.format, st.shape, 64, st.block,
                            st.dtype)
    assert entry is not None and ops.tuning_cache_info().db_hits == 1
    # unreadable env path: active_tune_db degrades to None, ops still work
    monkeypatch.setenv("REPRO_TUNE_DB", str(tmp_path))  # a directory
    tiling._ENV_DBS.clear()
    ops.clear_tuning_cache()
    ops.spmm(st, b, impl="kernel_interpret")


def test_adopt_tuned_entries_idempotent_counts_new_only(tmp_path, rng):
    st, _ = _operands(rng)
    db = TuneDB(tmp_path / "t.jsonl")
    db.record(_key(st, 64), _winner())
    db.record(_key(st, 128), _winner(us=20.0))
    assert ops.adopt_tuned_entries(db.winners()) == 2
    assert ops.adopt_tuned_entries(db.winners()) == 0  # re-preload: no-op
    assert ops.tuning_cache_info().db_hits == 2


# ---------------------------------------------------------------------------
# Satellites: timing env overrides + full counter reset
# ---------------------------------------------------------------------------


def test_tune_iters_warmup_env_overrides(monkeypatch, rng):
    seen = {}
    real = tiling._time_us

    def spy(fn, *args, warmup, iters):
        seen.update(warmup=warmup, iters=iters)
        return real(fn, *args, warmup=warmup, iters=iters)

    monkeypatch.setattr(tiling, "_time_us", spy)
    st, b = _operands(rng)
    monkeypatch.setenv(tiling.ENV_TUNE_ITERS_VAR, "2")
    monkeypatch.setenv(tiling.ENV_TUNE_WARMUP_VAR, "0")
    ops.autotune_spmm(st, b, impl="kernel_interpret", bns=(32,),
                      chunks_per_task=(4,), depths=(1,))
    assert seen == {"warmup": 0, "iters": 2}
    # explicit kwargs beat the env; malformed env falls back to defaults
    ops.clear_tuning_cache()
    monkeypatch.setenv(tiling.ENV_TUNE_ITERS_VAR, "not-a-number")
    ops.autotune_spmm(st, b, impl="kernel_interpret", bns=(32,),
                      chunks_per_task=(4,), depths=(1,), warmup=0, iters=1)
    assert seen == {"warmup": 0, "iters": 1}
    assert tiling._env_tune_int(tiling.ENV_TUNE_ITERS_VAR, 3, minimum=1) == 3
    monkeypatch.setenv(tiling.ENV_TUNE_ITERS_VAR, "-5")  # clamped to minimum
    assert tiling._env_tune_int(tiling.ENV_TUNE_ITERS_VAR, 3, minimum=1) == 1


def test_clear_tuning_cache_resets_every_counter(tmp_path, rng):
    st, b = _operands(rng)
    ops.set_tune_db(TuneDB(tmp_path / "t.jsonl"))
    ops.autotune_spmm(st, b, **SWEEP)
    ops.spmm(st, b, impl="kernel_interpret")  # count a depth/codec selection
    info = ops.tuning_cache_info()
    assert info.autotuned == 1 and info.sweeps == 1
    assert info.pipeline_depths and info.value_codecs
    ops.clear_tuning_cache()
    info = ops.tuning_cache_info()
    assert dataclasses_zeroed(info)


def dataclasses_zeroed(info) -> bool:
    return (info.hits == info.misses == info.size == info.autotuned == 0
            and info.pipeline_depths == {} and info.value_codecs == {}
            and info.db_hits == info.db_misses == info.db_stale == 0
            and info.sweeps == 0)


# ---------------------------------------------------------------------------
# Farm: jobs, inline run, merge across writers
# ---------------------------------------------------------------------------


def test_tune_job_roundtrip_and_unknown_fields():
    job = TuneJob(fmt="wcsr", block=(16, 8), codecs=("none", "int8"))
    assert TuneJob.from_dict(job.to_dict()) == job
    with pytest.raises(ValueError, match="unknown fields"):
        TuneJob.from_dict({"fmt": "bcsr", "bogus": 1})


def test_run_farm_inline_produces_warm_startable_db(tmp_path):
    path = str(tmp_path / "farm.jsonl")
    summary = run_farm(smoke_fleet(), path, workers=0)
    assert summary["tuned"] == 2 and not summary["failed"]
    db = TuneDB(path)
    assert len(db) == 2 and db.quarantined == 0
    fmts = {k[1] for k in db.entries}
    assert fmts == {"bcsr", "wcsr"}
    # every record carries the deterministic structure digest: re-running
    # a job on another worker maps to the same provenance
    job = smoke_fleet()[0]
    r1 = run_job(job)
    r2 = run_job(job)
    assert r1["key"] == r2["key"]


def test_run_farm_survives_a_bad_job(tmp_path):
    path = str(tmp_path / "farm.jsonl")
    jobs = [smoke_fleet()[0],
            TuneJob(fmt="nope", m=64, k=64, n=32, block=(16, 16))]
    summary = run_farm(jobs, path, workers=0)
    assert summary["tuned"] == 1
    assert len(summary["failed"]) == 1
    assert summary["failed"][0]["job"]["fmt"] == "nope"
    assert len(TuneDB(path)) == 1  # the good winner was still committed


# ---------------------------------------------------------------------------
# ServeEngine warm-start (the acceptance criterion, in-suite)
# ---------------------------------------------------------------------------


def _tiny_engine(tune_db):
    import jax

    from repro.configs import ARCHS, reduced_config
    from repro.models.registry import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=1, vocab_size=512)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, slots=1, max_len=32, page_size=8, chunk=8,
                      prefill_block_q=8, tune_db=tune_db)
    rng = np.random.default_rng(0)
    req = Request(rid=0, prompt=rng.integers(0, 512, (6,)), max_new_tokens=2)
    eng.run([req])
    assert req.done
    return eng


def test_engine_warm_starts_with_zero_sweeps(tmp_path):
    path = str(tmp_path / "farm.jsonl")
    run_farm(smoke_fleet(), path, workers=0)
    ops.clear_tuning_cache()
    ops.set_tune_db(None)
    eng = _tiny_engine(path)
    db = eng.stats()["tune_db"]
    assert db["entries"] == 2 and db["quarantined"] == 0
    assert db["db_hits"] > 0, db
    assert db["sweeps"] == 0, db


def test_engine_with_corrupt_db_serves_normally(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text("definitely { not json\n" * 3)
    eng = _tiny_engine(str(path))
    db = eng.stats()["tune_db"]
    assert db["entries"] == 0 and db["quarantined"] == 3
    assert db["sweeps"] == 0  # degraded path never sweeps on its own


def test_engine_without_db_reports_none():
    eng = _tiny_engine(None)
    assert eng.stats()["tune_db"] is None
