"""The ``repro.sparse`` layer: SparseTensor ergonomics, the SparseFormat
registry, structure/values separation, and cached execution plans
(``repro.ops.make_plan``)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ops as ops
from repro.sparse import (
    BCSR, WCSR, SparseStructure, SparseTensor, apply_block_mask, convert,
    format_of, get_format, random_block_mask, registered_sparse_formats,
    sparsify, structure_of, wcsr_from_dense,
)


def _mats(rng, m=128, k=128, n=96, density=0.3):
    d = rng.normal(size=(m, k)).astype(np.float32)
    d *= rng.random(d.shape) < density
    sa = SparseTensor.from_dense(d, "bcsr", block=(32, 32))
    sw = SparseTensor.from_dense(d, "wcsr", block=(32, 8))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    return d, sa, sw, b


# ---------------------------------------------------------------------------
# Acceptance: __matmul__ == spmm bit-for-bit under every available backend
# ---------------------------------------------------------------------------


def test_matmul_matches_spmm_bitwise_every_backend(rng):
    d, sa, sw, b = _mats(rng)
    for st in (sa, sw):
        raw = st.raw
        backends = ops.available_backends(f"spmm/{st.format}")
        assert backends, st.format
        for impl in backends:
            with ops.use_config(impl=impl):
                got = np.asarray(st @ b)
            want = np.asarray(ops.spmm(raw, b, impl=impl))
            assert np.array_equal(got, want), (st.format, impl)
            # per-call override form too
            got2 = np.asarray(st.matmul(b, impl=impl))
            assert np.array_equal(got2, want), (st.format, impl)


# ---------------------------------------------------------------------------
# Acceptance: make_plan decomposes tasks once per structure across steps
# ---------------------------------------------------------------------------


def test_make_plan_task_decomposition_once_across_serve_steps(rng):
    _, _, sw, b = _mats(rng)
    ops.clear_plan_cache()
    for _ in range(6):  # repeated serve steps, same layer
        sw.matmul(b, impl="kernel_interpret")
    info = ops.plan_cache_info()
    assert info.task_decompositions == 1
    assert info.misses == 1 and info.hits == 5

    # value swaps (weight update) and dtype casts share the structure ->
    # never re-derive the task decomposition
    sw_updated = sw.with_values(sw.data[0] * 2.0)
    sw_cast = sw.astype(jnp.bfloat16)
    assert sw_updated.structure is sw.structure
    assert sw_cast.structure is sw.structure
    sw_updated.matmul(b, impl="kernel_interpret")
    sw_cast.matmul(b.astype(jnp.bfloat16), impl="kernel_interpret")
    assert ops.plan_cache_info().task_decompositions == 1

    # a different structure does plan again
    d2 = np.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    d2 *= np.asarray(rng.random(d2.shape) < 0.2)
    sw2 = SparseTensor.from_dense(d2, "wcsr", block=(32, 8))
    sw2.matmul(b, impl="kernel_interpret")
    assert ops.plan_cache_info().task_decompositions == 2


def test_make_plan_inspectable(rng):
    _, sa, sw, b = _mats(rng)
    pa = ops.make_plan(sa, b.shape[1], dtype=sa.dtype)
    assert pa.tasks is None and pa.bn > 0
    pw = ops.make_plan(sw.structure, b.shape[1], dtype=sw.dtype)
    assert pw.num_tasks == len(pw.tasks[0]) > 0
    with pytest.raises(TypeError, match="SparseStructure"):
        ops.make_plan(np.zeros((4, 4)), 8)


def test_make_plan_infers_tensor_dtype(rng):
    """make_plan(SparseTensor, n) keys on the tensor's value dtype, so the
    inspectable plan is the one the matmul actually executed with."""
    _, _, sw, b = _mats(rng)
    ops.clear_plan_cache()
    sw.matmul(b, impl="kernel_interpret")  # plans with float32 values
    assert ops.plan_cache_info().misses == 1
    ops.make_plan(sw, b.shape[1])  # dtype inferred -> cache hit, no re-plan
    info = ops.plan_cache_info()
    assert info.hits == 1 and info.misses == 1


# ---------------------------------------------------------------------------
# Structure/values separation
# ---------------------------------------------------------------------------


def test_structure_hashable_and_content_equal(rng):
    d, sa, sw, _ = _mats(rng)
    s1 = structure_of(sa.raw)
    assert s1 == sa.structure and hash(s1) == hash(sa.structure)
    assert s1 != sw.structure
    # usable as dict key
    cache = {sa.structure: "a", sw.structure: "w"}
    assert cache[s1] == "a"


def test_attach_values_roundtrip(rng):
    d, sa, sw, _ = _mats(rng)
    for st, cls in ((sa, BCSR), (sw, WCSR)):
        rebuilt = st.structure.attach_values(*st.data)
        assert isinstance(rebuilt, cls)
        assert np.array_equal(np.asarray(st.todense()),
                              np.asarray(SparseTensor.wrap(rebuilt).todense()))


def test_pytree_roundtrip_and_jit(rng):
    d, sa, sw, b = _mats(rng)
    leaves, treedef = jax.tree_util.tree_flatten(sa)
    assert len(leaves) == 1  # values only; structure is static aux data
    sa2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert sa2.structure is sa.structure

    f = jax.jit(lambda t, x: ops.spmm(t, x, impl="ref"))
    np.testing.assert_allclose(np.asarray(f(sa, b)),
                               np.asarray(sa.matmul(b, impl="ref")),
                               atol=1e-5)
    # the WCSR *kernel* path is traceable through SparseTensor: the task
    # decomposition comes from the static structure, not a traced window_ptr
    g = jax.jit(lambda t, x: ops.spmm(t, x, impl="kernel_interpret"))
    np.testing.assert_allclose(
        np.asarray(g(sw, b)),
        np.asarray(sw.matmul(b, impl="kernel_interpret")), atol=1e-5)
    # ... while a raw WCSR under jit still raises the clear error
    with pytest.raises(ValueError, match="SparseTensor"):
        jax.jit(lambda w_, x: ops.spmm(w_, x, impl="kernel_interpret"))(
            sw.raw, b)


# ---------------------------------------------------------------------------
# SparseTensor ergonomics
# ---------------------------------------------------------------------------


def test_tensor_properties_and_transpose(rng):
    d, sa, sw, _ = _mats(rng)
    assert sa.format == "bcsr" and sw.format == "wcsr"
    assert sa.shape == d.shape and sw.shape == d.shape
    assert 0 < sa.density <= 1.0
    assert sa.fill_ratio(d) <= 1.0 + 1e-9
    at = sa.T
    assert at.shape == (d.shape[1], d.shape[0])
    assert np.allclose(np.asarray(at.todense()), d.T)
    wt = sw.T
    assert np.allclose(np.asarray(wt.todense()), d.T)


def test_tensor_to_conversion(rng):
    d, sa, _, _ = _mats(rng)
    sw = sa.to("wcsr", block=(32, 8))
    assert isinstance(sw, SparseTensor) and sw.format == "wcsr"
    assert np.allclose(np.asarray(sw.todense()), d)
    assert sa.to("bcsr") is sa  # same-format convert is the identity


def test_same_format_convert_with_kwargs_reblocks(rng):
    d, sa, _, _ = _mats(rng)
    rb = sa.to("bcsr", block=(64, 64))  # re-pack through the dense hop
    assert rb is not sa and rb.block == (64, 64)
    assert np.array_equal(np.asarray(rb.todense()), np.asarray(sa.todense()))
    with pytest.raises(TypeError, match="unexpected keyword"):
        sa.to("bcsr", blokc=(64, 64))  # typos never silently no-op


def test_astype_same_structure_new_dtype(rng):
    _, sa, _, _ = _mats(rng)
    sb = sa.astype(jnp.bfloat16)
    assert sb.dtype == jnp.bfloat16
    assert sb.structure is sa.structure
    assert sa.dtype == jnp.float32  # original untouched


def test_sparsify_returns_tensor_both_formats(rng):
    w = rng.normal(size=(128, 64)).astype(np.float32)
    a = sparsify(w, format="bcsr", block=(32, 32), sparsity=0.75)
    # 25% of 8 blocks kept (+ zero coverage blocks for empty block-rows)
    assert a.format == "bcsr" and 2 <= a.raw.nnz_blocks <= 4
    assert a.fill_ratio(np.asarray(a.todense())) <= 1.0 + 1e-9
    ww = sparsify(w, format="wcsr", block=(32, 8), sparsity=0.9,
                  method="random", seed=1)
    assert ww.format == "wcsr"
    band = sparsify(w, format="bcsr", block=(32, 32), method="banded",
                    bandwidth_blocks=0)
    assert band.raw.nnz_blocks >= 4
    with pytest.raises(ValueError, match="unknown format"):
        sparsify(w, format="csr5", sparsity=0.5)


def test_sparse_linear_from_sparse_tensor(rng):
    from repro.core.sparse_linear import (SparseLinear, SparseLinearSpec,
                                          sparse_linear_from_dense)

    w = rng.normal(size=(128, 64)).astype(np.float32)
    layer = sparse_linear_from_dense(
        w, SparseLinearSpec(64, 128, sparsity=0.5, block=(32, 32)))
    st = layer.to_sparse()
    assert st.format == "bcsr"
    layer2 = SparseLinear.from_sparse(st)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    with ops.use_config(impl="ref"):
        np.testing.assert_allclose(np.asarray(layer(x)),
                                   np.asarray(layer2(x)), atol=1e-5)


# ---------------------------------------------------------------------------
# SparseFormat registry
# ---------------------------------------------------------------------------


def test_format_registry_lookup(rng):
    d, sa, sw, _ = _mats(rng)
    assert format_of(sa.raw).name == "bcsr"
    assert format_of(sw).name == "wcsr"  # SparseTensor via its structure
    assert format_of(d).name == "dense"
    assert {"bcsr", "wcsr", "dense"} <= set(registered_sparse_formats())
    with pytest.raises(ValueError, match="unknown sparse format"):
        get_format("csr5")
    with pytest.raises(TypeError, match="unsupported sparse format"):
        format_of(object())


def test_spmm_dispatch_via_registry_rejects_dense(rng):
    with pytest.raises(TypeError, match="unsupported sparse format"):
        ops.spmm(np.zeros((4, 4)), jnp.zeros((4, 4)))


def test_register_format_compat_hook(rng):
    """ops.register_format still plugs a new type into spmm dispatch."""
    from repro.sparse import registry as sreg

    class FakeFmt:
        pass

    calls = []

    @ops.register_backend("spmm/fake", "only")
    def _fake_backend(a, b, cfg):
        calls.append(a)
        return jnp.zeros((1, 1))

    try:
        ops.register_format(FakeFmt, "spmm/fake")
        ops.spmm(FakeFmt(), jnp.zeros((4, 4)), impl="only")
        assert len(calls) == 1
    finally:
        from repro.ops import registry as oreg
        oreg._BACKENDS.pop("spmm/fake", None)
        sreg._BY_NAME.pop("fakefmt", None)
        sreg._BY_TYPE.pop(FakeFmt, None)


def test_serve_engine_stats_exposes_plan_cache():
    from repro.serve.engine import ServeEngine

    stats_keys = {"active_slots", "free_slots", "plan_cache", "tuning_cache"}
    # a minimal engine over a stub model (stats() must not require traffic)
    class _Cache:
        kv = ssm = prev1 = prev2 = None

    class _Model:
        cfg = None

        def init_decode_cache(self, slots, max_len):
            return _Cache()

        def decode_step(self, p, c, tok, pos):
            return jnp.zeros((tok.shape[0], 4)), c

    eng = ServeEngine(_Model(), params={}, slots=2, max_len=8)
    s = eng.stats()
    assert stats_keys <= set(s)
    assert s["free_slots"] == 2
