"""Distributed semantics on a forced multi-device host mesh (subprocess:
device count must be fixed before jax initializes). Covers: sharded train
step numerics vs single device, MoE shard_map path, compressed/hierarchical
collectives, GPipe equivalence, elastic checkpoint restore onto a mesh, the
structure-aware sparse partitioner (in-process: pure host-side numpy),
sharded-vs-single-device spmm equality on a 4-device mesh, and dynamic
structure growth repartitioning with only affected shards reshipped."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8):
    src = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_sharded_train_step_matches_single_device():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced_config
    from repro.models.registry import build_model
    from repro.models.common import mesh_context
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import make_mesh_rules, param_shardings, batch_shardings
    from repro.train.step import init_train_state, make_train_step

    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=2, tp_shards=2)
    m = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
    }
    params = m.init(jax.random.PRNGKey(0))
    # single device
    s0 = init_train_state(params)
    st0, m0 = jax.jit(make_train_step(m))(s0, batch)
    # 4 data x 2 model mesh
    mesh = make_test_mesh(data=4, model=2)
    rules = make_mesh_rules(mesh)
    with mesh_context(mesh, rules):
        s1 = init_train_state(params)
        st1, m1 = jax.jit(make_train_step(m))(s1, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-3, (m0, m1)
    for a, b in zip(jax.tree.leaves(st0.params), jax.tree.leaves(st1.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)
    print("OK")
    """)


def test_moe_shard_map_matches_local():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced_config
    from repro.models.registry import build_model
    from repro.models.common import mesh_context
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import make_mesh_rules

    # EP: 4 experts over 2 model shards
    cfg = reduced_config(ARCHS["kimi-k2-1t-a32b"], tp_shards=2,
                         capacity_factor=8.0)
    assert cfg.expert_partition == "expert"
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    l0, _ = m.forward(params, batch)
    mesh = make_test_mesh(data=4, model=2)
    with mesh_context(mesh, make_mesh_rules(mesh)):
        l1, _ = jax.jit(m.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-3)
    print("OK")
    """)


def test_compressed_and_hierarchical_psum():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.collectives import (
        compressed_psum_bf16, compressed_psum_int8_ef, hierarchical_psum)

    mesh = make_test_mesh(data=2, model=2, pod=2)
    x = jnp.arange(16, dtype=jnp.float32).reshape(8, 2) / 7.0

    def f(x):
        exact = jax.lax.psum(x, ("pod", "data"))
        hier = hierarchical_psum(x, "data", "pod")
        comp = compressed_psum_bf16(x, ("pod", "data"))
        q, err = compressed_psum_int8_ef(x, ("pod", "data"))
        return exact, hier, comp, q, err

    out = shard_map(f, mesh=mesh,
                        in_specs=P(("pod", "data")),
                        out_specs=(P(("pod", "data")),) * 5,
                        check_vma=False)(x)
    exact, hier, comp, q, err = map(np.asarray, out)
    np.testing.assert_allclose(hier, exact, rtol=1e-6)
    np.testing.assert_allclose(comp, exact, rtol=2e-2, atol=1e-2)
    np.testing.assert_allclose(q, exact, rtol=0.1, atol=0.05)
    # error feedback residual bounded by one quantization step
    assert np.abs(err).max() <= np.abs(x).max() / 127 + 1e-6
    print("OK")
    """)


def test_gpipe_matches_sequential():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.pipeline import gpipe, split_stages

    mesh = make_test_mesh(data=2, model=1, pod=4)  # 4 pipeline stages
    rng = np.random.default_rng(0)
    L, D = 8, 16
    ws = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) / np.sqrt(D))

    def stage_fn(w_stack, x):  # w_stack: [L/S, D, D]
        for i in range(w_stack.shape[0]):
            x = jnp.tanh(x @ w_stack[i])
        return x

    xs = jnp.asarray(rng.normal(size=(6, 8, D)).astype(np.float32))  # 6 microbatches
    piped = gpipe(stage_fn, mesh, axis="pod", data_axes=("data",))
    got = piped(split_stages(ws, 4), xs)
    want = xs
    for i in range(L):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # and it differentiates (autodiff through ppermute)
    loss = lambda w: jnp.sum(piped(split_stages(w, 4), xs) ** 2)
    g = jax.grad(loss)(ws)
    assert np.isfinite(np.asarray(g)).all()
    print("OK")
    """)


# ---------------------------------------------------------------------------
# Structure-aware sparse partitioner (host-side; no multi-device needed)
# ---------------------------------------------------------------------------


def _skewed(m, k, density, seed=0):
    """Power-law row-degree synthetic (the irregular-sparsity regime)."""
    rng = np.random.default_rng(seed)
    a = np.zeros((m, k), np.float32)
    row_nnz = np.maximum(1, (k * density * m *
                             (np.arange(1, m + 1) ** -0.9)
                             / (np.arange(1, m + 1) ** -0.9).sum())).astype(int)
    for i in range(m):
        cols = rng.choice(k, size=min(int(row_nnz[i]), k), replace=False)
        a[i, cols] = rng.normal(size=len(cols))
    return a


def test_partitioner_balance_on_skewed_matrix():
    from repro.parallel.sparse import partition_structure
    from repro.sparse import SparseTensor

    d = _skewed(256, 256, 0.08)
    for fmt, block in [("bcsr", (16, 16)), ("wcsr", (16, 8))]:
        st = SparseTensor.from_dense(d, fmt, block=block)
        part = partition_structure(st.structure, 4)
        bal = part.balance()
        # acceptance bound: worst shard carries <= 1.5x the mean stored work
        assert bal["ratio"] <= 1.5, (fmt, bal)
        # shards exactly tile the stored work (nothing dropped or duplicated)
        assert sum(bal["stored_per_shard"]) == st.structure.stored_elements
        assert len(part.shards) == 4
        for s in part.shards:
            assert s.shape == st.structure.shape  # full logical shape


def test_partitioner_giant_row_and_empty_windows():
    from repro.parallel.sparse import partition_structure
    from repro.sparse import SparseTensor

    # single giant row: all work in one window / block-row must still split
    d = np.zeros((128, 128), np.float32)
    d[5, :] = 1.0
    st = SparseTensor.from_dense(d, "wcsr", block=(16, 8))
    bal = partition_structure(st.structure, 4).balance()
    # the giant window splits at chunk granularity across all shards
    assert bal["ratio"] <= 1.5, bal
    assert min(bal["stored_per_shard"]) > 0

    stb = SparseTensor.from_dense(d, "bcsr", block=(16, 16))
    balb = partition_structure(stb.structure, 4).balance()
    assert balb["ratio"] <= 1.5, balb

    # mostly-empty windows: partition stays valid (some shards may be empty)
    d2 = np.zeros((128, 128), np.float32)
    d2[64:80, 10:20] = 1.0
    st2 = SparseTensor.from_dense(d2, "wcsr", block=(16, 8))
    part2 = partition_structure(st2.structure, 4)
    assert sum(part2.balance()["stored_per_shard"]) == \
        st2.structure.stored_elements

    # fully-empty matrix: no crash, work conserved
    st3 = SparseTensor.from_dense(np.zeros((64, 64), np.float32),
                                  "wcsr", block=(16, 8))
    part3 = partition_structure(st3.structure, 4)
    assert sum(part3.balance()["stored_per_shard"]) == \
        st3.structure.stored_elements


def test_partition_cache_memoizes_per_structure():
    from repro.ops import clear_plan_cache, make_partition, plan_cache_info
    from repro.sparse import SparseTensor

    d = _skewed(64, 64, 0.1, seed=1)
    st = SparseTensor.from_dense(d, "wcsr", block=(16, 8))
    clear_plan_cache()
    p1 = make_partition(st.structure, 4)
    p2 = make_partition(st, 4)  # SparseTensor accepted, same key
    assert p1 is p2
    info = plan_cache_info()
    assert info.partition_misses == 1 and info.partition_hits == 1
    assert info.partitions == 1
    # a value swap keeps the structure object -> same cached partition
    assert make_partition(st.with_values(st.data[0] * 2).structure, 4) is p1
    clear_plan_cache()
    assert plan_cache_info().partitions == 0


# ---------------------------------------------------------------------------
# Sharded spmm vs single device (forced 4-device host mesh)
# ---------------------------------------------------------------------------


def test_sharded_spmm_matches_single_device():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.sparse import SparseTensor
    from repro.ops import spmm, plan_cache_info
    from repro.parallel.sparse import use_sparse_mesh

    rng = np.random.default_rng(0)
    d = rng.normal(size=(256, 128)).astype(np.float32)
    d *= rng.random(d.shape) < 0.12
    b = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    mesh = jax.make_mesh((4,), ("data",))
    assert mesh.shape["data"] == 4
    for fmt, block in [("bcsr", (32, 32)), ("wcsr", (32, 8))]:
        st = SparseTensor.from_dense(d, fmt, block=block)
        y0 = np.asarray(spmm(st, b))  # single-device, default backend
        sst = st.shard(mesh, "data")
        for impl in ("ref", "kernel_interpret"):
            y1 = np.asarray(spmm(sst, b, impl=impl))
            np.testing.assert_allclose(y1, y0, atol=2e-4, rtol=1e-4)
        # jit over the sharded operand (structure/partition are static aux)
        yj = np.asarray(jax.jit(lambda s, x: spmm(s, x))(sst, b))
        np.testing.assert_allclose(yj, y0, atol=2e-4, rtol=1e-4)
        # auto-shard: plain SparseTensor inside a sparse-mesh scope
        with use_sparse_mesh(mesh):
            y2 = np.asarray(st @ b)
        np.testing.assert_allclose(y2, y0, atol=2e-4, rtol=1e-4)
    info = plan_cache_info()
    assert info.partitions == 2, info       # one partition per structure
    assert info.partition_misses == 2, info
    # value swaps reuse the cached partition (the serving contract)
    sst2 = st.shard(mesh, "data").with_values(st.data[0] * 2.0)
    y3 = np.asarray(spmm(sst2, b, impl="ref"))
    np.testing.assert_allclose(y3, 2.0 * y0, atol=4e-4, rtol=1e-4)
    assert plan_cache_info().partition_misses == 2
    print("OK")
    """, devices=4)


def test_sharded_quantized_spmm_matches_single_device():
    """Value-codec shards ship compressed: each shard carries its int8
    payload slice plus the f32 scales of exactly its own chunks/blocks,
    local kernels fuse the dequant, and the partition cache is shared with
    the raw tensor of the same structure."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.sparse import SparseTensor
    from repro.ops import spmm, plan_cache_info, clear_plan_cache
    clear_plan_cache()

    rng = np.random.default_rng(0)
    d = rng.normal(size=(256, 128)).astype(np.float32)
    d *= rng.random(d.shape) < 0.12
    b = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    mesh = jax.make_mesh((4,), ("data",))
    for fmt, block in [("bcsr", (32, 32)), ("wcsr", (32, 8))]:
        st = SparseTensor.from_dense(d, fmt, block=block)
        q = st.quantize("int8")
        y0 = np.asarray(spmm(q, b))          # single-device quantized
        sst = q.shard(mesh, "data")
        assert sst.codec == "int8" and len(sst.data) == 2
        assert sst.data[0].dtype == jnp.int8  # compressed on the wire
        for impl in ("ref", "kernel_interpret"):
            y1 = np.asarray(spmm(sst, b, impl=impl))
            np.testing.assert_allclose(y1, y0, atol=2e-4, rtol=1e-4)
        # jit over the sharded quantized operand
        yj = np.asarray(jax.jit(lambda s, x: spmm(s, x))(sst, b))
        np.testing.assert_allclose(yj, y0, atol=2e-4, rtol=1e-4)
        # bf16-compressed output collective composes with the codec
        yb = np.asarray(spmm(sst, b, impl="ref", reduce="bf16"))
        np.testing.assert_allclose(yb, y0, atol=2e-2, rtol=2e-2)
        # quantized + raw tensors of one structure share the partition
        st.shard(mesh, "data")
    info = plan_cache_info()
    assert info.partitions == 2, info
    assert info.partition_misses == 2, info
    print("OK")
    """, devices=4)


def test_dynamic_append_reships_only_affected_shards():
    """Grow the last window chunk-by-chunk until the balanced partitioner
    migrates a chunk boundary. Every repartition must be a *patch* (the
    ``partition.patched`` counter), untouched shards must be reused by
    object (the ``shards_reused`` counter — those shards are never
    re-shipped to their device), and the grown sharded operand must still
    match single-device spmm."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.sparse import SparseTensor, delta_stats
    from repro.ops import spmm, make_partition, clear_plan_cache, cache_stats
    clear_plan_cache()

    rng = np.random.default_rng(0)
    d = rng.normal(size=(256, 128)).astype(np.float32)
    d *= rng.random(d.shape) < 0.02   # ~half the columns stored per window
    b = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    mesh = jax.make_mesh((4,), ("data",))
    st = SparseTensor.from_dense(d, "wcsr", block=(32, 8))
    part = make_partition(st, 4)
    bounds0 = np.asarray(part.bounds).copy()
    bounds_prev = bounds0
    w = 7  # grow the LAST window: prefix shards must stay reusable
    migrated, steps = False, 0
    for step in range(8):
        g = st.structure
        p0, p1 = int(g.ptrs[w]), int(g.ptrs[w + 1])
        stored = set(int(c) for c in g.indices[0][p0:p1] if int(c) >= 0)
        free = [c for c in range(128) if c not in stored][:8]
        if len(free) < 8:
            break
        vals = rng.normal(size=(32, 8)).astype(np.float32)
        before = delta_stats()
        st = st.append_window_chunks(w, free, vals)
        part = make_partition(st, 4)
        after = delta_stats()
        steps += 1
        shipped = after["shards_reshipped"] - before["shards_reshipped"]
        reused = after["shards_reused"] - before["shards_reused"]
        assert shipped + reused == 4, (shipped, reused)
        bounds = np.asarray(part.bounds)
        if np.array_equal(bounds, bounds_prev):
            # pure growth: only the shard holding the touched window ships
            assert shipped == 1, (step, shipped)
        else:
            # a chunk migrated: boundary shards reship, the rest reuse
            assert shipped <= 3, (step, shipped)
        bounds_prev = bounds
        if not np.array_equal(bounds, bounds0):
            migrated = True
            break
    assert migrated, "no chunk migrated across the growth trace"
    cs = cache_stats()
    assert cs["partition"]["patched"] == steps, cs["partition"]
    assert cs["partition"]["misses"] == 1, cs["partition"]  # base only

    y0 = np.asarray(spmm(st, b, impl="ref"))
    sst = st.shard(mesh, "data")
    y1 = np.asarray(spmm(sst, b, impl="ref"))
    np.testing.assert_allclose(y1, y0, atol=2e-4, rtol=1e-4)
    print("OK")
    """, devices=4)


def test_elastic_checkpoint_restore_onto_mesh(tmp_path):
    _run(f"""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt.checkpoint import restore, save
    from repro.launch.mesh import make_test_mesh

    tree = {{"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}}
    save({str(tmp_path)!r}, 1, tree)  # saved from a "1-device job"
    # restore onto an 8-device mesh with 4-way sharding (elastic restart)
    mesh = make_test_mesh(data=4, model=2)
    sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
    out = restore({str(tmp_path)!r}, 1, tree, sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))
    print("OK")
    """)


def test_chunked_combine_matches_blocking():
    """The chunked overlapped combine is a row-partition of the same math:
    for every format x impl x codec x route, combine_chunks>1 must equal
    the blocking combine_chunks=1 result to float tolerance, and the
    dispatch/schedule counters must record the chunked path."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.sparse import SparseTensor
    import repro.ops as ops
    from repro.ops import spmm

    rng = np.random.default_rng(0)
    d = rng.normal(size=(256, 128)).astype(np.float32)
    d *= rng.random(d.shape) < 0.12
    mesh = jax.make_mesh((4,), ("data",))
    ops.clear_tuning_cache()
    for fmt, block in [("bcsr", (32, 32)), ("wcsr", (32, 8))]:
        st = SparseTensor.from_dense(d, fmt, block=block)
        stq = st.quantize("int8")
        for n in (64, 2):  # full-tile and skinny (spmv-routed) RHS
            b = jnp.asarray(rng.normal(size=(128, n)).astype(np.float32))
            for operand in (st, stq):
                sst = operand.shard(mesh, "data")
                for impl in ("ref", "kernel_interpret"):
                    y1 = np.asarray(spmm(sst, b, impl=impl,
                                         combine_chunks=1))
                    y3 = np.asarray(spmm(sst, b, impl=impl,
                                         combine_chunks=3))
                    np.testing.assert_allclose(
                        y3, y1, atol=1e-5, rtol=1e-5,
                        err_msg=f"{fmt} {impl} n={n} "
                                f"codec={operand.codec}")
    cs = ops.cache_stats()["combine"]
    assert cs["chunked"] > 0 and cs["blocking"] > 0, cs
    assert cs["chunks"].get(3, 0) > 0, cs
    assert cs["schedules_built"] > 0, cs
    assert cs["shard_chunks_built"] > 0, cs

    # structure delta: the patched partition keeps untouched shards by
    # object, so the fresh schedule's per-shard chunk arrays memo-hit as
    # long as the chunk bounds survive the re-balance. Skewed block
    # counts park the chunk cuts far from any snap midpoint, so the
    # one-block delta in the last row cannot move them.
    counts = [10, 10, 10, 1, 1, 1, 1, 1]
    d2 = np.zeros((256, 320), np.float32)
    for i, cnt in enumerate(counts):
        d2[32 * i:32 * (i + 1), :32 * cnt] = rng.normal(
            size=(32, 32 * cnt)).astype(np.float32)
    b2 = jnp.asarray(rng.normal(size=(320, 64)).astype(np.float32))
    base = SparseTensor.from_dense(d2, "bcsr", block=(32, 32))
    y0 = np.asarray(spmm(base.shard(mesh, "data"), b2,
                         impl="kernel_interpret", combine_chunks=3))
    before = ops.cache_stats()["combine"]
    grown = base.append_blocks([7], [5], rng.normal(
        size=(1, 32, 32)).astype(np.float32))
    y1 = np.asarray(spmm(grown.shard(mesh, "data"), b2,
                         impl="kernel_interpret", combine_chunks=3))
    after = ops.cache_stats()["combine"]
    assert after["shard_chunks_reused"] > before["shard_chunks_reused"], (
        before, after)
    np.testing.assert_allclose(
        y1, np.asarray(grown.todense()) @ np.asarray(b2),
        atol=2e-3, rtol=1e-3)
    print("OK")
    """, devices=4)


def test_two_axis_mesh_and_hier_reduce():
    """2-D (data, model) sharded operands: equivalence with the
    single-device result under psum, bf16 and the hierarchical combine;
    reduce='hier' on a 1-axis operand must raise."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    import pytest
    from repro.sparse import SparseTensor
    import repro.ops as ops
    from repro.ops import spmm
    from repro.parallel.sparse import use_sparse_mesh

    rng = np.random.default_rng(0)
    d = rng.normal(size=(256, 128)).astype(np.float32)
    d *= rng.random(d.shape) < 0.12
    b = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ops.clear_tuning_cache()
    for fmt, block in [("bcsr", (32, 32)), ("wcsr", (32, 8))]:
        st = SparseTensor.from_dense(d, fmt, block=block)
        y0 = np.asarray(spmm(st, b))
        sst = st.shard(mesh, ("data", "model"))
        assert sst.num_shards == 4 and sst.axis == ("data", "model")
        for impl in ("ref", "kernel_interpret"):
            yp = np.asarray(spmm(sst, b, impl=impl))
            np.testing.assert_allclose(yp, y0, atol=2e-4, rtol=1e-4)
            yh = np.asarray(spmm(sst, b, impl=impl, reduce="hier"))
            np.testing.assert_allclose(yh, yp, atol=1e-5, rtol=1e-5)
            yc = np.asarray(spmm(sst, b, impl=impl, reduce="hier",
                                 combine_chunks=2))
            np.testing.assert_allclose(yc, yp, atol=1e-5, rtol=1e-5)
        yb = np.asarray(spmm(sst, b, impl="ref", reduce="bf16"))
        np.testing.assert_allclose(yb, y0, atol=2e-2, rtol=1e-2)
    # auto-shard over both axes via the mesh scope
    with use_sparse_mesh(mesh, ("data", "model")):
        y2 = np.asarray(st @ b)
    np.testing.assert_allclose(y2, y0, atol=2e-4, rtol=1e-4)
    # hier needs a 2-axis operand
    mesh1 = jax.make_mesh((4,), ("data",))
    sst1 = st.shard(mesh1, "data")
    with pytest.raises(ValueError, match="hier"):
        spmm(sst1, b, impl="ref", reduce="hier")
    assert ops.cache_stats()["combine"]["hier_calls"] > 0
    print("OK")
    """, devices=4)


def test_autotune_sweeps_combine_chunks_on_mesh():
    """autotune_spmm(mesh=...) times the sharded path and records a
    combine_chunks winner that the "auto" knob then adopts (and that a
    TuneDB round-trips like every other field)."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.sparse import SparseTensor
    import repro.ops as ops

    rng = np.random.default_rng(0)
    d = rng.normal(size=(256, 128)).astype(np.float32)
    d *= rng.random(d.shape) < 0.12
    b = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    mesh = jax.make_mesh((4,), ("data",))
    st = SparseTensor.from_dense(d, "bcsr", block=(32, 32))
    ops.clear_tuning_cache()
    win = ops.autotune_spmm(st, b, bns=(64,), codecs=("none",),
                            mesh=mesh, combine_chunks=(1, 3),
                            warmup=0, iters=1, use_db=False)
    assert win["combine_chunks"] in (1, 3), win
    tuned = ops.tuned_entry("spmm", "bcsr", st.shape, 64, st.block,
                            st.dtype)
    assert tuned["combine_chunks"] == win["combine_chunks"], tuned
    # "auto" adopts the measured winner
    got = ops.resolve_combine_chunks(
        "auto", 64, num_groups=8, num_shards=4, op="spmm", fmt="bcsr",
        shape=st.shape, block=st.block, dtype=st.dtype, count=False)
    assert got == win["combine_chunks"], (got, win)
    # without a mesh the sweep records no combine (unsharded calls)
    ops.clear_tuning_cache()
    win1 = ops.autotune_spmm(st, b, bns=(64,), codecs=("none",),
                             warmup=0, iters=1, use_db=False)
    assert win1["combine_chunks"] is None, win1
    print("OK")
    """, devices=4)
