"""Distributed semantics on an 8-device host mesh (subprocess: device count
must be fixed before jax initializes). Covers: sharded train step numerics
vs single device, MoE shard_map path, compressed/hierarchical collectives,
GPipe equivalence, elastic checkpoint restore onto a mesh."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    src = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_sharded_train_step_matches_single_device():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced_config
    from repro.models.registry import build_model
    from repro.models.common import mesh_context
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import make_mesh_rules, param_shardings, batch_shardings
    from repro.train.step import init_train_state, make_train_step

    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=2, tp_shards=2)
    m = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
    }
    params = m.init(jax.random.PRNGKey(0))
    # single device
    s0 = init_train_state(params)
    st0, m0 = jax.jit(make_train_step(m))(s0, batch)
    # 4 data x 2 model mesh
    mesh = make_test_mesh(data=4, model=2)
    rules = make_mesh_rules(mesh)
    with mesh_context(mesh, rules):
        s1 = init_train_state(params)
        st1, m1 = jax.jit(make_train_step(m))(s1, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-3, (m0, m1)
    for a, b in zip(jax.tree.leaves(st0.params), jax.tree.leaves(st1.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)
    print("OK")
    """)


def test_moe_shard_map_matches_local():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced_config
    from repro.models.registry import build_model
    from repro.models.common import mesh_context
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import make_mesh_rules

    # EP: 4 experts over 2 model shards
    cfg = reduced_config(ARCHS["kimi-k2-1t-a32b"], tp_shards=2,
                         capacity_factor=8.0)
    assert cfg.expert_partition == "expert"
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    l0, _ = m.forward(params, batch)
    mesh = make_test_mesh(data=4, model=2)
    with mesh_context(mesh, make_mesh_rules(mesh)):
        l1, _ = jax.jit(m.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-3)
    print("OK")
    """)


def test_compressed_and_hierarchical_psum():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.collectives import (
        compressed_psum_bf16, compressed_psum_int8_ef, hierarchical_psum)

    mesh = make_test_mesh(data=2, model=2, pod=2)
    x = jnp.arange(16, dtype=jnp.float32).reshape(8, 2) / 7.0

    def f(x):
        exact = jax.lax.psum(x, ("pod", "data"))
        hier = hierarchical_psum(x, "data", "pod")
        comp = compressed_psum_bf16(x, ("pod", "data"))
        q, err = compressed_psum_int8_ef(x, ("pod", "data"))
        return exact, hier, comp, q, err

    out = shard_map(f, mesh=mesh,
                        in_specs=P(("pod", "data")),
                        out_specs=(P(("pod", "data")),) * 5,
                        check_vma=False)(x)
    exact, hier, comp, q, err = map(np.asarray, out)
    np.testing.assert_allclose(hier, exact, rtol=1e-6)
    np.testing.assert_allclose(comp, exact, rtol=2e-2, atol=1e-2)
    np.testing.assert_allclose(q, exact, rtol=0.1, atol=0.05)
    # error feedback residual bounded by one quantization step
    assert np.abs(err).max() <= np.abs(x).max() / 127 + 1e-6
    print("OK")
    """)


def test_gpipe_matches_sequential():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.pipeline import gpipe, split_stages

    mesh = make_test_mesh(data=2, model=1, pod=4)  # 4 pipeline stages
    rng = np.random.default_rng(0)
    L, D = 8, 16
    ws = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) / np.sqrt(D))

    def stage_fn(w_stack, x):  # w_stack: [L/S, D, D]
        for i in range(w_stack.shape[0]):
            x = jnp.tanh(x @ w_stack[i])
        return x

    xs = jnp.asarray(rng.normal(size=(6, 8, D)).astype(np.float32))  # 6 microbatches
    piped = gpipe(stage_fn, mesh, axis="pod", data_axes=("data",))
    got = piped(split_stages(ws, 4), xs)
    want = xs
    for i in range(L):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # and it differentiates (autodiff through ppermute)
    loss = lambda w: jnp.sum(piped(split_stages(w, 4), xs) ** 2)
    g = jax.grad(loss)(ws)
    assert np.isfinite(np.asarray(g)).all()
    print("OK")
    """)


def test_elastic_checkpoint_restore_onto_mesh(tmp_path):
    _run(f"""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt.checkpoint import restore, save
    from repro.launch.mesh import make_test_mesh

    tree = {{"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}}
    save({str(tmp_path)!r}, 1, tree)  # saved from a "1-device job"
    # restore onto an 8-device mesh with 4-way sharding (elastic restart)
    mesh = make_test_mesh(data=4, model=2)
    sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
    out = restore({str(tmp_path)!r}, 1, tree, sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))
    print("OK")
    """)
