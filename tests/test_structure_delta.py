"""Delta-vs-rebuild differential harness for ``repro.sparse.delta``.

The contract under test: applying a sequence of structural edits
(``append_blocks`` / ``retire_blocks`` for BCSR, ``append_window_chunks`` /
``retire_window_chunks`` for WCSR) through the delta layer must be
*indistinguishable* from rebuilding the grown/shrunk matrix from dense —
structures content-equal, content digests equal, plans and partitions
structurally equal, and spmm numerically identical (exact for raw values;
within the documented codec tolerance when touched groups requantize).
Untouched codec scale groups must survive an edit *bitwise* — requantizing
everything would silently pass the tolerance checks, so that invariant gets
its own bitwise assertion against the pre-delta tensor.

Property-based via hypothesis (or the deterministic conftest stub when the
real package isn't installed): random base structures x random edit
sequences x {none, int8, fp8_e4m3} x pipeline depths 1-3.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st_

import repro.ops as ops
from repro.ops import (cache_stats, clear_plan_cache, make_partition,
                       make_plan)
from repro.parallel.sparse import partition_structure
from repro.sparse import (SparseTensor, append_blocks, append_window_chunks,
                          delta_of, delta_stats, registered_value_codecs,
                          retire_blocks, retire_window_chunks)

# generous: touched groups requantize with mixed old+fresh values, so the
# patched payload legitimately differs from the rebuilt one inside a group
DIFF_TOL = {"none": 1e-6, "int8": 0.05, "fp8_e4m3": 0.12}
CODECS = tuple(c for c in ("none", "int8", "fp8_e4m3")
               if c == "none" or c in registered_value_codecs())

M = K = 64
WBLOCK = (16, 8)
BBLOCK = (16, 16)


def _rel(got, ref):
    return float(np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-12))


def _dense(rng, density=0.04):
    # element density 0.04 -> a 16-row window stores ~half its columns
    # (1 - 0.96**16), leaving real room for both appends and retires
    d = rng.normal(size=(M, K)).astype(np.float32)
    d *= rng.random(d.shape) < density
    return d


# ---------------------------------------------------------------------------
# WCSR edit sequences
# ---------------------------------------------------------------------------


def _wcsr_stored(g, w):
    p0, p1 = int(g.ptrs[w]), int(g.ptrs[w + 1])
    return sorted(int(c) for c in g.indices[0][p0:p1] if int(c) >= 0)


def _apply_wcsr_ops(rng, st, d, nops):
    """Random append/retire chunk edits; returns (tensor, dense oracle)."""
    b_row, _ = st.structure.block
    windows = M // b_row
    d = d.copy()
    for _ in range(nops):
        w = int(rng.integers(0, windows))
        stored = _wcsr_stored(st.structure, w)
        free = [c for c in range(K) if c not in stored]
        # retire only when it leaves the window non-degenerate
        if stored and (not free or rng.random() < 0.4):
            cols = [stored[int(rng.integers(0, len(stored)))]]
            st = st.retire_window_chunks(w, cols)
            d[w * b_row:(w + 1) * b_row, cols] = 0.0
        else:
            n = int(rng.integers(1, min(3, len(free)) + 1))
            cols = sorted(rng.choice(free, size=n, replace=False).tolist())
            vals = rng.normal(size=(b_row, n)).astype(np.float32)
            vals[np.abs(vals) < 1e-3] = 1e-3  # keep columns dense-visible
            st = st.append_window_chunks(w, cols, vals)
            d[w * b_row:(w + 1) * b_row, cols] = vals
    return st, d


@settings(max_examples=6)
@given(seed=st_.integers(0, 10_000), codec=st_.sampled_from(CODECS))
def test_wcsr_edit_sequence_matches_rebuild(seed, codec):
    rng = np.random.default_rng(seed)
    d = _dense(rng)
    st = SparseTensor.from_dense(d, "wcsr", block=WBLOCK)
    if codec != "none":
        st = st.quantize(codec)
    st, d = _apply_wcsr_ops(rng, st, d, nops=4)

    rb = SparseTensor.from_dense(d, "wcsr", block=WBLOCK)
    if codec != "none":
        rb = rb.quantize(codec)
    assert st.structure == rb.structure
    assert st.structure.content_digest() == rb.structure.content_digest()

    b = jnp.asarray(rng.normal(size=(K, 32)).astype(np.float32))
    got = np.asarray(ops.spmm(st, b, impl="ref"))
    want = np.asarray(ops.spmm(rb, b, impl="ref"))
    assert _rel(got, want) <= DIFF_TOL[codec], (codec, _rel(got, want))


# ---------------------------------------------------------------------------
# BCSR edit sequences
# ---------------------------------------------------------------------------


def _bcsr_oracle(d, true_mask, cover_mask):
    """Rebuild from dense exactly as the retire coverage rule demands.

    Coverage blocks are *sticky*: once ``retire_blocks`` (or the base
    build) inserts a zero block at ``(r, 0)`` to keep the emptied row
    visible to the kernel, it stays stored — a later append into that row
    does not remove it (structurally it's indistinguishable from a real
    block), only an explicit retire does. The oracle mask is therefore
    ``true_mask | cover_mask``, with ``cover_mask`` evolved alongside.
    """
    mask_stored = true_mask | cover_mask
    bm, bk = BBLOCK
    dm = d * np.repeat(np.repeat(true_mask, bm, 0), bk, 1)
    return dm, SparseTensor.from_dense(dm, "bcsr", block=BBLOCK,
                                       mask=mask_stored)


def _init_cover(true_mask):
    cover = np.zeros_like(true_mask)
    cover[~true_mask.any(axis=1), 0] = True
    return cover


def _apply_bcsr_ops(rng, st, d, true_mask, cover_mask, nops):
    bm, bk = BBLOCK
    m_b, k_b = M // bm, K // bk
    d = d.copy()
    true_mask = true_mask.copy()
    cover_mask = cover_mask.copy()
    for _ in range(nops):
        g = st.structure
        stored = set(zip(g.indices[0][:g.nnz].tolist(),
                         g.indices[1][:g.nnz].tolist()))
        real = [(r, c) for (r, c) in stored
                if true_mask[r, c] and not cover_mask[r, c]]
        free = [(r, c) for r in range(m_b) for c in range(k_b)
                if (r, c) not in stored]
        if real and (not free or rng.random() < 0.4):
            r, c = real[int(rng.integers(0, len(real)))]
            st = st.retire_blocks([r], [c])
            true_mask[r, c] = False
            d[r * bm:(r + 1) * bm, c * bk:(c + 1) * bk] = 0.0
            if not true_mask[r].any() and not cover_mask[r].any():
                cover_mask[r, 0] = True  # the retire inserted coverage
        else:
            r, c = free[int(rng.integers(0, len(free)))]
            vals = rng.normal(size=(1, bm, bk)).astype(np.float32)
            st = st.append_blocks([r], [c], vals)
            true_mask[r, c] = True
            d[r * bm:(r + 1) * bm, c * bk:(c + 1) * bk] = vals[0]
    return st, d, true_mask, cover_mask


@settings(max_examples=6)
@given(seed=st_.integers(0, 10_000), codec=st_.sampled_from(CODECS))
def test_bcsr_edit_sequence_matches_rebuild(seed, codec):
    rng = np.random.default_rng(seed)
    bm, bk = BBLOCK
    true_mask = rng.random((M // bm, K // bk)) < 0.4
    d = rng.normal(size=(M, K)).astype(np.float32)
    cover = _init_cover(true_mask)
    dm, st = _bcsr_oracle(d, true_mask, cover)
    if codec != "none":
        st = st.quantize(codec)
    st, dm, true_mask, cover = _apply_bcsr_ops(rng, st, dm, true_mask,
                                               cover, nops=4)

    _, rb = _bcsr_oracle(dm, true_mask, cover)
    if codec != "none":
        rb = rb.quantize(codec)
    assert st.structure == rb.structure
    assert st.structure.content_digest() == rb.structure.content_digest()

    b = jnp.asarray(rng.normal(size=(K, 32)).astype(np.float32))
    got = np.asarray(ops.spmm(st, b, impl="ref"))
    want = np.asarray(ops.spmm(rb, b, impl="ref"))
    assert _rel(got, want) <= DIFF_TOL[codec], (codec, _rel(got, want))


# ---------------------------------------------------------------------------
# Kernel path: patched structures through the real (interpret) kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_wcsr_patched_structure_through_kernel_depths(rng, depth):
    d = _dense(rng)
    st = SparseTensor.from_dense(d, "wcsr", block=WBLOCK)
    st, d = _apply_wcsr_ops(rng, st, d, nops=3)
    b = jnp.asarray(rng.normal(size=(K, 16)).astype(np.float32))
    ref = np.asarray(ops.spmm(st, b, impl="ref"))
    got = np.asarray(ops.spmm(st, b, impl="kernel_interpret", bn=16,
                              pipeline_depth=depth))
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-5)


# ---------------------------------------------------------------------------
# Untouched codec scale groups survive the edit bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec",
                         [c for c in CODECS if c != "none"] or
                         [pytest.param("int8", marks=pytest.mark.skip)])
def test_untouched_scale_groups_bitwise_wcsr(rng, codec):
    d = _dense(rng)
    q = SparseTensor.from_dense(d, "wcsr", block=WBLOCK).quantize(codec)
    cols = [c for c in range(K) if c not in _wcsr_stored(q.structure, 1)][:2]
    assert len(cols) == 2, "base draw left no room to append"
    vals = rng.normal(size=(WBLOCK[0], 2)).astype(np.float32)
    q2 = q.append_window_chunks(1, cols, vals)
    dlt = delta_of(q2.structure)
    assert dlt is not None and dlt.kind == "append"
    s_old = np.asarray(q.data[1])
    s_new = np.asarray(q2.data[1])
    np.testing.assert_array_equal(s_new[:, dlt.kept_dst],
                                  s_old[:, dlt.kept_src])
    p_old = np.asarray(q.data[0])
    p_new = np.asarray(q2.data[0])
    b_col = q.structure.block[1]
    for src, dst in zip(dlt.kept_src, dlt.kept_dst):
        np.testing.assert_array_equal(
            p_new[:, dst * b_col:(dst + 1) * b_col],
            p_old[:, src * b_col:(src + 1) * b_col])


@pytest.mark.parametrize("codec",
                         [c for c in CODECS if c != "none"] or
                         [pytest.param("int8", marks=pytest.mark.skip)])
def test_untouched_scale_groups_bitwise_bcsr(rng, codec):
    bm, bk = BBLOCK
    true_mask = rng.random((M // bm, K // bk)) < 0.4
    d = rng.normal(size=(M, K)).astype(np.float32)
    _, st = _bcsr_oracle(d, true_mask, _init_cover(true_mask))
    q = st.quantize(codec)
    g = q.structure
    stored = set(zip(g.indices[0][:g.nnz].tolist(),
                     g.indices[1][:g.nnz].tolist()))
    r, c = next((i, j) for i in range(M // bm) for j in range(K // bk)
                if (i, j) not in stored)
    q2 = q.append_blocks([r], [c], rng.normal(size=(1, bm, bk)
                                              ).astype(np.float32))
    dlt = delta_of(q2.structure)
    s_old, s_new = np.asarray(q.data[1]), np.asarray(q2.data[1])
    np.testing.assert_array_equal(s_new[list(dlt.kept_dst)],
                                  s_old[list(dlt.kept_src)])
    p_old, p_new = np.asarray(q.data[0]), np.asarray(q2.data[0])
    np.testing.assert_array_equal(p_new[list(dlt.kept_dst)],
                                  p_old[list(dlt.kept_src)])
    ds = delta_stats()
    assert ds["groups_requantized"] >= 1  # the fresh block
    assert ds["groups_reused"] >= len(dlt.kept_src)


# ---------------------------------------------------------------------------
# Plans / partitions: patched entries structurally equal to a fresh build
# ---------------------------------------------------------------------------


def test_patched_plan_and_partition_structurally_equal(rng):
    clear_plan_cache()
    d = _dense(rng)
    st = SparseTensor.from_dense(d, "wcsr", block=WBLOCK)
    make_plan(st, 32)
    make_partition(st, 4)
    st2, _ = _apply_wcsr_ops(rng, st, d, nops=1)
    plan = make_plan(st2, 32)
    for got, want in zip(plan.tasks,
                         st2.structure.tasks(plan.chunks_per_task)):
        np.testing.assert_array_equal(got, want)
    part = make_partition(st2, 4)
    fresh = partition_structure(st2.structure, 4)
    np.testing.assert_array_equal(part.bounds, fresh.bounds)
    assert all(a == b for a, b in zip(part.shards, fresh.shards))
    cs = cache_stats()
    assert cs["plan"]["patched"] == 1 and cs["partition"]["patched"] == 1
    clear_plan_cache()


# ---------------------------------------------------------------------------
# Digest: memoized on the instance, incremental across deltas
# ---------------------------------------------------------------------------


def test_content_digest_memoized(rng):
    d = _dense(rng)
    g = SparseTensor.from_dense(d, "wcsr", block=WBLOCK).structure
    assert g._digest is None  # lazily computed...
    first = g.content_digest()
    assert g._digest == first  # ...then memoized on the instance
    assert g.content_digest() == first  # stable across lookups


def test_digest_incremental_equals_rebuilt(rng):
    d = _dense(rng)
    st = SparseTensor.from_dense(d, "wcsr", block=WBLOCK)
    st2, d2 = _apply_wcsr_ops(rng, st, d, nops=3)
    g2 = st2.structure
    # the delta chain pre-splices row digests: only touched rows recompute
    assert g2._rowdig is not None
    rb = SparseTensor.from_dense(d2, "wcsr", block=WBLOCK).structure
    assert g2.content_digest() == rb.content_digest()
    # and a different structure still gets a different digest
    assert g2.content_digest() != st.structure.content_digest()


# ---------------------------------------------------------------------------
# Validation errors
# ---------------------------------------------------------------------------


def test_append_duplicate_raises(rng):
    d = _dense(rng)
    g = SparseTensor.from_dense(d, "wcsr", block=WBLOCK).structure
    stored = _wcsr_stored(g, 0)
    assert stored, "base draw stored nothing in window 0"
    with pytest.raises(ValueError, match="already stored"):
        append_window_chunks(g, 0, [stored[0]])

    bm, bk = BBLOCK
    mask = np.ones((M // bm, K // bk), bool)
    db = rng.normal(size=(M, K)).astype(np.float32)
    gb = SparseTensor.from_dense(db, "bcsr", block=BBLOCK,
                                 mask=mask).structure
    with pytest.raises(ValueError, match="already stored"):
        append_blocks(gb, [0], [0])


def test_retire_missing_raises(rng):
    d = _dense(rng)
    g = SparseTensor.from_dense(d, "wcsr", block=WBLOCK).structure
    free = [c for c in range(K) if c not in _wcsr_stored(g, 0)]
    with pytest.raises(ValueError):
        retire_window_chunks(g, 0, [free[0]])

    bm, bk = BBLOCK
    mask = np.zeros((M // bm, K // bk), bool)
    mask[0, 1] = True
    db = np.zeros((M, K), np.float32)
    db[:bm, bk:2 * bk] = 1.0
    gb = SparseTensor.from_dense(db, "bcsr", block=BBLOCK,
                                 mask=mask).structure
    with pytest.raises(ValueError):
        retire_blocks(gb, [0], [0])


def test_structure_and_tensor_level_edits_agree(rng):
    d = _dense(rng)
    st = SparseTensor.from_dense(d, "wcsr", block=WBLOCK)
    cols = [c for c in range(K) if c not in _wcsr_stored(st.structure, 2)][:2]
    g2, dlt = append_window_chunks(st.structure, 2, cols)
    vals = rng.normal(size=(WBLOCK[0], 2)).astype(np.float32)
    st2 = st.append_window_chunks(2, cols, vals)
    assert st2.structure == g2
    assert delta_of(st2.structure) is not None
    assert dlt.unit_shift == 0 or dlt.unit_shift > 0
