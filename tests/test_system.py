"""End-to-end behaviour tests: per-arch smoke (reduced configs), prefill vs
decode consistency, sparse-FFN training, paper-claim trend checks."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models.registry import build_model

KEY = jax.random.PRNGKey(7)


def _batch(cfg, rng, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.cross_attn_every:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_vision_tokens, cfg.d_model)), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch, rng):
    """REQUIRED per-arch smoke: reduced config, one forward + one train-grad
    step on CPU, asserting output shapes + no NaNs."""
    cfg = reduced_config(ARCHS[arch])
    m = build_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg, rng)
    logits, aux = m.forward(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    loss, grads = jax.value_and_grad(m.loss, allow_int=True)(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(
        float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
        for g in jax.tree.leaves(grads)
        if hasattr(g, "dtype") and jnp.issubdtype(g.dtype, jnp.inexact)
    )
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [
    "minitron-4b", "h2o-danube-1.8b", "mixtral-8x22b", "rwkv6-1.6b",
    "hymba-1.5b", "granite-3-2b",
])
def test_prefill_decode_consistency(arch, rng):
    over = {"capacity_factor": 8.0} if ARCHS[arch].is_moe else {}
    cfg = reduced_config(ARCHS[arch], **over)
    m = build_model(cfg)
    params = m.init(KEY)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_all, _ = m.forward(params, {"tokens": toks})
    cache = m.init_decode_cache(B, S)
    errs = []
    for t in range(S):
        dl, cache = m.decode_step(params, cache, toks[:, t],
                                  jnp.full((B,), t, jnp.int32))
        errs.append(np.abs(np.asarray(dl) - np.asarray(logits_all[:, t])).max())
    assert max(errs) < 1e-3, errs


def test_sliding_window_restricts_attention(rng):
    """Tokens beyond the window must not influence the output."""
    cfg = reduced_config(ARCHS["h2o-danube-1.8b"], sliding_window=8,
                         num_layers=1)
    m = build_model(cfg)
    params = m.init(KEY)
    B, S = 1, 32
    t1 = rng.integers(0, cfg.vocab_size, (B, S))
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 7) % cfg.vocab_size  # outside window of last token
    l1, _ = m.forward(params, {"tokens": jnp.asarray(t1, jnp.int32)})
    l2, _ = m.forward(params, {"tokens": jnp.asarray(t2, jnp.int32)})
    last1 = np.asarray(l1[0, -1])
    last2 = np.asarray(l2[0, -1])
    np.testing.assert_allclose(last1, last2, atol=1e-5)


def test_sparse_ffn_matches_dense_at_zero_sparsity(rng):
    """Sparse layout with all blocks kept must equal the dense matmul."""
    from repro.models.ffn import local_bcsr_matmul_t, make_balanced_sparse

    p = make_balanced_sparse(KEY, 64, 96, 1, 0.0, (32, 32), jnp.float32, "out")
    x = jnp.asarray(rng.normal(size=(10, 96)).astype(np.float32))
    y = local_bcsr_matmul_t(p["values"][0, 0], p["rows"][0], p["cols"][0],
                            x, 2)
    w = np.zeros((64, 96), np.float32)
    vals = np.asarray(p["values"][0, 0])
    for i, (r, c) in enumerate(zip(np.asarray(p["rows"][0]),
                                   np.asarray(p["cols"][0]))):
        w[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32] += vals[i]
    np.testing.assert_allclose(np.asarray(y), w @ np.asarray(x).T, atol=1e-4)


def test_sparse_ffn_training_reduces_loss(rng):
    """Paper-technique integration: a block-sparse-FFN model trains."""
    from repro.data.synthetic import SyntheticLM
    from repro.train.step import init_train_state, make_train_step

    cfg = reduced_config(ARCHS["qwen2.5-7b"], ffn_sparsity=0.5,
                         sparse_block=(32, 32), num_layers=2)
    m = build_model(cfg)
    data = SyntheticLM(cfg.vocab_size, seed=0)
    step = jax.jit(make_train_step(m, peak_lr=5e-3, warmup=5, total_steps=60))
    state = init_train_state(m.init(KEY))
    losses = []
    for i in range(30):
        nb = data.batch(i, 8, 32)
        batch = {k: jnp.asarray(v) for k, v in nb.items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_paper_trend_sparsity_reduces_work(rng):
    """Table III trend: stored-block count (kernel work) drops with sparsity."""
    from repro.sparse import (apply_block_mask, bcsr_from_dense,
                              random_block_mask)

    m, k = 512, 256
    work = []
    for sp in (0.5, 0.9):
        d = apply_block_mask(
            rng.normal(size=(m, k)).astype(np.float32),
            random_block_mask((m, k), (64, 64), sp, seed=3), (64, 64))
        a = bcsr_from_dense(d, (64, 64))
        work.append(a.nnz_blocks)
    assert work[1] < work[0]
