"""Unified ``repro.ops`` API: format dispatch, config layering, env-var
precedence, auto-tiling + tuning cache, and deprecation-shim forwarding."""

import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ops as ops
from repro.kernels.bcsr.ref import bcsr_spmm_ref
from repro.kernels.sddmm.ref import sddmm_ref
from repro.kernels.wcsr.ref import wcsr_spmm_ref
from repro.ops import (OpConfig, auto_bn, clear_tuning_cache, current_config,
                       sddmm, spmm, tuning_cache_info, use_config)
from repro.sparse import BCSR, bcsr_from_dense, wcsr_from_dense


def _mats(rng, m=128, k=128, n=96, density=0.3):
    d = rng.normal(size=(m, k)).astype(np.float32)
    d *= rng.random(d.shape) < density
    a = bcsr_from_dense(d, (32, 32))
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    return d, a, w, b


# ---------------------------------------------------------------------------
# Dispatch by format
# ---------------------------------------------------------------------------


def test_spmm_dispatches_on_bcsr(rng):
    d, a, _, b = _mats(rng)
    got = np.asarray(spmm(a, b))
    np.testing.assert_allclose(got, np.asarray(bcsr_spmm_ref(a, b)),
                               atol=1e-4)
    np.testing.assert_allclose(got, d @ np.asarray(b), atol=1e-3)


def test_spmm_dispatches_on_wcsr(rng):
    d, _, w, b = _mats(rng)
    got = np.asarray(spmm(w, b))
    np.testing.assert_allclose(got, np.asarray(wcsr_spmm_ref(w, b)),
                               atol=1e-4)
    np.testing.assert_allclose(got, d @ np.asarray(b), atol=1e-3)


def test_spmm_rejects_unknown_format(rng):
    with pytest.raises(TypeError, match="unsupported sparse format"):
        spmm(np.zeros((4, 4)), jnp.zeros((4, 4)))


def test_spmm_kernel_interpret_matches_ref_both_formats(rng):
    _, a, w, b = _mats(rng)
    for fmt in (a, w):
        got = np.asarray(spmm(fmt, b, impl="kernel_interpret"))
        ref = np.asarray(spmm(fmt, b, impl="ref"))
        np.testing.assert_allclose(got, ref, atol=2e-4)


def test_spmm_unknown_impl_lists_backends(rng):
    _, a, _, b = _mats(rng)
    with pytest.raises(ValueError, match="registered backends"):
        spmm(a, b, impl="nonsense")


def test_wcsr_kernel_under_jit_raises_clear_error(rng):
    _, _, w, b = _mats(rng)
    with pytest.raises(ValueError, match="impl='ref'"):
        jax.jit(lambda w_, b_: spmm(w_, b_, impl="kernel_interpret"))(w, b)
    # the traceable ref path works under jit
    out = jax.jit(lambda w_, b_: spmm(w_, b_, impl="ref"))(w, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(wcsr_spmm_ref(w, b)), atol=1e-4)


# ---------------------------------------------------------------------------
# Config contexts + env var
# ---------------------------------------------------------------------------


def test_use_config_nesting(monkeypatch):
    monkeypatch.delenv(ops.ENV_IMPL_VAR, raising=False)
    assert current_config().impl is None
    with use_config(impl="ref", bn=128):
        assert current_config().impl == "ref"
        assert current_config().bn == 128
        with use_config(impl="kernel_interpret"):
            # inner impl shadows, outer bn inherited
            assert current_config().impl == "kernel_interpret"
            assert current_config().bn == 128
        assert current_config().impl == "ref"
    assert current_config().impl is None
    assert current_config().bn == "auto"


def test_env_var_flips_backend_and_contexts_win(rng, monkeypatch):
    _, a, _, b = _mats(rng)
    calls = []

    @ops.register_backend("spmm/bcsr", "probe")
    def _probe(a_, b_, cfg):
        calls.append(cfg)
        return bcsr_spmm_ref(a_, b_, out_dtype=cfg.out_dtype)

    try:
        monkeypatch.setenv(ops.ENV_IMPL_VAR, "probe")
        assert current_config().impl == "probe"
        spmm(a, b)  # zero call-site changes, env picks the backend
        assert len(calls) == 1
        # explicit context takes precedence over the env var
        with use_config(impl="ref"):
            assert current_config().impl == "ref"
            spmm(a, b)
        assert len(calls) == 1
        # call-site kwarg takes precedence over everything
        spmm(a, b, impl="ref")
        assert len(calls) == 1
    finally:
        from repro.ops import registry as reg
        reg._BACKENDS["spmm/bcsr"].pop("probe", None)


def test_use_config_flips_backend_without_call_site_changes(rng):
    _, a, _, b = _mats(rng)

    def call_site():  # knows nothing about impls
        return spmm(a, b)

    ref = np.asarray(call_site())
    with use_config(impl="kernel_interpret"):
        kern = np.asarray(call_site())
    np.testing.assert_allclose(kern, ref, atol=2e-4)


def test_config_rejects_unknown_field():
    with pytest.raises(TypeError):
        ops.resolved_config(bogus=1)


# ---------------------------------------------------------------------------
# Auto-tiling + tuning cache
# ---------------------------------------------------------------------------


def test_auto_bn_matches_select_bn():
    from repro.kernels.tuning import select_bn

    clear_tuning_cache()
    for n in (128, 256, 384, 1000):
        assert auto_bn(n, 64, 64) == select_bn(n, 64, 64)


def test_auto_bn_cache_keys_on_block_size():
    clear_tuning_cache()
    auto_bn(256, 32, 32, op="t", shape=(128, 128))
    auto_bn(256, 128, 128, op="t", shape=(128, 128))  # same shape, new block
    assert tuning_cache_info().misses == 2


def test_legacy_auto_default_respects_config(rng):
    """Shim default impl='auto' must not shadow use_config / env."""
    _, a, _, b = _mats(rng)
    calls = []

    @ops.register_backend("spmm/bcsr", "probe2")
    def _probe(a_, b_, cfg):
        calls.append(1)
        return bcsr_spmm_ref(a_, b_)

    try:
        with use_config(impl="probe2"):
            import warnings
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                from repro.kernels.bcsr.ops import bcsr_spmm
                bcsr_spmm(a, b)  # legacy entry, impl defaults to "auto"
        assert calls == [1]
    finally:
        from repro.ops import registry as reg
        reg._BACKENDS["spmm/bcsr"].pop("probe2", None)


def test_tuning_cache_hit_miss(rng):
    _, a, _, b = _mats(rng)
    clear_tuning_cache()
    with use_config(impl="kernel_interpret"):
        spmm(a, b)
        info1 = tuning_cache_info()
        spmm(a, b)  # same (op, format, shape, dtype, impl) key
        info2 = tuning_cache_info()
        spmm(a, jnp.concatenate([b, b], axis=1))  # new n -> new key
        info3 = tuning_cache_info()
    assert info1.misses == 1 and info1.hits == 0
    assert info2.misses == 1 and info2.hits == 1
    assert info3.misses == 2
    assert info3.size == 2


def test_auto_bn_default_matches_explicit(rng):
    _, a, _, b = _mats(rng)
    auto = np.asarray(spmm(a, b, impl="kernel_interpret"))
    explicit = np.asarray(spmm(a, b, impl="kernel_interpret", bn=96))
    np.testing.assert_allclose(auto, explicit, atol=2e-4)


# ---------------------------------------------------------------------------
# Dynamic structure: plan patching counters
# ---------------------------------------------------------------------------


def _growing_wcsr(rng):
    from repro.sparse import SparseTensor
    d = rng.normal(size=(64, 64)).astype(np.float32)
    d *= rng.random(d.shape) < 0.04  # leave free columns in every window
    return SparseTensor.from_dense(d, "wcsr", block=(16, 8)).structure


def _append_one(g, w):
    from repro.sparse import append_window_chunks
    stored = set(int(c) for c in
                 g.indices[0][int(g.ptrs[w]):int(g.ptrs[w + 1])]
                 if int(c) >= 0)
    col = next(c for c in range(64) if c not in stored)
    g2, _ = append_window_chunks(g, w, [col])
    return g2


def test_n_appends_n_plan_patches_zero_replans(rng):
    from repro.ops import cache_stats, clear_plan_cache, make_plan
    clear_plan_cache()
    g = _growing_wcsr(rng)
    make_plan(g, 32)
    warm = cache_stats()
    assert warm["plan"]["misses"] == 1 and warm["plan"]["patched"] == 0
    n = 5
    for i in range(n):
        g = _append_one(g, i % 4)
        make_plan(g, 32)
    cs = cache_stats()
    assert cs["plan"]["patched"] == n  # every growth step patched
    assert cs["plan"]["misses"] == warm["plan"]["misses"]  # 0 full re-plans
    # the §III-C task split was only ever computed once, for the base
    assert cs["tasks"]["decompositions"] == warm["tasks"]["decompositions"]
    assert cs["delta"]["appends"] == n
    assert cs["delta"]["plan_patched"] == n
    clear_plan_cache()


def test_clear_tuning_cache_resets_delta_counters(rng):
    from repro.ops import cache_stats, clear_plan_cache, make_plan
    clear_plan_cache()
    g = _growing_wcsr(rng)
    make_plan(g, 32)
    make_plan(_append_one(g, 0), 32)
    before = cache_stats()
    assert before["plan"]["patched"] == 1 and before["delta"]["appends"] == 1
    clear_tuning_cache()
    after = cache_stats()
    assert after["plan"]["patched"] == 0 and after["partition"]["patched"] == 0
    assert all(v == 0 for v in after["delta"].values()), after["delta"]


# ---------------------------------------------------------------------------
# sddmm + differentiable matmul under the same roof
# ---------------------------------------------------------------------------


def test_sddmm_matches_ref(rng):
    _, a, _, b = _mats(rng)
    dc = jnp.asarray(rng.normal(size=(128, 96)).astype(np.float32))
    got = np.asarray(sddmm(dc, b, a, impl="kernel_interpret"))
    np.testing.assert_allclose(got, np.asarray(sddmm_ref(dc, b, a)),
                               atol=2e-4)


def test_bcsr_matmul_grad_respects_config(rng):
    d, a, _, b = _mats(rng, n=64)
    s = ops.structure_of(a)
    vals = a.blocks

    def loss(v):
        return jnp.sum(ops.bcsr_matmul(v, b, s) ** 2)

    with use_config(impl="ref"):
        g_ref = jax.grad(loss)(vals)
    with use_config(impl="kernel_interpret"):
        g_kern = jax.grad(loss)(vals)
    np.testing.assert_allclose(np.asarray(g_kern), np.asarray(g_ref),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Deprecation shims
# ---------------------------------------------------------------------------


def test_old_entry_points_warn_and_forward(rng):
    d, a, w, b = _mats(rng)
    from repro.kernels.bcsr.ops import bcsr_spmm
    from repro.kernels.sddmm.ops import sddmm as old_sddmm
    from repro.kernels.wcsr.ops import wcsr_spmm

    with pytest.warns(DeprecationWarning):
        old_b = np.asarray(bcsr_spmm(a, b, impl="kernel_interpret"))
    np.testing.assert_allclose(
        old_b, np.asarray(spmm(a, b, impl="kernel_interpret")), atol=1e-6)

    with pytest.warns(DeprecationWarning):
        old_w = np.asarray(wcsr_spmm(w, b, impl="ref"))
    np.testing.assert_allclose(old_w, np.asarray(spmm(w, b, impl="ref")),
                               atol=1e-6)

    dc = jnp.asarray(rng.normal(size=(128, 96)).astype(np.float32))
    with pytest.warns(DeprecationWarning):
        old_s = np.asarray(old_sddmm(dc, b, a, impl="ref"))
    np.testing.assert_allclose(old_s, np.asarray(sddmm(dc, b, a, impl="ref")),
                               atol=1e-6)


def test_old_block_attn_entry_warns_and_forwards(rng):
    from repro.kernels.block_attn.ops import block_sparse_attention

    B, H, S, D = 1, 2, 128, 16
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    mask = np.tril(np.ones((H, S // 64, S // 64), bool))
    with pytest.warns(DeprecationWarning):
        old = np.asarray(block_sparse_attention(
            q, k, v, mask, block_q=64, block_k=64, impl="ref"))
    new = np.asarray(ops.sparse_attention(
        q, k, v, mask, block_q=64, block_k=64, impl="ref"))
    np.testing.assert_allclose(old, new, atol=1e-6)


def test_old_structure_imports_still_work():
    from repro.kernels.bcsr.ops import BCSRStructure, structure_of

    assert BCSRStructure is ops.BCSRStructure
    assert structure_of is ops.structure_of


def test_shim_warnings_point_at_caller(rng):
    """Every kernels/*/ops.py shim warns with stacklevel=2, so the reported
    frame is the *caller's* file — not the shim module (the BCSR shim used
    to differ from the other three)."""
    _, a, w, b = _mats(rng)
    from repro.kernels.bcsr.ops import bcsr_spmm
    from repro.kernels.sddmm.ops import sddmm as old_sddmm
    from repro.kernels.wcsr.ops import wcsr_spmm

    dc = jnp.asarray(rng.normal(size=(128, 96)).astype(np.float32))
    calls = [
        lambda: bcsr_spmm(a, b, impl="ref"),
        lambda: wcsr_spmm(w, b, impl="ref"),
        lambda: old_sddmm(dc, b, a, impl="ref"),
    ]
    for call in calls:
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            call()
        dep = [r for r in rec if issubclass(r.category, DeprecationWarning)
               and "deprecated" in str(r.message)]
        assert dep, "no DeprecationWarning emitted"
        assert dep[0].filename == __file__, (
            f"warning points at {dep[0].filename}, not the caller")
