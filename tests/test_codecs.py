"""Value codecs end-to-end: per-block-scaled int8 / emulated fp8 sparse
values with fused in-kernel dequant.

Acceptance surface: quantized spmm (BCSR + WCSR, pipeline depths 1-3) and
sddmm match the f32 reference within the documented tolerance; the fused
kernels are (near-)bit-consistent with the materialized quantize-dequantize
reference; autotune adopts a codec only when the accuracy guard passes;
casts re-quantize but still hit the structure-keyed caches; bcsr_matmul's
backward routes through the codec-aware dequant path; the unified
``cache_stats`` aggregator and the bytes-moved model report codec traffic.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ops as ops
from repro.sparse import (SparseTensor, convert, registered_value_codecs,
                          sparsify)
from repro.sparse.codecs import (decode_format_values, encode_format_values,
                                 get_codec, modeled_value_bytes)

DEPTHS = (1, 2, 3)
# documented accuracy bounds vs the f32 reference (docs/performance.md):
# error measured as max|got - ref| / max|ref| on normal-distributed data
TOL = {"int8": 0.02, "fp8_e4m3": 0.06}
CODECS = tuple(c for c in ("int8", "fp8_e4m3")
               if c in registered_value_codecs())


def _mats(rng, m=96, k=160, n=64, density=0.25):
    d = rng.normal(size=(m, k)).astype(np.float32)
    d *= rng.random(d.shape) < density
    sa = SparseTensor.from_dense(d, "bcsr", block=(32, 32))
    sw = SparseTensor.from_dense(d, "wcsr", block=(32, 8))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    return d, sa, sw, b


def _rel(got, ref):
    return float(np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-12))


# ---------------------------------------------------------------------------
# Acceptance: quantized spmm matches the f32 reference, all depths/formats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("fmt", ["bcsr", "wcsr"])
def test_spmm_codec_matches_f32_reference_across_depths(rng, codec, fmt):
    d, sa, sw, b = _mats(rng)
    st = {"bcsr": sa, "wcsr": sw}[fmt]
    ref = np.asarray(ops.spmm(st, b, impl="ref"))
    q = st.quantize(codec)
    assert q.structure is st.structure  # codec never forks the structure
    fakequant = np.asarray(ops.spmm(q, b, impl="ref"))
    for depth in DEPTHS:
        got = np.asarray(ops.spmm(q, b, impl="kernel_interpret", bn=32,
                                  pipeline_depth=depth))
        assert _rel(got, ref) <= TOL[codec], (fmt, codec, depth)
        # the fused in-kernel dequant must agree with the materialized
        # quantize-dequantize reference to float roundoff
        np.testing.assert_allclose(got, fakequant, atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("codec", CODECS)
def test_spmm_codec_under_jit(rng, codec):
    """Quantized SparseTensor traces through jit: payload + scales are the
    leaves, structure + codec are static aux data."""
    _, sa, sw, b = _mats(rng)
    for st in (sa, sw):
        q = st.quantize(codec)
        leaves, treedef = jax.tree_util.tree_flatten(q)
        assert len(leaves) == 2  # payload + scales
        q2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert q2.codec == codec and q2.structure is q.structure
        f = jax.jit(lambda t, x: ops.spmm(t, x, impl="kernel_interpret",
                                          bn=32))
        np.testing.assert_allclose(
            np.asarray(f(q, b)),
            np.asarray(q.matmul(b, impl="kernel_interpret", bn=32)),
            atol=1e-5)


def test_spmm_value_codec_applies_to_raw_operands(rng):
    """An explicit codec on a raw BCSR/WCSR container must quantize (via a
    one-shot wrap), never silently no-op."""
    _, sa, sw, b = _mats(rng)
    for st in (sa, sw):
        want = np.asarray(ops.spmm(st.quantize("int8"), b,
                                   impl="kernel_interpret", bn=32))
        got = np.asarray(ops.spmm(st.raw, b, impl="kernel_interpret", bn=32,
                                  value_codec="int8"))
        np.testing.assert_array_equal(got, want)
        raw = np.asarray(ops.spmm(st.raw, b, impl="kernel_interpret", bn=32))
        assert not np.array_equal(got, raw)  # the knob demonstrably applied


def test_spmm_value_codec_kwarg_quantizes_on_the_fly(rng):
    """spmm(st, b, value_codec="int8") quantizes an unquantized operand
    (memoized on the tensor) — same result as quantizing up front."""
    _, sa, _, b = _mats(rng)
    want = np.asarray(ops.spmm(sa.quantize("int8"), b,
                               impl="kernel_interpret", bn=32))
    got = np.asarray(ops.spmm(sa, b, impl="kernel_interpret", bn=32,
                              value_codec="int8"))
    np.testing.assert_array_equal(got, want)
    assert sa._quantized is not None and "int8" in sa._quantized
    # an operand's own codec wins over a conflicting config
    got2 = np.asarray(ops.spmm(sa.quantize("int8"), b,
                               impl="kernel_interpret", bn=32,
                               value_codec="none"))
    np.testing.assert_array_equal(got2, want)


@pytest.mark.parametrize("codec", CODECS)
def test_sddmm_codec_matches_f32_reference(rng, codec):
    from repro.sparse import apply_block_mask, bcsr_from_dense, \
        random_block_mask

    d = apply_block_mask(
        rng.normal(size=(64, 96)).astype(np.float32),
        random_block_mask((64, 96), (32, 32), 0.5, seed=2), (32, 32))
    a = bcsr_from_dense(d, (32, 32))
    dc = jnp.asarray(rng.normal(size=(64, 80)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(96, 80)).astype(np.float32))
    ref = np.asarray(ops.sddmm(dc, b, a, impl="ref"))
    fakequant = np.asarray(ops.sddmm(dc, b, a, impl="ref",
                                     value_codec=codec))
    for depth in (0,) + DEPTHS:
        got = np.asarray(ops.sddmm(dc, b, a, impl="kernel_interpret", bn=16,
                                   pipeline_depth=depth, value_codec=codec))
        assert _rel(got, ref) <= TOL[codec], (codec, depth)
        np.testing.assert_allclose(got, fakequant, atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("codec", CODECS)
def test_block_attn_codec_matches_fakequant_reference(rng, codec):
    """Quantized K/V gather: the kernel must agree with the ref backend
    running the same quantize-dequantize round trip; softmax amplifies
    the quantization error vs true f32, so that check is looser."""
    B, H, KVH, S, D = 2, 4, 2, 256, 32
    bq = bk = 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, KVH, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, KVH, S, D)).astype(np.float32))
    nb = S // bq
    mask = np.zeros((H, nb, nb), bool)
    for h in range(H):
        for i in range(nb):
            mask[h, i, max(0, i - 1 - h % 2): i + 1] = True
            mask[h, i, 0] = True
    mask[0, 0, :] = False  # empty q-block (count == 0 < depth)
    ref = np.asarray(ops.sparse_attention(q, k, v, mask, block_q=bq,
                                          block_k=bk, impl="ref"))
    fakequant = np.asarray(ops.sparse_attention(
        q, k, v, mask, block_q=bq, block_k=bk, impl="ref",
        value_codec=codec))
    for depth in (0,) + DEPTHS:
        got = np.asarray(ops.sparse_attention(
            q, k, v, mask, block_q=bq, block_k=bk, impl="kernel_interpret",
            pipeline_depth=depth, value_codec=codec))
        np.testing.assert_allclose(got, fakequant, atol=1e-4)
        assert float(np.max(np.abs(got - ref))) <= 0.2, (codec, depth)


# ---------------------------------------------------------------------------
# Satellite: astype / value swaps re-quantize but hit structure-keyed caches
# ---------------------------------------------------------------------------


def test_astype_requantizes_but_hits_structure_caches(rng):
    _, _, sw, b = _mats(rng)
    q = sw.quantize("int8")
    ops.clear_plan_cache()
    q.matmul(b, impl="kernel_interpret")
    info = ops.plan_cache_info()
    assert info.task_decompositions == 1 and info.misses == 1

    # cast: must re-quantize (fresh payload/scales) on the same structure
    qc = q.astype(jnp.bfloat16)
    assert qc.codec == "int8"
    assert qc.structure is q.structure
    assert qc.data[0] is not q.data[0]
    qc.matmul(b, impl="kernel_interpret")
    info = ops.plan_cache_info()
    # new (dtype-keyed) plan, but the §III-C task split is structure-keyed
    # and shared — the serving amortization contract survives quantization
    assert info.task_decompositions == 1

    # value swap keeps codec + structure, never re-plans
    q2 = q.with_values(q.payload, q.scales * 2.0)
    assert q2.codec == "int8" and q2.structure is q.structure
    got = np.asarray(q2.matmul(b, impl="kernel_interpret"))
    want = 2.0 * np.asarray(q.matmul(b, impl="kernel_interpret"))
    np.testing.assert_allclose(got, want, atol=1e-3,
                               rtol=1e-4)
    assert ops.plan_cache_info().task_decompositions == 1

    # quantized and raw tensors of one structure share the task cache too
    sw.matmul(b, impl="kernel_interpret")
    assert ops.plan_cache_info().task_decompositions == 1


def test_plan_carries_and_keys_codec(rng):
    _, _, sw, b = _mats(rng)
    ops.clear_plan_cache()
    p0 = ops.make_plan(sw, b.shape[1], ops.OpConfig(bn=32))
    pq = ops.make_plan(sw.quantize("int8"), b.shape[1], ops.OpConfig(bn=32))
    assert p0.value_codec == "none" and pq.value_codec == "int8"
    assert p0 is not pq  # distinct cache entries per codec
    assert pq.tasks is p0.tasks  # ...sharing the structure-keyed task split
    assert ops.make_plan(sw.quantize("int8"), b.shape[1],
                         ops.OpConfig(bn=32)) is pq


# ---------------------------------------------------------------------------
# Autotune: codec sweep + accuracy guard
# ---------------------------------------------------------------------------


def test_autotune_codec_guard_rejects_and_adopts(rng):
    _, _, sw, b = _mats(rng)
    ops.clear_tuning_cache()
    # impossible tolerance: every codec is rejected before timing, the
    # winner stays raw
    best = ops.autotune_spmm(sw, b, impl="kernel_interpret", bns=(32,),
                             chunks_per_task=(4,), depths=(1,),
                             codecs=("none", "int8"), codec_tol=1e-9,
                             warmup=0, iters=1)
    assert best["value_codec"] == "none"
    assert "int8" in best["rejected_codecs"]
    assert best["rejected_codecs"]["int8"] > 1e-9
    y = np.asarray(ops.spmm(sw, b, impl="kernel_interpret",
                            value_codec="auto"))
    ref = np.asarray(ops.spmm(sw, b, impl="ref"))
    np.testing.assert_allclose(y, ref, atol=2e-4 * max(1, np.abs(ref).max()))

    # permissive tolerance + an int8-only sweep: the codec passes the guard
    # and wins; "auto" callers adopt it, everyone else stays raw
    ops.clear_tuning_cache()
    best = ops.autotune_spmm(sw, b, impl="kernel_interpret", bns=(32,),
                             chunks_per_task=(4,), depths=(1,),
                             codecs=("int8",), codec_tol=0.05,
                             warmup=0, iters=1)
    assert best["value_codec"] == "int8"
    assert best["rejected_codecs"] == {}
    y_auto = np.asarray(ops.spmm(sw, b, impl="kernel_interpret",
                                 value_codec="auto"))
    y_q = np.asarray(ops.spmm(sw.quantize("int8"), b,
                              impl="kernel_interpret"))
    np.testing.assert_array_equal(y_auto, y_q)
    # without the opt-in the raw path is untouched
    y_raw = np.asarray(ops.spmm(sw, b, impl="kernel_interpret"))
    np.testing.assert_allclose(y_raw, ref,
                               atol=2e-4 * max(1, np.abs(ref).max()))
    ops.clear_tuning_cache()


def test_autotune_all_codecs_rejected_raises(rng):
    """codecs= without "none" and an impossible tolerance: every candidate
    is rejected, so there is no winner — a clear error, not a crash."""
    _, _, sw, b = _mats(rng)
    ops.clear_tuning_cache()
    with pytest.raises(ValueError, match="rejected by the accuracy guard"):
        ops.autotune_spmm(sw, b, impl="kernel_interpret", bns=(32,),
                          chunks_per_task=(4,), depths=(1,),
                          codecs=("int8",), codec_tol=1e-9,
                          warmup=0, iters=1)
    assert ops.tuning_cache_info().autotuned == 0  # nothing was cached
    ops.clear_tuning_cache()


# ---------------------------------------------------------------------------
# Satellite: bcsr_matmul codec-aware backward (grad equivalence)
# ---------------------------------------------------------------------------


def test_bcsr_matmul_codec_grad_matches_dequantized_forward(rng):
    from repro.sparse import apply_block_mask, bcsr_from_dense, \
        random_block_mask
    from repro.ops.matmul import _quantized_values, structure_of

    d = apply_block_mask(
        rng.normal(size=(64, 96)).astype(np.float32),
        random_block_mask((64, 96), (32, 32), 0.5, seed=3), (32, 32))
    a = bcsr_from_dense(d, (32, 32))
    s = structure_of(a)
    values = a.blocks
    b = jnp.asarray(rng.normal(size=(96, 48)).astype(np.float32))

    with ops.use_config(impl="ref"):
        # forward parity: codec path == explicit quantize-dequantize path
        yq = ops.bcsr_matmul(values, b, s, None, "int8")
        vq = _quantized_values(values, "int8")
        y2 = ops.bcsr_matmul(vq, b, s)
        np.testing.assert_allclose(np.asarray(yq), np.asarray(y2),
                                   atol=1e-4, rtol=1e-5)

        # grad equivalence: dB must come from Q(values)^T (the codec-aware
        # dequant path), dvalues is the straight-through estimate
        gv_q, gb_q = jax.grad(
            lambda v_, b_: ops.bcsr_matmul(v_, b_, s, None, "int8").sum(),
            argnums=(0, 1))(values, b)
        gv_2, gb_2 = jax.grad(
            lambda v_, b_: ops.bcsr_matmul(v_, b_, s).sum(),
            argnums=(0, 1))(vq, b)
    np.testing.assert_allclose(np.asarray(gb_q), np.asarray(gb_2),
                               atol=1e-4, rtol=1e-5)
    # STE: parameter grad is codec-independent (sddmm of dC, B)
    np.testing.assert_allclose(np.asarray(gv_q), np.asarray(gv_2),
                               atol=1e-4, rtol=1e-5)
    # and the raw-value path's dB differs whenever quantization moved A
    gb_raw = jax.grad(
        lambda b_: ops.bcsr_matmul(values, b, s, "ref").sum())(b)
    assert not np.allclose(np.asarray(gb_q), np.asarray(gb_raw),
                           atol=1e-7)


# ---------------------------------------------------------------------------
# Representation layer: encode/decode, conversion, sparsify, repr
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", CODECS)
def test_encode_decode_roundtrip_tolerance(rng, codec):
    d, sa, sw, _ = _mats(rng)
    for st in (sa, sw):
        payload, scales = encode_format_values(
            st.format, st.block, st.data[0], codec)
        assert payload.dtype == get_codec(codec).storage_dtype
        assert scales.dtype == jnp.float32
        back = decode_format_values(st.format, st.block, payload, scales)
        ref = np.asarray(st.data[0])
        err = np.max(np.abs(np.asarray(back) - ref))
        assert err <= TOL[codec] * np.max(np.abs(ref))
        # exact zeros stay exact (zero-scale groups)
        assert np.all(np.asarray(back)[ref == 0] == 0)


def test_quantize_dequantize_todense(rng):
    d, sa, _, _ = _mats(rng)
    q = sa.quantize("int8")
    assert q.codec == "int8" and q.dtype == jnp.int8
    assert q.scales is not None and sa.scales is None
    assert "codec=int8" in repr(q)
    # memoized per codec; quantize("none") decodes
    assert sa.quantize("int8") is q
    dq = q.quantize("none")
    assert dq.codec == "none" and len(dq.data) == 1
    np.testing.assert_allclose(np.asarray(q.todense()), d, atol=0.02)
    np.testing.assert_allclose(np.asarray(q.T.todense()), d.T, atol=0.02)


def test_convert_and_sparsify_codec_plumbing(rng):
    d, sa, _, _ = _mats(rng)
    # quantize on conversion, from dense and raw inputs
    q = convert(d, "bcsr", block=(32, 32), codec="int8")
    assert isinstance(q, SparseTensor) and q.codec == "int8"
    # same-format convert with only a codec change re-encodes in place
    q2 = convert(sa, "bcsr", codec="int8")
    assert q2.codec == "int8" and q2.structure is sa.structure
    assert convert(q2, "bcsr") is q2  # identity keeps the codec
    # cross-format hop: dequantize for the hop, re-quantize on the way out
    w = q2.to("wcsr", block=(32, 8))
    assert w.format == "wcsr" and w.codec == "int8"
    np.testing.assert_allclose(np.asarray(w.todense()), d, atol=0.05)
    # codec="none" strips it
    assert convert(q2, "bcsr", codec="none").codec == "none"
    sp = sparsify(np.asarray(d), format="wcsr", block=(32, 8),
                  sparsity=0.9, method="random", codec="int8")
    assert sp.codec == "int8"
    with pytest.raises(ValueError, match="unknown value codec"):
        sa.quantize("int4")


def test_modeled_value_bytes():
    m = modeled_value_bytes(1024, 256, "int8")
    assert m["baseline_bytes"] == 4096
    assert m["compressed_bytes"] == 1024 + 4 * 4  # payload + 4 group scales
    assert 3.9 < m["reduction"] < 4.0
    assert modeled_value_bytes(1024, 256, "none")["reduction"] == 1.0


# ---------------------------------------------------------------------------
# Counters: cache_stats aggregator, codec selections, bytes report
# ---------------------------------------------------------------------------


def test_cache_stats_unifies_counters(rng):
    _, _, sw, b = _mats(rng)
    ops.clear_plan_cache()
    ops.clear_tuning_cache()
    sw.quantize("int8").matmul(b, impl="kernel_interpret")
    sw.matmul(b, impl="kernel_interpret")
    cs = ops.cache_stats()
    assert set(cs) == {"plan", "tasks", "partition", "tuning", "selections",
                       "tune_db", "spmv", "delta", "combine"}
    assert set(cs["spmv"]) == {"dispatched", "full_tile"}
    assert set(cs["combine"]) == {"chunked", "blocking", "chunks",
                                  "schedules_built", "shard_chunks_built",
                                  "shard_chunks_reused", "hier_calls",
                                  "hier_fallback"}
    # unsharded calls never chunk the combine
    assert cs["combine"]["chunked"] == 0
    # derived from the same counters as the legacy accessors — never a
    # second set that can drift
    p = ops.plan_cache_info()
    t = ops.tuning_cache_info()
    assert cs["plan"] == {"hits": p.hits, "misses": p.misses, "size": p.size,
                          "patched": p.plan_patched}
    assert cs["tasks"]["decompositions"] == p.task_decompositions == 1
    assert cs["partition"]["misses"] == p.partition_misses
    assert cs["tuning"]["autotuned"] == t.autotuned
    assert cs["selections"]["pipeline_depth"] == t.pipeline_depths
    assert cs["selections"]["value_codec"] == t.value_codecs
    assert cs["selections"]["value_codec"].get("int8", 0) >= 1
    assert cs["selections"]["value_codec"].get("none", 0) >= 1
    assert cs["tune_db"] == {"hits": t.db_hits, "misses": t.db_misses,
                             "stale": t.db_stale, "sweeps": t.sweeps}
    # the bytes-moved model reports the quantized plan
    rep = ops.codec_bytes_report()
    mine = [r for r in rep if r["codec"] == "int8"
            and r["shape"] == sw.shape and r["fmt"] == "wcsr"]
    assert mine and mine[0]["reduction"] > 2.0


def test_serve_stats_surface_codec_keys():
    from repro.serve.engine import ServeEngine

    class _Cache:
        kv = ssm = prev1 = prev2 = None

    class _Model:
        cfg = None

        def init_decode_cache(self, slots, max_len):
            return _Cache()

        def decode_step(self, p, c, tok, pos):
            return jnp.zeros((tok.shape[0], 4)), c

    eng = ServeEngine(_Model(), params={}, slots=2, max_len=8)
    s = eng.stats()
    assert {"value_codecs", "codec_bytes", "cache_stats",
            "pipeline_depths"} <= set(s)
    assert s["value_codecs"] == s["tuning_cache"].value_codecs
    assert s["cache_stats"]["selections"]["value_codec"] == s["value_codecs"]
    assert isinstance(s["codec_bytes"], list)
