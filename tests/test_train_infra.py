"""Optimizers, grad accumulation, checkpointing, trainer fault tolerance,
data pipeline."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models.registry import build_model
from repro.optim import adafactor, adamw
from repro.train.step import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mod", [adamw, adafactor])
def test_optimizer_converges_quadratic(mod):
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]),
              "idx": jnp.asarray([1, 2, 3], jnp.int32)}  # int leaf carried
    state = mod.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss, allow_int=True)(params)
        params, state = mod.apply(params, g, state, lr=0.1)
    assert float(loss(params)) < 1e-2
    assert (np.asarray(params["idx"]) == [1, 2, 3]).all()  # untouched


def test_adamw_layerwise_map_matches_direct():
    """The lax.map path for stacked leaves must equal the direct update."""
    rng = np.random.default_rng(0)
    big = jnp.asarray(rng.normal(size=(8, 4, 4)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(8, 4, 4)).astype(np.float32))
    s1 = adamw.init({"w": big})
    p1, _ = adamw.apply({"w": big}, {"w": g}, s1, lr=0.01)
    # same data as 8 separate small leaves (direct path)
    ps = {f"w{i}": big[i] for i in range(8)}
    gs = {f"w{i}": g[i] for i in range(8)}
    s2 = adamw.init(ps)
    p2, _ = adamw.apply(ps, gs, s2, lr=0.01)
    for i in range(8):
        np.testing.assert_allclose(np.asarray(p1["w"][i]),
                                   np.asarray(p2[f"w{i}"]), rtol=1e-6)


def test_grad_accumulation_equivalence(rng):
    """microbatches=4 must match microbatches=1 up to float tolerance."""
    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=1)
    m = build_model(cfg)
    params = m.init(KEY)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
    }
    s1 = init_train_state(params)
    s2 = init_train_state(params)
    st1, m1 = jax.jit(make_train_step(m, microbatches=1))(s1, batch)
    st4, m4 = jax.jit(make_train_step(m, microbatches=4))(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    l1 = jax.tree.leaves(st1.params)
    l4 = jax.tree.leaves(st4.params)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, rng):
    from repro.ckpt.checkpoint import latest_step, restore, save

    tree = {"a": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray(rng.normal(size=(3,)), jnp.bfloat16)}}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_atomicity(tmp_path, rng):
    """A stale tmp dir (crashed writer) must be invisible to latest_step."""
    from repro.ckpt.checkpoint import latest_step, save

    save(str(tmp_path), 5, {"x": jnp.ones((2,))})
    crashed = tmp_path / "step_00000009.tmp.1234"
    crashed.mkdir()
    (crashed / "arrays.npz").write_bytes(b"garbage")
    incomplete = tmp_path / "step_00000010"
    incomplete.mkdir()  # renamed dir without manifest (impossible, but...)
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_keep_k(tmp_path):
    from repro.ckpt.checkpoint import Checkpointer

    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, {"x": jnp.full((2,), float(s))})
    ck.wait()
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_trainer_restart_resumes(tmp_path, rng):
    """Kill-and-restart: a new Trainer resumes from the latest checkpoint."""
    from repro.data.synthetic import SyntheticLM

    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=1)
    m = build_model(cfg)
    data = SyntheticLM(cfg.vocab_size, seed=0)

    def batch_fn(step):
        nb = data.batch(step, 4, 16)
        return {k: jnp.asarray(v) for k, v in nb.items()}

    tcfg = TrainerConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                         log_every=100, peak_lr=1e-3)
    tr1 = Trainer(m, tcfg)
    state, start = tr1.init_or_restore(KEY)
    assert start == 0
    # run only 4 steps, then "crash" (abandon the trainer)
    for step in range(4):
        state, _ = tr1.train_step(state, batch_fn(step))
        if tr1.ckpt and (step + 1) % tcfg.ckpt_every == 0:
            tr1.ckpt.save_async(step + 1, state, {})
    tr1.ckpt.wait()

    tr2 = Trainer(m, tcfg)
    state2, start2 = tr2.init_or_restore(KEY)
    assert start2 == 3  # resumed from the intact checkpoint
    final = tr2.run(state2, batch_fn, start_step=start2)
    assert int(final.opt.step) == tcfg.total_steps


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_deterministic():
    from repro.data.synthetic import SyntheticLM

    d = SyntheticLM(128, seed=1)
    b1 = d.batch(3, 4, 16)
    b2 = d.batch(3, 4, 16)
    assert (b1["tokens"] == b2["tokens"]).all()
    b3 = d.batch(4, 4, 16)
    assert not (b1["tokens"] == b3["tokens"]).all()
    # labels are next tokens
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_prefetcher_overlaps():
    from repro.data.pipeline import Prefetcher

    seen = []
    pf = Prefetcher(lambda step: {"step": step}, start_step=5, depth=2)
    for _ in range(4):
        step, batch = pf.get()
        seen.append(step)
    pf.close()
    assert seen == [5, 6, 7, 8]
