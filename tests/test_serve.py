"""Serving engine: batched continuous-batching output must equal sequential
single-request decode; slot reuse must not leak state."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(3)


def _sequential_decode(m, params, prompt, n_new, max_len=64):
    cache = m.init_decode_cache(1, max_len)
    pos = 0
    for tok in prompt:
        logits, cache = m.decode_step(
            params, cache, jnp.asarray([tok], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        pos += 1
    out = []
    cur = int(np.argmax(np.asarray(logits)[0]))
    out.append(cur)
    for _ in range(n_new - 1):
        logits, cache = m.decode_step(
            params, cache, jnp.asarray([cur], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        pos += 1
        cur = int(np.argmax(np.asarray(logits)[0]))
        out.append(cur)
    return out


def test_engine_matches_sequential(rng):
    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=2)
    m = build_model(cfg)
    params = m.init(KEY)
    prompts = [rng.integers(0, cfg.vocab_size, (p,)).tolist()
               for p in (3, 5, 4)]
    want = [_sequential_decode(m, params, p, 4) for p in prompts]
    eng = ServeEngine(m, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.asarray(p), max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for r, w in zip(reqs, want):
        assert r.done
        assert r.out_tokens == w, (r.rid, r.out_tokens, w)


def test_engine_stats_pipeline_depth_counters(rng):
    """stats() must surface the §III-A pipeline-depth selection counters
    (tuning_cache.pipeline_depths + the top-level dashboard key)."""
    import jax.numpy as jnp
    import repro.ops as ops
    from repro.sparse import wcsr_from_dense

    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=1)
    m = build_model(cfg)
    params = m.init(KEY)
    eng = ServeEngine(m, params, slots=1, max_len=32)
    stats = eng.stats()
    assert "pipeline_depths" in stats
    assert isinstance(stats["pipeline_depths"], dict)
    assert stats["pipeline_depths"] == stats["tuning_cache"].pipeline_depths
    # a depth-pinned spmm shows up in the engine's counters (process-global,
    # like the other cache counters)
    d = rng.normal(size=(64, 96)).astype(np.float32)
    d *= rng.random(d.shape) < 0.3
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    before = eng.stats()["pipeline_depths"].get(2, 0)
    ops.spmm(w, b, impl="kernel_interpret", bn=32, pipeline_depth=2)
    assert eng.stats()["pipeline_depths"].get(2, 0) == before + 1


def test_engine_slot_reuse_no_leak(rng):
    """Same prompt admitted before and after other traffic must produce
    identical outputs (slot reset works)."""
    cfg = reduced_config(ARCHS["h2o-danube-1.8b"], num_layers=2)
    m = build_model(cfg)
    params = m.init(KEY)
    prompt = rng.integers(0, cfg.vocab_size, (4,))
    eng = ServeEngine(m, params, slots=1, max_len=64)
    r1 = Request(rid=0, prompt=prompt, max_new_tokens=3)
    r2 = Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, (6,)),
                 max_new_tokens=3)
    r3 = Request(rid=2, prompt=prompt, max_new_tokens=3)
    eng.run([r1, r2, r3])
    assert r1.out_tokens == r3.out_tokens
