"""Format round-trips + hypothesis property tests on the core invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.formats import (
    BCSR, WCSR, bcsr_from_dense, bcsr_from_mask, bcsr_to_dense,
    bcsr_transpose, block_mask_from_dense, fill_ratio, make_wcsr_tasks,
    rcm_permutation, wcsr_from_dense, wcsr_to_dense,
)
from repro.core.sparsify import (
    apply_block_mask, banded_block_mask, magnitude_block_mask,
    random_block_mask,
)


def _sparse_dense(rng, m, k, bm, bk, sparsity):
    d = rng.normal(size=(m, k)).astype(np.float32)
    mask = random_block_mask((m, k), (bm, bk), sparsity, seed=1)
    return apply_block_mask(d, mask, (bm, bk))


def test_bcsr_roundtrip(rng):
    d = _sparse_dense(rng, 128, 192, 32, 32, 0.6)
    a = bcsr_from_dense(d, (32, 32))
    assert np.allclose(np.asarray(bcsr_to_dense(a)), d)


def test_bcsr_covers_empty_rows(rng):
    d = np.zeros((128, 64), np.float32)
    d[:32, :32] = rng.normal(size=(32, 32))  # only block-row 0 nonzero
    a = bcsr_from_dense(d, (32, 32))
    rows = set(np.asarray(a.block_rows)[: a.nnz_blocks].tolist())
    assert rows == {0, 1, 2, 3}  # every block-row covered
    assert np.allclose(np.asarray(bcsr_to_dense(a)), d)


def test_bcsr_transpose(rng):
    d = _sparse_dense(rng, 96, 160, 32, 32, 0.5)
    a = bcsr_from_dense(d, (32, 32))
    at = bcsr_transpose(a)
    assert np.allclose(np.asarray(bcsr_to_dense(at)), d.T)
    assert at.shape == (160, 96)


def test_wcsr_roundtrip(rng):
    d = rng.normal(size=(128, 200)).astype(np.float32)
    d *= rng.random(d.shape) > 0.8
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    assert np.allclose(np.asarray(wcsr_to_dense(w)), d)
    assert w.padded_cols % 8 == 0


def test_fill_ratio_ordering(rng):
    """WCSR is never less compact than BCSR for scattered sparsity."""
    d = rng.normal(size=(128, 256)).astype(np.float32)
    d *= rng.random(d.shape) > 0.95
    a = bcsr_from_dense(d, (32, 32), pad_to=None)
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    assert fill_ratio(d, w) >= fill_ratio(d, a) - 1e-9


def test_wcsr_tasks_cover_all_chunks(rng):
    d = rng.normal(size=(128, 300)).astype(np.float32)
    d *= rng.random(d.shape) > 0.7
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    t_win, t_start, t_n = make_wcsr_tasks(w, chunks_per_task=3)
    ptr = np.asarray(w.window_ptr) // 8
    covered = {(int(w_), s)
               for w_, st_, n in zip(t_win, t_start, t_n)
               for s in range(st_, st_ + n)}
    want = {(wi, c) for wi in range(w.num_windows)
            for c in range(ptr[wi], ptr[wi + 1])}
    assert covered == want
    assert all(n <= 3 for n in t_n)


def test_rcm_reduces_bandwidth():
    rng = np.random.default_rng(3)
    n = 96
    d = np.zeros((n, n), np.float32)
    idx = rng.permutation(n)
    for i in range(n - 1):  # a path graph, randomly permuted
        d[idx[i], idx[i + 1]] = 1.0
        d[idx[i + 1], idx[i]] = 1.0
    perm = rcm_permutation(d)
    dp = d[np.ix_(perm, perm)]
    bw = lambda x: max(abs(i - j) for i, j in zip(*np.nonzero(x)))
    assert bw(dp) < bw(d)


def test_magnitude_mask_keeps_top_blocks(rng):
    w = rng.normal(size=(64, 64)).astype(np.float32)
    w[:32, :32] *= 100  # block (0,0) clearly dominant
    m = magnitude_block_mask(w, (32, 32), sparsity=0.75)
    assert m[0, 0] and m.sum() == 1


def test_banded_mask_shape():
    m = banded_block_mask((128, 128), (32, 32), bandwidth_blocks=1)
    assert m.shape == (4, 4)
    assert m[0, 0] and not m[0, 3]


@settings(max_examples=15, deadline=None)
@given(
    mb=st.integers(2, 4), kb=st.integers(2, 5),
    bm=st.sampled_from([8, 16]), bk=st.sampled_from([8, 16]),
    sparsity=st.floats(0.0, 0.9), seed=st.integers(0, 100),
)
def test_property_bcsr_roundtrip(mb, kb, bm, bk, sparsity, seed):
    rng = np.random.default_rng(seed)
    d = _sparse_dense(rng, mb * bm, kb * bk, bm, bk, sparsity)
    a = bcsr_from_dense(d, (bm, bk))
    assert np.allclose(np.asarray(bcsr_to_dense(a)), d)
    # structural invariants
    rows = np.asarray(a.block_rows)[: a.nnz_blocks]
    assert (np.diff(rows) >= 0).all()  # sorted by block row
    ptr = np.asarray(a.block_row_ptr)
    assert ptr[-1] == a.nnz_blocks


@settings(max_examples=15, deadline=None)
@given(
    wb=st.integers(1, 4), k=st.integers(8, 64),
    density=st.floats(0.05, 1.0), seed=st.integers(0, 100),
)
def test_property_wcsr_roundtrip(wb, k, density, seed):
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(wb * 16, k)).astype(np.float32)
    d *= rng.random(d.shape) < density
    w = wcsr_from_dense(d, b_row=16, b_col=8)
    assert np.allclose(np.asarray(wcsr_to_dense(w)), d)
    # every real packed column has a valid source column
    ci = np.asarray(w.col_idx)
    assert ((ci >= -1) & (ci < k)).all()
