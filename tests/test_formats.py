"""Format round-trips + hypothesis property tests on the core invariants,
now through the ``repro.sparse`` layer (conversion graph, transposes, the
structure-side task decomposition)."""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse import (
    BCSR, WCSR, SparseStructure, apply_block_mask, banded_block_mask,
    bcsr_from_dense, bcsr_from_mask, bcsr_to_dense, bcsr_transpose,
    block_mask_from_dense, convert, fill_ratio, magnitude_block_mask,
    make_wcsr_tasks, random_block_mask, rcm_permutation, structure_of,
    wcsr_from_dense, wcsr_to_dense, wcsr_transpose,
)


def _sparse_dense(rng, m, k, bm, bk, sparsity):
    d = rng.normal(size=(m, k)).astype(np.float32)
    mask = random_block_mask((m, k), (bm, bk), sparsity, seed=1)
    return apply_block_mask(d, mask, (bm, bk))


def test_bcsr_roundtrip(rng):
    d = _sparse_dense(rng, 128, 192, 32, 32, 0.6)
    a = bcsr_from_dense(d, (32, 32))
    assert np.allclose(np.asarray(bcsr_to_dense(a)), d)


def test_bcsr_covers_empty_rows(rng):
    d = np.zeros((128, 64), np.float32)
    d[:32, :32] = rng.normal(size=(32, 32))  # only block-row 0 nonzero
    a = bcsr_from_dense(d, (32, 32))
    rows = set(np.asarray(a.block_rows)[: a.nnz_blocks].tolist())
    assert rows == {0, 1, 2, 3}  # every block-row covered
    assert np.allclose(np.asarray(bcsr_to_dense(a)), d)


def test_bcsr_transpose(rng):
    d = _sparse_dense(rng, 96, 160, 32, 32, 0.5)
    a = bcsr_from_dense(d, (32, 32))
    at = bcsr_transpose(a)
    assert np.allclose(np.asarray(bcsr_to_dense(at)), d.T)
    assert at.shape == (160, 96)


def test_wcsr_roundtrip(rng):
    d = rng.normal(size=(128, 200)).astype(np.float32)
    d *= rng.random(d.shape) > 0.8
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    assert np.allclose(np.asarray(wcsr_to_dense(w)), d)
    assert w.padded_cols % 8 == 0


def test_wcsr_transpose(rng):
    d = rng.normal(size=(96, 160)).astype(np.float32)
    d *= rng.random(d.shape) > 0.9
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    wt = wcsr_transpose(w)
    assert wt.shape == (160, 96)
    assert np.allclose(np.asarray(wcsr_to_dense(wt)), d.T)


def test_wcsr_transpose_involution(rng):
    d = rng.normal(size=(64, 64)).astype(np.float32)
    d *= rng.random(d.shape) > 0.85
    w = wcsr_from_dense(d, b_row=16, b_col=8)
    wtt = wcsr_transpose(wcsr_transpose(w))
    assert np.array_equal(np.asarray(wcsr_to_dense(wtt)), d)


def test_wcsr_transpose_non_divisible_raises(rng):
    d = rng.normal(size=(32, 40)).astype(np.float32)  # k=40, b_row=32
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    with pytest.raises(ValueError, match="not divisible"):
        wcsr_transpose(w)
    # an explicit transposed window height that divides k works
    wt = wcsr_transpose(w, b_row=8)
    assert np.allclose(np.asarray(wcsr_to_dense(wt)), np.asarray(d).T)


def test_fill_ratio_ordering(rng):
    """WCSR is never less compact than BCSR for scattered sparsity."""
    d = rng.normal(size=(128, 256)).astype(np.float32)
    d *= rng.random(d.shape) > 0.95
    a = bcsr_from_dense(d, (32, 32), pad_to=None)
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    assert fill_ratio(d, w) >= fill_ratio(d, a) - 1e-9


# ---------------------------------------------------------------------------
# WCSR task decomposition (now on SparseStructure)
# ---------------------------------------------------------------------------


def test_wcsr_tasks_cover_all_chunks(rng):
    d = rng.normal(size=(128, 300)).astype(np.float32)
    d *= rng.random(d.shape) > 0.7
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    t_win, t_start, t_n = make_wcsr_tasks(w, chunks_per_task=3)
    ptr = np.asarray(w.window_ptr) // 8
    covered = {(int(w_), s)
               for w_, st_, n in zip(t_win, t_start, t_n)
               for s in range(st_, st_ + n)}
    want = {(wi, c) for wi in range(w.num_windows)
            for c in range(ptr[wi], ptr[wi + 1])}
    assert covered == want
    assert all(n <= 3 for n in t_n)


def test_wcsr_tasks_empty_window(rng):
    """A window with no nonzero columns emits no task (zero-init covers it)."""
    d = np.zeros((96, 64), np.float32)
    d[:32] = rng.normal(size=(32, 64))   # window 0 dense
    d[64:] = rng.normal(size=(32, 64))   # window 2 dense; window 1 empty
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    t_win, t_start, t_n = make_wcsr_tasks(w, chunks_per_task=4)
    assert 1 not in set(t_win.tolist())
    assert set(t_win.tolist()) == {0, 2}
    assert (t_n > 0).all()
    # and tasks from the structure are identical to the compat wrapper's
    s = structure_of(w)
    got = s.tasks(4)
    for a_, b_ in zip(got, (t_win, t_start, t_n)):
        assert np.array_equal(a_, b_)


def test_wcsr_tasks_fully_empty_matrix():
    """A fully-empty matrix yields the single no-op task (non-empty grid)."""
    w = wcsr_from_dense(np.zeros((64, 64), np.float32), b_row=32, b_col=8)
    t_win, t_start, t_n = make_wcsr_tasks(w, chunks_per_task=2)
    assert t_win.tolist() == [0]
    assert t_start.tolist() == [0]
    assert t_n.tolist() == [0]


# ---------------------------------------------------------------------------
# Conversion graph round-trips
# ---------------------------------------------------------------------------


def test_convert_roundtrip_both_formats(rng):
    d = _sparse_dense(rng, 64, 96, 16, 16, 0.5)
    for fmt, kw in (("bcsr", {"block": (16, 16)}),
                    ("wcsr", {"block": (16, 8)})):
        back = np.asarray(convert(convert(d, fmt, **kw), "dense"))
        assert np.array_equal(back, d), fmt


def test_convert_cross_format_via_dense_hop(rng):
    d = _sparse_dense(rng, 64, 64, 16, 16, 0.6)
    a = convert(d, "bcsr", block=(16, 16))
    w = convert(a, "wcsr", block=(16, 8))
    assert isinstance(w, WCSR)
    assert np.array_equal(np.asarray(wcsr_to_dense(w)), d)
    a2 = convert(w, "bcsr", block=(16, 16))
    assert isinstance(a2, BCSR)
    assert np.array_equal(np.asarray(bcsr_to_dense(a2)), d)


def test_convert_mask_edge(rng):
    d = rng.normal(size=(64, 64)).astype(np.float32)
    mask = np.zeros((4, 4), bool)
    mask[0, 0] = mask[2, 3] = True
    a = convert(d, "bcsr", block=(16, 16), mask=mask)
    want = apply_block_mask(d, mask, (16, 16))
    assert np.allclose(np.asarray(bcsr_to_dense(a)), want)


def test_convert_rejects_unknown_kwargs_and_formats(rng):
    d = rng.normal(size=(32, 32)).astype(np.float32)
    with pytest.raises(TypeError, match="unexpected keyword"):
        convert(d, "bcsr", blokc=(16, 16))
    with pytest.raises(ValueError, match="unknown sparse format"):
        convert(d, "csr5")


def test_convert_non_divisible_raises(rng):
    d = rng.normal(size=(48, 40)).astype(np.float32)
    with pytest.raises(ValueError, match="not divisible"):
        convert(d, "bcsr", block=(32, 32))
    with pytest.raises(ValueError, match="not divisible"):
        convert(d, "wcsr", block=(32, 8))


# ---------------------------------------------------------------------------
# Misc invariants (masks, RCM)
# ---------------------------------------------------------------------------


def test_rcm_reduces_bandwidth():
    rng = np.random.default_rng(3)
    n = 96
    d = np.zeros((n, n), np.float32)
    idx = rng.permutation(n)
    for i in range(n - 1):  # a path graph, randomly permuted
        d[idx[i], idx[i + 1]] = 1.0
        d[idx[i + 1], idx[i]] = 1.0
    perm = rcm_permutation(d)
    dp = d[np.ix_(perm, perm)]
    bw = lambda x: max(abs(i - j) for i, j in zip(*np.nonzero(x)))
    assert bw(dp) < bw(d)


def test_magnitude_mask_keeps_top_blocks(rng):
    w = rng.normal(size=(64, 64)).astype(np.float32)
    w[:32, :32] *= 100  # block (0,0) clearly dominant
    m = magnitude_block_mask(w, (32, 32), sparsity=0.75)
    assert m[0, 0] and m.sum() == 1


def test_banded_mask_shape():
    m = banded_block_mask((128, 128), (32, 32), bandwidth_blocks=1)
    assert m.shape == (4, 4)
    assert m[0, 0] and not m[0, 3]


# ---------------------------------------------------------------------------
# Deprecated core.formats / core.sparsify shims
# ---------------------------------------------------------------------------


def test_core_formats_shims_warn_and_forward(rng):
    from repro.core import formats as old_formats
    from repro.core import sparsify as old_sparsify

    assert old_formats.BCSR is BCSR  # same pytree classes, no wrapping
    assert old_formats.WCSR is WCSR
    d = _sparse_dense(rng, 64, 64, 16, 16, 0.5)
    with pytest.warns(DeprecationWarning, match="repro.sparse"):
        a_old = old_formats.bcsr_from_dense(d, (16, 16))
    a_new = bcsr_from_dense(d, (16, 16))
    assert np.array_equal(np.asarray(a_old.blocks), np.asarray(a_new.blocks))
    with pytest.warns(DeprecationWarning):
        old_a = old_sparsify.sparsify_to_bcsr(d, (16, 16), 0.5, seed=3)
    from repro.sparse import sparsify
    new_a = sparsify(d, format="bcsr", block=(16, 16), sparsity=0.5,
                     seed=3).raw
    assert np.array_equal(np.asarray(old_a.blocks), np.asarray(new_a.blocks))
    with pytest.warns(DeprecationWarning):
        old_w = old_sparsify.sparsify_to_wcsr(d, 16, 8, 0.5, method="random")
    assert isinstance(old_w, WCSR)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    mb=st.integers(2, 4), kb=st.integers(2, 5),
    bm=st.sampled_from([8, 16]), bk=st.sampled_from([8, 16]),
    sparsity=st.floats(0.0, 0.9), seed=st.integers(0, 100),
)
def test_property_bcsr_roundtrip(mb, kb, bm, bk, sparsity, seed):
    rng = np.random.default_rng(seed)
    d = _sparse_dense(rng, mb * bm, kb * bk, bm, bk, sparsity)
    a = bcsr_from_dense(d, (bm, bk))
    assert np.allclose(np.asarray(bcsr_to_dense(a)), d)
    # structural invariants
    rows = np.asarray(a.block_rows)[: a.nnz_blocks]
    assert (np.diff(rows) >= 0).all()  # sorted by block row
    ptr = np.asarray(a.block_row_ptr)
    assert ptr[-1] == a.nnz_blocks


@settings(max_examples=15, deadline=None)
@given(
    wb=st.integers(1, 4), k=st.integers(8, 64),
    density=st.floats(0.05, 1.0), seed=st.integers(0, 100),
)
def test_property_wcsr_roundtrip(wb, k, density, seed):
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(wb * 16, k)).astype(np.float32)
    d *= rng.random(d.shape) < density
    w = wcsr_from_dense(d, b_row=16, b_col=8)
    assert np.allclose(np.asarray(wcsr_to_dense(w)), d)
    # every real packed column has a valid source column
    ci = np.asarray(w.col_idx)
    assert ((ci >= -1) & (ci < k)).all()


@settings(max_examples=10, deadline=None)
@given(
    mb=st.integers(1, 4), kb=st.integers(1, 4),
    bm=st.sampled_from([8, 16]), bk=st.sampled_from([8, 16]),
    sparsity=st.floats(0.0, 1.0), seed=st.integers(0, 100),
)
def test_property_convert_roundtrip_equals_masked_dense(mb, kb, bm, bk,
                                                        sparsity, seed):
    """convert(convert(x, fmt), "dense") recovers the block-masked dense
    exactly, for both formats (satellite: conversion-graph round-trip)."""
    rng = np.random.default_rng(seed)
    d0 = rng.normal(size=(mb * bm, kb * bk)).astype(np.float32)
    mask = random_block_mask(d0.shape, (bm, bk), sparsity, seed=seed,
                             ensure_row_nonempty=False)
    d = apply_block_mask(d0, mask, (bm, bk))
    for fmt, kw in (("bcsr", {"block": (bm, bk)}),
                    ("wcsr", {"block": (bm, 8)})):
        back = np.asarray(convert(convert(d, fmt, **kw), "dense"))
        assert np.array_equal(back, d), fmt


@settings(max_examples=10, deadline=None)
@given(
    mb=st.integers(1, 3), kb=st.integers(1, 3),
    sparsity=st.floats(0.0, 0.9), seed=st.integers(0, 100),
)
def test_property_bcsr_transpose_involution(mb, kb, sparsity, seed):
    rng = np.random.default_rng(seed)
    d = _sparse_dense(rng, mb * 16, kb * 16, 16, 16, sparsity)
    a = bcsr_from_dense(d, (16, 16))
    att = bcsr_transpose(bcsr_transpose(a))
    assert att.shape == a.shape and att.block == a.block
    assert np.array_equal(np.asarray(bcsr_to_dense(att)), d)


@settings(max_examples=10, deadline=None)
@given(
    wb=st.integers(1, 3), kb=st.integers(1, 3),
    density=st.floats(0.0, 0.6), seed=st.integers(0, 100),
)
def test_property_wcsr_transpose(wb, kb, density, seed):
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(wb * 16, kb * 16)).astype(np.float32)
    d *= rng.random(d.shape) < density
    w = wcsr_from_dense(d, b_row=16, b_col=8)
    wt = wcsr_transpose(w, b_row=16)
    assert np.array_equal(np.asarray(wcsr_to_dense(wt)), d.T)
    wtt = wcsr_transpose(wt, b_row=16)
    assert np.array_equal(np.asarray(wcsr_to_dense(wtt)), d)
