"""Per-kernel interpret-mode validation: shape/dtype/sparsity sweeps,
assert_allclose against the pure-jnp oracles (and the independent densify
oracle), plus hypothesis property tests (SpMM linearity)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse import (apply_block_mask, bcsr_from_dense,
                          random_block_mask, wcsr_from_dense)
from repro.kernels.bcsr.kernel import run_bcsr_spmm
from repro.kernels.bcsr.ref import bcsr_spmm_ref, bcsr_spmm_dense_ref
from repro.kernels.sddmm.ops import sddmm
from repro.kernels.sddmm.ref import sddmm_ref
from repro.kernels.wcsr.ops import wcsr_spmm
from repro.kernels.wcsr.ref import wcsr_spmm_ref, wcsr_spmm_dense_ref


def _mk(rng, m, k, bm, bk, sparsity, dtype):
    d = rng.normal(size=(m, k)).astype(dtype)
    mask = random_block_mask((m, k), (bm, bk), sparsity, seed=2)
    return apply_block_mask(d, mask, (bm, bk))


TOL = {np.float32: 2e-4, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 192, 96), (256, 128, 200)])
@pytest.mark.parametrize("block", [(32, 32), (64, 64)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
@pytest.mark.parametrize("dtype", ["f32", "bf16"])
def test_bcsr_kernel_sweep(rng, m, k, n, block, sparsity, dtype):
    dt = np.float32 if dtype == "f32" else jnp.bfloat16
    d = _mk(rng, m, k, block[0], block[1], sparsity, np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    a = bcsr_from_dense(d.astype(dt), block)
    bj = jnp.asarray(b).astype(dt)
    got = np.asarray(run_bcsr_spmm(a, bj, bn=64, out_dtype=jnp.float32))
    ref = np.asarray(bcsr_spmm_ref(a, bj, out_dtype=jnp.float32))
    oracle = np.asarray(bcsr_spmm_dense_ref(a, bj, out_dtype=jnp.float32))
    tol = TOL[dt] * max(1.0, np.abs(oracle).max())
    np.testing.assert_allclose(got, ref, atol=tol)
    np.testing.assert_allclose(got, oracle, atol=tol)


@pytest.mark.parametrize("m,k,n", [(64, 96, 64), (128, 200, 120)])
@pytest.mark.parametrize("b_row,b_col", [(32, 8), (64, 16)])
@pytest.mark.parametrize("density", [0.02, 0.3])
@pytest.mark.parametrize("chunks_per_task", [2, 8])
def test_wcsr_kernel_sweep(rng, m, k, n, b_row, b_col, density,
                           chunks_per_task):
    d = rng.normal(size=(m, k)).astype(np.float32)
    d *= rng.random(d.shape) < density
    w = wcsr_from_dense(d, b_row=b_row, b_col=b_col)
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    got = np.asarray(wcsr_spmm(w, b, impl="kernel_interpret", bn=64,
                               chunks_per_task=chunks_per_task))
    ref = np.asarray(wcsr_spmm_ref(w, b))
    oracle = np.asarray(wcsr_spmm_dense_ref(w, b))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))
    np.testing.assert_allclose(got, oracle,
                               atol=2e-4 * max(1, np.abs(oracle).max()))


@pytest.mark.parametrize("chunks_per_task", [2, 8])
def test_wcsr_pipelined_gather_matches(rng, chunks_per_task):
    """Q-deep gather pipeline instances == the serial depth=1 instance
    (the legacy shim's pipeline_gather bool still routes correctly)."""
    from repro.ops import spmm

    d = rng.normal(size=(96, 160)).astype(np.float32)
    d *= rng.random(d.shape) < 0.25
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    b = jnp.asarray(rng.normal(size=(160, 64)).astype(np.float32))
    sync = np.asarray(spmm(w, b, impl="kernel_interpret", bn=32,
                           chunks_per_task=chunks_per_task,
                           pipeline_depth=1))
    legacy = np.asarray(wcsr_spmm(w, b, impl="kernel_interpret", bn=32,
                                  chunks_per_task=chunks_per_task,
                                  pipeline_gather=True))
    np.testing.assert_allclose(legacy, sync, atol=1e-5)
    for depth in (2, 3):
        q = np.asarray(spmm(w, b, impl="kernel_interpret", bn=32,
                            chunks_per_task=chunks_per_task,
                            pipeline_depth=depth))
        np.testing.assert_allclose(q, sync, atol=1e-5)


@pytest.mark.parametrize("n", [8, 32, 100, 127])
def test_bcsr_small_n(rng, n):
    """n below the 128-lane width: the tile is the whole operand."""
    d = _mk(rng, 64, 64, 32, 32, 0.5, np.float32)
    a = bcsr_from_dense(d, (32, 32))
    b = jnp.asarray(rng.normal(size=(64, n)).astype(np.float32))
    got = np.asarray(run_bcsr_spmm(a, b, bn=512))
    ref = np.asarray(bcsr_spmm_ref(a, b))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


@pytest.mark.parametrize("n,bn", [(130, 512), (130, 64), (200, 64),
                                  (300, 128)])
def test_bcsr_non_lane_aligned_n(rng, n, bn):
    """n >= 128 but not a multiple of bn: clamp-then-pad must round-trip."""
    d = _mk(rng, 64, 64, 32, 32, 0.5, np.float32)
    a = bcsr_from_dense(d, (32, 32))
    b = jnp.asarray(rng.normal(size=(64, n)).astype(np.float32))
    got = np.asarray(run_bcsr_spmm(a, b, bn=bn))
    ref = np.asarray(bcsr_spmm_ref(a, b))
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


def test_wcsr_empty_matrix(rng):
    d = np.zeros((64, 64), np.float32)
    w = wcsr_from_dense(d, b_row=32, b_col=8)
    b = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    got = np.asarray(wcsr_spmm(w, b, impl="kernel_interpret", bn=32))
    assert np.allclose(got, 0)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 96, 160)])
@pytest.mark.parametrize("sparsity", [0.3, 0.8])
def test_sddmm_kernel_sweep(rng, m, k, n, sparsity):
    d = _mk(rng, m, k, 32, 32, sparsity, np.float32)
    a = bcsr_from_dense(d, (32, 32))
    dc = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    got = np.asarray(sddmm(dc, b, a, impl="kernel_interpret", bn=32))
    ref = np.asarray(sddmm_ref(dc, b, a))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), sparsity=st.floats(0.0, 0.95))
def test_property_bcsr_linearity(seed, sparsity):
    """SpMM is linear: A(x+y) = Ax + Ay and A(cx) = c Ax."""
    rng = np.random.default_rng(seed)
    d = _mk(rng, 64, 64, 32, 32, sparsity, np.float32)
    a = bcsr_from_dense(d, (32, 32))
    x = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    ax = np.asarray(run_bcsr_spmm(a, x, bn=32))
    ay = np.asarray(run_bcsr_spmm(a, y, bn=32))
    axy = np.asarray(run_bcsr_spmm(a, x + y, bn=32))
    np.testing.assert_allclose(axy, ax + ay, atol=1e-3)
    a3x = np.asarray(run_bcsr_spmm(a, 3.0 * x, bn=32))
    np.testing.assert_allclose(a3x, 3.0 * ax, atol=1e-3)


def test_block_attn_kernel(rng):
    from repro.kernels.block_attn.ops import block_sparse_attention
    from repro.kernels.block_attn.ref import block_sparse_attention_ref

    B, H, KVH, S, D = 2, 4, 2, 256, 32
    bq = bk = 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, KVH, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, KVH, S, D)).astype(np.float32))
    nb = S // bq
    mask = np.zeros((H, nb, nb), bool)
    for h in range(H):
        for i in range(nb):
            mask[h, i, max(0, i - 1 - h % 2): i + 1] = True
            mask[h, i, 0] = True
    got = np.asarray(block_sparse_attention(
        q, k, v, mask, block_q=bq, block_k=bk, impl="kernel_interpret"))
    ref = np.asarray(block_sparse_attention_ref(
        q, k, v, mask, block_q=bq, block_k=bk))
    np.testing.assert_allclose(got, ref, atol=2e-5)


def test_block_attn_matches_dense_when_full(rng):
    """Full block mask == dense causal attention."""
    from repro.kernels.block_attn.ops import block_sparse_attention
    B, H, S, D = 1, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    mask = np.tril(np.ones((H, S // 64, S // 64), bool))
    got = np.asarray(block_sparse_attention(
        q, k, v, mask, block_q=64, block_k=64, impl="kernel_interpret"))
    s = np.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(D)
    tri = np.tril(np.ones((S, S), bool))
    s = np.where(tri, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhqk,bhkd->bhqd", p, np.asarray(v))
    np.testing.assert_allclose(got, want, atol=2e-4)
