"""repro.parallel.collectives semantics on a forced multi-device host mesh
(subprocess: device count must be fixed before jax initializes). Covers
``hierarchical_psum`` on dividing and non-dividing leading dims (both must
equal the flat two-axis psum; the non-dividing case must be *counted* as a
fallback and warned about once), and the ``compressed_psum_int8_ef``
error-feedback contract: the running mean of repeated reductions converges
to the exact sum at the 1/T telescoping rate."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 4):
    src = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_hierarchical_psum_dividing_matches_flat_psum():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel.collectives import (collective_counters,
                                            hierarchical_psum,
                                            reset_collective_counters)

    mesh = jax.make_mesh((2, 2), ("outer", "inner"))
    xs = np.random.default_rng(0).normal(size=(4, 8, 3)).astype(np.float32)

    def hier(x):
        return hierarchical_psum(x[0], "inner", "outer")[None]

    def flat(x):
        return jax.lax.psum(x[0], ("inner", "outer"))[None]

    kw = dict(mesh=mesh, in_specs=P(("outer", "inner")),
              out_specs=P(("outer", "inner")), check_vma=False)
    reset_collective_counters()
    got = np.asarray(shard_map(hier, **kw)(jnp.asarray(xs)))
    want = np.asarray(shard_map(flat, **kw)(jnp.asarray(xs)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    c = collective_counters()
    assert c["hier_calls"] == 1 and c["hier_fallback"] == 0, c
    print("OK")
    """)


def test_hierarchical_psum_non_dividing_falls_back_counted():
    _run("""
    import warnings
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel.collectives import (collective_counters,
                                            hierarchical_psum,
                                            reset_collective_counters)
    import repro.ops as ops

    mesh = jax.make_mesh((2, 2), ("outer", "inner"))
    # leading dim 3 does not divide inner size 2 -> counted fallback
    xs = np.random.default_rng(1).normal(size=(4, 3, 5)).astype(np.float32)

    def hier(x):
        return hierarchical_psum(x[0], "inner", "outer")[None]

    def flat(x):
        return jax.lax.psum(x[0], ("inner", "outer"))[None]

    kw = dict(mesh=mesh, in_specs=P(("outer", "inner")),
              out_specs=P(("outer", "inner")), check_vma=False)
    reset_collective_counters()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = np.asarray(shard_map(hier, **kw)(jnp.asarray(xs)))
        # second trace: the warning is one-shot, the counter is not
        got2 = np.asarray(jax.jit(shard_map(hier, **kw))(jnp.asarray(xs)))
    want = np.asarray(shard_map(flat, **kw)(jnp.asarray(xs)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(got2, want, rtol=1e-6, atol=1e-6)
    hits = [w for w in rec if "hierarchical_psum" in str(w.message)]
    assert len(hits) == 1, [str(w.message) for w in rec]
    c = collective_counters()
    assert c["hier_calls"] == 2 and c["hier_fallback"] == 2, c
    # the tallies surface on the unified dashboard
    assert ops.cache_stats()["combine"]["hier_fallback"] == 2
    print("OK")
    """)


def test_compressed_psum_int8_ef_mean_converges():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel.collectives import compressed_psum_int8_ef

    mesh = jax.make_mesh((4,), ("data",))
    xs = np.random.default_rng(2).normal(size=(4, 16)).astype(np.float32)
    exact = xs.sum(0)

    def mean_of(T):
        def body(x):
            x = x[0]
            err = jnp.zeros_like(x)
            acc = jnp.zeros_like(x)
            for _ in range(T):
                red, err = compressed_psum_int8_ef(x, "data", err)
                acc = acc + red
            return (acc / T)[None]
        f = shard_map(body, mesh=mesh, in_specs=P("data"),
                      out_specs=P("data"), check_vma=False)
        return np.asarray(f(jnp.asarray(xs)))[0]

    e1 = np.abs(mean_of(1) - exact).max()
    e16 = np.abs(mean_of(16) - exact).max()
    # telescoping: sum_t red_t = T*exact - sum_d err_T, so the mean error
    # decays like |err_T|/T — bounded by the per-device quantization step
    ndev = 4
    step_bound = ndev * 1.2 * np.abs(xs).max() / 127.0
    assert e1 <= step_bound, (e1, step_bound)
    assert e16 <= step_bound / 8.0 + 1e-6, (e16, step_bound)
    assert e16 <= e1 / 2.0 + 1e-6, (e1, e16)
    print("OK")
    """)
