"""Serving runtime: paged KV allocator, chunked prefill, scheduler.

Equivalence contract (documented tolerances): the chunked block-sparse
prefill and the token-by-token legacy path compute the same math through
different reduction orders, so logits agree to fp32 rounding (~1e-6 here;
asserted at 1e-4) and greedy tokens agree exactly. Under a value codec the
prefill attention fake-quantizes gathered K/V while the tokenwise decode
path does not, so only a coarse logits tolerance + engine liveness is
asserted (the codec's own accuracy contract lives in test_codecs.py).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import PageAllocationError, PagedKVCache
from repro.serve.scheduler import WaitQueue, _percentile

KEY = jax.random.PRNGKey(7)
CFG = reduced_config(ARCHS["granite-3-2b"], num_layers=2)


@pytest.fixture(scope="module")
def model_params():
    m = build_model(CFG)
    return m, m.init(KEY)


def _reqs(rng, lengths, max_new=4, **kw):
    return [Request(rid=i, prompt=rng.integers(0, CFG.vocab_size, (n,)),
                    max_new_tokens=max_new, **kw)
            for i, n in enumerate(lengths)]


# -- chunked vs legacy ------------------------------------------------------


def test_chunked_matches_legacy_tokens(model_params, rng):
    """Same requests through the paged/chunked default and the legacy
    token-at-a-time path must produce identical greedy tokens; prompt
    lengths straddle chunk and page boundaries."""
    m, params = model_params
    mk = lambda legacy: ServeEngine(
        m, params, slots=2, max_len=48, page_size=8, chunk=8,
        prefill_block_q=4, legacy_prefill=legacy)
    a = _reqs(rng, (3, 10, 17))
    b = [Request(rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens)
         for r in a]
    paged, legacy = mk(False), mk(True)
    assert paged.stats()["mode"] == "paged"
    assert legacy.stats()["mode"] == "legacy"
    paged.run(a)
    legacy.run(b)
    for ra, rb in zip(a, b):
        assert ra.done and rb.done
        assert ra.out_tokens == rb.out_tokens, ra.rid


def test_chunked_prefill_logits_match_forward(model_params, rng):
    """Final-chunk logits equal the bulk forward oracle within fp32
    reordering tolerance (1e-4; observed ~1e-6) with equal argmax."""
    m, params = model_params
    eng = ServeEngine(m, params, slots=1, max_len=32, page_size=8, chunk=8,
                      prefill_block_q=4)
    toks = rng.integers(0, CFG.vocab_size, (12,))
    req = Request(rid=0, prompt=toks, max_new_tokens=1)
    eng.submit(req)
    # drive prefill chunks manually; capture the final chunk's logits
    eng.tick()  # admit + chunk 1 (no logits)
    cur = int(eng._prefill_cursor[0])
    with eng._scope():
        logits = eng.prefiller.run_chunk(
            params, eng.pool, eng.pages[0], cur, toks[cur:],
            with_logits=True)
    want, _ = m.forward(params, {"tokens": jnp.asarray(toks)[None]})
    want = np.asarray(want[0, cur:])
    assert np.max(np.abs(logits - want)) < 1e-4
    assert (logits.argmax(-1) == want.argmax(-1)).all()


def test_chunked_prefill_under_codec(model_params, rng):
    """With a value codec the prefill attention quantizes gathered K/V —
    logits drift from the exact path within a coarse documented tolerance
    and the engine still serves greedy tokens end to end."""
    from repro.ops import OpConfig

    m, params = model_params
    eng = ServeEngine(m, params, slots=1, max_len=32, page_size=8, chunk=16,
                      prefill_block_q=4, op_config=OpConfig(value_codec="int8"))
    toks = rng.integers(0, CFG.vocab_size, (12,))
    req = Request(rid=0, prompt=toks, max_new_tokens=3)
    eng.submit(req)
    eng.tick()  # single final chunk: first token emitted under the codec
    assert len(req.out_tokens) >= 1
    eng2 = ServeEngine(m, params, slots=1, max_len=32, page_size=8, chunk=16,
                       prefill_block_q=4,
                       op_config=OpConfig(value_codec="int8"))
    eng2.submit(Request(rid=0, prompt=toks, max_new_tokens=1))
    with eng2._scope():
        logits = eng2.prefiller.run_chunk(
            params, eng2.pool, eng2.pool.alloc(2), 0, toks, with_logits=True)
    want, _ = m.forward(params, {"tokens": jnp.asarray(toks)[None]})
    assert np.max(np.abs(logits - np.asarray(want[0]))) < 1.0  # documented
    eng.run([])  # drain the already-submitted request
    assert req.done and len(req.out_tokens) == 3


# -- scheduling / tick accounting ------------------------------------------


def test_tick_bound(model_params, rng):
    """A P-token prompt admits and completes in ceil(P/chunk) + new + O(1)
    ticks — the acceptance bound (not P ticks)."""
    m, params = model_params
    eng = ServeEngine(m, params, slots=1, max_len=64, page_size=8, chunk=8,
                      prefill_block_q=4)
    P, new = 33, 5
    req = _reqs(rng, (P,), max_new=new)[0]
    eng.run([req])
    assert req.done and len(req.out_tokens) == new
    assert eng.ticks <= -(-P // 8) + new + 2, eng.ticks


def test_queue_when_full_and_priority(model_params, rng):
    """More requests than slots queue (never drop); within the queue,
    lower priority value is admitted first."""
    m, params = model_params
    eng = ServeEngine(m, params, slots=1, max_len=32, page_size=8, chunk=8,
                      prefill_block_q=4)
    reqs = _reqs(rng, (3, 4, 5), max_new=2)
    reqs[0].priority = 5  # submitted first, served last
    for r in reqs:
        eng.submit(r)
    assert eng.stats()["queue_depth"] == 3
    eng.tick()
    # priority 0 beats the earlier-submitted priority 5
    assert reqs[1].out_tokens and reqs[0].out_tokens is None
    assert eng.stats()["queue_depth"] == 2
    eng.run([])
    assert all(r.done for r in reqs)
    assert [len(r.out_tokens) for r in reqs] == [2, 2, 2]
    rec = eng.telemetry.records
    assert rec[0].admit_tick > max(rec[1].admit_tick, rec[2].admit_tick)


def test_too_long_prompt_rejected(model_params, rng):
    m, params = model_params
    eng = ServeEngine(m, params, slots=1, max_len=16, page_size=8,
                      num_pages=2, chunk=8, prefill_block_q=4)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(_reqs(rng, (16,))[0])
    eng2 = ServeEngine(m, params, slots=1, max_len=64, page_size=8,
                       num_pages=2, chunk=8, prefill_block_q=4)
    with pytest.raises(ValueError, match="pages"):
        eng2.submit(_reqs(rng, (20,))[0])  # 3 pages > pool of 2


def test_decode_growth_allocates_and_frees(model_params, rng):
    """Decode past the prompt's last page allocates pages one at a time;
    completion returns everything to the pool."""
    m, params = model_params
    eng = ServeEngine(m, params, slots=1, max_len=32, page_size=4, chunk=4,
                      prefill_block_q=4)
    req = _reqs(rng, (3,), max_new=8)[0]
    eng.submit(req)
    eng.tick()  # admit + prefill (1 page)
    assert eng.pool.used_pages == 1
    peak = 0
    while not req.done:
        eng.tick()
        peak = max(peak, eng.pool.used_pages)
    assert peak == 3  # positions 0..10 span 3 pages of 4
    assert eng.pool.used_pages == 0
    assert eng.pool.free_pages == eng.pool.num_pages


# -- staleness / allocator --------------------------------------------------


def test_recycled_pages_no_stale_kv(model_params, rng):
    """Same prompt before and after other traffic through the same single
    slot must produce identical tokens, and freed pages must be masked
    (pos = -1) and zeroed so nothing can attend to them."""
    m, params = model_params
    eng = ServeEngine(m, params, slots=1, max_len=32, page_size=8, chunk=8,
                      prefill_block_q=4)
    prompt = rng.integers(0, CFG.vocab_size, (10,))
    r1 = Request(rid=0, prompt=prompt, max_new_tokens=3)
    r2 = Request(rid=1, prompt=rng.integers(0, CFG.vocab_size, (12,)),
                 max_new_tokens=3)
    r3 = Request(rid=2, prompt=prompt, max_new_tokens=3)
    eng.run([r1, r2, r3])
    assert r1.out_tokens == r3.out_tokens
    assert bool((np.asarray(eng.pool.pos) == -1).all())
    # every real page is zeroed on free; the null page (a write sink for
    # masked rows) may hold garbage but its pos stays -1 forever
    assert not np.asarray(eng.pool.k[:, :eng.pool.num_pages]).any()


def test_paged_allocator_free_realloc(rng):
    pool = PagedKVCache(CFG, num_pages=4, page_size=8)
    a = pool.alloc(3)
    assert pool.used_pages == 3
    with pytest.raises(PageAllocationError):
        pool.alloc(2)
    # dirty a page, free it, and check mask + zeroing
    pool.pos = pool.pos.at[a[0]].set(7)
    pool.k = pool.k.at[:, a[0]].set(1.0)
    pool.free(a[:2])
    assert pool.used_pages == 1
    assert bool((np.asarray(pool.pos[a[0]]) == -1).all())
    assert not np.asarray(pool.k[:, a[0]]).any()
    with pytest.raises(ValueError, match="double free"):
        pool.free([a[0]])
    b = pool.alloc(3)  # freed ids come back
    assert set(a[:2]) <= set(b)
    tab = pool.table([[b[0]], []], width=2)
    assert tab.shape == (2, 2)
    assert int(tab[0, 1]) == pool.null_page and int(tab[1, 0]) == pool.null_page


def test_wait_queue_and_percentiles():
    q = WaitQueue()
    q.push("lo", 5)
    q.push("hi", 0)
    q.push("hi2", 0)
    assert len(q) == 3
    assert q.pop() == "hi" and q.pop() == "hi2" and q.pop() == "lo"
    assert _percentile([], 50) != _percentile([], 50)  # NaN
    assert _percentile([3.0], 95) == 3.0
    assert _percentile([1, 2, 3, 4], 50) == 2.5


# -- legacy path regression -------------------------------------------------


def test_legacy_prefill_masks_other_slots(model_params, rng):
    """Prefilling one slot must leave every other active slot's cache rows
    bitwise untouched (the historical pool-wide rewrite bug)."""
    m, params = model_params
    eng = ServeEngine(m, params, slots=2, max_len=32, legacy_prefill=True)
    assert not eng.paged
    r1 = _reqs(rng, (4,), max_new=8)[0]
    assert eng.try_admit(r1)  # slot 0 mid-flight
    before = jax.tree.map(lambda t: np.asarray(t[:, 0]).copy(),
                          (eng.cache.kv.k, eng.cache.kv.v, eng.cache.kv.pos))
    r2 = Request(rid=1, prompt=rng.integers(0, CFG.vocab_size, (6,)),
                 max_new_tokens=8)
    assert eng.try_admit(r2)  # prefill slot 1 while slot 0 is active
    after = jax.tree.map(lambda t: np.asarray(t[:, 0]),
                         (eng.cache.kv.k, eng.cache.kv.v, eng.cache.kv.pos))
    for x, y in zip(before, after):
        assert np.array_equal(x, y)
    eng.run([])  # both slots drain to completion
    assert r1.done and r2.done


def test_stats_serving_fields(model_params, rng):
    m, params = model_params
    eng = ServeEngine(m, params, slots=2, max_len=32, page_size=8, chunk=8,
                      prefill_block_q=4)
    reqs = _reqs(rng, (6, 9), max_new=3)
    eng.run(reqs)
    s = eng.stats()
    assert s["mode"] == "paged"
    assert s["queue_depth"] == 0
    assert s["page_utilization"] == 0.0  # all freed on completion
    assert s["pages"]["num_pages"] == eng.pool.num_pages
    assert s["prefill_tokens"] == 6 + 9
    assert s["decode_tokens"] >= 2 * 2  # (max_new - 1) per request
    assert np.isfinite(s["ttft"]["p50_ticks"])
    assert np.isfinite(s["ttft"]["p95_s"])
    assert s["ticks"] == eng.ticks > 0


# -- rectangular kernel entry ----------------------------------------------


def test_rectangular_attention_q_offset(rng):
    """The prefill-chunk kernel entry: a chunk of q rows at q_offset
    against a longer K/V prefix equals the matching rows of the square
    computation, for the ref backend, the interpreted kernel, and the
    traced-CSR (tuple-mask) form."""
    from repro import ops
    from repro.ops.attention import csr_encode_block_mask

    b, h, skv, d, bq, bk = 1, 2, 32, 16, 8, 8
    sq, off = 8, 10
    q = jnp.asarray(rng.normal(size=(b, h, skv, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, 1, skv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, 1, skv, d)).astype(np.float32))
    full = np.ones((h, skv // bq, skv // bk), bool)
    want = np.asarray(ops.sparse_attention(
        q, k, v, full, block_q=bq, block_k=bk, causal=True,
        impl="ref"))[:, :, off:off + sq]
    rect_mask = np.ones((h, sq // bq, skv // bk), bool)
    qc = q[:, :, off:off + sq]
    for impl in ("ref", "kernel_interpret"):
        got = np.asarray(ops.sparse_attention(
            qc, k, v, rect_mask, block_q=bq, block_k=bk, causal=True,
            impl=impl, q_offset=off))
        assert np.max(np.abs(got - want)) < 1e-5, impl
    ptr, kcols, _ = csr_encode_block_mask(rect_mask)
    got = np.asarray(ops.sparse_attention(
        qc, k, v, (jnp.asarray(ptr), jnp.asarray(kcols)), block_q=bq,
        block_k=bk, causal=True, impl="kernel_interpret",
        q_offset=jnp.int32(off), pad_active_to=skv // bk))
    assert np.max(np.abs(got - want)) < 1e-5
