"""Skinny-N fast path: the ``spmv`` op family and its ``spmm`` dispatch.

The contract under test: for a decode-shaped RHS the GEMV kernel family
(``wcsr_spmv_kernel`` / ``bcsr_spmv_kernel``, reached via ``repro.ops.spmv``
or ``spmm`` auto-dispatch at ``n_cols <= spmv_threshold``) is numerically
interchangeable with the full-tile SpMM path — across formats, value codecs
and pipeline depths — while being a *different* compiled dataflow (row-split
multiply-accumulate, B VMEM-resident). Dispatch decisions are observable in
``cache_stats()["spmv"]``, the resolved route is part of the ``Plan`` cache
key, and a structure-delta edit patches the spmv plan instead of re-planning.
"""

import numpy as np
import jax.numpy as jnp
import pytest

import repro.ops as ops
from repro.ops import (DEFAULT_SPMV_THRESHOLD, cache_stats, clear_plan_cache,
                       clear_tuning_cache, make_plan, resolve_spmv_route,
                       spmm, spmv, spmv_dispatch_info, use_config)
from repro.sparse import SparseTensor, registered_value_codecs

M = K = 64
WBLOCK = (16, 8)
BBLOCK = (16, 16)
CODECS = tuple(c for c in ("none", "int8", "fp8_e4m3")
               if c == "none" or c in registered_value_codecs())
# spmv vs the f32 reference: same budgets the codec suite documents
TOL = {"none": 1e-5, "int8": 0.05, "fp8_e4m3": 0.12}


def _rel(got, ref):
    return float(np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-12))


def _tensor(rng, fmt, density=0.4):
    d = rng.normal(size=(M, K)).astype(np.float32)
    d *= rng.random(d.shape) < density
    block = WBLOCK if fmt == "wcsr" else BBLOCK
    return SparseTensor.from_dense(d, fmt, block=block), d


# ---------------------------------------------------------------------------
# Equivalence: spmv == spmm across formats x codecs x depths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("fmt", ["wcsr", "bcsr"])
def test_spmv_matches_spmm(fmt, codec, depth, rng):
    st, d = _tensor(rng, fmt)
    if codec != "none":
        st = st.quantize(codec)
    b = jnp.asarray(rng.normal(size=(K, 1)).astype(np.float32))
    ref = d @ np.asarray(b)
    with use_config(impl="kernel_interpret", pipeline_depth=depth):
        got = np.asarray(spmv(st, b))
        full = np.asarray(spmm(st, b, spmv_threshold=0))  # full-tile path
    assert _rel(got, ref) <= TOL[codec], (fmt, codec, depth)
    # both kernel families dequantize the same payload: they agree far
    # tighter than either agrees with the f32 oracle
    assert _rel(got, full) <= 1e-5, (fmt, codec, depth)


@pytest.mark.parametrize("fmt", ["wcsr", "bcsr"])
def test_spmv_vector_and_matrix_forms(fmt, rng):
    st, d = _tensor(rng, fmt)
    v = jnp.asarray(rng.normal(size=(K,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(K, 3)).astype(np.float32))
    with use_config(impl="kernel_interpret"):
        y = np.asarray(spmv(st, v))
        c = np.asarray(spmv(st, b))
    assert y.shape == (M,)
    assert _rel(y, d @ np.asarray(v)) <= 1e-5
    assert c.shape == (M, 3)
    assert _rel(c, d @ np.asarray(b)) <= 1e-5


# ---------------------------------------------------------------------------
# Edge cases: empty rows, single stored block
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["wcsr", "bcsr"])
def test_spmv_empty_rows(fmt, rng):
    _, d = _tensor(rng, fmt)
    d[16:32, :] = 0.0  # one whole block-row / window stores nothing
    block = WBLOCK if fmt == "wcsr" else BBLOCK
    st = SparseTensor.from_dense(d, fmt, block=block)
    b = jnp.asarray(rng.normal(size=(K, 1)).astype(np.float32))
    with use_config(impl="kernel_interpret"):
        got = np.asarray(spmv(st, b))
    assert np.all(got[16:32] == 0.0)
    assert _rel(got, d @ np.asarray(b)) <= 1e-5


@pytest.mark.parametrize("fmt", ["wcsr", "bcsr"])
def test_spmv_single_block(fmt, rng):
    d = np.zeros((M, K), np.float32)
    d[:16, :8] = rng.normal(size=(16, 8)).astype(np.float32)
    block = WBLOCK if fmt == "wcsr" else BBLOCK
    st = SparseTensor.from_dense(d, fmt, block=block)
    b = jnp.asarray(rng.normal(size=(K, 1)).astype(np.float32))
    with use_config(impl="kernel_interpret"):
        got = np.asarray(spmv(st, b))
    assert _rel(got, d @ np.asarray(b)) <= 1e-5


# ---------------------------------------------------------------------------
# Dispatch: threshold resolution + counters
# ---------------------------------------------------------------------------


def test_spmm_auto_dispatches_skinny_rhs(rng):
    st, d = _tensor(rng, "wcsr")
    clear_plan_cache()
    clear_tuning_cache()
    assert spmv_dispatch_info() == {"dispatched": 0, "full_tile": 0}
    with use_config(impl="kernel_interpret"):
        # N=1 <= DEFAULT_SPMV_THRESHOLD: rides the GEMV family
        b1 = jnp.asarray(rng.normal(size=(K, 1)).astype(np.float32))
        got = np.asarray(spmm(st, b1))
    assert spmv_dispatch_info()["dispatched"] == 1
    assert _rel(got, d @ np.asarray(b1)) <= 1e-5
    with use_config(impl="kernel_interpret"):
        # wide N stays on the tile kernels
        bw = jnp.asarray(rng.normal(size=(K, 128)).astype(np.float32))
        spmm(st, bw)
        # an explicit 0 threshold disables the fast path even at N=1
        spmm(st, b1, spmv_threshold=0)
    info = spmv_dispatch_info()
    assert info == {"dispatched": 1, "full_tile": 2}
    # explicit int threshold pins the crossover above the default
    assert DEFAULT_SPMV_THRESHOLD < 8
    b8 = jnp.asarray(rng.normal(size=(K, 8)).astype(np.float32))
    with use_config(impl="kernel_interpret"):
        got8 = np.asarray(spmm(st, b8, spmv_threshold=8))
    assert spmv_dispatch_info()["dispatched"] == 2
    assert _rel(got8, d @ np.asarray(b8)) <= 1e-5
    # the counters surface through the unified aggregator
    assert cache_stats()["spmv"] == spmv_dispatch_info()


def test_autotuned_route_steers_auto_threshold(rng):
    st, _ = _tensor(rng, "wcsr")
    clear_plan_cache()
    clear_tuning_cache()
    b = jnp.asarray(rng.normal(size=(K, 1)).astype(np.float32))
    w = ops.autotune_spmm(st, b, impl="kernel_interpret", codecs=("none",),
                          warmup=0, iters=1, use_db=False)
    assert w["route"] in ("spmm", "spmv")
    # "auto" now resolves to the measured route for this exact problem
    got = resolve_spmv_route("auto", 1, op="spmm", fmt="wcsr",
                             shape=st.shape, block=st.block, dtype=st.dtype,
                             count=False)
    assert got == w["route"]


def test_route_is_plan_cache_keyed(rng):
    st, _ = _tensor(rng, "wcsr")
    clear_plan_cache()
    p_mm = make_plan(st.structure, 1, dtype=st.dtype, route="spmm")
    p_mv = make_plan(st.structure, 1, dtype=st.dtype, route="spmv")
    assert p_mm is not p_mv and p_mm.route == "spmm" and p_mv.route == "spmv"
    assert ops.plan_cache_info().misses == 2
    # both routes hit their own entry on re-lookup
    assert make_plan(st.structure, 1, dtype=st.dtype, route="spmv") is p_mv
    assert ops.plan_cache_info().hits == 1


# ---------------------------------------------------------------------------
# Dynamic structure: a delta edit patches the spmv plan, no re-plan
# ---------------------------------------------------------------------------


def test_structure_delta_patches_spmv_plan(rng):
    st, d = _tensor(rng, "wcsr", density=0.04)
    clear_plan_cache()
    clear_tuning_cache()
    b = jnp.asarray(rng.normal(size=(K, 1)).astype(np.float32))
    with use_config(impl="kernel_interpret"):
        spmv(st, b)  # plans (and caches) the spmv route for the base
    before = cache_stats()["plan"]
    # grow one window by a chunk (at a column it doesn't store yet)
    g = st.structure
    p0, p1 = int(g.ptrs[0]), int(g.ptrs[1])
    stored = {int(c) for c in g.indices[0][p0:p1] if int(c) >= 0}
    w, cols = 0, [next(c for c in range(K) if c not in stored)]
    vals = rng.normal(size=(WBLOCK[0], 1)).astype(np.float32)
    grown = st.append_window_chunks(w, cols, vals)
    d2 = d.copy()
    d2[:WBLOCK[0], cols] = vals
    with use_config(impl="kernel_interpret"):
        got = np.asarray(spmv(grown, b))
    after = cache_stats()["plan"]
    assert after["patched"] == before["patched"] + 1
    assert after["misses"] == before["misses"]  # no full re-plan
    assert _rel(got, d2 @ np.asarray(b)) <= 1e-5
