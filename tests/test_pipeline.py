"""The unified §III-A async pipeline engine: depth-swept interpret-mode
equivalence for every kernel with an indirect operand, knob promotion
through OpConfig / make_plan / the plan cache, extras validation, the
pipeline_gather deprecation path, and the measured auto-tuner."""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

import repro.ops as ops
from repro.kernels.pipeline import MAX_DEPTH, validate_depth
from repro.ops import OpConfig, make_plan, use_config
from repro.sparse import (SparseTensor, apply_block_mask, bcsr_from_dense,
                          random_block_mask, wcsr_from_dense)

DEPTHS = (1, 2, 3)


def _wcsr(rng, m, k, density, b_row=32, b_col=8):
    d = rng.normal(size=(m, k)).astype(np.float32)
    d *= rng.random(d.shape) < density
    return wcsr_from_dense(d, b_row=b_row, b_col=b_col)


# ---------------------------------------------------------------------------
# depth-swept equivalence vs the jnp references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("density,chunks_per_task", [
    (0.25, 4),   # multi-task windows
    (0.02, 8),   # single-chunk windows: nchunks (1) < depth (2, 3)
])
def test_wcsr_depth_matches_ref(rng, depth, density, chunks_per_task):
    w = _wcsr(rng, 96, 160, density)
    b = jnp.asarray(rng.normal(size=(160, 64)).astype(np.float32))
    ref = np.asarray(ops.spmm(w, b, impl="ref"))
    got = np.asarray(ops.spmm(w, b, impl="kernel_interpret", bn=32,
                              chunks_per_task=chunks_per_task,
                              pipeline_depth=depth))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


@pytest.mark.parametrize("depth", DEPTHS)
def test_wcsr_empty_matrix_all_depths(rng, depth):
    w = wcsr_from_dense(np.zeros((64, 64), np.float32), b_row=32, b_col=8)
    b = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    got = np.asarray(ops.spmm(w, b, impl="kernel_interpret", bn=32,
                              pipeline_depth=depth))
    assert np.allclose(got, 0)


def test_wcsr_all_depths_bitwise_equal(rng):
    """f32 accumulation order is depth-invariant: identical results."""
    w = _wcsr(rng, 64, 96, 0.3)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    outs = [np.asarray(ops.spmm(w, b, impl="kernel_interpret", bn=32,
                                pipeline_depth=q)) for q in DEPTHS]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


@pytest.mark.parametrize("depth", (0,) + DEPTHS)
def test_sddmm_depth_matches_ref(rng, depth):
    d = apply_block_mask(
        rng.normal(size=(64, 96)).astype(np.float32),
        random_block_mask((64, 96), (32, 32), 0.5, seed=2), (32, 32))
    a = bcsr_from_dense(d, (32, 32))
    dc = jnp.asarray(rng.normal(size=(64, 80)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(96, 80)).astype(np.float32))
    ref = np.asarray(ops.sddmm(dc, b, a, impl="ref"))
    got = np.asarray(ops.sddmm(dc, b, a, impl="kernel_interpret", bn=16,
                               pipeline_depth=depth))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


def test_sddmm_single_tile_below_depth(rng):
    """One n-tile (nchunks=1) is fewer chunks than any depth >= 2."""
    d = rng.normal(size=(64, 64)).astype(np.float32)
    a = bcsr_from_dense(d, (32, 32))
    dc = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    ref = np.asarray(ops.sddmm(dc, b, a, impl="ref"))
    for depth in DEPTHS:
        got = np.asarray(ops.sddmm(dc, b, a, impl="kernel_interpret", bn=32,
                                   pipeline_depth=depth))
        np.testing.assert_allclose(got, ref,
                                   atol=2e-4 * max(1, np.abs(ref).max()))


@pytest.mark.parametrize("depth", (0,) + DEPTHS)
def test_block_attn_depth_matches_ref(rng, depth):
    from repro.kernels.block_attn.ref import block_sparse_attention_ref

    B, H, KVH, S, D = 2, 4, 2, 256, 32
    bq = bk = 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, KVH, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, KVH, S, D)).astype(np.float32))
    nb = S // bq
    mask = np.zeros((H, nb, nb), bool)
    for h in range(H):
        for i in range(nb):
            mask[h, i, max(0, i - 1 - h % 2): i + 1] = True
            mask[h, i, 0] = True
    mask[0, 0, :] = False  # an empty-window q-block (count == 0 < depth)
    ref = np.asarray(block_sparse_attention_ref(
        q, k, v, mask, block_q=bq, block_k=bk))
    got = np.asarray(ops.sparse_attention(
        q, k, v, mask, block_q=bq, block_k=bk, impl="kernel_interpret",
        pipeline_depth=depth))
    np.testing.assert_allclose(got, ref, atol=2e-5)


# ---------------------------------------------------------------------------
# knob promotion: OpConfig -> make_plan -> kernel, cache keyed on depth
# ---------------------------------------------------------------------------


def test_depth_validation():
    assert validate_depth(1) == 1
    assert validate_depth(0, allow_zero=True) == 0
    for bad in (0, -1, MAX_DEPTH + 1):
        with pytest.raises(ValueError):
            validate_depth(bad)


def test_use_config_pipeline_depth_reaches_kernel(rng):
    w = _wcsr(rng, 64, 96, 0.3)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    ref = np.asarray(ops.spmm(w, b, impl="ref"))
    with use_config(impl="kernel_interpret", bn=32, pipeline_depth=3):
        got = np.asarray(ops.spmm(w, b))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


def test_plan_carries_depth_and_keys_cache(rng):
    ops.clear_plan_cache()
    st = SparseTensor.from_dense(
        np.asarray(_wcsr_dense(rng)), format="wcsr", b_row=32, b_col=8)
    p2 = make_plan(st, 64, OpConfig(bn=32, pipeline_depth=2))
    p3 = make_plan(st, 64, OpConfig(bn=32, pipeline_depth=3))
    assert p2.pipeline_depth == 2 and p3.pipeline_depth == 3
    assert p2 is not p3  # distinct cache entries per depth
    assert make_plan(st, 64, OpConfig(bn=32, pipeline_depth=2)) is p2
    info = ops.plan_cache_info()
    assert info.misses >= 2 and info.hits >= 1
    # the task decomposition is depth-independent: shared across depths
    assert p2.tasks is p3.tasks


def _wcsr_dense(rng):
    d = rng.normal(size=(64, 96)).astype(np.float32)
    d *= rng.random(d.shape) < 0.3
    return d


def test_bcsr_plan_has_no_depth(rng):
    st = SparseTensor.from_dense(
        apply_block_mask(rng.normal(size=(64, 64)).astype(np.float32),
                         random_block_mask((64, 64), (32, 32), 0.5, seed=3),
                         (32, 32)),
        format="bcsr", block=(32, 32))
    assert make_plan(st, 64, OpConfig(bn=32)).pipeline_depth is None


def test_depth_counters_reported(rng):
    w = _wcsr(rng, 64, 96, 0.3)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    before = ops.tuning_cache_info().pipeline_depths.get(3, 0)
    ops.spmm(w, b, impl="kernel_interpret", bn=32, pipeline_depth=3)
    after = ops.tuning_cache_info().pipeline_depths.get(3, 0)
    assert after == before + 1


# ---------------------------------------------------------------------------
# extras validation + deprecation path
# ---------------------------------------------------------------------------


def test_spmm_rejects_unknown_extras(rng):
    w = _wcsr(rng, 64, 96, 0.3)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    with pytest.raises(TypeError, match="pipline_gather"):
        ops.spmm(w, b, impl="kernel_interpret", pipline_gather=True)
    with pytest.raises(TypeError, match="no_such_knob"):
        ops.spmm(w, b, impl="ref", no_such_knob=1)


def test_legacy_shim_inherits_ambient_depth(rng):
    """wcsr_spmm(a, b) without pipeline_gather must not pin depth 1: an
    ambient use_config(pipeline_depth=...) scope reaches legacy callers."""
    import warnings as w

    from repro.kernels.wcsr.ops import wcsr_spmm

    wm = _wcsr(rng, 64, 96, 0.3)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    before = ops.tuning_cache_info().pipeline_depths.get(3, 0)
    with w.catch_warnings():
        w.simplefilter("ignore", DeprecationWarning)
        with use_config(pipeline_depth=3):
            wcsr_spmm(wm, b, impl="kernel_interpret", bn=32)
    assert ops.tuning_cache_info().pipeline_depths.get(3, 0) == before + 1


def test_pipeline_gather_deprecated_maps_to_depth(rng):
    w = _wcsr(rng, 64, 96, 0.3)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    ref = np.asarray(ops.spmm(w, b, impl="ref"))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = np.asarray(ops.spmm(w, b, impl="kernel_interpret", bn=32,
                                  pipeline_gather=True))
    assert any(issubclass(r.category, DeprecationWarning)
               and "pipeline_depth" in str(r.message) for r in rec)
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


# ---------------------------------------------------------------------------
# measured auto-tune over (bn, chunks_per_task, pipeline_depth)
# ---------------------------------------------------------------------------


def test_autotune_selects_and_steers_auto(rng):
    w = _wcsr(rng, 64, 96, 0.3)
    st = SparseTensor.wrap(w)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    ops.clear_tuning_cache()
    best = ops.autotune_spmm(st, b, impl="kernel_interpret",
                             bns=(32,), chunks_per_task=(4,),
                             depths=(1, 2), warmup=0, iters=1)
    assert best["pipeline_depth"] in (1, 2)
    assert best["bn"] == 32 and best["chunks_per_task"] == 4
    info = ops.tuning_cache_info()
    assert info.autotuned == 1
    # the tuner's own probing must not pollute the selection counters
    assert info.pipeline_depths == {}
    # an "auto" plan adopts every tuned knob, and the adoption is counted.
    # The ambient config (what a real spmm call resolves) must adopt the
    # tuned chunks_per_task too — its package default is deliberately not
    # a concrete 8.
    plan = make_plan(st, 64, ops.current_config())
    assert plan.bn == 32
    assert plan.chunks_per_task == 4
    assert plan.pipeline_depth == best["pipeline_depth"]
    assert ops.tuning_cache_info().pipeline_depths == {
        best["pipeline_depth"]: 1}
    # ...and still computes the right answer end-to-end
    ref = np.asarray(ops.spmm(w, b, impl="ref"))
    got = np.asarray(ops.spmm(st, b, impl="kernel_interpret"))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))
    ops.clear_tuning_cache()


def test_depth_zero_on_wcsr_degrades_to_serial(rng):
    """pipeline_depth=0 means 'no explicit pipeline'; WCSR has no Mosaic
    path for its gather, so an engine-wide 0 must run the serial gather
    (and be counted as depth 1), not fail inside the kernel."""
    w = _wcsr(rng, 64, 96, 0.3)
    b = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    ref = np.asarray(ops.spmm(w, b, impl="ref"))
    with use_config(pipeline_depth=0):
        got = np.asarray(ops.spmm(w, b, impl="kernel_interpret", bn=32))
    np.testing.assert_allclose(got, ref, atol=2e-4 * max(1, np.abs(ref).max()))


def test_extras_accept_positional_default_knobs(rng):
    """Externally registered backends may declare knobs as plain defaults
    (not keyword-only); validation must accept those."""
    from repro.ops.spmm import _validate_extras
    from repro.ops.registry import Backend

    def fn(a, b, cfg, myknob=True, *, kwonly=None):
        return None

    backend = Backend("ext", fn, lambda: True, 0)
    _validate_extras(backend, {"myknob": False, "kwonly": 1})  # no raise
    with pytest.raises(TypeError, match="mybnob"):
        _validate_extras(backend, {"mybnob": False})


def test_autotune_bcsr_sweeps_bn_only(rng):
    d = apply_block_mask(rng.normal(size=(64, 64)).astype(np.float32),
                         random_block_mask((64, 64), (32, 32), 0.5, seed=4),
                         (32, 32))
    st = SparseTensor.from_dense(d, format="bcsr", block=(32, 32))
    b = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    ops.clear_tuning_cache()
    best = ops.autotune_spmm(st, b, impl="kernel_interpret", bns=(32, 64),
                             warmup=0, iters=1)
    assert best["pipeline_depth"] is None  # Mosaic-managed: bn only
    assert best["bn"] in (32, 64)
    ops.clear_tuning_cache()
