"""MInference-lite pattern selection properties + tuning policy."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.sparse_attention import (
    block_topk_mask, causal_block_mask, local_sink_mask, mask_density,
    profile_block_scores, select_patterns, vertical_slash_mask,
)
from repro.kernels.tuning import padding_waste, select_bn, vmem_usage


def test_local_sink_mask_shape():
    m = local_sink_mask(8, 8, window_blocks=2, sink_blocks=1)
    assert m[7, 7] and m[7, 6] and not m[7, 4]
    assert m[7, 0]  # sink
    assert not m[0, 5]  # causal


def test_pattern_recall_monotone(rng):
    q = rng.normal(size=(1, 2, 256, 16)).astype(np.float32)
    k = rng.normal(size=(1, 2, 256, 16)).astype(np.float32)
    bs = profile_block_scores(jnp.asarray(q), jnp.asarray(k), block=32)
    m_small, ch_small = select_patterns(bs, budget=0.2)
    m_big, ch_big = select_patterns(bs, budget=0.6)
    for cs, cb in zip(ch_small, ch_big):
        assert cb.recall >= cs.recall - 0.05


def test_selected_masks_are_causal(rng):
    q = rng.normal(size=(1, 2, 128, 16)).astype(np.float32)
    k = rng.normal(size=(1, 2, 128, 16)).astype(np.float32)
    bs = profile_block_scores(jnp.asarray(q), jnp.asarray(k), block=32)
    masks, _ = select_patterns(bs, budget=0.5)
    causal = causal_block_mask(4, 4)
    assert not np.logical_and(masks, ~causal[None]).any()
    # diagonal always kept (local information never dropped)
    for h in range(masks.shape[0]):
        assert np.diagonal(masks[h]).all()


def test_sink_head_prefers_sink_pattern(rng):
    """A head with strong attention-sink structure should keep column 0."""
    q = rng.normal(size=(2, 1, 256, 16)).astype(np.float32)
    k = rng.normal(size=(2, 1, 256, 16)).astype(np.float32)
    k[:, 0, :32] += 3.0  # massive sink at the first block
    bs = profile_block_scores(jnp.asarray(q), jnp.asarray(k), block=32)
    masks, choices = select_patterns(bs, budget=0.3)
    assert masks[0][:, 0].all()


def test_select_bn_policy():
    assert select_bn(1024) == 1024  # largest divisor wins
    assert select_bn(512) == 512
    assert 18944 // 2 % select_bn(18944 // 2) == 0
    assert padding_waste(1024, 512) == 0.0
    assert padding_waste(1000, 512) > 0.0
    # VMEM ceiling respected
    assert vmem_usage(128, 128, select_bn(4096)) <= 16 * 1024 * 1024
