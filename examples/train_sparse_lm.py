"""Train a small LM with a block-sparse FFN end-to-end on synthetic data,
with checkpointing + restart (kill it mid-run and re-launch: it resumes).

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [steps]
"""

import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced_config
from repro.data.synthetic import SyntheticLM
from repro.models.registry import build_model
from repro.train.trainer import Trainer, TrainerConfig

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 60

cfg = reduced_config(
    ARCHS["qwen2.5-7b"], num_layers=2, d_model=128, d_ff=256,
    vocab_size=512, ffn_sparsity=0.5, sparse_block=(32, 32))
model = build_model(cfg)
data = SyntheticLM(cfg.vocab_size, seed=0)


def batch_fn(step):
    nb = data.batch(step, 16, 64)
    return {k: jnp.asarray(v) for k, v in nb.items()}


tcfg = TrainerConfig(total_steps=steps, ckpt_every=20,
                     ckpt_dir="/tmp/repro_train_sparse_lm", peak_lr=3e-3,
                     warmup=10)
trainer = Trainer(model, tcfg)
state, start = trainer.init_or_restore(jax.random.PRNGKey(0))
print(f"starting at step {start} "
      f"({'resumed from checkpoint' if start else 'fresh'})")


def on_step(step, metrics):
    if step % 10 == 0:
        print(f"  step {step:4d} loss={float(metrics['loss']):.4f} "
              f"lr={float(metrics['lr']):.2e}")


state = trainer.run(state, batch_fn, start_step=start, on_step=on_step)
first = trainer.history[0]["loss"] if trainer.history else float("nan")
last = trainer.history[-1]["loss"] if trainer.history else float("nan")
print(f"loss {first:.3f} -> {last:.3f}; stragglers detected: "
      f"{trainer.straggler_steps}")
print("train_sparse_lm OK")
