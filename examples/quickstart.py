"""Quickstart: the paper's technique in five steps, via the unified
``repro.ops`` API.

1. take a dense weight, 2. block-prune it to BCSR, 3. run the polymorphic
``spmm`` (Pallas kernel in interpret mode on CPU) against the jnp oracle,
4. drop the sparse layer into a model, 5. compare dense-vs-sparse modeled
v5e latency.

``repro.ops.spmm(a, b)`` dispatches on the format of ``a`` (BCSR or WCSR),
auto-selects the output tile width (paper §IV-C), and obeys the ambient
``use_config(...)`` / ``REPRO_SPARSE_IMPL`` execution config.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import pathlib
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from repro.core.formats import fill_ratio, wcsr_from_dense
from repro.core.sparse_linear import SparseLinearSpec, sparse_linear_from_dense
from repro.core.sparsify import sparsify_to_bcsr
from repro.ops import spmm, use_config
from benchmarks.common import model_bcsr_time, PEAK_MXU, HBM_BW

rng = np.random.default_rng(0)

# 1. a dense FFN-ish weight
OUT, IN, TOKENS = 1024, 512, 256
w = rng.normal(size=(OUT, IN)).astype(np.float32)

# 2. 90% block sparsity, 64x64 blocks (paper §IV-D setting, scaled)
a = sparsify_to_bcsr(w, (64, 64), sparsity=0.9, method="magnitude")
print(f"BCSR: {a.nnz_blocks} blocks kept of {(OUT//64)*(IN//64)}, "
      f"fill_ratio={fill_ratio(np.where(np.abs(w) > 0, w, 0), a):.3f}")

# 3. one spmm() for every format: kernel (interpret on CPU) vs jnp reference,
#    flipped via config contexts — the call sites never change
x = jnp.asarray(rng.normal(size=(IN, TOKENS)).astype(np.float32))
with use_config(impl="kernel_interpret"):
    y_kernel = spmm(a, x)          # BCSR -> block-streaming kernel
y_ref = spmm(a, x, impl="ref")
err = float(jnp.max(jnp.abs(y_kernel - y_ref)))
print(f"Pallas kernel vs jnp oracle max err: {err:.2e}")
assert err < 1e-3

# the same entry point handles irregular sparsity via WCSR
w_irregular = wcsr_from_dense(
    np.where(rng.random((OUT, IN)) < 0.02, w, 0), b_row=64, b_col=8)
y_w = spmm(w_irregular, x)         # WCSR -> window-gather path
print(f"WCSR spmm out {y_w.shape} (same API, different format)")

# 4. a drop-in sparse linear layer (differentiable: SDDMM backward)
layer = sparse_linear_from_dense(
    w, SparseLinearSpec(IN, OUT, sparsity=0.9, block=(64, 64)))
tokens = jnp.asarray(rng.normal(size=(4, 8, IN)).astype(np.float32))
with use_config(impl="ref"):
    out = layer(tokens)
    grad = jax.grad(lambda v: jnp.sum(
        layer.__class__(values=v, structure=layer.structure)(tokens) ** 2
    ))(layer.values)
print(f"sparse layer out {out.shape}, dvalues {grad.shape} "
      f"(norm {float(jnp.linalg.norm(grad)):.2f})")

# 5. modeled v5e latency, dense vs sparse
t_dense = max(2.0 * OUT * IN * TOKENS / PEAK_MXU,
              (OUT * IN + IN * TOKENS + OUT * TOKENS) * 2 / HBM_BW)
t_sparse = model_bcsr_time(a.nnz_blocks, 64, 64, TOKENS, 128, k=IN)
print(f"modeled v5e: dense {t_dense*1e6:.1f}us vs BCSR {t_sparse*1e6:.1f}us "
      f"({t_dense/t_sparse:.2f}x)")
print("quickstart OK")
