"""Quickstart: the paper's technique in five steps.

1. take a dense weight, 2. block-prune it to BCSR, 3. run the Pallas SpMM
kernel (interpret mode on CPU) against the jnp oracle, 4. drop the sparse
layer into a model, 5. compare dense-vs-sparse modeled v5e latency.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.formats import fill_ratio
from repro.core.sparse_linear import SparseLinearSpec, sparse_linear_from_dense
from repro.core.sparsify import sparsify_to_bcsr
from repro.kernels.bcsr.ops import bcsr_spmm
from repro.kernels.bcsr.ref import bcsr_spmm_ref
from benchmarks.common import model_bcsr_time, PEAK_MXU, HBM_BW

rng = np.random.default_rng(0)

# 1. a dense FFN-ish weight
OUT, IN, TOKENS = 1024, 512, 256
w = rng.normal(size=(OUT, IN)).astype(np.float32)

# 2. 90% block sparsity, 64x64 blocks (paper §IV-D setting, scaled)
a = sparsify_to_bcsr(w, (64, 64), sparsity=0.9, method="magnitude")
print(f"BCSR: {a.nnz_blocks} blocks kept of {(OUT//64)*(IN//64)}, "
      f"fill_ratio={fill_ratio(np.where(np.abs(w) > 0, w, 0), a):.3f}")

# 3. kernel vs oracle
x = jnp.asarray(rng.normal(size=(IN, TOKENS)).astype(np.float32))
y_kernel = bcsr_spmm(a, x, impl="kernel_interpret", bn=128)
y_ref = bcsr_spmm_ref(a, x)
err = float(jnp.max(jnp.abs(y_kernel - y_ref)))
print(f"Pallas kernel vs jnp oracle max err: {err:.2e}")
assert err < 1e-3

# 4. a drop-in sparse linear layer (differentiable: SDDMM backward)
layer = sparse_linear_from_dense(
    w, SparseLinearSpec(IN, OUT, sparsity=0.9, block=(64, 64)))
tokens = jnp.asarray(rng.normal(size=(4, 8, IN)).astype(np.float32))
out = layer(tokens, impl="ref")
grad = jax.grad(lambda v: jnp.sum(
    layer.__class__(values=v, structure=layer.structure)(tokens, "ref") ** 2
))(layer.values)
print(f"sparse layer out {out.shape}, dvalues {grad.shape} "
      f"(norm {float(jnp.linalg.norm(grad)):.2f})")

# 5. modeled v5e latency, dense vs sparse
t_dense = max(2.0 * OUT * IN * TOKENS / PEAK_MXU,
              (OUT * IN + IN * TOKENS + OUT * TOKENS) * 2 / HBM_BW)
t_sparse = model_bcsr_time(a.nnz_blocks, 64, 64, TOKENS, 128, k=IN)
print(f"modeled v5e: dense {t_dense*1e6:.1f}us vs BCSR {t_sparse*1e6:.1f}us "
      f"({t_dense/t_sparse:.2f}x)")
print("quickstart OK")
