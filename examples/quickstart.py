"""Quickstart: the paper's technique in five steps, via the format-agnostic
``repro.sparse`` layer + the unified ``repro.ops`` API.

1. take a dense weight, 2. ``sparsify`` it into a ``SparseTensor`` (BCSR),
3. run ``A @ B`` (Pallas kernel in interpret mode on CPU) against the jnp
oracle and convert to WCSR through the conversion graph, 4. drop the sparse
layer into a model, 5. compare dense-vs-sparse modeled v5e latency.

``SparseTensor`` separates structure from values: host-side planning (tile
selection, the WCSR task decomposition) is memoized per structure
(``repro.ops.make_plan``), so repeated calls — a serving loop — plan once.
``A @ B`` obeys the ambient ``use_config(...)`` / ``REPRO_SPARSE_IMPL``
execution config like every ``repro.ops`` entry point.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import pathlib
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from repro.core.sparse_linear import SparseLinearSpec, sparse_linear_from_dense
from repro.ops import make_plan, plan_cache_info, use_config
from repro.sparse import SparseTensor, sparsify
from benchmarks.common import model_bcsr_time, PEAK_MXU, HBM_BW

rng = np.random.default_rng(0)

# 1. a dense FFN-ish weight
OUT, IN, TOKENS = 1024, 512, 256
w = rng.normal(size=(OUT, IN)).astype(np.float32)

# 2. 90% block sparsity, 64x64 blocks (paper §IV-D setting, scaled)
a = sparsify(w, format="bcsr", block=(64, 64), sparsity=0.9,
             method="magnitude")
print(f"{a}: {a.raw.nnz_blocks} blocks kept of {(OUT//64)*(IN//64)}, "
      f"fill_ratio={a.fill_ratio(np.where(np.abs(w) > 0, w, 0)):.3f}")

# 3. array-API ergonomics: A @ B for every format. Kernel (interpret on CPU)
#    vs jnp reference, flipped via config contexts — call sites never change.
x = jnp.asarray(rng.normal(size=(IN, TOKENS)).astype(np.float32))
with use_config(impl="kernel_interpret"):
    y_kernel = a @ x               # BCSR -> block-streaming kernel
y_ref = a.matmul(x, impl="ref")
err = float(jnp.max(jnp.abs(y_kernel - y_ref)))
print(f"Pallas kernel vs jnp oracle max err: {err:.2e}")
assert err < 1e-3

# the conversion graph reaches WCSR from anywhere (here: bcsr -> dense ->
# wcsr); irregular sparsity would come straight from sparsify(format="wcsr")
w_irregular = SparseTensor.from_dense(
    np.where(rng.random((OUT, IN)) < 0.02, w, 0), "wcsr", block=(64, 8))
with use_config(impl="kernel_interpret"):
    for _ in range(3):             # a serving loop: plans once, reuses after
        y_w = w_irregular @ x      # WCSR -> window-gather path
info = plan_cache_info()
print(f"WCSR spmm out {y_w.shape} (same API, different format); "
      f"task decompositions: {info.task_decompositions}, "
      f"plan hits: {info.hits}")
assert info.task_decompositions == 1
plan = make_plan(w_irregular, TOKENS)  # the memoized plan, inspectable
print(f"plan: bn={plan.bn}, tasks={plan.num_tasks} "
      f"(chunks_per_task={plan.chunks_per_task})")

# 3b. value codecs: store the sparse values as int8 payload + per-chunk
#     f32 scales — kernels move the compressed bytes and dequantize
#     in-register, structure-keyed planning caches are shared with the
#     raw tensor (docs/formats.md "Value codecs")
w_q = w_irregular.quantize("int8")
with use_config(impl="kernel_interpret"):
    y_q = w_q @ x
q_err = float(jnp.max(jnp.abs(y_q - y_w)) / jnp.max(jnp.abs(y_w)))
from repro.sparse.codecs import modeled_value_bytes
mb = modeled_value_bytes(w_q.structure.stored_elements, 64 * 8, "int8")
print(f"int8 codec: rel err {q_err:.4f}, modeled sparse-operand bytes "
      f"{mb['reduction']:.2f}x smaller")
assert q_err < 0.02
assert plan_cache_info().task_decompositions == 1  # codec shares the split

# 4. a drop-in sparse linear layer (differentiable: SDDMM backward)
layer = sparse_linear_from_dense(
    w, SparseLinearSpec(IN, OUT, sparsity=0.9, block=(64, 64)))
tokens = jnp.asarray(rng.normal(size=(4, 8, IN)).astype(np.float32))
with use_config(impl="ref"):
    out = layer(tokens)
    grad = jax.grad(lambda v: jnp.sum(
        layer.__class__(values=v, structure=layer.structure)(tokens) ** 2
    ))(layer.values)
print(f"sparse layer out {out.shape}, dvalues {grad.shape} "
      f"(norm {float(jnp.linalg.norm(grad)):.2f})")

# 5. modeled v5e latency, dense vs sparse
t_dense = max(2.0 * OUT * IN * TOKENS / PEAK_MXU,
              (OUT * IN + IN * TOKENS + OUT * TOKENS) * 2 / HBM_BW)
t_sparse = model_bcsr_time(a.raw.nnz_blocks, 64, 64, TOKENS, 128, k=IN)
print(f"modeled v5e: dense {t_dense*1e6:.1f}us vs BCSR {t_sparse*1e6:.1f}us "
      f"({t_dense/t_sparse:.2f}x)")
print("quickstart OK")
