"""MInference-style sparse-attention prefill (paper §IV-D):
profile per-head attention offline, select block patterns, run prefill
through the block-sparse attention kernel, and report recall + speedup
bounds.

Run:  PYTHONPATH=src python examples/sparse_attention_prefill.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sparse_attention import (mask_density, profile_block_scores,
                                         select_patterns)
from repro.kernels.block_attn.ref import block_sparse_attention_ref
from repro.ops import sparse_attention

rng = np.random.default_rng(0)
B, H, KVH, S, D = 1, 4, 2, 512, 32
BLOCK = 64

q = rng.normal(size=(B, H, S, D)).astype(np.float32)
k = rng.normal(size=(B, KVH, S, D)).astype(np.float32)
v = rng.normal(size=(B, KVH, S, D)).astype(np.float32)
# give the heads structure: head 0 sink-ish, head 1 local-ish
q[:, 0] += 1.5
k[:, 0, :BLOCK] += 1.5

# offline profiling pass (MInference's head analysis)
scores = profile_block_scores(jnp.asarray(q), jnp.asarray(k), block=BLOCK)
masks, choices = select_patterns(scores, budget=0.35)
for h, c in enumerate(choices):
    print(f"head {h}: pattern={c.name:14s} recall={c.recall:.3f} "
          f"density={c.density:.3f}")

out_sparse = sparse_attention(
    jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), masks,
    block_q=BLOCK, block_k=BLOCK, impl="kernel_interpret")
out_ref = block_sparse_attention_ref(
    jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), masks,
    block_q=BLOCK, block_k=BLOCK)
err = float(jnp.max(jnp.abs(out_sparse - out_ref)))
print(f"kernel vs ref max err: {err:.2e}")
assert err < 1e-4

avg_density = float(np.mean([mask_density(m) for m in masks]))
print(f"avg causal block density {avg_density:.2f} -> attention-FLOP bound "
      f"{1/avg_density:.2f}x (paper: MInference reaches 1.73x E2E at 64K)")
print("sparse_attention_prefill OK")
