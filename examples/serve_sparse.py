"""End-to-end serving driver (the paper is an inference paper, §IV-D):
serve a small block-sparse-FFN model with batched requests through the
continuous-batching engine, and verify batched outputs equal sequential
decode.

Run:  PYTHONPATH=src python examples/serve_sparse.py
"""

import time

import numpy as np
import jax

from repro.configs import ARCHS, reduced_config
from repro.models.registry import build_model
from repro.ops import OpConfig
from repro.serve.engine import Request, ServeEngine

rng = np.random.default_rng(0)

# a small Qwen-like model with 50% block-sparse FFN (the paper's technique)
cfg = reduced_config(ARCHS["qwen2.5-7b"], num_layers=2, ffn_sparsity=0.5,
                     sparse_block=(32, 32))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"model: {cfg.name} reduced, {cfg.num_layers}L d={cfg.d_model} "
      f"ffn_sparsity={cfg.ffn_sparsity}")

# op_config pins the sparse-op backend engine-wide (repro.ops semantics);
# REPRO_SPARSE_IMPL=... would do the same without code changes. Prompts are
# bulk-prefilled chunk-by-chunk through the block-sparse attention path
# (docs/serving.md) into a paged KV cache — one long prompt costs
# ceil(P/chunk) engine ticks, not P.
engine = ServeEngine(model, params, slots=4, max_len=128, page_size=16,
                     chunk=32, prefill_block_q=16,
                     op_config=OpConfig(impl="ref"))
requests = [
    Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (p,)),
            max_new_tokens=8)
    for i, p in enumerate([5, 9, 50, 7, 6, 4])  # rid 2: a 2-chunk prompt
]
t0 = time.perf_counter()
done = engine.run(requests)
dt = time.perf_counter() - t0
total_new = sum(len(r.out_tokens) for r in requests)
print(f"served {len(done)}/{len(requests)} requests, {total_new} tokens "
      f"in {dt:.2f}s ({total_new/dt:.1f} tok/s on CPU, "
      f"{engine.ticks} engine ticks)")
for r in requests[:3]:
    print(f"  req{r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
assert all(r.done for r in requests)
stats = engine.stats()
print(f"engine stats: mode={stats['mode']} queue_depth={stats['queue_depth']} "
      f"page_utilization={stats['page_utilization']:.2f} "
      f"prefill_tokens={stats['prefill_tokens']} "
      f"decode_tokens={stats['decode_tokens']}")
print(f"  ttft: p50={stats['ttft']['p50_ticks']:.0f} ticks "
      f"p95={stats['ttft']['p95_ticks']:.0f} ticks")
print(f"  plan_cache={stats['plan_cache']}")
print("serve_sparse OK")
