#!/usr/bin/env python
"""Fail on broken relative links in README.md and docs/*.md.

Checks every markdown link whose target is a relative path (http(s),
mailto and pure-anchor links are skipped; a ``#fragment`` on a relative
target is stripped before the existence check). Exit code 1 lists every
broken link. Run from anywhere: paths resolve against the repo root.

    python tools/check_links.py
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check(md: pathlib.Path) -> list:
    broken = []
    for target in LINK.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            broken.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return broken


def main() -> int:
    files = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
    broken = []
    for md in files:
        if md.exists():
            broken.extend(check(md))
    for b in broken:
        print(b, file=sys.stderr)
    print(f"checked {len(files)} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
