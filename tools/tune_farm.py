#!/usr/bin/env python
"""Offline autotune farm CLI — sweep a job fleet into a persistent TuneDB.

Runs the measured ``repro.ops.autotune_spmm`` sweep for every job in a
declarative fleet and commits the winners to a ``repro.tune.TuneDB`` file,
fanning out over a subprocess pool when ``--workers > 0`` (each worker owns
an isolated jax runtime; concurrent appends merge without clobbering).
Point serving replicas at the produced file via ``REPRO_TUNE_DB=<path>`` or
``ServeEngine(tune_db=<path>)`` and they warm-start with zero in-process
sweeps. See docs/performance.md ("Persistent tuning").

Usage:

    # CI-sized smoke fleet, inline, into tune.jsonl
    python tools/tune_farm.py --db tune.jsonl --smoke

    # representative serving fleet over 4 workers
    python tools/tune_farm.py --db tune.jsonl --workers 4

    # custom fleet (JSON list of TuneJob field dicts)
    python tools/tune_farm.py --db tune.jsonl --fleet fleet.json

    # inspect / compact an existing DB without tuning
    python tools/tune_farm.py --db tune.jsonl --stats
    python tools/tune_farm.py --db tune.jsonl --compact
"""

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="Sweep an autotune job fleet into a persistent TuneDB.")
    p.add_argument("--db", required=True,
                   help="TuneDB path (JSON-lines; created if missing)")
    fleet = p.add_mutually_exclusive_group()
    fleet.add_argument("--fleet", metavar="FILE",
                       help="JSON list of TuneJob field dicts")
    fleet.add_argument("--smoke", action="store_true",
                       help="CI-sized two-job fleet")
    p.add_argument("--workers", type=int, default=0,
                   help="subprocess pool size (0 = run jobs inline)")
    p.add_argument("--no-compact", action="store_true",
                   help="skip the final merge-rewrite of the DB file")
    p.add_argument("--stats", action="store_true",
                   help="print DB stats as JSON and exit (no tuning)")
    p.add_argument("--compact", action="store_true",
                   help="compact the DB file and exit (no tuning)")
    args = p.parse_args(argv)

    from repro.tune import (TuneDB, default_fleet, load_fleet, run_farm,
                            smoke_fleet)

    if args.stats or args.compact:
        db = TuneDB(args.db)
        if args.compact:
            n = db.compact()
            print(f"compacted {args.db}: {n} records", file=sys.stderr)
        print(json.dumps(db.stats(), indent=2, sort_keys=True))
        return 0

    if args.fleet:
        jobs = load_fleet(args.fleet)
    elif args.smoke:
        jobs = smoke_fleet()
    else:
        jobs = default_fleet()

    summary = run_farm(jobs, args.db, workers=args.workers,
                       compact=not args.no_compact)
    print(json.dumps(summary, indent=2, sort_keys=True))
    if summary["failed"]:
        print(f"{len(summary['failed'])}/{summary['jobs']} jobs failed",
              file=sys.stderr)
        return 1
    print(f"tuned {summary['tuned']}/{summary['jobs']} jobs -> {args.db}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
