import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For train/prefill shapes this lowers ``train_step`` (prefill lowers the
forward); decode shapes lower ``serve_step`` (one token vs a seq_len cache).
Prints ``memory_analysis()`` (fit proof) and ``cost_analysis()`` (FLOPs /
bytes) per cell and appends a JSON record consumed by
``analysis/roofline`` + EXPERIMENTS.md.

Cost accounting: XLA counts ``lax.scan`` bodies once, so the scanned-layer
module under-reports per-layer FLOPs/bytes/collectives. The dry-run
therefore compiles two small **probe** modules per cell (layers unrolled,
attention q-chunks unrolled, single-chunk loss) at 2 and 4 layer-units and
extrapolates terms(L) = a + b*L to the full depth — exact for everything
linear in depth (everything except the rwkv/mamba time recurrences, whose
inner-scan cost is small and noted in EXPERIMENTS.md). The scanned compile
still provides the fit proof (memory_analysis) and the multi-pod success
proof.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch all|<id>[,<id>..]] [--shape all|<name>] [--mesh both|single|multi]
      [--out results/dryrun.jsonl] [--sparse 0.9] [--optimizer auto]
      [--no-probe] [--skip-done]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.flops import attention_flops, model_flops
from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     analyze_compiled)
from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.models.common import mesh_context
from repro.models.registry import build_model
from repro.parallel.sharding import (batch_shardings, make_mesh_rules,
                                     param_shardings)
from repro.serve.step import decode_cache_axes, make_serve_step
from repro.train.step import init_train_state, make_train_step
from repro.optim import adamw, adafactor

# v5e per-chip HBM budget the fit check reports against
HBM_PER_CHIP = 16 * 1024**3


def _opt_for(cfg: ModelConfig, override: str) -> str:
    if override != "auto":
        return override
    # Adafactor above 30B params (DESIGN.md §6)
    return "adafactor" if cfg.param_count() > 30e9 else "adamw"


def _opt_state_axes(params_axes, optimizer: str):
    """Optimizer-state axes mirror the param axes (scalar sentinels -> ())."""
    if optimizer == "adamw":
        return adamw.AdamWState(step=(), mu=params_axes, nu=params_axes)
    return adafactor.AdafactorState(step=(), vr=params_axes, vc=params_axes)


def lower_cell(cfg: ModelConfig, shape, mesh, optimizer: str = "auto"):
    """Lower the cell's step function with full shardings; returns lowered."""
    model = build_model(cfg)
    rules = make_mesh_rules(mesh, fsdp=cfg.fsdp)
    opt = _opt_for(cfg, optimizer)
    key = jax.random.PRNGKey(0)

    with mesh_context(mesh, rules):
        params_struct = jax.eval_shape(model.init, key)
        axes = model.param_axes()
        params_sh = param_shardings(mesh, params_struct, axes, rules)

        if shape.kind == "train":
            step_fn = make_train_step(model, optimizer=opt)
            state_struct = jax.eval_shape(
                lambda p: init_train_state(p, opt), params_struct)
            opt_axes = _opt_state_axes(axes, opt)
            opt_sh = param_shardings(mesh, state_struct.opt, opt_axes, rules)
            state_sh = type(state_struct)(params=params_sh, opt=opt_sh)
            batch_struct = model.input_spec(shape)
            batch_sh = batch_shardings(mesh, batch_struct, rules)
            lowered = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh), donate_argnums=(0,)
            ).lower(state_struct, batch_struct)
        elif shape.kind == "prefill":
            fwd = lambda p, b: model.forward(p, b)
            batch_struct = model.input_spec(shape)
            batch_sh = batch_shardings(mesh, batch_struct, rules)
            lowered = jax.jit(
                fwd, in_shardings=(params_sh, batch_sh)
            ).lower(params_struct, batch_struct)
        else:  # decode
            b = shape.global_batch
            front = {}
            if cfg.cross_attn_every:
                front["vision_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)
            elif cfg.is_encdec:
                front["enc_states"] = jax.ShapeDtypeStruct(
                    (b, min(shape.seq_len, 4096), cfg.d_model), jnp.bfloat16)
            cache_struct = jax.eval_shape(
                lambda: model.init_decode_cache(b, shape.seq_len,
                                                *front.values()))
            cache_axes = decode_cache_axes(cfg)
            cache_sh = param_shardings(mesh, cache_struct, cache_axes, rules)
            serve = make_serve_step(model)
            tok = jax.ShapeDtypeStruct((b,), jnp.int32)
            tok_sh = batch_shardings(mesh, tok, rules)
            lowered = jax.jit(
                serve,
                in_shardings=(params_sh, cache_sh, tok_sh, tok_sh),
                donate_argnums=(1,),
            ).lower(params_struct, cache_struct, tok, tok)
    return lowered, opt


# ---------------------------------------------------------------------------
# Cost probes: unrolled small-depth compiles, extrapolated linearly in depth
# ---------------------------------------------------------------------------


def _probe_cfg(cfg: ModelConfig, units: int) -> ModelConfig:
    over = dict(scan_layers=False, attn_unroll=True, loss_chunk=1 << 30,
                remat=True)
    if cfg.cross_attn_every:
        over["num_layers"] = units * cfg.cross_attn_every
    elif cfg.is_encdec:
        over["num_layers"] = units
        over["encoder_layers"] = units
    else:
        over["num_layers"] = units
    return dataclasses.replace(cfg, **over)


def _full_units(cfg: ModelConfig) -> int:
    if cfg.cross_attn_every:
        return cfg.num_layers // cfg.cross_attn_every
    return cfg.num_layers


def probe_terms(cfg: ModelConfig, shape, mesh, optimizer: str):
    """(flops, hbm_bytes, coll_bytes) per device, extrapolated to full depth."""
    u1, u2 = (1, 2) if cfg.cross_attn_every else (2, 4)
    vals = []
    for u in (u1, u2):
        pc = _probe_cfg(cfg, u)
        lowered, _ = lower_cell(pc, shape, mesh, optimizer)
        compiled = lowered.compile()
        r = analyze_compiled(compiled, mesh.devices.size)
        vals.append((r.flops_per_device, r.hbm_bytes_per_device,
                     r.coll_bytes_per_device))
        del compiled, lowered
    full = _full_units(cfg)
    out = []
    for v1, v2 in zip(*vals):
        b = (v2 - v1) / (u2 - u1)
        a = v1 - b * u1
        out.append(max(a + b * full, 0.0))
    return tuple(out)


def dryrun_cell(cfg: ModelConfig, shape, mesh, *, optimizer="auto",
                sparse: float = 0.0, probe: bool = True, verbose=True):
    n_chips = mesh.devices.size
    cfg = dataclasses.replace(
        cfg,
        tp_shards=mesh.shape["model"],
        ffn_sparsity=sparse if sparse > 0 else cfg.ffn_sparsity,
    )
    t0 = time.time()
    lowered, opt = lower_cell(cfg, shape, mesh, optimizer)
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mf = model_flops(cfg, shape) + attention_flops(cfg, shape)
    report = analyze_compiled(compiled, n_chips, model_flops_total=mf)
    ma = compiled.memory_analysis()
    per_chip = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    fits = per_chip <= HBM_PER_CHIP

    rec = report.to_dict()
    if probe:
        pf, pm, pc = probe_terms(cfg, shape, mesh, optimizer)
        rec.update(
            flops_per_device=pf, hbm_bytes_per_device=pm,
            coll_bytes_per_device=pc,
            compute_s=pf / PEAK_FLOPS, memory_s=pm / HBM_BW,
            collective_s=pc / ICI_BW,
        )
        terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
                 "collective": rec["collective_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        rec["dominant_time_s"] = max(terms.values())
        rec["useful_fraction"] = mf / (pf * n_chips) if pf else None
        rec["roofline_fraction"] = (
            (mf / n_chips) / (rec["dominant_time_s"] * PEAK_FLOPS)
            if rec["dominant_time_s"] > 0 else None)

    if verbose:
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/1e9:.2f}GB "
              f"out={ma.output_size_in_bytes/1e9:.2f}GB "
              f"temp={ma.temp_size_in_bytes/1e9:.2f}GB "
              f"alias={ma.alias_size_in_bytes/1e9:.2f}GB "
              f"-> {per_chip/1e9:.2f}GB/chip (fits={fits})")
        print(f"  cost_analysis: flops/dev={rec['flops_per_device']:.3e} "
              f"hbm/dev={rec['hbm_bytes_per_device']:.3e} "
              f"coll/dev={rec['coll_bytes_per_device']:.3e}"
              + (" [probe-extrapolated]" if probe else " [scan-raw]"))
        print(f"  roofline: compute={rec['compute_s']*1e3:.2f}ms "
              f"memory={rec['memory_s']*1e3:.2f}ms "
              f"collective={rec['collective_s']*1e3:.2f}ms "
              f"bottleneck={rec['bottleneck']} "
              f"useful={rec['useful_fraction'] and round(rec['useful_fraction'], 3)}")
    rec.update(
        arch=cfg.name, shape=shape.name,
        mesh="x".join(map(str, mesh.devices.shape)),
        n_chips=n_chips, optimizer=opt, sparse=cfg.ffn_sparsity,
        per_chip_bytes=per_chip, fits=bool(fits), compile_s=compile_s,
        kind=shape.kind, probed=probe,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["both", "single", "multi"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--sparse", type=float, default=0.0)
    ap.add_argument("--optimizer", default="auto")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              round(r.get("sparse", 0.0), 4)))
                except Exception:
                    pass

    failures = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "x".join(map(str, mesh.devices.shape))
        # probes (roofline) only on the single-pod mesh, per the spec
        probe = (not args.no_probe) and not multi
        for an in archs:
            cfg = ARCHS[an]
            for sn in shapes:
                shape = SHAPES[sn]
                ok, why = shape_applicable(cfg, shape)
                if not ok:
                    print(f"[skip] {an} x {sn} x {mesh_name}: {why}")
                    continue
                if (an, sn, mesh_name, round(args.sparse, 4)) in done:
                    print(f"[done] {an} x {sn} x {mesh_name}")
                    continue
                print(f"[cell] {an} x {sn} x {mesh_name} ...", flush=True)
                try:
                    t0 = time.time()
                    rec = dryrun_cell(cfg, shape, mesh, sparse=args.sparse,
                                      optimizer=args.optimizer, probe=probe)
                    rec["wall_s"] = time.time() - t0
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                except Exception as e:
                    failures += 1
                    print(f"  FAILED: {type(e).__name__}: {e}")
                    traceback.print_exc()
    print(f"dry-run complete; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
