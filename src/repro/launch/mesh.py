"""Production mesh construction (see MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older versions default every
    # axis to Auto, which is exactly what we ask for on newer ones.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh over however many (CPU) devices the test process has."""
    if pod is not None:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))
