"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains reduced configs; on a real TPU slice the
same entry point builds the production mesh and shards everything through
``parallel.sharding`` (the dry-run proves those programs compile).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced_config
from repro.data.synthetic import SyntheticLM
from repro.models.registry import build_model
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sparse", type=float, default=0.0)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (CPU container default)")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        over = {}
        if args.sparse > 0:
            over = dict(ffn_sparsity=args.sparse, sparse_block=(32, 32))
        cfg = reduced_config(cfg, **over)
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab_size, seed=0)

    def batch_fn(step):
        nb = data.batch(step, args.batch, args.seq)
        out = {k: jnp.asarray(v) for k, v in nb.items()}
        if cfg.cross_attn_every:
            out["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
        if cfg.is_encdec:
            out["frames"] = jnp.zeros(
                (args.batch, args.seq, cfg.d_model), jnp.float32)
        return out

    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, peak_lr=args.lr, optimizer=args.optimizer,
        microbatches=args.microbatches,
    )
    trainer = Trainer(model, tcfg)
    state, start = trainer.init_or_restore(jax.random.PRNGKey(0))

    def on_step(step, metrics):
        if step % 10 == 0:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f}")

    trainer.run(state, batch_fn, start_step=start, on_step=on_step)
    print(f"done; stragglers={trainer.straggler_steps}")


if __name__ == "__main__":
    main()
