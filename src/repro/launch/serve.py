"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``."""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import ARCHS, reduced_config
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--sparse", type=float, default=0.0)
    args = ap.parse_args()

    over = {}
    if args.sparse > 0:
        over = dict(ffn_sparsity=args.sparse, sparse_block=(32, 32))
    cfg = reduced_config(ARCHS[args.arch], **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    engine = ServeEngine(model, params, slots=args.slots, max_len=256)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (3 + i % 5,)),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"{len(reqs)} requests, {toks} new tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
