"""Block pruning: produce block-sparse weights (paper §IV-D methodology).

The paper applies *random* block sparsity at 80/90/95/99% to FFN weights
("deliberately relaxing accuracy constraints to focus on the upper bound of
performance gains"). We implement that, plus magnitude-based block pruning
(the realistic counterpart used by structured-pruning work the paper cites)
and a banded pattern (SuiteSparse-style locality after RCM reordering).

The single entry point is ``sparsify(dense, format=..., method=...)``, which
returns a ``SparseTensor`` in either co-designed format; the mask helpers
remain public for callers that build custom patterns.
``core.sparsify.sparsify_to_bcsr`` / ``sparsify_to_wcsr`` forward here as
deprecated shims.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.sparse.formats import bcsr_from_mask, wcsr_from_dense

__all__ = [
    "random_block_mask",
    "magnitude_block_mask",
    "banded_block_mask",
    "apply_block_mask",
    "sparsify",
]


def _grid(shape: Tuple[int, int], block: Tuple[int, int]) -> Tuple[int, int]:
    m, k = shape
    bm, bk = block
    if m % bm or k % bk:
        raise ValueError(f"shape {shape} not divisible by block {block}")
    return m // bm, k // bk


def random_block_mask(
    shape: Tuple[int, int],
    block: Tuple[int, int],
    sparsity: float,
    seed: int = 0,
    ensure_row_nonempty: bool = True,
) -> np.ndarray:
    """Random block mask with exactly round((1-sparsity)*nblocks) kept blocks."""
    mb, kb = _grid(shape, block)
    rng = np.random.default_rng(seed)
    n = mb * kb
    keep = int(round((1.0 - sparsity) * n))
    mask = np.zeros(n, bool)
    mask[rng.choice(n, size=keep, replace=False)] = True
    mask = mask.reshape(mb, kb)
    if ensure_row_nonempty and keep >= mb:
        for r in np.nonzero(~mask.any(axis=1))[0]:
            # move a block from the densest row to keep count constant
            donor = int(np.argmax(mask.sum(axis=1)))
            c = int(np.nonzero(mask[donor])[0][0])
            mask[donor, c] = False
            mask[r, rng.integers(kb)] = True
    return mask


def magnitude_block_mask(
    weight: np.ndarray, block: Tuple[int, int], sparsity: float
) -> np.ndarray:
    """Keep the top (1-sparsity) fraction of blocks by Frobenius norm."""
    w = np.asarray(weight)
    mb, kb = _grid(w.shape, block)
    bm, bk = block
    norms = np.linalg.norm(
        w.reshape(mb, bm, kb, bk).transpose(0, 2, 1, 3).reshape(mb, kb, -1), axis=-1
    )
    n = mb * kb
    keep = int(round((1.0 - sparsity) * n))
    flat = norms.reshape(-1)
    thresh_idx = np.argsort(flat)[::-1][:keep]
    mask = np.zeros(n, bool)
    mask[thresh_idx] = True
    return mask.reshape(mb, kb)


def banded_block_mask(
    shape: Tuple[int, int], block: Tuple[int, int], bandwidth_blocks: int
) -> np.ndarray:
    """Banded structure (SuiteSparse-style locality after RCM reordering)."""
    mb, kb = _grid(shape, block)
    r = np.arange(mb)[:, None]
    c = np.arange(kb)[None, :]
    # map row-block index onto col-block scale for rectangular matrices
    center = r * (kb / mb)
    return np.abs(c - center) <= bandwidth_blocks


def apply_block_mask(
    weight: np.ndarray, mask: np.ndarray, block: Tuple[int, int]
) -> np.ndarray:
    """Zero out masked blocks of a dense weight (dense reference of pruning)."""
    w = np.asarray(weight).copy()
    mb, kb = _grid(w.shape, block)
    bm, bk = block
    w4 = w.reshape(mb, bm, kb, bk)
    w4 *= mask[:, None, :, None]
    return w4.reshape(w.shape)


def _block_mask(w, block, method, sparsity, seed, bandwidth_blocks):
    if method == "magnitude":
        if sparsity is None:
            raise ValueError("method='magnitude' requires sparsity=")
        return magnitude_block_mask(w, block, sparsity)
    if method == "random":
        if sparsity is None:
            raise ValueError("method='random' requires sparsity=")
        return random_block_mask(w.shape, block, sparsity, seed)
    if method == "banded":
        if bandwidth_blocks is None:
            raise ValueError("method='banded' requires bandwidth_blocks=")
        return banded_block_mask(w.shape, block, bandwidth_blocks)
    raise ValueError(f"unknown method {method!r}")


def sparsify(
    weight: np.ndarray,
    *,
    format: str = "bcsr",
    sparsity: float | None = None,
    method: str = "magnitude",
    block: Tuple[int, int] | None = None,
    seed: int = 0,
    pad_to: int | None = None,
    bandwidth_blocks: int | None = None,
    codec: str = "none",
):
    """Prune a dense weight and pack it into either co-designed format.

    Replaces the ``sparsify_to_bcsr`` / ``sparsify_to_wcsr`` pair with one
    format-agnostic entry. Returns a ``SparseTensor``. ``codec`` quantizes
    the packed values on the way out (``repro.sparse.codecs``): the tensor
    then stores the compressed payload + per-group f32 scales.

    * ``format="bcsr"``: block-granular pruning (``method`` selects the
      block mask: ``"magnitude"`` | ``"random"`` | ``"banded"``),
      ``block=(b_row, b_col)`` defaults to (128, 128). ``pad_to`` pads the
      stored-block count (serving: stable kernel shapes across layers).
    * ``format="wcsr"``: element-granular pruning (finer granularity is the
      format's point) for ``"magnitude"`` / ``"random"``; ``"banded"``
      falls back to the block-banded pattern. ``block=(b_row, b_col)``
      defaults to (128, 8): window height x packed-column padding unit.
    """
    from repro.sparse.tensor import SparseTensor

    w = np.asarray(weight)
    fmt = format.lower()

    def _finish(st):
        return st if codec in (None, "none") else st.quantize(codec)

    if fmt == "bcsr":
        block = (128, 128) if block is None else tuple(block)
        mask = _block_mask(w, block, method, sparsity, seed, bandwidth_blocks)
        wm = apply_block_mask(w, mask, block)
        return _finish(
            SparseTensor.wrap(bcsr_from_mask(wm, mask, block, pad_to=pad_to)))
    if fmt == "wcsr":
        b_row, b_col = (128, 8) if block is None else block
        if method == "magnitude":
            if sparsity is None:
                raise ValueError("method='magnitude' requires sparsity=")
            thresh = np.quantile(np.abs(w), sparsity)
            wm = np.where(np.abs(w) > thresh, w, 0)
        elif method == "random":
            if sparsity is None:
                raise ValueError("method='random' requires sparsity=")
            rng = np.random.default_rng(seed)
            wm = np.where(rng.random(w.shape) > sparsity, w, 0)
        elif method == "banded":
            if bandwidth_blocks is None:
                raise ValueError("method='banded' requires bandwidth_blocks=")
            mask = banded_block_mask(w.shape, (b_row, b_col), bandwidth_blocks)
            wm = apply_block_mask(w, mask, (b_row, b_col))
        else:
            raise ValueError(f"unknown method {method!r}")
        return _finish(SparseTensor.wrap(wcsr_from_dense(wm, b_row, b_col)))
    raise ValueError(f"sparsify: unknown format {format!r} "
                     "(expected 'bcsr' or 'wcsr')")
