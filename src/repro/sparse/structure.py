"""Structure/values separation: the hashable, host-side half of a sparse
matrix.

A ``SparseStructure`` captures everything about a sparse operand that is
*static* — shape, block geometry, CSR-style pointers and index arrays — and
none of the value data. Two tensors with the same pruning pattern share one
structure object, so:

* it is the memoization key for host-side planning
  (``repro.ops.make_plan``): tile-width selection and the WCSR task
  decomposition (paper §III-C) run once per structure, not once per call —
  the per-step overhead a serving system amortizes across repeated shapes;
* swapping values (weight updates, dtype casts) never re-plans: a
  ``SparseTensor.astype`` / value replacement keeps the same structure
  object;
* it is hashable and equality-comparable by content, which also makes it
  valid jax pytree aux data — ``SparseTensor`` flows through ``jit`` with
  the structure as static metadata and only values as traced leaves.

Index data is stored as read-only int32 numpy arrays (not boxed python
ints) and hashed/compared through their raw bytes, so a structure costs the
same memory as its source index arrays and hashing is one memoized C pass.

The WCSR load-balancing task decomposition (formerly
``core.formats.make_wcsr_tasks``) lives here as ``SparseStructure.tasks``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import BCSR, WCSR

__all__ = ["SparseStructure", "structure_of", "wcsr_planning_structure",
           "make_wcsr_tasks"]


def _frozen_i32(x) -> np.ndarray:
    a = np.ascontiguousarray(np.asarray(x, np.int32))
    a.setflags(write=False)
    return a


class SparseStructure:
    """Immutable, hashable structure of a BCSR or WCSR matrix.

    Fields (all host-side, no device arrays):
      fmt:     "bcsr" | "wcsr"
      shape:   (m, k) of the logical dense matrix
      block:   (b_row, b_col) block geometry
      nnz:     bcsr: real (non-padding) stored blocks; wcsr: padded_cols
      ptrs:    bcsr: block_row_ptr; wcsr: window_ptr (read-only i32 array)
      indices: bcsr: (block_rows, block_cols) incl. padding entries;
               wcsr: (col_idx,) — read-only i32 arrays

    The hash covers the full content (via the arrays' bytes) and is
    computed once; a structure is hashed on every planned op call.
    """

    __slots__ = ("fmt", "shape", "block", "nnz", "ptrs", "indices",
                 "_hash", "_dev", "_digest", "_rowdig")

    def __init__(self, fmt: str, shape: Tuple[int, int],
                 block: Tuple[int, int], nnz: int, ptrs, indices):
        self.fmt = str(fmt)
        self.shape = (int(shape[0]), int(shape[1]))
        self.block = (int(block[0]), int(block[1]))
        self.nnz = int(nnz)
        self.ptrs = _frozen_i32(ptrs)
        self.indices = tuple(_frozen_i32(ix) for ix in indices)
        self._hash = None
        self._dev = None  # memoized device index arrays
        self._digest = None  # memoized content_digest()
        self._rowdig = None  # per-row digests (delta splicing)

    # -- identity ----------------------------------------------------------
    def _key(self):
        return (self.fmt, self.shape, self.block, self.nnz,
                self.ptrs.tobytes(),
                tuple(ix.tobytes() for ix in self.indices))

    def __eq__(self, other):
        if not isinstance(other, SparseStructure):
            return NotImplemented
        return self._key() == other._key()

    def __hash__(self):
        if self._hash is None:
            self._hash = hash(self._key())
        return self._hash

    def _row_digest(self, r: int) -> bytes:
        """Digest of one block-row / row-window's stored index content.

        Padding entries past ``ptrs[-1]`` are excluded — they are
        derivable from the stored entries plus ``nnz``, which the header
        hash in ``content_digest`` already covers.
        """
        import hashlib

        p0, p1 = int(self.ptrs[r]), int(self.ptrs[r + 1])
        arr = self.indices[1] if self.fmt == "bcsr" else self.indices[0]
        return hashlib.sha1(arr[p0:p1].tobytes()).digest()

    def row_digests(self) -> Tuple[bytes, ...]:
        """Per block-row / per row-window digests, computed once.

        Structure deltas (``repro.sparse.delta``) splice these: a patched
        structure recomputes only its touched rows' digests and reuses the
        base structure's digests for the rest, so ``content_digest`` costs
        O(touched) instead of O(nnz) along an append/retire chain.
        """
        if self._rowdig is None:
            self._rowdig = tuple(self._row_digest(r)
                                 for r in range(len(self.ptrs) - 1))
        return self._rowdig

    def content_digest(self) -> str:
        """Stable hex digest of the full structure content (memoized).

        Unlike ``__hash__`` (salted per process for str/bytes), this is
        reproducible across processes and hosts — it is the structure key
        the persistent tuning database (``repro.tune``) records, so a
        farm-tuned entry can be matched back to the exact pruning pattern
        it was measured on. It is combined from per-row digests
        (``row_digests``) plus a cheap header/ptrs hash, so delta-produced
        structures (``repro.sparse.delta``) compute it incrementally, and
        the result is cached on the instance — repeated TuneDB lookups on
        one structure no longer rehash the full index arrays.
        """
        if self._digest is None:
            import hashlib

            h = hashlib.sha1()
            h.update(f"{self.fmt}|{self.shape}|{self.block}|{self.nnz}|"
                     .encode())
            h.update(self.ptrs.tobytes())
            for d in self.row_digests():
                h.update(d)
            self._digest = h.hexdigest()
        return self._digest

    def __repr__(self):
        return (f"SparseStructure(fmt={self.fmt!r}, shape={self.shape}, "
                f"block={self.block}, nnz={self.nnz})")

    # -- derived geometry --------------------------------------------------
    @property
    def stored_elements(self) -> int:
        """Values physically stored (incl. format padding) — fill-ratio
        denominator (paper §II-C)."""
        if self.fmt == "bcsr":
            return self.nnz * self.block[0] * self.block[1]
        return self.nnz * self.block[0]  # wcsr: padded_cols * b_row

    @property
    def density(self) -> float:
        m, k = self.shape
        return self.stored_elements / (m * k)

    @property
    def num_windows(self) -> int:
        return self.shape[0] // self.block[0]

    # -- device index arrays (memoized uploads) ----------------------------
    def index_arrays(self) -> Dict[str, jax.Array]:
        """The structure's index arrays as device arrays, uploaded once.

        Under an enclosing trace the uploads become traced constants,
        which must not be memoized on this (shared, long-lived) object —
        they would leak out of the trace; only concrete arrays are cached.
        """
        if self._dev is not None:
            return self._dev
        if self.fmt == "bcsr":
            rows, cols = self.indices
            dev = {
                "block_rows": jnp.asarray(rows),
                "block_cols": jnp.asarray(cols),
                "block_row_ptr": jnp.asarray(self.ptrs),
            }
        elif self.fmt == "wcsr":
            (col_idx,) = self.indices
            dev = {
                "col_idx": jnp.asarray(col_idx),
                "window_ptr": jnp.asarray(self.ptrs),
            }
        else:
            raise ValueError(f"unknown structure format {self.fmt!r}")
        if not any(isinstance(a, jax.core.Tracer) for a in dev.values()):
            self._dev = dev
        return self._dev if self._dev is not None else dev

    # -- raw-format reconstruction -----------------------------------------
    def attach_values(self, *data) -> "BCSR | WCSR":
        """Rebuild the raw format container from this structure + values."""
        ix = self.index_arrays()
        if self.fmt == "bcsr":
            (blocks,) = data
            return BCSR(
                blocks=blocks,
                block_rows=ix["block_rows"],
                block_cols=ix["block_cols"],
                block_row_ptr=ix["block_row_ptr"],
                shape=self.shape,
                block=self.block,
                nnz_blocks=self.nnz,
            )
        (values,) = data
        return WCSR(
            values=values,
            col_idx=ix["col_idx"],
            window_ptr=ix["window_ptr"],
            shape=self.shape,
            b_row=self.block[0],
            b_col=self.block[1],
            padded_cols=self.nnz,
        )

    # -- WCSR task decomposition (paper §III-C) ----------------------------
    def tasks(self, chunks_per_task: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Split windows into fixed-size sub-tasks (§III-C load balancing).

        Each task covers up to ``chunks_per_task`` packed-column chunks of
        ``b_col`` columns within one window. Empty windows simply emit no
        task (the kernel's zero-initialized output covers them). Returns
        (task_window, task_chunk_start, task_nchunks) host arrays.

        This is the expensive host-side planning step; callers go through
        ``repro.ops.make_plan`` so it runs once per structure.
        """
        if self.fmt != "wcsr":
            raise ValueError(f"tasks(): not a wcsr structure ({self.fmt!r})")
        b_col = self.block[1]
        ptr = self.ptrs
        t_win, t_start, t_n = [], [], []
        for w in range(len(ptr) - 1):
            c0, c1 = int(ptr[w]), int(ptr[w + 1])
            nchunks = (c1 - c0) // b_col
            g = 0
            while g < nchunks:
                take = min(chunks_per_task, nchunks - g)
                t_win.append(w)
                t_start.append(c0 // b_col + g)
                t_n.append(take)
                g += take
        if not t_win:  # fully-empty matrix: one no-op task keeps grids non-empty
            t_win, t_start, t_n = [0], [0], [0]
        return (
            np.asarray(t_win, np.int32),
            np.asarray(t_start, np.int32),
            np.asarray(t_n, np.int32),
        )


def structure_of(x) -> SparseStructure:
    """Extract the ``SparseStructure`` of a raw BCSR / WCSR (host transfer).

    ``SparseTensor`` carries its structure; this is the one-time extraction
    used when wrapping a raw format.
    """
    if isinstance(x, BCSR):
        return SparseStructure(
            fmt="bcsr", shape=x.shape, block=x.block, nnz=x.nnz_blocks,
            ptrs=jax.device_get(x.block_row_ptr),
            indices=(jax.device_get(x.block_rows),
                     jax.device_get(x.block_cols)),
        )
    if isinstance(x, WCSR):
        return SparseStructure(
            fmt="wcsr", shape=x.shape, block=(x.b_row, x.b_col),
            nnz=x.padded_cols,
            ptrs=jax.device_get(x.window_ptr),
            indices=(jax.device_get(x.col_idx),),
        )
    structure = getattr(x, "structure", None)
    if isinstance(structure, SparseStructure):
        return structure
    raise TypeError(f"structure_of: unsupported type {type(x).__name__}")


def wcsr_planning_structure(a: WCSR) -> SparseStructure:
    """Ptrs-only structure for planning a *raw* WCSR call.

    Task decomposition and tile selection only need ``window_ptr`` and the
    geometry, so the per-call cost is O(num_windows) — the same order as
    the old ``make_wcsr_tasks`` loop — instead of pulling the full
    ``col_idx`` to the host. (``SparseTensor`` operands skip even this:
    their full structure is extracted once at wrap time.)
    """
    return SparseStructure(
        fmt="wcsr", shape=a.shape, block=(a.b_row, a.b_col),
        nnz=a.padded_cols, ptrs=jax.device_get(a.window_ptr), indices=((),))


def make_wcsr_tasks(a, chunks_per_task: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Task decomposition for a raw WCSR (compat wrapper).

    Prefer ``repro.ops.make_plan`` — it memoizes the decomposition per
    structure; this wrapper re-derives it from ``window_ptr`` every call.
    """
    return wcsr_planning_structure(a).tasks(chunks_per_task)
