"""Sparse formats from the AsyncSparse paper, as JAX pytrees.

Two complementary formats (paper §II-C):

* ``BCSR`` — Block Compressed Sparse Row. ``A`` is tiled into fixed
  ``b_row x b_col`` blocks; only blocks containing at least one nonzero are
  stored densely. Contiguous block storage makes both operands bulk-DMA-able
  (the TMA-friendly format; on TPU the analogue is BlockSpec streaming driven
  by scalar-prefetched block indices).

* ``WCSR`` — Window Compressed Sparse Row. Rows are grouped into windows of
  ``b_row``; per window the union of nonzero columns is stored as packed
  length-``b_row`` column vectors, padded to a multiple of ``b_col``. Much
  lower padding for scattered sparsity, at the cost of an indirect gather of
  the dense operand (cooperative gather on GPU; scalar-core row DMAs on TPU).

Both are registered dataclass pytrees: index/value arrays are leaves (so the
formats flow through jit / pjit / shard_map), sizes and block shapes are
static metadata.

This module holds the raw containers and their host-side constructors; the
format-agnostic layer on top (``SparseTensor``, the conversion graph, the
``SparseFormat`` registry) lives in the sibling modules of ``repro.sparse``.
``repro.core.formats`` re-exports these names as deprecated shims.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BCSR",
    "WCSR",
    "bcsr_from_dense",
    "bcsr_to_dense",
    "bcsr_from_mask",
    "bcsr_transpose",
    "wcsr_from_dense",
    "wcsr_to_dense",
    "wcsr_transpose",
    "block_mask_from_dense",
    "rcm_permutation",
]


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, b: int) -> int:
    return _cdiv(a, b) * b


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["blocks", "block_rows", "block_cols", "block_row_ptr"],
    meta_fields=["shape", "block", "nnz_blocks"],
)
@dataclasses.dataclass
class BCSR:
    """Block Compressed Sparse Row matrix.

    Attributes:
      blocks:        [nnz_padded, b_row, b_col] dense block values. Padding
                     blocks (index >= nnz_blocks) are all-zero.
      block_rows:    [nnz_padded] i32 block-row index per stored block,
                     sorted ascending. Padding entries repeat the last valid
                     block-row so kernels revisit an already-open output tile.
      block_cols:    [nnz_padded] i32 block-col index per stored block
                     (0 for padding entries — harmless, values are zero).
      block_row_ptr: [m_blocks + 1] i32 CSR-style pointers into the block
                     arrays (excludes padding).
      shape:         static (m, k) of the logical dense matrix.
      block:         static (b_row, b_col).
      nnz_blocks:    static count of real (non-padding) blocks.
    """

    blocks: jax.Array
    block_rows: jax.Array
    block_cols: jax.Array
    block_row_ptr: jax.Array
    shape: Tuple[int, int]
    block: Tuple[int, int]
    nnz_blocks: int

    @property
    def dtype(self):
        return self.blocks.dtype

    @property
    def nnz_padded(self) -> int:
        return self.blocks.shape[0]

    @property
    def grid_blocks(self) -> Tuple[int, int]:
        return (self.shape[0] // self.block[0], self.shape[1] // self.block[1])

    def density(self) -> float:
        m, k = self.shape
        return self.nnz_blocks * self.block[0] * self.block[1] / (m * k)

    def astype(self, dtype) -> "BCSR":
        return dataclasses.replace(self, blocks=self.blocks.astype(dtype))


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["values", "col_idx", "window_ptr"],
    meta_fields=["shape", "b_row", "b_col", "padded_cols"],
)
@dataclasses.dataclass
class WCSR:
    """Window Compressed Sparse Row matrix.

    Attributes:
      values:      [b_row, total_padded_cols] packed column vectors. Column
                   ``c`` belongs to the window ``w`` with
                   ``window_ptr[w] <= c < window_ptr[w+1]`` and holds the
                   values of A[w*b_row:(w+1)*b_row, col_idx[c]].
      col_idx:     [total_padded_cols] i32 original column per packed column;
                   -1 for padding columns (their values are zero).
      window_ptr:  [num_windows + 1] i32, multiples of b_col.
      shape:       static (m, k).
      b_row:       static window height.
      b_col:       static packed-column padding unit (the k-granularity of
                   the micro-matmuls; lane-aligned on TPU).
      padded_cols: static total packed columns (values.shape[1]).
    """

    values: jax.Array
    col_idx: jax.Array
    window_ptr: jax.Array
    shape: Tuple[int, int]
    b_row: int
    b_col: int
    padded_cols: int

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def num_windows(self) -> int:
        return self.shape[0] // self.b_row

    def density(self) -> float:
        m, k = self.shape
        return self.padded_cols * self.b_row / (m * k)

    def astype(self, dtype) -> "WCSR":
        return dataclasses.replace(self, values=self.values.astype(dtype))


# ---------------------------------------------------------------------------
# BCSR construction
# ---------------------------------------------------------------------------


def block_mask_from_dense(dense: np.ndarray, block: Tuple[int, int]) -> np.ndarray:
    """Boolean [m_blocks, k_blocks] mask of blocks containing any nonzero."""
    m, k = dense.shape
    bm, bk = block
    if m % bm or k % bk:
        raise ValueError(f"shape {dense.shape} not divisible by block {block}")
    r = np.asarray(dense).reshape(m // bm, bm, k // bk, bk)
    return (r != 0).any(axis=(1, 3))


def bcsr_from_mask(
    dense: np.ndarray,
    mask: np.ndarray,
    block: Tuple[int, int],
    pad_to: int | None = None,
    cover_empty_rows: bool = True,
) -> BCSR:
    """Build BCSR keeping exactly the blocks selected by ``mask``.

    With ``cover_empty_rows`` (default), block-rows with no stored block get
    one explicit zero block so the TPU kernel visits (and zero-fills) every
    output row-block — the analogue of the GPU kernel's C initialization.
    """
    dense = np.asarray(dense)
    m, k = dense.shape
    bm, bk = block
    if m % bm or k % bk:
        raise ValueError(f"shape {dense.shape} not divisible by block {block}")
    mask = np.asarray(mask, bool).copy()
    if cover_empty_rows:
        empty = ~mask.any(axis=1)
        mask[empty, 0] = True
    rows, cols = np.nonzero(mask)  # row-major order == sorted by block row
    nnz = len(rows)
    npad = max(nnz, 1) if pad_to is None else pad_to
    if npad < nnz:
        raise ValueError(f"pad_to={pad_to} < nnz_blocks={nnz}")
    blocks = np.zeros((npad, bm, bk), dense.dtype)
    r4 = dense.reshape(m // bm, bm, k // bk, bk).transpose(0, 2, 1, 3)
    if nnz:
        blocks[:nnz] = r4[rows, cols]
    # Padding repeats the last valid row (keeps output revisiting monotone).
    last_row = rows[-1] if nnz else 0
    prow = np.full(npad, last_row, np.int32)
    pcol = np.zeros(npad, np.int32)
    if nnz:
        prow[:nnz] = rows
        pcol[:nnz] = cols
    ptr = np.zeros(m // bm + 1, np.int32)
    np.add.at(ptr, rows + 1, 1)
    ptr = np.cumsum(ptr).astype(np.int32)
    return BCSR(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(prow),
        block_cols=jnp.asarray(pcol),
        block_row_ptr=jnp.asarray(ptr),
        shape=(m, k),
        block=(bm, bk),
        nnz_blocks=int(nnz),
    )


def bcsr_from_dense(
    dense: np.ndarray, block: Tuple[int, int], pad_to: int | None = None
) -> BCSR:
    """Build BCSR from a dense matrix, keeping blocks with any nonzero."""
    return bcsr_from_mask(dense, block_mask_from_dense(dense, block), block, pad_to)


def bcsr_to_dense(a: BCSR) -> jax.Array:
    """Pure-jnp densify (oracle for tests)."""
    m, k = a.shape
    bm, bk = a.block
    mb, kb = a.grid_blocks
    nnz = a.nnz_blocks
    out = jnp.zeros((mb, kb, bm, bk), a.dtype)
    idx = jnp.arange(a.nnz_padded)
    valid = idx < nnz
    # Scatter-add real blocks; padding scattered with zero contribution.
    vals = jnp.where(valid[:, None, None], a.blocks, 0)
    out = out.at[a.block_rows, a.block_cols].add(vals)
    return out.transpose(0, 2, 1, 3).reshape(m, k)


def bcsr_transpose(a: BCSR) -> BCSR:
    """Structure-preserving transpose: (k, m) BCSR with transposed blocks.

    The permutation is derived from the (static) structure on the host, so
    this is cheap under jit: a gather + per-block transpose.
    """
    rows = np.asarray(jax.device_get(a.block_rows))
    cols = np.asarray(jax.device_get(a.block_cols))
    nnz = a.nnz_blocks
    order = np.lexsort((rows[:nnz], cols[:nnz]))  # sort by (new row=old col)
    npad = a.nnz_padded
    perm = np.arange(npad)
    perm[:nnz] = order
    new_rows = np.zeros(npad, np.int32)
    new_cols = np.zeros(npad, np.int32)
    new_rows[:nnz] = cols[:nnz][order]
    new_cols[:nnz] = rows[:nnz][order]
    last = new_rows[nnz - 1] if nnz else 0
    new_rows[nnz:] = last
    kb = a.shape[1] // a.block[1]
    ptr = np.zeros(kb + 1, np.int32)
    np.add.at(ptr, new_rows[:nnz] + 1, 1)
    ptr = np.cumsum(ptr).astype(np.int32)
    blocks_t = a.blocks[jnp.asarray(perm)].transpose(0, 2, 1)
    return BCSR(
        blocks=blocks_t,
        block_rows=jnp.asarray(new_rows),
        block_cols=jnp.asarray(new_cols),
        block_row_ptr=jnp.asarray(ptr),
        shape=(a.shape[1], a.shape[0]),
        block=(a.block[1], a.block[0]),
        nnz_blocks=nnz,
    )


# ---------------------------------------------------------------------------
# WCSR construction
# ---------------------------------------------------------------------------


def wcsr_from_dense(
    dense: np.ndarray, b_row: int, b_col: int, pad_cols_to: int | None = None
) -> WCSR:
    """Build WCSR: per window, the union of nonzero columns, padded to b_col."""
    dense = np.asarray(dense)
    m, k = dense.shape
    if m % b_row:
        raise ValueError(f"m={m} not divisible by b_row={b_row}")
    num_windows = m // b_row
    per_window_cols = []
    for w in range(num_windows):
        sub = dense[w * b_row : (w + 1) * b_row]
        nz = np.nonzero((sub != 0).any(axis=0))[0]
        per_window_cols.append(nz)
    ptr = [0]
    for nz in per_window_cols:
        ptr.append(ptr[-1] + _round_up(max(len(nz), 0), b_col))
    total = ptr[-1]
    if pad_cols_to is not None:
        if pad_cols_to < total:
            raise ValueError(f"pad_cols_to={pad_cols_to} < required {total}")
        total = pad_cols_to
    total = max(total, b_col)
    values = np.zeros((b_row, total), dense.dtype)
    col_idx = np.full(total, -1, np.int32)
    for w, nz in enumerate(per_window_cols):
        s = ptr[w]
        col_idx[s : s + len(nz)] = nz
        values[:, s : s + len(nz)] = dense[w * b_row : (w + 1) * b_row][:, nz]
    return WCSR(
        values=jnp.asarray(values),
        col_idx=jnp.asarray(col_idx),
        window_ptr=jnp.asarray(np.asarray(ptr, np.int32)),
        shape=(m, k),
        b_row=b_row,
        b_col=b_col,
        padded_cols=total,
    )


def wcsr_to_dense(a: WCSR) -> jax.Array:
    """Pure-jnp densify (oracle for tests)."""
    m, k = a.shape
    ptr = jnp.asarray(a.window_ptr)
    c = jnp.arange(a.padded_cols)
    # window id per packed column
    win = jnp.searchsorted(ptr, c, side="right") - 1
    win = jnp.clip(win, 0, a.num_windows - 1)
    valid = a.col_idx >= 0
    col = jnp.where(valid, a.col_idx, 0)
    out = jnp.zeros((a.num_windows, k, a.b_row), a.dtype)
    vals = jnp.where(valid[None, :], a.values, 0)  # [b_row, C]
    out = out.at[win, col].add(vals.T)
    return out.transpose(0, 2, 1).reshape(m, k)


def wcsr_transpose(a: WCSR, b_row: int | None = None,
                   b_col: int | None = None) -> WCSR:
    """Transpose to a (k, m) WCSR, re-packing windows over the column dim.

    Unlike ``bcsr_transpose`` there is no structure-preserving permutation —
    each transposed window's column union must be recomputed — so this is a
    host-side dense hop, intended for offline preprocessing (same cost class
    as building the format in the first place). The transposed window height
    defaults to the source ``b_row`` and must divide ``k``.
    """
    b_row = a.b_row if b_row is None else b_row
    b_col = a.b_col if b_col is None else b_col
    k = a.shape[1]
    if k % b_row:
        raise ValueError(
            f"wcsr_transpose: transposed row count {k} not divisible by "
            f"b_row={b_row}")
    dense_t = np.asarray(jax.device_get(wcsr_to_dense(a))).T
    return wcsr_from_dense(dense_t, b_row=b_row, b_col=b_col)


# ---------------------------------------------------------------------------
# Shared utilities
# ---------------------------------------------------------------------------


def rcm_permutation(dense_or_mask: np.ndarray) -> np.ndarray:
    """Reverse Cuthill-McKee row/col permutation (paper preprocessing)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    a = sp.csr_matrix(np.asarray(dense_or_mask) != 0)
    # RCM needs a structurally symmetric graph.
    sym = ((a + a.T) > 0).astype(np.int8)
    return np.asarray(reverse_cuthill_mckee(sym.tocsr(), symmetric_mode=True))
