"""The ``SparseFormat`` protocol + registry: one descriptor per format.

This subsumes the scattered isinstance checks that used to live in
``repro.ops.registry.resolve_format`` (spmm dispatch) and
``core.formats.fill_ratio`` (stored-element counting): every per-format
behavior — which spmm op family handles it, how to densify it, how to count
stored values, how to extract/reattach its structure, how to transpose it —
is declared once here, and new formats plug in with
``register_sparse_format`` without touching any dispatch site.

``"dense"`` is registered too (with ``op=None``) so the conversion graph in
``repro.sparse.convert`` can route through it; attempting to ``spmm`` a
dense array still raises the usual TypeError.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.sparse import formats as F
from repro.sparse.structure import SparseStructure, structure_of

__all__ = [
    "SparseFormat",
    "register_sparse_format",
    "registered_sparse_formats",
    "get_format",
    "format_of",
    "format_name_of",
    "fill_ratio",
]


@dataclasses.dataclass(frozen=True)
class SparseFormat:
    """Descriptor of one sparse format.

    Attributes:
      name:            registry key ("bcsr", "wcsr", "dense", ...).
      fmt_type:        the pytree container class (None for dense arrays).
      op:              spmm op family ("spmm/bcsr", ...) or None if the
                       format cannot be a spmm operand.
      stored_elements: raw -> number of physically stored values (incl.
                       format padding); fill-ratio denominator (§II-C).
      to_dense:        raw -> dense jax array.
      structure_of:    raw -> SparseStructure (host transfer, done once).
      values_of:       raw -> tuple of value leaves (the trainable /
                       swappable part).
      transpose:       raw -> raw of the same format, transposed.
    """

    name: str
    fmt_type: Optional[type]
    op: Optional[str] = None
    stored_elements: Optional[Callable[[Any], int]] = None
    to_dense: Optional[Callable] = None
    structure_of: Optional[Callable[[Any], SparseStructure]] = None
    values_of: Optional[Callable[[Any], tuple]] = None
    transpose: Optional[Callable] = None


_BY_NAME: Dict[str, SparseFormat] = {}
_BY_TYPE: Dict[type, SparseFormat] = {}


def register_sparse_format(fmt: SparseFormat) -> SparseFormat:
    """Register (or replace) a format descriptor by name and by type."""
    _BY_NAME[fmt.name] = fmt
    if fmt.fmt_type is not None:
        _BY_TYPE[fmt.fmt_type] = fmt
    return fmt


def registered_sparse_formats():
    """Registered format names, dense last."""
    return sorted(_BY_NAME, key=lambda n: (n == "dense", n))


def get_format(name: str) -> SparseFormat:
    """Look up a format descriptor by name."""
    try:
        return _BY_NAME[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown sparse format {name!r}; registered: "
            f"{registered_sparse_formats()}") from None


def _is_dense(x) -> bool:
    return isinstance(x, (np.ndarray, jax.Array)) or np.isscalar(x)


def format_of(x) -> SparseFormat:
    """Descriptor for a value: raw format container, SparseTensor or array."""
    fmt = _BY_TYPE.get(type(x))
    if fmt is not None:
        return fmt
    for t, f in _BY_TYPE.items():
        if isinstance(x, t):
            return f
    structure = getattr(x, "structure", None)
    if isinstance(structure, SparseStructure):  # SparseTensor, duck-typed
        return get_format(structure.fmt)
    if _is_dense(x):
        return _BY_NAME["dense"]
    raise TypeError(
        f"unsupported sparse format {type(x).__name__}; registered "
        f"formats: {registered_sparse_formats()}")


def format_name_of(x) -> str:
    """Registry name of a value's format: ``format_name_of(a) == "bcsr"``."""
    return format_of(x).name


def fill_ratio(dense: np.ndarray, fmt) -> float:
    """Fraction of stored values that are true nonzeros (paper §II-C)."""
    nnz = int((np.asarray(dense) != 0).sum())
    desc = format_of(fmt)
    if desc.stored_elements is None:
        raise TypeError(f"fill_ratio: format {desc.name!r} has no storage "
                        f"accounting")
    return nnz / max(desc.stored_elements(fmt), 1)


# ---------------------------------------------------------------------------
# Built-in formats
# ---------------------------------------------------------------------------

register_sparse_format(SparseFormat(
    name="bcsr",
    fmt_type=F.BCSR,
    op="spmm/bcsr",
    stored_elements=lambda a: a.nnz_blocks * a.block[0] * a.block[1],
    to_dense=F.bcsr_to_dense,
    structure_of=structure_of,
    values_of=lambda a: (a.blocks,),
    transpose=F.bcsr_transpose,
))

register_sparse_format(SparseFormat(
    name="wcsr",
    fmt_type=F.WCSR,
    op="spmm/wcsr",
    stored_elements=lambda a: a.padded_cols * a.b_row,
    to_dense=F.wcsr_to_dense,
    structure_of=structure_of,
    values_of=lambda a: (a.values,),
    transpose=F.wcsr_transpose,
))

register_sparse_format(SparseFormat(
    name="dense",
    fmt_type=None,  # matched structurally by format_of
    op=None,        # spmm rejects dense operands
))
