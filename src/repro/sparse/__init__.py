"""``repro.sparse`` — the format-agnostic sparse-tensor layer.

Built on ``repro.ops`` (which supplies execution: dispatch, config,
auto-tiling, plan caching), this package supplies *representation*:

* raw formats: ``BCSR`` / ``WCSR`` pytrees + host-side constructors
  (``formats``);
* the ``SparseFormat`` protocol + registry (``registry``) — per-format
  behavior declared once, new formats plug in without touching dispatch;
* the conversion graph — ``convert(x, "wcsr", block=...)`` routes through
  registered edges (``convert``), and ``sparsify(dense, format=...,
  method=...)`` prunes straight into either format (``sparsify``);
* structure/values separation — hashable ``SparseStructure`` as the
  planning key (``structure``), and the ``SparseTensor`` wrapper with
  ``A @ B`` / ``.T`` / ``.astype`` / ``.to`` / ``.shard`` ergonomics
  (``tensor``).

``repro.core.formats`` and ``repro.core.sparsify`` re-export the old names
as deprecation shims. Multi-device distribution of these operands lives in
``repro.parallel.sparse`` (``SparseTensor.shard`` lazily routes there).

Exported symbols (one-liners; see each docstring for the full story):

**Containers + constructors**

* ``BCSR`` / ``WCSR`` — the raw format pytrees (paper §II-C); see
  docs/formats.md for the memory-layout walkthrough.
* ``bcsr_from_dense(d, block)`` / ``wcsr_from_dense(d, b_row, b_col)`` —
  host-side builders: ``a = bcsr_from_dense(d, (64, 64))``.
* ``bcsr_from_mask(d, mask, block)`` — keep exactly the blocks ``mask``
  selects (plus empty-row coverage).
* ``bcsr_to_dense(a)`` / ``wcsr_to_dense(w)`` — pure-jnp densify oracles.
* ``bcsr_transpose(a)`` / ``wcsr_transpose(w)`` — format-preserving
  transpose (WCSR re-packs windows via a host-side dense hop).
* ``block_mask_from_dense(d, block)`` — boolean block-occupancy mask.
* ``rcm_permutation(d)`` — Reverse Cuthill-McKee row/col order (the
  paper's preprocessing): ``p = rcm_permutation(d); d[p][:, p]``.

**Format registry**

* ``SparseFormat`` — one descriptor per format (op family, densify,
  storage accounting, structure/values split, transpose).
* ``register_sparse_format(fmt)`` — plug a new format into dispatch,
  ``fill_ratio`` and conversion without touching call sites.
* ``registered_sparse_formats()`` / ``get_format(name)`` /
  ``format_of(x)`` / ``format_name_of(x)`` — lookups:
  ``format_name_of(a) == "bcsr"``.
* ``fill_ratio(dense, fmt)`` — true nonzeros / stored values (§II-C):
  the format-choice metric.

**Conversion + pruning**

* ``convert(x, "wcsr", block=...)`` — route through the conversion graph
  (dense ↔ bcsr/wcsr, mask → bcsr, cross-format via dense hop).
* ``register_conversion(src, dst, fn)`` / ``registered_conversions()`` —
  extend/inspect the graph.
* ``sparsify(w, format=..., sparsity=0.9, method="magnitude")`` — prune a
  dense matrix straight into either format, returns a ``SparseTensor``.
* ``apply_block_mask(w, mask, block)`` — zero everything outside ``mask``.
* ``magnitude_block_mask`` / ``random_block_mask`` / ``banded_block_mask``
  — block-mask generators for the three pruning methods.

**Value codecs**

* ``ValueCodec`` — one per-group-scaled low-precision value representation
  (``none`` | ``int8`` | ``fp8_e4m3``); see ``repro.sparse.codecs``.
* ``register_value_codec(c)`` / ``registered_value_codecs()`` /
  ``get_codec(name)`` — registry lookups.
* ``SparseTensor.quantize("int8")`` / ``.dequantize()`` — hop between raw
  and compressed value storage; ``sparsify(..., codec=...)`` /
  ``convert(..., codec=...)`` quantize on conversion. Kernels consume the
  payload with fused in-register dequant — structure-keyed caches are
  shared with the raw tensors.

**Structure/values separation**

* ``SparseStructure`` — the hashable, host-side half of a sparse matrix;
  memoization key for ``repro.ops.make_plan`` / ``make_partition``.
* ``structure_of(x)`` — one-time extraction from a raw container.
* ``make_wcsr_tasks(w, cpt)`` — compat wrapper for the §III-C task split
  (prefer ``repro.ops.make_plan``, which memoizes it).
* ``SparseTensor`` — the format-agnostic operand: ``st @ b``, ``.T``,
  ``.astype``, ``.to("wcsr", block=...)``, ``.todense()``,
  ``.shard(mesh, axis)``; a pytree with only values as leaves.

**Dynamic structure (deltas)**

* ``append_blocks`` / ``retire_blocks`` (BCSR) and
  ``append_window_chunks`` / ``retire_window_chunks`` (WCSR) — structural
  edits returning ``(new_structure, StructureDelta)``; the tensor-level
  twins (``SparseTensor.append_blocks`` & co.) also splice values,
  requantizing only touched codec groups.
* ``StructureDelta`` / ``delta_of(structure)`` — the edit record and its
  registry: ``make_plan``/``make_partition`` patch cached entries across
  registered deltas instead of rebuilding (see docs/formats.md
  "Structure deltas").
* ``delta_stats()`` — appends/retires, groups reused vs requantized,
  shards reused vs reshipped (mirrored in ``repro.ops.cache_stats()
  ["delta"]`` and ``ServeEngine.stats()["structure_deltas"]``).
"""

from repro.sparse.codecs import (ValueCodec, get_codec,
                                 register_value_codec,
                                 registered_value_codecs)
from repro.sparse.convert import (convert, register_conversion,
                                  registered_conversions)
from repro.sparse.delta import (StructureDelta, append_blocks,
                                append_window_chunks, delta_of, delta_stats,
                                retire_blocks, retire_window_chunks)
from repro.sparse.formats import (BCSR, WCSR, bcsr_from_dense, bcsr_from_mask,
                                  bcsr_to_dense, bcsr_transpose,
                                  block_mask_from_dense, rcm_permutation,
                                  wcsr_from_dense, wcsr_to_dense,
                                  wcsr_transpose)
from repro.sparse.registry import (SparseFormat, fill_ratio, format_name_of,
                                   format_of, get_format,
                                   register_sparse_format,
                                   registered_sparse_formats)
from repro.sparse.sparsify import (apply_block_mask, banded_block_mask,
                                   magnitude_block_mask, random_block_mask,
                                   sparsify)
from repro.sparse.structure import (SparseStructure, make_wcsr_tasks,
                                    structure_of)
from repro.sparse.tensor import SparseTensor

__all__ = [
    # containers + constructors
    "BCSR", "WCSR", "bcsr_from_dense", "bcsr_from_mask", "bcsr_to_dense",
    "bcsr_transpose", "wcsr_from_dense", "wcsr_to_dense", "wcsr_transpose",
    "block_mask_from_dense", "rcm_permutation",
    # format registry
    "SparseFormat", "register_sparse_format", "registered_sparse_formats",
    "get_format", "format_of", "format_name_of", "fill_ratio",
    # conversion + pruning
    "convert", "register_conversion", "registered_conversions", "sparsify",
    "apply_block_mask", "banded_block_mask", "magnitude_block_mask",
    "random_block_mask",
    # structure/values separation
    "SparseStructure", "structure_of", "make_wcsr_tasks", "SparseTensor",
    # value codecs
    "ValueCodec", "register_value_codec", "registered_value_codecs",
    "get_codec",
    # dynamic structure (deltas)
    "StructureDelta", "append_blocks", "retire_blocks",
    "append_window_chunks", "retire_window_chunks", "delta_of",
    "delta_stats",
]
