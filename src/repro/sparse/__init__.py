"""``repro.sparse`` — the format-agnostic sparse-tensor layer.

Built on ``repro.ops`` (which supplies execution: dispatch, config,
auto-tiling, plan caching), this package supplies *representation*:

* raw formats: ``BCSR`` / ``WCSR`` pytrees + host-side constructors
  (``formats``);
* the ``SparseFormat`` protocol + registry (``registry``) — per-format
  behavior declared once, new formats plug in without touching dispatch;
* the conversion graph — ``convert(x, "wcsr", block=...)`` routes through
  registered edges (``convert``), and ``sparsify(dense, format=...,
  method=...)`` prunes straight into either format (``sparsify``);
* structure/values separation — hashable ``SparseStructure`` as the
  planning key (``structure``), and the ``SparseTensor`` wrapper with
  ``A @ B`` / ``.T`` / ``.astype`` / ``.to`` ergonomics (``tensor``).

``repro.core.formats`` and ``repro.core.sparsify`` re-export the old names
as deprecation shims.
"""

from repro.sparse.convert import (convert, register_conversion,
                                  registered_conversions)
from repro.sparse.formats import (BCSR, WCSR, bcsr_from_dense, bcsr_from_mask,
                                  bcsr_to_dense, bcsr_transpose,
                                  block_mask_from_dense, rcm_permutation,
                                  wcsr_from_dense, wcsr_to_dense,
                                  wcsr_transpose)
from repro.sparse.registry import (SparseFormat, fill_ratio, format_name_of,
                                   format_of, get_format,
                                   register_sparse_format,
                                   registered_sparse_formats)
from repro.sparse.sparsify import (apply_block_mask, banded_block_mask,
                                   magnitude_block_mask, random_block_mask,
                                   sparsify)
from repro.sparse.structure import (SparseStructure, make_wcsr_tasks,
                                    structure_of)
from repro.sparse.tensor import SparseTensor

__all__ = [
    # containers + constructors
    "BCSR", "WCSR", "bcsr_from_dense", "bcsr_from_mask", "bcsr_to_dense",
    "bcsr_transpose", "wcsr_from_dense", "wcsr_to_dense", "wcsr_transpose",
    "block_mask_from_dense", "rcm_permutation",
    # format registry
    "SparseFormat", "register_sparse_format", "registered_sparse_formats",
    "get_format", "format_of", "format_name_of", "fill_ratio",
    # conversion + pruning
    "convert", "register_conversion", "registered_conversions", "sparsify",
    "apply_block_mask", "banded_block_mask", "magnitude_block_mask",
    "random_block_mask",
    # structure/values separation
    "SparseStructure", "structure_of", "make_wcsr_tasks", "SparseTensor",
]
