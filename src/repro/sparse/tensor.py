"""``SparseTensor`` — the format-agnostic sparse operand.

One wrapper over the co-designed formats with array-API ergonomics::

    st = repro.sparse.sparsify(w, format="bcsr", sparsity=0.9, block=(64, 64))
    y = st @ x                     # routes into repro.ops.spmm (OpConfig
                                   # precedence applies: use_config / env)
    st.T, st.astype(jnp.bfloat16), st.density, st.fill_ratio(w)
    st.to("wcsr", block=(64, 8))   # conversion graph

Structure/values separation is the point: ``st.structure`` is a hashable
``SparseStructure`` shared across value swaps (weight updates, dtype casts),
so host-side planning (``repro.ops.make_plan``) memoizes per layer — serving
plans once and decodes forever. ``SparseTensor`` is a registered pytree with
*only the values as leaves*; under ``jit`` the structure rides along as
static aux data, which also makes the WCSR kernel path traceable (its task
decomposition comes from the concrete structure, not from a traced
``window_ptr``).
"""

from __future__ import annotations

from typing import Tuple

import jax

from repro.sparse.registry import fill_ratio as _fill_ratio
from repro.sparse.registry import format_of
from repro.sparse.structure import SparseStructure

__all__ = ["SparseTensor"]


class SparseTensor:
    """structure: static ``SparseStructure``; data: tuple of value leaves."""

    __slots__ = ("structure", "data", "_raw", "_sharded")

    def __init__(self, structure: SparseStructure, data):
        self.structure = structure
        self.data = tuple(data)
        self._raw = None
        self._sharded = None  # memoized (mesh, axis) -> ShardedSparseTensor

    @classmethod
    def wrap(cls, raw) -> "SparseTensor":
        """Wrap a raw BCSR/WCSR container (one-time structure extraction)."""
        if isinstance(raw, SparseTensor):
            return raw
        desc = format_of(raw)
        if desc.structure_of is None or desc.values_of is None:
            raise TypeError(
                f"SparseTensor.wrap: format {desc.name!r} does not support "
                "structure/values separation")
        st = cls(desc.structure_of(raw), desc.values_of(raw))
        st._raw = raw
        return st

    @classmethod
    def from_dense(cls, dense, format: str = "bcsr", **kw) -> "SparseTensor":
        """Convert a dense matrix and wrap it: ``from_dense(d, "wcsr", block=...)``."""
        from repro.sparse.convert import convert

        return cls.wrap(convert(dense, format, **kw))

    # -- views -------------------------------------------------------------
    @property
    def raw(self):
        """The raw format container (rebuilt lazily after pytree round-trips)."""
        if self._raw is None:
            self._raw = self.structure.attach_values(*self.data)
        return self._raw

    @property
    def format(self) -> str:
        return self.structure.fmt

    @property
    def shape(self) -> Tuple[int, int]:
        return self.structure.shape

    @property
    def block(self) -> Tuple[int, int]:
        return self.structure.block

    @property
    def dtype(self):
        return self.data[0].dtype

    @property
    def density(self) -> float:
        """Stored fraction of the logical dense matrix (incl. padding)."""
        return self.structure.density

    def fill_ratio(self, dense) -> float:
        """Fraction of stored values that are true nonzeros of ``dense``."""
        return _fill_ratio(dense, self.raw)

    # -- transforms --------------------------------------------------------
    def with_values(self, *data) -> "SparseTensor":
        """Same structure, new value leaves — never re-plans."""
        return SparseTensor(self.structure, data)

    def astype(self, dtype) -> "SparseTensor":
        return self.with_values(*(x.astype(dtype) for x in self.data))

    @property
    def T(self) -> "SparseTensor":
        desc = format_of(self.raw)
        if desc.transpose is None:
            raise TypeError(f"format {desc.name!r} has no transpose")
        return SparseTensor.wrap(desc.transpose(self.raw))

    def to(self, format: str, **kw) -> "SparseTensor":
        """Convert through the registered conversion graph."""
        from repro.sparse.convert import convert

        return convert(self, format, **kw)

    def todense(self) -> jax.Array:
        from repro.sparse.convert import convert

        return convert(self.raw, "dense")

    def shard(self, mesh, axis: str = "data"):
        """Distribute over one mesh axis, partitioned by stored work.

        Returns a ``repro.parallel.sparse.ShardedSparseTensor``: per-device
        shards balanced by nonzero/block count (the paper's §III-C split at
        mesh scale), whose ``@``/``spmm`` runs the local kernel per device
        and sums partial outputs. The partition is memoized per structure
        (``repro.ops.make_partition``) and the sharded wrapper per
        (mesh, axis) on this tensor, so serving shards each layer once::

            sst = st.shard(mesh, "data")
            y = sst @ b                  # == st @ b, on mesh.shape["data"]
        """
        key = (mesh, str(axis))
        if self._sharded is not None and key in self._sharded:
            return self._sharded[key]
        from repro.parallel.sparse import shard_tensor

        sst = shard_tensor(self, mesh, axis)
        if not any(isinstance(x, jax.core.Tracer) for x in self.data):
            if self._sharded is None:
                self._sharded = {}
            self._sharded[key] = sst
        return sst

    # -- ops ---------------------------------------------------------------
    def __matmul__(self, b) -> jax.Array:
        """``self @ B`` via ``repro.ops.spmm`` (ambient OpConfig applies)."""
        from repro.ops import spmm

        return spmm(self, b)

    def matmul(self, b, **kw) -> jax.Array:
        """``spmm`` with per-call keyword overrides (impl=, bn=, ...)."""
        from repro.ops import spmm

        return spmm(self, b, **kw)

    def __repr__(self):
        return (f"SparseTensor({self.format}, shape={self.shape}, "
                f"block={self.block}, dtype={self.dtype}, "
                f"density={self.density:.4f})")


jax.tree_util.register_pytree_node(
    SparseTensor,
    lambda st: (st.data, st.structure),
    lambda structure, data: SparseTensor(structure, data),
)
