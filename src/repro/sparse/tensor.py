"""``SparseTensor`` — the format-agnostic sparse operand.

One wrapper over the co-designed formats with array-API ergonomics::

    st = repro.sparse.sparsify(w, format="bcsr", sparsity=0.9, block=(64, 64))
    y = st @ x                     # routes into repro.ops.spmm (OpConfig
                                   # precedence applies: use_config / env)
    st.T, st.astype(jnp.bfloat16), st.density, st.fill_ratio(w)
    st.to("wcsr", block=(64, 8))   # conversion graph
    st.quantize("int8")            # per-block-scaled value codec

Structure/values separation is the point: ``st.structure`` is a hashable
``SparseStructure`` shared across value swaps (weight updates, dtype casts),
so host-side planning (``repro.ops.make_plan``) memoizes per layer — serving
plans once and decodes forever. ``SparseTensor`` is a registered pytree with
*only the values as leaves*; under ``jit`` the structure rides along as
static aux data, which also makes the WCSR kernel path traceable (its task
decomposition comes from the concrete structure, not from a traced
``window_ptr``).

Value codecs (``repro.sparse.codecs``) extend the same separation to the
value *representation*: a quantized tensor carries ``(payload, scales)`` as
its two value leaves and the codec name as static aux data, while the
structure object stays codec-free — so quantized and raw tensors of one
pruning pattern share every structure-keyed cache (plans' task splits,
mesh partitions) verbatim. ``quantize``/``dequantize`` hop between the
representations; kernels consume the payload directly with fused
in-register dequant (``repro.ops.spmm`` threads payload + scales through).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.sparse.registry import fill_ratio as _fill_ratio
from repro.sparse.registry import format_of
from repro.sparse.structure import SparseStructure

__all__ = ["SparseTensor"]


def _is_traced(data) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in data)


class SparseTensor:
    """structure: static ``SparseStructure``; data: tuple of value leaves
    (one raw value array, or ``(payload, scales)`` under a value codec)."""

    __slots__ = ("structure", "data", "codec", "_raw", "_sharded",
                 "_quantized")

    def __init__(self, structure: SparseStructure, data,
                 codec: str = "none"):
        self.structure = structure
        self.data = tuple(data)
        self.codec = str(codec)
        self._raw = None
        self._sharded = None  # memoized (mesh, axis) -> ShardedSparseTensor
        self._quantized = None  # memoized codec name -> SparseTensor

    @classmethod
    def wrap(cls, raw) -> "SparseTensor":
        """Wrap a raw BCSR/WCSR container (one-time structure extraction)."""
        if isinstance(raw, SparseTensor):
            return raw
        desc = format_of(raw)
        if desc.structure_of is None or desc.values_of is None:
            raise TypeError(
                f"SparseTensor.wrap: format {desc.name!r} does not support "
                "structure/values separation")
        st = cls(desc.structure_of(raw), desc.values_of(raw))
        st._raw = raw
        return st

    @classmethod
    def from_dense(cls, dense, format: str = "bcsr", **kw) -> "SparseTensor":
        """Convert a dense matrix and wrap it: ``from_dense(d, "wcsr", block=...)``.

        ``codec=`` quantizes on conversion (``repro.sparse.codecs``).
        """
        from repro.sparse.convert import convert

        out = convert(dense, format, **kw)
        return out if isinstance(out, SparseTensor) else cls.wrap(out)

    # -- views -------------------------------------------------------------
    @property
    def raw(self):
        """The raw format container (rebuilt lazily after pytree round-trips).

        Under a value codec this **dequantizes**: the raw containers store
        dense-dtype values, so conversions / densify / transpose see the
        decoded matrix. The hot spmm path never calls this for quantized
        tensors — ``repro.ops.spmm`` ships the compressed payload + scales
        straight to the kernels.
        """
        if self._raw is None:
            if self.codec != "none":
                from repro.sparse.codecs import decode_format_values

                values = decode_format_values(
                    self.format, self.block, self.data[0], self.data[1])
                raw = self.structure.attach_values(values)
            else:
                raw = self.structure.attach_values(*self.data)
            if _is_traced(self.data):
                return raw  # don't let traced constants outlive the trace
            self._raw = raw
        return self._raw

    @property
    def format(self) -> str:
        return self.structure.fmt

    @property
    def shape(self) -> Tuple[int, int]:
        return self.structure.shape

    @property
    def block(self) -> Tuple[int, int]:
        return self.structure.block

    @property
    def dtype(self):
        """Dtype of the stored leaf (the payload dtype under a codec)."""
        return self.data[0].dtype

    @property
    def payload(self) -> jax.Array:
        """The stored value leaf (compressed under a codec)."""
        return self.data[0]

    @property
    def scales(self) -> Optional[jax.Array]:
        """Per-group f32 codec scales, or None for codec ``"none"``."""
        return self.data[1] if self.codec != "none" else None

    @property
    def density(self) -> float:
        """Stored fraction of the logical dense matrix (incl. padding)."""
        return self.structure.density

    def fill_ratio(self, dense) -> float:
        """Fraction of stored values that are true nonzeros of ``dense``."""
        return _fill_ratio(dense, self.raw)

    # -- value codecs ------------------------------------------------------
    def quantize(self, codec: str) -> "SparseTensor":
        """Re-encode the values under ``codec`` — same structure object.

        Quantized variants are memoized per codec on this tensor (eager
        values only), so a serving loop that adopts a tuned codec pays the
        encode once per layer. ``quantize("none")`` dequantizes.
        """
        from repro.sparse.codecs import encode_format_values, get_codec

        name = get_codec(codec).name
        if name == self.codec:
            return self
        if self.codec != "none":  # re-encode via the decoded values
            base = self.dequantize()
            return base if name == "none" else base.quantize(name)
        if name == "none":
            return self
        if self._quantized is not None and name in self._quantized:
            return self._quantized[name]
        payload, scales = encode_format_values(
            self.format, self.block, self.data[0], name)
        q = SparseTensor(self.structure, (payload, scales), codec=name)
        if not _is_traced(self.data):
            if self._quantized is None:
                self._quantized = {}
            self._quantized[name] = q
        return q

    def dequantize(self, dtype=None) -> "SparseTensor":
        """Decode back to a raw-value tensor (codec ``"none"``)."""
        if self.codec == "none":
            return self if dtype is None else self.astype(dtype)
        from repro.sparse.codecs import decode_format_values

        import jax.numpy as jnp

        values = decode_format_values(
            self.format, self.block, self.data[0], self.data[1],
            dtype=dtype or jnp.float32)
        return SparseTensor(self.structure, (values,))

    # -- transforms --------------------------------------------------------
    def with_values(self, *data) -> "SparseTensor":
        """Same structure (and codec) new value leaves — never re-plans."""
        return SparseTensor(self.structure, data, codec=self.codec)

    def astype(self, dtype) -> "SparseTensor":
        """Cast the value dtype. Under a codec this **re-quantizes**:
        decode -> cast -> encode, keeping the same structure object so
        every structure-keyed cache (plans, tasks, partitions) still
        hits."""
        if self.codec != "none":
            return self.dequantize(dtype).quantize(self.codec)
        return self.with_values(*(x.astype(dtype) for x in self.data))

    @property
    def T(self) -> "SparseTensor":
        if self.codec != "none":
            # transpose re-packs groups -> decode, transpose, re-encode
            return self.dequantize().T.quantize(self.codec)
        desc = format_of(self.raw)
        if desc.transpose is None:
            raise TypeError(f"format {desc.name!r} has no transpose")
        return SparseTensor.wrap(desc.transpose(self.raw))

    def to(self, format: str, **kw) -> "SparseTensor":
        """Convert through the registered conversion graph.

        Cross-format hops dequantize and re-quantize (the destination
        groups differ); pass ``codec=`` to override the destination codec.
        """
        from repro.sparse.convert import convert

        return convert(self, format, **kw)

    def todense(self) -> jax.Array:
        from repro.sparse.convert import convert

        return convert(self.raw, "dense")

    def shard(self, mesh, axis="data"):
        """Distribute over mesh axes, partitioned by stored work.

        Returns a ``repro.parallel.sparse.ShardedSparseTensor``: per-device
        shards balanced by nonzero/block count (the paper's §III-C split at
        mesh scale), whose ``@``/``spmm`` runs the local kernel per device
        and sums partial outputs. ``axis`` is one mesh-axis name or a tuple
        (``("data", "model")`` shards over both axes jointly — required for
        ``reduce="hier"``). Quantized tensors ship their shards in
        compressed form — each shard's payload slice travels with the f32
        scales of exactly its chunks/blocks. The partition is memoized per
        structure (``repro.ops.make_partition``) and the sharded wrapper
        per (mesh, axes) on this tensor, so serving shards each layer
        once::

            sst = st.shard(mesh, "data")
            y = sst @ b                  # == st @ b, on mesh.shape["data"]
        """
        key = (mesh, (str(axis),) if isinstance(axis, str)
               else tuple(str(x) for x in axis))
        if self._sharded is not None and key in self._sharded:
            return self._sharded[key]
        from repro.parallel.sparse import shard_tensor

        sst = shard_tensor(self, mesh, axis)
        if not _is_traced(self.data):
            if self._sharded is None:
                self._sharded = {}
            self._sharded[key] = sst
        return sst

    # -- dynamic structure (repro.sparse.delta) ----------------------------
    def _apply_delta(self, new_structure, delta, fresh_values):
        from repro.sparse.delta import patch_values

        data = patch_values(delta, self.data, self.codec, fresh_values)
        return SparseTensor(new_structure, data, codec=self.codec)

    def append_blocks(self, rows, cols, values=None) -> "SparseTensor":
        """Grow a BCSR tensor: store new blocks at ``(rows[i], cols[i])``.

        ``values`` is ``[len(rows), bm, bk]`` raw (dense-dtype) block
        values in request order (zeros when omitted). Returns a new tensor
        whose structure is one registered delta away from this one, so
        downstream planning/partitioning **patches** instead of
        rebuilding, and under a codec only the new blocks are quantized —
        every kept block's payload and scale is spliced bitwise.
        """
        from repro.sparse.delta import append_blocks

        new, d = append_blocks(self.structure, rows, cols)
        return self._apply_delta(new, d, values)

    def retire_blocks(self, rows, cols) -> "SparseTensor":
        """Shrink a BCSR tensor: drop stored blocks (see ``append_blocks``).

        A block-row losing its last block keeps a zero coverage block at
        column 0 (the unsharded kernel's every-row-covered invariant).
        """
        from repro.sparse.delta import retire_blocks

        new, d = retire_blocks(self.structure, rows, cols)
        return self._apply_delta(new, d, None)

    def append_window_chunks(self, window, cols,
                             values=None) -> "SparseTensor":
        """Grow a WCSR tensor: store columns ``cols`` in ``window``.

        ``values`` is ``[b_row, len(cols)]`` raw column values in request
        order (zeros when omitted). Only the touched window's chunks are
        re-encoded under a codec; untouched chunks (including their f32
        scales) splice bitwise. The delta is registered, so
        ``make_plan``/``make_partition`` patch their cached entries.
        """
        from repro.sparse.delta import append_window_chunks

        new, d = append_window_chunks(self.structure, window, cols)
        return self._apply_delta(new, d, values)

    def retire_window_chunks(self, window, cols) -> "SparseTensor":
        """Shrink a WCSR tensor: drop stored columns from ``window``."""
        from repro.sparse.delta import retire_window_chunks

        new, d = retire_window_chunks(self.structure, window, cols)
        return self._apply_delta(new, d, None)

    # -- ops ---------------------------------------------------------------
    def __matmul__(self, b) -> jax.Array:
        """``self @ B`` via ``repro.ops.spmm`` (ambient OpConfig applies)."""
        from repro.ops import spmm

        return spmm(self, b)

    def matmul(self, b, **kw) -> jax.Array:
        """``spmm`` with per-call keyword overrides (impl=, bn=, ...)."""
        from repro.ops import spmm

        return spmm(self, b, **kw)

    def __repr__(self):
        codec = "" if self.codec == "none" else f", codec={self.codec}"
        return (f"SparseTensor({self.format}, shape={self.shape}, "
                f"block={self.block}, dtype={self.dtype}, "
                f"density={self.density:.4f}{codec})")


jax.tree_util.register_pytree_node(
    SparseTensor,
    lambda st: (st.data, (st.structure, st.codec)),
    lambda aux, data: SparseTensor(aux[0], data, codec=aux[1]),
)
