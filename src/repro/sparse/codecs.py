"""Value codecs: per-group-scaled low-precision storage of sparse values.

The async pipelines of the paper are bandwidth-bound on the sparse operand:
every byte the Q-deep gather (§III-A) does not move widens the
latency-hiding headroom the depth ablation measures. Acc-SpMM's
bit-compression of the sparse operand and cuTeSpMM's footprint-driven tile
residency (PAPERS.md) both treat operand bytes as a first-order knob; this
module makes that knob pluggable for every value-carrying array in
``repro``.

A ``ValueCodec`` stores values as a compact *payload* plus per-group f32
*scales* (symmetric quantization: ``v ≈ payload * scale``, one scale per
group). The group is always one kernel consumption unit — a ``[bm, bk]``
block for BCSR, a ``[b_row, b_col]`` packed-column chunk for WCSR, a
``[bk, n]`` row-block of a gathered dense operand — so kernels can
dequantize **in-register** right after the DMA lands
(``repro.kernels.pipeline.dequant_tile``) and HBM traffic is only the
compressed payload plus one f32 scale per group.

Built-in codecs:

* ``none``       — identity: values stored at their dense dtype.
* ``int8``       — symmetric int8: ``payload = round(v / scale)`` clipped
                   to [-127, 127], ``scale = amax(group) / 127`` (f32).
* ``fp8_e4m3``   — emulated fp8: payload stored as ``float8_e4m3fn``
                   (4 exponent / 3 mantissa bits, finite-only), scaled so
                   the group max lands at the format's top magnitude
                   (448). Gated on the jax build exposing the dtype; this
                   container emulates the arithmetic in f32 — the wire
                   format (1 byte/value + f32 group scales) is what the
                   bytes-moved modeling measures.

Quantization and dequantization are pure ``jnp`` (jit-traceable), so
quantize-aware paths (``repro.ops.bcsr_matmul``'s codec forward) trace into
compiled steps. Structure hashing is untouched: payload + scales are value
leaves, the ``SparseStructure`` stays codec-free, and every structure-keyed
cache (plans' task splits, mesh partitions) is shared between quantized and
raw tensors of the same pattern.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ValueCodec",
    "register_value_codec",
    "registered_value_codecs",
    "get_codec",
    "resolve_codec_name",
    "encode_format_values",
    "decode_format_values",
    "decode_window_values",
    "encode_rowblocks",
    "decode_rowblocks",
    "fake_quant_rowblocks",
    "encode_seq_blocks",
    "decode_seq_blocks",
    "fake_quant_seq_blocks",
    "modeled_value_bytes",
]

_F8E4M3 = getattr(jnp, "float8_e4m3fn", None)


@dataclasses.dataclass(frozen=True)
class ValueCodec:
    """One value-storage scheme: payload dtype + unit-scale cast.

    Attributes:
      name:            registry key ("none", "int8", "fp8_e4m3", ...).
      storage_dtype:   payload dtype (None for the identity codec).
      bytes_per_value: payload bytes per stored value (scales excluded —
                       they are accounted separately, one f32 per group).
      cap:             largest magnitude representable at unit scale; the
                       encoder maps each group's absolute max onto it.
      cast_unit:       ``cast_unit(x_f32_in_[-cap, cap])`` -> payload array
                       (the rounding/clipping rule of the format).
    """

    name: str
    storage_dtype: Any
    bytes_per_value: float
    cap: float
    cast_unit: Optional[Callable[[jax.Array], jax.Array]] = None


_CODECS: Dict[str, ValueCodec] = {}


def register_value_codec(codec: ValueCodec) -> ValueCodec:
    """Register (or replace) a codec by name."""
    _CODECS[codec.name] = codec
    return codec


def registered_value_codecs():
    """Registered codec names, ``"none"`` first."""
    return sorted(_CODECS, key=lambda n: (n != "none", n))


def get_codec(name: str) -> ValueCodec:
    """Look up a codec descriptor by name."""
    try:
        return _CODECS[str(name).lower()]
    except KeyError:
        raise ValueError(
            f"unknown value codec {name!r}; registered: "
            f"{registered_value_codecs()}") from None


def resolve_codec_name(value_codec) -> str:
    """Normalize an ``OpConfig.value_codec`` field to a concrete name.

    ``None`` and ``"auto"`` resolve to ``"none"`` here — the measured
    auto-tune adoption of ``"auto"`` happens at the spmm dispatch layer
    (``repro.ops.spmm``), which has the operand/tuning context this
    helper deliberately does not.
    """
    if value_codec in (None, "none", "auto"):
        return "none"
    return get_codec(value_codec).name


# ---------------------------------------------------------------------------
# Built-in codecs
# ---------------------------------------------------------------------------

register_value_codec(ValueCodec(
    name="none", storage_dtype=None, bytes_per_value=0.0, cap=0.0))

register_value_codec(ValueCodec(
    name="int8",
    storage_dtype=jnp.int8,
    bytes_per_value=1.0,
    cap=127.0,
    cast_unit=lambda x: jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8),
))

if _F8E4M3 is not None:  # gated: older jax builds lack the ml_dtypes fp8
    register_value_codec(ValueCodec(
        name="fp8_e4m3",
        storage_dtype=_F8E4M3,
        bytes_per_value=1.0,
        cap=448.0,  # float8_e4m3fn max finite magnitude
        cast_unit=lambda x: x.astype(_F8E4M3),
    ))


# ---------------------------------------------------------------------------
# Group encode/decode (pure jnp — traceable under jit)
# ---------------------------------------------------------------------------


def _encode_groups(x: jax.Array, codec: ValueCodec, axes: Tuple[int, ...]
                   ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-group quantization over the reduced ``axes``.

    Returns ``(payload, scale)`` with ``scale`` keeping reduced dims
    (keepdims) in f32. All-zero groups store scale 0 (payload is 0 too),
    so they decode to exact zeros.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = amax / codec.cap
    safe = jnp.where(scale > 0, scale, 1.0)
    return codec.cast_unit(xf / safe), scale


def _decode(payload: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (payload.astype(jnp.float32) * scale.astype(jnp.float32)
            ).astype(dtype)


def encode_format_values(fmt: str, block: Tuple[int, int], values: jax.Array,
                         codec: str) -> Tuple[jax.Array, jax.Array]:
    """Quantize one format's value leaf into ``(payload, scales)``.

    Wire format (the shapes kernels stream):

    * bcsr — values ``[nnz_p, bm, bk]`` -> payload same shape
      (``storage_dtype``), scales ``[nnz_p, 1]`` f32: one scale per stored
      block.
    * wcsr — values ``[b_row, C]`` -> payload same shape, scales
      ``[1, C // b_col]`` f32: one scale per packed-column chunk (the
      §III-C consumption unit), so a scale travels with its chunk through
      task splits and mesh shards.
    """
    c = get_codec(codec)
    if c.name == "none":
        raise ValueError("encode_format_values: codec 'none' stores raw "
                         "values; nothing to encode")
    if fmt == "bcsr":
        payload, scale = _encode_groups(values, c, axes=(1, 2))
        return payload, scale.reshape(values.shape[0], 1)
    if fmt == "wcsr":
        b_row, b_col = int(block[0]), int(block[1])
        cols = values.shape[1]
        if cols % b_col:
            raise ValueError(
                f"wcsr values width {cols} not a multiple of b_col={b_col}")
        nchunks = cols // b_col
        r = values.reshape(values.shape[0], nchunks, b_col)
        payload, scale = _encode_groups(r, c, axes=(0, 2))
        return (payload.reshape(values.shape),
                scale.reshape(1, nchunks))
    raise ValueError(f"encode_format_values: unsupported format {fmt!r}")


def decode_format_values(fmt: str, block: Tuple[int, int], payload: jax.Array,
                         scales: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Dequantize ``(payload, scales)`` back to a dense-dtype value leaf."""
    if fmt == "bcsr":
        return _decode(payload, scales.reshape(-1, 1, 1), dtype)
    if fmt == "wcsr":
        b_col = int(block[1])
        nchunks = payload.shape[1] // b_col
        r = payload.reshape(payload.shape[0], nchunks, b_col)
        out = _decode(r, scales.reshape(1, nchunks, 1), dtype)
        return out.reshape(payload.shape)
    raise ValueError(f"decode_format_values: unsupported format {fmt!r}")


def decode_window_values(block: Tuple[int, int], payload: jax.Array,
                         scales: jax.Array, codec: str,
                         dtype=jnp.float32) -> jax.Array:
    """Dequantize one window's chunk-aligned WCSR column slice.

    The incremental-requantization path (``repro.sparse.delta.
    patch_values``) reconstructs only the *touched* window in f32 before
    re-encoding it; every untouched chunk's payload and scale are spliced
    bitwise without ever being decoded. ``payload`` is ``[b_row, width]``
    (a ``b_col``-multiple slice) with ``scales`` ``[1, width // b_col]`` —
    exactly the window's rows of the wire format.
    """
    c = get_codec(codec)
    if c.name == "none":
        raise ValueError("decode_window_values: codec 'none' stores raw "
                         "values; nothing to decode")
    return decode_format_values("wcsr", block, payload, scales, dtype)


# ---------------------------------------------------------------------------
# Dense-operand grouping (the *gathered* operands of sddmm / block-attn)
# ---------------------------------------------------------------------------


def encode_rowblocks(x: jax.Array, bk: int, codec: str
                     ) -> Tuple[jax.Array, jax.Array]:
    """Quantize a dense ``[k, n]`` operand per ``bk``-row block.

    The sddmm kernel gathers B in ``[bk, n-slice]`` tiles indexed by
    ``block_cols``; one f32 scale per row-block (scales ``[k // bk, 1]``)
    lets the consumer dequantize the whole gathered tile with a single
    scalar multiply.
    """
    c = get_codec(codec)
    k = x.shape[0]
    if k % bk:
        raise ValueError(f"encode_rowblocks: k={k} not a multiple of {bk}")
    r = x.reshape(k // bk, bk, x.shape[1])
    payload, scale = _encode_groups(r, c, axes=(1, 2))
    return payload.reshape(x.shape), scale.reshape(k // bk, 1)


def decode_rowblocks(payload: jax.Array, scales: jax.Array, bk: int,
                     dtype=jnp.float32) -> jax.Array:
    k = payload.shape[0]
    r = payload.reshape(k // bk, bk, payload.shape[1])
    return _decode(r, scales.reshape(-1, 1, 1), dtype).reshape(payload.shape)


def fake_quant_rowblocks(x: jax.Array, bk: int, codec: str) -> jax.Array:
    """Quantize-dequantize round trip (the reference backends' view)."""
    payload, scales = encode_rowblocks(x, bk, codec)
    return decode_rowblocks(payload, scales, bk, dtype=x.dtype)


def encode_seq_blocks(x: jax.Array, blk: int, codec: str
                      ) -> Tuple[jax.Array, jax.Array]:
    """Quantize a ``[rows, S, D]`` K/V operand per ``blk``-long seq block.

    The block-attention kernel gathers K/V in ``[blk, D]`` blocks per
    (kv row, active k-block); scales are ``[rows, S // blk]`` f32 — one per
    gathered block.
    """
    c = get_codec(codec)
    rows, s, d = x.shape
    if s % blk:
        raise ValueError(f"encode_seq_blocks: S={s} not a multiple of {blk}")
    r = x.reshape(rows, s // blk, blk, d)
    payload, scale = _encode_groups(r, c, axes=(2, 3))
    return payload.reshape(x.shape), scale.reshape(rows, s // blk)


def decode_seq_blocks(payload: jax.Array, scales: jax.Array, blk: int,
                      dtype=jnp.float32) -> jax.Array:
    rows, s, d = payload.shape
    r = payload.reshape(rows, s // blk, blk, d)
    return _decode(r, scales.reshape(rows, -1, 1, 1), dtype
                   ).reshape(payload.shape)


def fake_quant_seq_blocks(x: jax.Array, blk: int, codec: str) -> jax.Array:
    payload, scales = encode_seq_blocks(x, blk, codec)
    return decode_seq_blocks(payload, scales, blk, dtype=x.dtype)


# ---------------------------------------------------------------------------
# Bytes-moved modeling
# ---------------------------------------------------------------------------


def modeled_value_bytes(stored_elements: int, group_size: int, codec: str,
                        baseline_itemsize: int = 4) -> Dict[str, float]:
    """Modeled sparse-operand traffic for one structure under ``codec``.

    ``baseline_itemsize`` is the dense value dtype the codec replaces
    (values in this repro originate as f32; pass 2 for a bf16 baseline).
    Compressed traffic = payload bytes + one f32 scale per ``group_size``
    values. Used by ``repro.ops.codec_bytes_report`` and the
    ``table2/codec_*`` ablation rows.
    """
    c = get_codec(codec)
    baseline = float(stored_elements) * baseline_itemsize
    if c.name == "none":
        compressed = baseline
        scale_bytes = 0.0
    else:
        scale_bytes = (stored_elements / max(group_size, 1)) * 4.0
        compressed = stored_elements * c.bytes_per_value + scale_bytes
    return {
        "codec": c.name,
        "baseline_bytes": baseline,
        "compressed_bytes": compressed,
        "scale_bytes": scale_bytes,
        "saved_bytes": baseline - compressed,
        "reduction": baseline / max(compressed, 1e-12),
    }
