"""Format conversion graph: ``convert(x, "wcsr", block=...)``.

Conversions are registered edges between format names; ``convert`` finds the
shortest edge path (BFS) and applies it, so ``BCSR -> WCSR`` routes through
the registered ``bcsr -> dense -> wcsr`` hop without a dedicated direct
conversion. New formats plug in by registering ``dense`` edges and
immediately become reachable from every existing format.

Registered edges:

    dense -> bcsr   (block=..., mask=... for an explicit block mask,
                     pad_to=..., cover_empty_rows=...)
    bcsr  -> dense
    dense -> wcsr   (block=(b_row, b_col) or b_row=/b_col=, pad_cols_to=...)
    wcsr  -> dense

Keyword arguments are forwarded to the edges that accept them (by
signature); a keyword no edge on the path accepts is an error, so typos
don't silently vanish. ``SparseTensor`` inputs convert through their raw
container and are re-wrapped on the way out.

Structures produced by ``repro.sparse.delta`` edits (``append_blocks`` &
co.) flow through this graph unchanged: a delta-patched tensor densifies
and re-converts exactly like one built from scratch, because the delta
builders reproduce the ``bcsr_from_mask`` / ``wcsr_from_dense``
normalization (sorted indices, padding, empty-row coverage) bit for bit.
"""

from __future__ import annotations

import inspect
from collections import deque
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.sparse import formats as F
from repro.sparse.registry import format_name_of, get_format

__all__ = ["register_conversion", "registered_conversions", "convert"]

_EDGES: Dict[Tuple[str, str], Callable] = {}


def register_conversion(src: str, dst: str):
    """Decorator: register ``fn(x, **kw)`` as the ``src -> dst`` edge."""

    def deco(fn):
        fn._accepts = frozenset(
            p.name for p in inspect.signature(fn).parameters.values()
            if p.kind == inspect.Parameter.KEYWORD_ONLY)
        _EDGES[(src.lower(), dst.lower())] = fn
        return fn

    return deco


def registered_conversions() -> List[Tuple[str, str]]:
    """Registered (src, dst) conversion edges: ``("dense", "bcsr"), ...``."""
    return sorted(_EDGES)


def _find_path(src: str, dst: str) -> List[Tuple[str, str]]:
    """Shortest edge sequence from src to dst (BFS over the edge graph)."""
    frontier = deque([(src, ())])
    seen = {src}
    while frontier:
        node, path = frontier.popleft()
        if node == dst:
            return list(path)
        for (a, b_) in _EDGES:
            if a == node and b_ not in seen:
                seen.add(b_)
                frontier.append((b_, path + ((a, b_),)))
    raise ValueError(
        f"no conversion path {src!r} -> {dst!r}; registered edges: "
        f"{registered_conversions()}")


def convert(x, to: str, codec: str | None = None, **kwargs):
    """Convert ``x`` (dense array, raw format, or SparseTensor) to ``to``.

    ``to`` is a registered format name ("dense", "bcsr", "wcsr", ...).
    Returns the same flavor as the input: raw in -> raw out, SparseTensor
    in -> SparseTensor out (unless ``to="dense"``, which always returns a
    dense array).

    ``codec`` selects a value codec (``repro.sparse.codecs``) for the
    result: quantize on conversion. Cross-format hops from a quantized
    ``SparseTensor`` dequantize for the hop (the raw containers and the
    dense intermediate are always dense-dtype) and re-quantize on the way
    out — to the source tensor's codec by default, or to ``codec`` when
    given (``codec="none"`` strips it). Requesting a codec on a raw/dense
    input returns a ``SparseTensor`` (the payload + scales carrier).
    """
    from repro.sparse.tensor import SparseTensor

    orig = x
    rewrap = isinstance(x, SparseTensor)
    src_codec = x.codec if rewrap else "none"
    if codec is not None:
        from repro.sparse.codecs import get_codec

        codec = get_codec(codec).name  # validates the codec name
    if rewrap:
        x = x.raw  # dequantized view for quantized tensors
    dst = get_format(to).name  # validates the target name
    src = format_name_of(x)
    out_codec = src_codec if codec is None else codec
    if src == dst and not kwargs:
        # identity path (keeps any cached SparseTensor structure) — unless
        # a codec change was requested, which re-encodes values in place
        if rewrap:
            return orig if out_codec == orig.codec else orig.quantize(out_codec)
        if out_codec == "none" or dst == "dense":
            return orig
        return SparseTensor.wrap(x).quantize(out_codec)
    if src == dst:
        # keywords request a re-pack (e.g. new block geometry): route
        # through dense so they apply — and typos still get validated
        path = _find_path(src, "dense") + _find_path("dense", dst)
    else:
        path = _find_path(src, dst)
    consumed = set()
    for edge in path:
        consumed |= _EDGES[edge]._accepts
    unknown = set(kwargs) - consumed
    if unknown:
        raise TypeError(
            f"convert {src!r} -> {dst!r}: unexpected keyword(s) "
            f"{sorted(unknown)}; path {path} accepts {sorted(consumed)}")
    for edge in path:
        fn = _EDGES[edge]
        kw = {k: v for k, v in kwargs.items() if k in fn._accepts}
        x = fn(x, **kw)
    if dst == "dense":
        return x  # always decoded: to_dense dequantizes
    if rewrap or out_codec != "none":
        out = SparseTensor.wrap(x)
        return out if out_codec == "none" else out.quantize(out_codec)
    return x


# ---------------------------------------------------------------------------
# Built-in edges
# ---------------------------------------------------------------------------


@register_conversion("dense", "bcsr")
def _dense_to_bcsr(x, *, block=(128, 128), mask=None, pad_to=None,
                   cover_empty_rows=True):
    x = np.asarray(x)
    block = tuple(block)
    if mask is None:
        mask = F.block_mask_from_dense(x, block)
    else:
        # an explicit mask defines the stored pattern: zero the rest so
        # coverage blocks (empty block-rows) don't leak unmasked values
        from repro.sparse.sparsify import apply_block_mask

        x = apply_block_mask(x, mask, block)
    return F.bcsr_from_mask(x, mask, block, pad_to=pad_to,
                            cover_empty_rows=cover_empty_rows)


@register_conversion("bcsr", "dense")
def _bcsr_to_dense(x):
    return F.bcsr_to_dense(x)


@register_conversion("dense", "wcsr")
def _dense_to_wcsr(x, *, block=None, b_row=None, b_col=None,
                   pad_cols_to=None):
    if block is not None:
        b_row, b_col = block
    b_row = 128 if b_row is None else int(b_row)
    b_col = 8 if b_col is None else int(b_col)
    return F.wcsr_from_dense(np.asarray(x), b_row=b_row, b_col=b_col,
                             pad_cols_to=pad_cols_to)


@register_conversion("wcsr", "dense")
def _wcsr_to_dense(x):
    return F.wcsr_to_dense(x)
