"""Structure deltas: incremental append/retire of stored blocks / chunks.

Every cache in the stack — plans, task decompositions, partitions, tuned
entries, codec encodings — keys on an immutable ``SparseStructure``, so a
serving workload whose sparsity mutates (growing causal block masks during
decode, MoE expert-routing shifts, in-training magnitude pruning) would
re-plan, re-partition and re-quantize from scratch on every step. This
module makes structure changes *first-class*: the four delta builders

* ``append_blocks`` / ``retire_blocks``          (BCSR, block granular)
* ``append_window_chunks`` / ``retire_window_chunks``  (WCSR, column granular)

each return a brand-new (still immutable) ``SparseStructure`` **plus** a
``StructureDelta`` describing exactly what moved: which block-rows /
row-windows were touched, how untouched value groups map from base to new
positions, and the half-open span of group slots outside which the change
is a pure prefix-copy / uniform shift. Downstream consumers patch instead
of rebuilding:

* ``repro.ops.make_plan`` reuses the base plan's tile width and patches
  only the touched windows' tasks (``patch_tasks``) — counted as
  ``plan_patched`` in ``cache_stats()``, not as a miss;
* ``repro.ops.make_partition`` → ``repro.parallel.sparse.patch_partition``
  recomputes boundaries but reships only the shards whose unit range
  intersects the changed span (pure-shift shards reuse the base shard
  object, and with it its per-shard plan cache entries);
* ``patch_values`` splices value arrays: for codec tensors the untouched
  groups' payload *and scales* are copied bitwise from the base encoding —
  only the touched groups are requantized
  (``groups_requantized`` / ``groups_reused`` counters).

The new structures reproduce ``bcsr_from_mask`` / ``wcsr_from_dense``
conventions exactly (row-major block order, coverage blocks for emptied
BCSR rows, ``b_col``-aligned window widths with ``-1`` column padding, the
``max(total, b_col)`` floor), so a delta chain is bit-identical in
structure to a from-scratch rebuild — the property
``tests/test_structure_delta.py`` checks differentially. Deltas also
splice per-row content digests, making ``content_digest()`` O(touched)
along a chain.

Delta records are kept in a registry keyed by the *new* structure
(``delta_of``), which is how ``make_plan`` / ``make_partition`` discover
that an incoming structure is one step away from something they already
planned. Padding normalization: delta-produced BCSR structures use the
default ``npad = max(nnz, 1)`` padding; bases built with an explicit
``pad_to`` are re-padded to the default on the first delta.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.sparse.structure import SparseStructure

__all__ = [
    "StructureDelta",
    "append_blocks",
    "retire_blocks",
    "append_window_chunks",
    "retire_window_chunks",
    "delta_of",
    "patch_tasks",
    "patch_values",
    "delta_stats",
    "reset_delta_stats",
]


# ---------------------------------------------------------------------------
# Counters (reset by clear_plan_cache / clear_tuning_cache)
# ---------------------------------------------------------------------------

def _zero_stats() -> Dict[str, int]:
    return {
        "appends": 0,
        "retires": 0,
        "groups_reused": 0,
        "groups_requantized": 0,
        "shards_reused": 0,
        "shards_reshipped": 0,
    }


_STATS = _zero_stats()


def delta_stats() -> Dict[str, int]:
    """Counters for the incremental-structure paths (copy).

    ``appends``/``retires`` count delta builder calls;
    ``groups_reused``/``groups_requantized`` count codec value groups
    (BCSR blocks / WCSR chunks) spliced bitwise vs re-encoded by
    ``patch_values``; ``shards_reused``/``shards_reshipped`` count
    per-device shards kept vs rebuilt by ``patch_partition``.
    """
    return dict(_STATS)


def reset_delta_stats() -> None:
    _STATS.update(_zero_stats())


def _count(key: str, n: int = 1) -> None:
    _STATS[key] += n


# ---------------------------------------------------------------------------
# The delta record
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class StructureDelta:
    """One structural edit: ``base`` structure -> ``new`` structure.

    Group/unit convention: a *group* is the codec scale granule and the
    partitioner unit — one stored block for BCSR, one packed ``b_col``
    column chunk for WCSR. ``kept_src``/``kept_dst`` map every group whose
    stored content is unchanged from its base slot to its new slot;
    ``fresh_dst`` lists new-structure groups that must be (re)encoded.
    ``span_base``/``span_new`` bound the edit: group slots below the span
    are identical in place, slots at/above it are the base suffix shifted
    uniformly by ``unit_shift`` — the invariant partition patching leans
    on. ``moved_src``/``moved_dst``/``fresh_pos`` are flat *value*
    positions (WCSR packed columns; BCSR block slots) for value splicing.
    """

    fmt: str                       # "bcsr" | "wcsr"
    kind: str                      # "append" | "retire"
    base: SparseStructure
    new: SparseStructure
    touched_rows: Tuple[int, ...]  # block-rows (bcsr) / windows (wcsr)
    kept_src: np.ndarray           # base group slots copied verbatim...
    kept_dst: np.ndarray           # ...to these new group slots
    fresh_dst: np.ndarray          # new group slots needing (re)encode
    span_base: Tuple[int, int]     # changed group-slot span in base
    span_new: Tuple[int, int]      # changed group-slot span in new
    moved_src: Optional[np.ndarray] = None  # wcsr: surviving col positions
    moved_dst: Optional[np.ndarray] = None
    fresh_pos: Optional[np.ndarray] = None  # appended entries, caller order

    @property
    def unit_shift(self) -> int:
        """Uniform slot shift of the base suffix past ``span_base``."""
        return ((self.span_new[1] - self.span_new[0])
                - (self.span_base[1] - self.span_base[0]))


_DELTAS: Dict[SparseStructure, StructureDelta] = {}


def delta_of(structure: SparseStructure) -> Optional[StructureDelta]:
    """The delta that produced ``structure``, if it came from one.

    ``make_plan`` / ``make_partition`` probe this on a cache miss: if the
    structure is one delta away from an already-planned base, they patch
    the base entry instead of rebuilding.
    """
    return _DELTAS.get(structure)


def _finish(d: StructureDelta) -> StructureDelta:
    _count("appends" if d.kind == "append" else "retires")
    # splice per-row digests: only touched rows are rehashed
    dig = list(d.base.row_digests())
    for r in d.touched_rows:
        dig[r] = d.new._row_digest(r)
    d.new._rowdig = tuple(dig)
    _DELTAS[d.new] = d
    return d


# ---------------------------------------------------------------------------
# BCSR: append / retire stored blocks
# ---------------------------------------------------------------------------


def _check_fmt(g, fmt: str, op: str) -> SparseStructure:
    if not isinstance(g, SparseStructure):
        from repro.sparse.structure import structure_of

        g = structure_of(g)
    if g.fmt != fmt:
        raise ValueError(f"{op}: expects a {fmt} structure, got {g.fmt!r}")
    return g


def _as_index(x, name: str) -> np.ndarray:
    a = np.atleast_1d(np.asarray(x, np.int64)).ravel()
    if a.size == 0:
        raise ValueError(f"{name}: empty request")
    return a


def _build_bcsr(g: SparseStructure, rows: np.ndarray,
                cols: np.ndarray) -> SparseStructure:
    """New BCSR structure from sorted (row, col) block lists, reproducing
    ``bcsr_from_mask`` conventions (default padding)."""
    m_b = g.shape[0] // g.block[0]
    nnz = len(rows)
    npad = max(nnz, 1)
    prow = np.full(npad, rows[-1] if nnz else 0, np.int64)
    pcol = np.zeros(npad, np.int64)
    prow[:nnz] = rows
    pcol[:nnz] = cols
    ptr = np.zeros(m_b + 1, np.int64)
    np.add.at(ptr, rows + 1, 1)
    ptr = np.cumsum(ptr)
    return SparseStructure(fmt="bcsr", shape=g.shape, block=g.block,
                           nnz=nnz, ptrs=ptr, indices=(prow, pcol))


def append_blocks(structure, rows, cols
                  ) -> Tuple[SparseStructure, StructureDelta]:
    """Add stored blocks at block coordinates ``(rows[i], cols[i])``.

    Returns ``(new_structure, delta)``. Appending a block that is already
    stored (including a zero *coverage* block left by ``retire_blocks``)
    is an error — retire it first if it must be replaced.
    """
    g = _check_fmt(structure, "bcsr", "append_blocks")
    bm, bk = g.block
    m_b, k_b = g.shape[0] // bm, g.shape[1] // bk
    rows = _as_index(rows, "append_blocks: rows")
    cols = _as_index(cols, "append_blocks: cols")
    if rows.shape != cols.shape:
        raise ValueError("append_blocks: rows/cols length mismatch")
    if ((rows < 0) | (rows >= m_b) | (cols < 0) | (cols >= k_b)).any():
        raise ValueError(
            f"append_blocks: block coords out of range for "
            f"{m_b}x{k_b} block grid")
    nnz = g.nnz
    b_rows = g.indices[0][:nnz].astype(np.int64)
    b_cols = g.indices[1][:nnz].astype(np.int64)
    base_keys = b_rows * k_b + b_cols
    new_keys = rows * k_b + cols
    if len(np.unique(new_keys)) != len(new_keys):
        raise ValueError("append_blocks: duplicate (row, col) in request")
    clash = np.isin(new_keys, base_keys)
    if clash.any():
        i = int(np.flatnonzero(clash)[0])
        raise ValueError(f"append_blocks: block ({rows[i]}, {cols[i]}) "
                         "already stored")
    order = np.argsort(np.concatenate([base_keys, new_keys]), kind="stable")
    dst = np.empty(len(order), np.int64)
    dst[order] = np.arange(len(order))
    fresh_pos = dst[nnz:]
    new = _build_bcsr(g, np.concatenate([b_rows, rows])[order],
                      np.concatenate([b_cols, cols])[order])
    lo = int(np.searchsorted(base_keys, new_keys.min()))
    hi = int(np.searchsorted(base_keys, new_keys.max()))
    d = StructureDelta(
        fmt="bcsr", kind="append", base=g, new=new,
        touched_rows=tuple(int(r) for r in np.unique(rows)),
        kept_src=np.arange(nnz), kept_dst=dst[:nnz],
        fresh_dst=np.sort(fresh_pos),
        span_base=(lo, hi), span_new=(lo, hi + len(new_keys)),
        fresh_pos=fresh_pos)
    _finish(d)
    return new, d


def retire_blocks(structure, rows, cols
                  ) -> Tuple[SparseStructure, StructureDelta]:
    """Remove stored blocks at block coordinates ``(rows[i], cols[i])``.

    A block-row whose last stored block is retired gets a zero *coverage*
    block at column 0 — the unsharded BCSR kernel only writes output rows
    it visits, so every block-row must keep at least one stored block
    (the same rule ``bcsr_from_mask(cover_empty_rows=True)`` applies).
    """
    g = _check_fmt(structure, "bcsr", "retire_blocks")
    bm, bk = g.block
    m_b, k_b = g.shape[0] // bm, g.shape[1] // bk
    rows = _as_index(rows, "retire_blocks: rows")
    cols = _as_index(cols, "retire_blocks: cols")
    if rows.shape != cols.shape:
        raise ValueError("retire_blocks: rows/cols length mismatch")
    nnz = g.nnz
    if nnz == 0:
        raise ValueError("retire_blocks: structure stores no blocks")
    b_rows = g.indices[0][:nnz].astype(np.int64)
    b_cols = g.indices[1][:nnz].astype(np.int64)
    base_keys = b_rows * k_b + b_cols
    rm_keys = rows * k_b + cols
    if len(np.unique(rm_keys)) != len(rm_keys):
        raise ValueError("retire_blocks: duplicate (row, col) in request")
    pos = np.searchsorted(base_keys, rm_keys)
    bad = (pos >= nnz) | (base_keys[np.minimum(pos, nnz - 1)] != rm_keys)
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        raise ValueError(f"retire_blocks: block ({rows[i]}, {cols[i]}) "
                         "not stored")
    keep = np.ones(nnz, bool)
    keep[pos] = False
    kept_rows, kept_cols = b_rows[keep], b_cols[keep]
    counts = np.bincount(kept_rows, minlength=m_b)
    emptied = np.asarray(
        [r for r in np.unique(rows) if counts[r] == 0], np.int64)
    cov_keys = emptied * k_b  # coverage block at (r, 0)
    order = np.argsort(np.concatenate([base_keys[keep], cov_keys]),
                       kind="stable")
    dst = np.empty(len(order), np.int64)
    dst[order] = np.arange(len(order))
    n_kept = int(keep.sum())
    new = _build_bcsr(
        g, np.concatenate([kept_rows, emptied])[order],
        np.concatenate([kept_cols, np.zeros(len(emptied), np.int64)])[order])
    lo, hi = int(pos.min()), int(pos.max()) + 1
    d = StructureDelta(
        fmt="bcsr", kind="retire", base=g, new=new,
        touched_rows=tuple(int(r) for r in np.unique(rows)),
        kept_src=np.flatnonzero(keep), kept_dst=dst[:n_kept],
        fresh_dst=np.sort(dst[n_kept:]),
        span_base=(lo, hi),
        span_new=(lo, hi - len(rm_keys) + len(emptied)),
        fresh_pos=dst[n_kept:])
    _finish(d)
    return new, d


# ---------------------------------------------------------------------------
# WCSR: append / retire packed columns of one row-window
# ---------------------------------------------------------------------------


def _round_up(x: int, to: int) -> int:
    return -(-x // to) * to


def _wcsr_edit(g: SparseStructure, w: int, union: np.ndarray,
               old_real: np.ndarray, kind: str,
               touched_cols) -> Tuple[SparseStructure, StructureDelta]:
    """Shared repack: window ``w``'s stored column set becomes ``union``."""
    b_row, b_col = g.block
    ptr = g.ptrs.astype(np.int64)
    p0, p1 = int(ptr[w]), int(ptr[w + 1])
    end_base = int(ptr[-1])
    width_new = _round_up(len(union), b_col)
    delta_w = width_new - (p1 - p0)
    new_ptr = ptr.copy()
    new_ptr[w + 1:] += delta_w
    total_new = max(int(new_ptr[-1]), b_col)
    ci = np.full(total_new, -1, np.int64)
    ci[:p0] = g.indices[0][:p0]
    ci[p0:p0 + len(union)] = union
    ci[p0 + width_new:p0 + width_new + (end_base - p1)] = \
        g.indices[0][p1:end_base]
    new = SparseStructure(fmt="wcsr", shape=g.shape, block=g.block,
                          nnz=total_new, ptrs=new_ptr, indices=(ci,))
    c_p0, c_p1, c_end = p0 // b_col, p1 // b_col, end_base // b_col
    c_shift = delta_w // b_col
    kept_src = np.concatenate([np.arange(c_p0), np.arange(c_p1, c_end)])
    kept_dst = np.concatenate([np.arange(c_p0),
                               np.arange(c_p1, c_end) + c_shift])
    # surviving columns of the window: old packed position -> new position
    surv = np.flatnonzero(np.isin(old_real, union))
    moved_src = p0 + surv
    moved_dst = p0 + np.searchsorted(union, old_real[surv])
    fresh_pos = (p0 + np.searchsorted(union, touched_cols)
                 if kind == "append" else np.empty(0, np.int64))
    d = StructureDelta(
        fmt="wcsr", kind=kind, base=g, new=new, touched_rows=(int(w),),
        kept_src=kept_src, kept_dst=kept_dst,
        fresh_dst=np.arange(c_p0, c_p0 + width_new // b_col),
        span_base=(c_p0, c_p1),
        span_new=(c_p0, c_p0 + width_new // b_col),
        moved_src=moved_src, moved_dst=moved_dst, fresh_pos=fresh_pos)
    _finish(d)
    return new, d


def _wcsr_window(g, w: int, op: str):
    b_col = g.block[1]
    if g.nnz % b_col:
        raise ValueError(f"{op}: padded_cols ({g.nnz}) not a multiple of "
                         f"b_col ({b_col}) — explicit pad_cols_to bases "
                         "are not delta-patchable")
    w = int(w)
    if not 0 <= w < g.num_windows:
        raise ValueError(f"{op}: window {w} out of range "
                         f"[0, {g.num_windows})")
    p0, p1 = int(g.ptrs[w]), int(g.ptrs[w + 1])
    old = g.indices[0][p0:p1].astype(np.int64)
    return w, old[old >= 0]


def append_window_chunks(structure, window, cols
                         ) -> Tuple[SparseStructure, StructureDelta]:
    """Add stored columns ``cols`` to row-window ``window``.

    The window's packed column set becomes the sorted union; its width is
    re-padded to a ``b_col`` multiple (``-1`` column padding), windows
    after it shift. Returns ``(new_structure, delta)``.
    """
    g = _check_fmt(structure, "wcsr", "append_window_chunks")
    w, old_real = _wcsr_window(g, window, "append_window_chunks")
    cols = _as_index(cols, "append_window_chunks: cols")
    if len(np.unique(cols)) != len(cols):
        raise ValueError("append_window_chunks: duplicate columns")
    if ((cols < 0) | (cols >= g.shape[1])).any():
        raise ValueError("append_window_chunks: columns out of range")
    if np.isin(cols, old_real).any():
        raise ValueError("append_window_chunks: column already stored in "
                         f"window {w}")
    union = np.sort(np.concatenate([old_real, cols]))
    return _wcsr_edit(g, w, union, old_real, "append", cols)


def retire_window_chunks(structure, window, cols
                         ) -> Tuple[SparseStructure, StructureDelta]:
    """Remove stored columns ``cols`` from row-window ``window``.

    The remaining columns repack densely (width re-padded to a ``b_col``
    multiple; a fully-emptied window keeps width 0 — empty windows are
    legal in WCSR, they simply emit no tasks).
    """
    g = _check_fmt(structure, "wcsr", "retire_window_chunks")
    w, old_real = _wcsr_window(g, window, "retire_window_chunks")
    cols = _as_index(cols, "retire_window_chunks: cols")
    if len(np.unique(cols)) != len(cols):
        raise ValueError("retire_window_chunks: duplicate columns")
    if not np.isin(cols, old_real).all():
        raise ValueError(f"retire_window_chunks: column not stored in "
                         f"window {w}")
    union = np.setdiff1d(old_real, cols)
    return _wcsr_edit(g, w, union, old_real, "retire", cols)


# ---------------------------------------------------------------------------
# Plan patching (WCSR task decomposition)
# ---------------------------------------------------------------------------


def patch_tasks(d: StructureDelta, base_tasks, chunks_per_task: int):
    """Patch a §III-C task decomposition across a delta.

    Tasks of untouched windows are kept with their chunk starts shifted by
    that window's pointer delta; touched windows' tasks are re-emitted
    from scratch. Output ordering matches ``SparseStructure.tasks``
    (windows ascending, chunk starts ascending within a window), so the
    patched arrays are element-equal to a from-scratch decomposition.
    """
    g_new, g_base = d.new, d.base
    b_col = g_new.block[1]
    t_win, t_start, t_n = (np.asarray(t, np.int64) for t in base_tasks)
    real = t_n > 0  # drop the empty-matrix sentinel task, if any
    t_win, t_start, t_n = t_win[real], t_start[real], t_n[real]
    touched = np.asarray(d.touched_rows, np.int64)
    keep = ~np.isin(t_win, touched)
    shifts = (g_new.ptrs[:-1].astype(np.int64)
              - g_base.ptrs[:-1].astype(np.int64)) // b_col
    k_win = t_win[keep]
    k_start = t_start[keep] + shifts[k_win]
    k_n = t_n[keep]
    n_win, n_start, n_n = [], [], []
    for w in touched:
        c0, c1 = int(g_new.ptrs[w]), int(g_new.ptrs[w + 1])
        nchunks = (c1 - c0) // b_col
        g = 0
        while g < nchunks:
            take = min(chunks_per_task, nchunks - g)
            n_win.append(int(w))
            n_start.append(c0 // b_col + g)
            n_n.append(take)
            g += take
    aw = np.concatenate([k_win, np.asarray(n_win, np.int64)])
    ast = np.concatenate([k_start, np.asarray(n_start, np.int64)])
    an = np.concatenate([k_n, np.asarray(n_n, np.int64)])
    order = np.lexsort((ast, aw))
    aw, ast, an = aw[order], ast[order], an[order]
    if not len(aw):  # fully-empty matrix: keep the no-op sentinel task
        aw, ast, an = np.zeros(1, np.int64), np.zeros(1, np.int64), \
            np.zeros(1, np.int64)
    return (np.asarray(aw, np.int32), np.asarray(ast, np.int32),
            np.asarray(an, np.int32))


# ---------------------------------------------------------------------------
# Value patching (codec-aware: untouched groups splice bitwise)
# ---------------------------------------------------------------------------


def patch_values(d: StructureDelta, data, codec: str = "none",
                 fresh_values=None):
    """Splice a value ``data`` tuple (raw or codec-encoded) across a delta.

    Untouched groups are copied verbatim — for codec tensors both the
    compressed payload and the f32 scales of kept groups are reused
    bitwise (counted in ``groups_reused``); only touched groups are
    (re)quantized (``groups_requantized``). ``fresh_values`` supplies the
    appended entries' raw (f32) values in the caller's request order —
    zeros when omitted. Retired slots need none; BCSR coverage blocks are
    zero (zero payload, zero scale — exactly what a rebuild encodes).
    """
    import jax.numpy as jnp

    g_new, g_base = d.new, d.base
    if d.fmt == "bcsr":
        return _patch_bcsr_values(d, data, codec, fresh_values, jnp)
    return _patch_wcsr_values(d, data, codec, fresh_values, jnp)


def _patch_bcsr_values(d, data, codec, fresh_values, jnp):
    from repro.sparse.codecs import encode_format_values

    bm, bk = d.new.block
    npad = max(d.new.nnz, 1)
    kept_src = jnp.asarray(d.kept_src, jnp.int32)
    kept_dst = jnp.asarray(d.kept_dst, jnp.int32)
    has_fresh = fresh_values is not None and len(d.fresh_pos)
    if codec == "none":
        (blocks,) = data
        out = jnp.zeros((npad, bm, bk), blocks.dtype)
        if len(d.kept_src):
            out = out.at[kept_dst].set(blocks[kept_src])
        if has_fresh:
            out = out.at[jnp.asarray(d.fresh_pos, jnp.int32)].set(
                jnp.asarray(fresh_values, blocks.dtype))
        return (out,)
    payload, scales = data
    outp = jnp.zeros((npad, bm, bk), payload.dtype)
    outs = jnp.zeros((npad, 1), scales.dtype)
    if len(d.kept_src):
        outp = outp.at[kept_dst].set(payload[kept_src])
        outs = outs.at[kept_dst].set(scales[kept_src])
    if has_fresh:
        fp, fs = encode_format_values(
            "bcsr", (bm, bk), jnp.asarray(fresh_values, jnp.float32), codec)
        pos = jnp.asarray(d.fresh_pos, jnp.int32)
        outp = outp.at[pos].set(fp)
        outs = outs.at[pos].set(fs)
    _count("groups_reused", len(d.kept_src))
    _count("groups_requantized", len(d.fresh_pos) if has_fresh else 0)
    return (outp, outs)


def _patch_wcsr_values(d, data, codec, fresh_values, jnp):
    from repro.sparse.codecs import decode_window_values, \
        encode_format_values

    g_new, g_base = d.new, d.base
    b_row, b_col = g_new.block
    nch_new = g_new.nnz // b_col
    w = d.touched_rows[0]
    p0n, p1n = int(g_new.ptrs[w]), int(g_new.ptrs[w + 1])
    kept_src = jnp.asarray(d.kept_src, jnp.int32)
    kept_dst = jnp.asarray(d.kept_dst, jnp.int32)
    has_fresh = fresh_values is not None and len(d.fresh_pos)
    if codec == "none":
        (vals,) = data
        r = vals.reshape(b_row, g_base.nnz // b_col, b_col)
        out = jnp.zeros((b_row, nch_new, b_col), vals.dtype)
        if len(d.kept_src):
            out = out.at[:, kept_dst].set(r[:, kept_src])
        out = out.reshape(b_row, g_new.nnz)
        if len(d.moved_src):
            out = out.at[:, jnp.asarray(d.moved_dst, jnp.int32)].set(
                vals[:, jnp.asarray(d.moved_src, jnp.int32)])
        if has_fresh:
            out = out.at[:, jnp.asarray(d.fresh_pos, jnp.int32)].set(
                jnp.asarray(fresh_values, vals.dtype))
        return (out,)
    payload, scales = data
    outp = jnp.zeros((b_row, nch_new, b_col), payload.dtype)
    outs = jnp.zeros((1, nch_new), scales.dtype)
    if len(d.kept_src):
        base_r = payload.reshape(b_row, g_base.nnz // b_col, b_col)
        outp = outp.at[:, kept_dst].set(base_r[:, kept_src])
        outs = outs.at[:, kept_dst].set(scales[:, kept_src])
    outp = outp.reshape(b_row, g_new.nnz)
    # rebuild the touched window in f32, then re-encode only its chunks
    win = jnp.zeros((b_row, p1n - p0n), jnp.float32)
    if len(d.moved_src):
        p0b, p1b = int(g_base.ptrs[w]), int(g_base.ptrs[w + 1])
        dec = decode_window_values(
            (b_row, b_col), payload[:, p0b:p1b],
            scales[:, p0b // b_col:p1b // b_col], codec)
        win = win.at[:, jnp.asarray(d.moved_dst - p0n, jnp.int32)].set(
            dec[:, jnp.asarray(d.moved_src - p0b, jnp.int32)])
    if has_fresh:
        win = win.at[:, jnp.asarray(d.fresh_pos - p0n, jnp.int32)].set(
            jnp.asarray(fresh_values, jnp.float32))
    if p1n > p0n:
        wp, ws = encode_format_values("wcsr", (b_row, b_col), win, codec)
        outp = outp.at[:, p0n:p1n].set(wp)
        outs = outs.at[:, p0n // b_col:p1n // b_col].set(ws)
    _count("groups_reused", len(d.kept_src))
    _count("groups_requantized", (p1n - p0n) // b_col)
    return (outp, outs)
