"""serve_step factory + decode-cache sharding axes for the dry-run.

Cache sharding (DESIGN.md §6, beyond-paper): the KV cache shards its
*sequence* dim over the model axis — flash-decoding-style split-KV. Each
model shard scores its cache segment; GSPMD inserts the small softmax-stat
and output psums. This is what fits decode_32k for the big archs (a
replicated 0.9 TB cache would never fit) and keeps kv_heads < model_size
archs shardable (head-sharding would not divide).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache
from repro.models.transformer import DecodeCache

__all__ = ["decode_cache_axes", "make_serve_step"]


def _kv_axes(stack_dims: int):
    lead = (None,) * stack_dims
    return KVCache(
        k=lead + ("batch", "kv_seq", None, None),
        v=lead + ("batch", "kv_seq", None, None),
        pos=lead + ("batch", "kv_seq"),
    )


def decode_cache_axes(cfg) -> DecodeCache:
    """Logical axes tree matching init_decode_cache's structure."""
    if cfg.is_encdec:
        return DecodeCache(
            kv=_kv_axes(1), ssm=None, prev1=None, prev2=None,
            xkv=("batch", None, None),
        )
    if cfg.cross_attn_every:
        return DecodeCache(
            kv=_kv_axes(2), ssm=None, prev1=None, prev2=None,
            xkv=("batch", None, None),
        )
    if cfg.family == "ssm":
        return DecodeCache(
            kv=None,
            ssm=(None, "batch", "heads", None, None),
            prev1=(None, "batch", None),
            prev2=(None, "batch", None),
            xkv=None,
        )
    if cfg.family == "hybrid":
        return DecodeCache(
            kv=_kv_axes(1),
            ssm=(None, "batch", "heads", None),
            prev1=None, prev2=None, xkv=None,
        )
    return DecodeCache(kv=_kv_axes(1), ssm=None, prev1=None, prev2=None, xkv=None)


def make_serve_step(model):
    """serve_step(params, cache, token [B], pos [B]) -> (next_token, cache).

    Greedy decode of one token — the op lowered for decode_* shapes.
    """

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step
