"""Chunked bulk prefill: whole prompt chunks through the block-sparse path.

The legacy engine fed prompts token-by-token through the decode step — a
64K prompt cost 64K engine ticks, each one redundantly re-decoding every
other active slot. ``ChunkedPrefiller`` instead runs one C-token chunk per
call through ``models.transformer.prefill_chunk``: every layer's attention
is a single ``sparse_attention`` dispatch (the §IV-D block-sparse prefill,
on the same pipeline emitter / OpConfig the rest of the engine traces) and
the chunk's KV lands in the paged pool in one scatter.

Retrace discipline — the part that makes this serve-able: the compiled
chunk function is fixed-shape. Chunk length ``C``, page-table width ``W``
and the CSR buffers (``ptr`` [H*nqb+1], ``kcols`` [H*nqb*nkb]) are static;
the chunk start, valid count, tokens and page ids are *traced* operands.
The causal-band block mask is therefore built on-device from the traced
``start`` (band widths via cumsum + searchsorted), and the kernel's grid is
pinned to the full ``nkb`` extent (``pad_active_to``) with padding steps
compute-masked. Net effect: one compile per (with_logits) variant, every
chunk of every prompt reuses it.

``attn_budget < 1`` swaps the full causal band for a sink + local-window
block pattern (the MInference/H2O-style sparse prefill): per q-row the band
of ``nblk`` causal blocks is cut to ``max(2, ceil(budget * nblk))`` — block
0 (the attention sink) plus the trailing window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import prefill_chunk


class ChunkedPrefiller:
    def __init__(self, cfg, *, page_size: int, null_page: int, width: int,
                 chunk: int = 256, block_q: int | None = None,
                 attn_budget: float = 1.0, attn_impl=None):
        self.cfg = cfg
        self.chunk = int(chunk)
        bq = int(block_q or min(128, self.chunk))
        if self.chunk % bq:
            raise ValueError(f"chunk {self.chunk} not a multiple of "
                             f"block_q {bq}")
        self.block_q = bq
        self.page_size = ps = int(page_size)
        self.width = W = int(width)
        self.null_page = int(null_page)
        self.attn_budget = float(attn_budget)
        self.attn_impl = attn_impl

        C, h = self.chunk, cfg.num_heads
        nqb = C // bq
        nkb = W  # block_k == page_size, so kv blocks are exactly the pages
        budget = self.attn_budget
        null = self.null_page

        def _band_csr(start):
            """Causal-band block CSR from the traced chunk start."""
            qi = jnp.arange(nqb)
            last = start + (qi + 1) * bq  # exclusive max qpos per row
            nblk = jnp.clip((last + ps - 1) // ps, 1, nkb).astype(jnp.int32)
            if budget < 1.0:
                count = jnp.minimum(nblk, jnp.maximum(
                    2, jnp.ceil(budget * nblk).astype(jnp.int32)))
            else:
                count = nblk
            counts = jnp.tile(count, h)  # row r = head*nqb + qi
            ptr = jnp.concatenate(
                [jnp.zeros(1, jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)])
            p = jnp.arange(h * nqb * nkb)
            row = jnp.clip(jnp.searchsorted(ptr, p, side="right") - 1, 0,
                           h * nqb - 1)
            j = (p - ptr[row]).astype(jnp.int32)
            if budget < 1.0:
                # sink block 0 + trailing window; count==nblk degenerates to
                # the full band so no column ever repeats within a row
                kcols = jnp.where(j == 0, 0, nblk[row % nqb] - counts[row] + j)
            else:
                kcols = j  # full band: columns 0..count-1
            return ptr, jnp.clip(kcols, 0, nkb - 1).astype(jnp.int32)

        def _run(params, k, v, pos_tab, pages_row, tokens, start, n_valid,
                 with_logits):
            i = jnp.arange(C)
            t = (start + i).astype(jnp.int32)
            valid = i < n_valid
            scatter_page = jnp.where(
                valid, pages_row[jnp.clip(t // ps, 0, W - 1)], null)
            within = (t % ps).astype(jnp.int32)
            pos_vals = jnp.where(valid, t, -1)
            return prefill_chunk(
                params, k, v, pos_tab, pages_row, tokens[None], t,
                scatter_page, within, pos_vals, _band_csr(start), cfg,
                block_q=bq, block_k=ps, with_logits=with_logits,
                attn_impl=attn_impl)

        self._fn = jax.jit(_run, static_argnames=("with_logits",))

    def run_chunk(self, params, pool, pages, start: int, tokens, *,
                  with_logits: bool):
        """Prefill ``tokens`` (<= chunk) at absolute ``start`` into ``pool``.

        ``pages`` is the sequence's page list (logical order). Mutates the
        pool's device arrays; returns logits [len(tokens), Vp] when
        ``with_logits`` (the final chunk — its last row seeds decode),
        else None.
        """
        n = len(tokens)
        if not 0 < n <= self.chunk:
            raise ValueError(f"chunk of {n} tokens (capacity {self.chunk})")
        buf = np.zeros(self.chunk, np.int32)
        buf[:n] = np.asarray(tokens, np.int32)
        row = jnp.asarray(
            list(pages) + [self.null_page] * (self.width - len(pages)),
            jnp.int32)
        logits, pool.k, pool.v, pool.pos = self._fn(
            params, pool.k, pool.v, pool.pos, row, jnp.asarray(buf),
            jnp.int32(start), jnp.int32(n), with_logits)
        return None if logits is None else np.asarray(logits[:n])
