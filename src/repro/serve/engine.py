"""Batched serving engine: chunked prefill + paged KV + continuous batching.

The engine keeps a fixed pool of decode slots over a shared *paged* KV pool
(``repro.serve.kvcache``). Requests wait in a priority queue, are admitted
when a slot and enough pages are free, have their prompt bulk-prefilled
chunk-by-chunk through the block-sparse attention path
(``repro.serve.prefill`` — the paper's §IV-D prefill actually running
block-sparse), then join the pooled decode step. Each engine tick is
Sarathi-style: at most one prefill chunk interleaved with one pooled decode
step, so long prompts never starve decode. Pages are allocated on admit and
on decode growth, freed (zeroed + position-invalidated) on completion.

The token-at-a-time **legacy path** survives behind ``legacy_prefill=True``
— and remains the automatic fallback for families the paged path doesn't
cover (SSM/hybrid state, cross-attention, sliding-window rings) — with its
historical defect fixed: prefilling one slot no longer rewrites every other
active slot's KV (non-target slots are masked out of the cache merge).

Sparse-op amortization: ops traced under the engine inherit its
``op_config`` (``repro.ops`` precedence), and any host-side planning they
trigger — §IV-C tile selection, the WCSR §III-C task decomposition — is
memoized per ``SparseStructure`` in the ``repro.ops.make_plan`` cache, so a
deployment plans each layer once and decodes forever. ``stats()`` surfaces
those cache counters for serving dashboards, plus the serving ledger:
queue depth, page utilization, prefill/decode token counters and TTFT
percentiles (``repro.serve.scheduler.Telemetry``).

Multi-device serving: pass ``mesh=`` and decode steps trace inside a
``repro.parallel.sparse.use_sparse_mesh`` scope — every ``SparseTensor``
spmm in the model auto-shards over the mesh (partitioned by nonzero work
via the ``make_partition`` cache, so the partitioner too runs once per
layer). ``stats()["sparse_shards"]`` reports the per-layer shard-balance
(worst/mean stored-work ratio per cached partition).

Warm-started tuning: pass ``tune_db=`` (a ``repro.tune.TuneDB`` or a path)
and the engine installs it process-wide (``repro.ops.set_tune_db``) and
preloads every env-valid farm-measured winner at construction and again at
each admission — so all ``"auto"`` knobs (tile width, chunks-per-task,
pipeline depth, value codec) resolve from disk and the replica performs
zero in-process autotune sweeps. ``stats()["tune_db"]`` reports the
db_hits / db_misses / db_stale / sweeps counters plus DB health; with no
(or a corrupt) DB the engine behaves bitwise-identically to today's
in-process path.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ops import OpConfig, use_config
from repro.serve.kvcache import PagedKVCache
from repro.serve.prefill import ChunkedPrefiller
from repro.serve.scheduler import Telemetry, WaitQueue


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [P] i32
    max_new_tokens: int
    priority: int = 0  # lower = admitted first
    out_tokens: Optional[List[int]] = None
    done: bool = False


def _paged_capable(cfg) -> bool:
    """Families the paged/chunked path covers; the rest stay legacy."""
    return (getattr(cfg, "family", None) in ("dense", "moe")
            and not getattr(cfg, "cross_attn_every", None)
            and getattr(cfg, "sliding_window", None) is None)


def _merge_slot_cache(old, new, s: int, cfg):
    """Adopt only batch row ``s`` of a freshly decoded cache tree.

    The legacy prefill decodes the whole pool per prompt token; merging
    just the target slot's rows keeps every other active slot's KV/SSM
    state untouched (the historical bug rewrote them all).
    """
    from repro.models.attention import KVCache
    from repro.serve.step import decode_cache_axes

    ax = decode_cache_axes(cfg)

    def pick(o, n, a):
        if o is None:
            return n
        sl = (slice(None),) * a.index("batch") + (s,)
        return o.at[sl].set(n[sl])

    kv = old.kv
    if kv is not None:
        kv = KVCache(*(pick(getattr(old.kv, f), getattr(new.kv, f),
                            getattr(ax.kv, f)) for f in ("k", "v", "pos")))
    return old._replace(
        kv=kv,
        ssm=pick(old.ssm, new.ssm, ax.ssm) if old.ssm is not None else None,
        prev1=(pick(old.prev1, new.prev1, ax.prev1)
               if old.prev1 is not None else None),
        prev2=(pick(old.prev2, new.prev2, ax.prev2)
               if old.prev2 is not None else None),
    )


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 4, max_len: int = 512,
                 frontend_inputs: Optional[dict] = None, greedy: bool = True,
                 op_config: Optional[OpConfig] = None,
                 mesh=None, mesh_axis="data",
                 page_size: int = 64, num_pages: Optional[int] = None,
                 chunk: int = 256, prefill_block_q: Optional[int] = None,
                 prefill_attn_budget: float = 1.0, prefill_attn_impl=None,
                 legacy_prefill: bool = False, tune_db=None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        # sparse-op execution config applied while decode steps trace, so a
        # serving deployment can flip kernel backends engine-wide without
        # touching the model code (repro.ops.use_config semantics)
        self.op_config = op_config
        # device mesh for sharded sparse operands: decode traces under
        # use_sparse_mesh so SparseTensor spmm distributes over mesh_axis
        # (one axis name, or a tuple like ("data", "model") for 2-D
        # sharding + reduce="hier"-capable operands)
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.greedy = greedy
        self.pos = np.zeros(slots, np.int64)  # next position per slot
        self.active: List[Optional[Request]] = [None] * slots
        self.budget = np.zeros(slots, np.int64)
        self.last_token = np.zeros(slots, np.int64)
        self.queue = WaitQueue()
        self.telemetry = Telemetry()
        self.ticks = 0
        # persistent tuning DB (repro.tune): install + warm-start preload,
        # so every "auto" knob (bn / chunks_per_task / pipeline_depth /
        # value_codec="auto") resolves from farm-measured winners and this
        # replica never pays an in-process sweep (db_hits > 0, sweeps == 0
        # in stats() — the warm-start invariant). None = today's behavior.
        self.tune_db = None
        if tune_db is not None:
            from repro.ops import set_tune_db

            self.tune_db = set_tune_db(tune_db)
            self._preload_tuning()

        self.paged = (not legacy_prefill) and _paged_capable(self.cfg)
        if self.paged:
            self.chunk = int(chunk)
            width = -(-max_len // page_size)  # page-table width per slot
            if num_pages is None:
                num_pages = slots * width
            self.pool = PagedKVCache(self.cfg, num_pages, page_size)
            self.pages: List[List[int]] = [[] for _ in range(slots)]
            # free -> prefill -> decode (-> stalled <-> decode) -> free
            self.state = ["free"] * slots
            self._prefill_cursor = np.zeros(slots, np.int64)
            self.prefiller = ChunkedPrefiller(
                self.cfg, page_size=page_size, null_page=self.pool.null_page,
                width=width, chunk=self.chunk, block_q=prefill_block_q,
                attn_budget=prefill_attn_budget, attn_impl=prefill_attn_impl)
            from repro.models.transformer import decode_step_paged

            cfg = self.cfg
            self._decode_paged_jit = jax.jit(
                lambda p, k, v, pt, tok, pos, pages, valid:
                decode_step_paged(p, k, v, pt, tok, pos, pages, valid, cfg))
        else:
            kw = frontend_inputs or {}
            self.cache = model.init_decode_cache(slots, max_len, **kw)
            self._decode_jit = jax.jit(
                lambda p, c, tok, pos: model.decode_step(p, c, tok, pos)
            )

    def _preload_tuning(self, *, refresh: bool = False) -> int:
        """Warm the in-process tuned cache from the persistent DB.

        Adopts every env-valid DB winner (``repro.ops.adopt_tuned_entries``
        — idempotent, so the admission-time re-preload is a cheap no-op at
        steady state), then counts the model's own sparse-layer structures
        against the DB via their content digests so ``stats()["tune_db"]``
        can report per-layer coverage. Runs at construction and at every
        admission (new structures may have appeared — e.g. layers swapped
        in, or another replica extended the DB between ``reload()`` s).
        """
        from repro.ops import adopt_tuned_entries
        from repro.sparse.tensor import SparseTensor

        if refresh:
            self.tune_db.reload()
        adopted = adopt_tuned_entries(self.tune_db.winners())
        # per-layer coverage: which of this model's SparseTensor params
        # have at least one farm-measured entry (matched by fmt/shape/block)
        covered = seen = 0
        leaves = jax.tree_util.tree_leaves(
            self.params, is_leaf=lambda x: isinstance(x, SparseTensor))
        for leaf in leaves:
            if not isinstance(leaf, SparseTensor):
                continue
            seen += 1
            if self.tune_db.match(op="spmm", fmt=leaf.format,
                                  shape=leaf.shape, block=leaf.block):
                covered += 1
        self._tune_coverage = {"sparse_params": seen,
                               "covered_params": covered}
        return adopted

    def _scope(self):
        """Ambient OpConfig + sparse-mesh scope for every traced call."""
        stack = contextlib.ExitStack()
        if self.op_config is not None:
            stack.enter_context(use_config(self.op_config))
        if self.mesh is not None:
            from repro.parallel.sparse import use_sparse_mesh

            stack.enter_context(use_sparse_mesh(self.mesh, self.mesh_axis))
        return stack

    def _decode(self, p, c, tok, pos):
        with self._scope():
            return self._decode_jit(p, c, tok, pos)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request for admission (priority, FIFO within priority).

        Raises ``ValueError`` for requests that could *never* run — a
        prompt longer than the per-slot page-table window (``max_len``) or
        than the whole page pool. Transient fullness is not an error: the
        request waits in the queue (admit-when-full queues, never drops).
        """
        plen = len(req.prompt)
        if plen >= self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt of {plen} tokens >= engine "
                f"max_len {self.max_len}")
        if self.paged:
            need = self.pool.pages_needed(plen)
            if need > self.pool.num_pages:
                raise ValueError(
                    f"request {req.rid}: prompt of {plen} tokens needs "
                    f"{need} pages but the pool holds only "
                    f"{self.pool.num_pages} (page_size "
                    f"{self.pool.page_size})")
        self.queue.push(req, req.priority)
        self.telemetry.on_submit(req.rid, plen, req.priority)

    def _free_slot(self) -> Optional[int]:
        for s in range(self.slots):
            if self.active[s] is None:
                return s
        return None

    def _admit_ready(self):
        """Admit queue heads while a slot + prompt pages are available."""
        if self.tune_db is not None and len(self.queue):
            # re-preload at admission: pick up winners another replica (or
            # the farm) appended since construction; idempotent, so at
            # steady state this is a no-op dict scan
            self._preload_tuning(refresh=True)
        while len(self.queue):
            s = self._free_slot()
            if s is None:
                return
            req = self.queue.peek()
            need = self.pool.pages_needed(len(req.prompt))
            if need > self.pool.free_pages:
                return  # backpressure: head-of-line waits, no starvation
            self.queue.pop()
            self.pages[s] = self.pool.alloc(need)
            self.state[s] = "prefill"
            self.active[s] = req
            req.out_tokens = []
            self._prefill_cursor[s] = 0
            self.pos[s] = 0
            # the prefill emits the first generated token: 1 budget spent
            self.budget[s] = req.max_new_tokens - 1
            self.telemetry.on_admit(req.rid)

    # -- paged tick ---------------------------------------------------------
    def tick(self):
        """One engine tick: admit, <= 1 prefill chunk, pooled decode."""
        assert self.paged, "tick() is the paged-mode loop; use step()"
        self.ticks += 1
        self.telemetry.ticks = self.ticks
        self._admit_ready()
        self._prefill_tick()
        self._decode_tick()

    def _prefill_tick(self):
        for s in range(self.slots):
            if self.state[s] != "prefill":
                continue
            req = self.active[s]
            cur = int(self._prefill_cursor[s])
            n = min(self.chunk, len(req.prompt) - cur)
            final = cur + n == len(req.prompt)
            with self._scope():
                logits = self.prefiller.run_chunk(
                    self.params, self.pool, self.pages[s], cur,
                    req.prompt[cur:cur + n], with_logits=final)
            self._prefill_cursor[s] = cur + n
            self.telemetry.prefill_tokens += n
            if final:
                nxt = int(np.argmax(logits[n - 1]))
                self.pos[s] = len(req.prompt)
                self.last_token[s] = nxt
                req.out_tokens.append(nxt)
                self.telemetry.on_first_token(req.rid)
                self.state[s] = "decode"
                if self.budget[s] <= 0:
                    self._complete(s)
            return  # Sarathi chunk budget: one chunk per tick

    def _decode_tick(self):
        from repro.serve.kvcache import PageAllocationError

        # growth: a decoding slot crossing a page boundary needs one page;
        # failure stalls just that slot until completions free pages
        for s in range(self.slots):
            if self.state[s] not in ("decode", "stalled"):
                continue
            if int(self.pos[s]) // self.pool.page_size >= len(self.pages[s]):
                try:
                    self.pages[s] += self.pool.alloc(1)
                    self.state[s] = "decode"
                except PageAllocationError:
                    self.state[s] = "stalled"
            else:
                self.state[s] = "decode"
        dec = [s for s in range(self.slots) if self.state[s] == "decode"]
        if not dec:
            return
        valid = np.zeros(self.slots, bool)
        valid[dec] = True
        table = self.pool.table(
            [self.pages[s] if valid[s] else [] for s in range(self.slots)],
            self.prefiller.width)
        with self._scope():
            logits, self.pool.k, self.pool.v, self.pool.pos = (
                self._decode_paged_jit(
                    self.params, self.pool.k, self.pool.v, self.pool.pos,
                    jnp.asarray(self.last_token, jnp.int32),
                    jnp.asarray(self.pos, jnp.int32), table,
                    jnp.asarray(valid)))
        logits = np.asarray(logits)
        self.telemetry.decode_tokens += len(dec)
        for s in dec:
            req = self.active[s]
            self.pos[s] += 1
            nxt = int(np.argmax(logits[s]))
            self.last_token[s] = nxt
            req.out_tokens.append(nxt)
            self.budget[s] -= 1
            if self.budget[s] <= 0 or self.pos[s] >= self.max_len - 1:
                self._complete(s)

    def _complete(self, s: int):
        req = self.active[s]
        req.done = True
        self.telemetry.on_finish(req.rid, len(req.out_tokens))
        self.active[s] = None
        self.state[s] = "free"
        self.pool.free(self.pages[s])  # zero + pos=-1: no stale KV reuse
        self.pages[s] = []
        self.pos[s] = 0
        self.last_token[s] = 0

    # -- legacy path (token-at-a-time prefill over ring caches) -------------
    def try_admit(self, req: Request) -> bool:
        if self.tune_db is not None:
            self._preload_tuning(refresh=True)
        for s in range(self.slots):
            if self.active[s] is None:
                if req.rid not in self.telemetry.records:
                    self.telemetry.on_submit(req.rid, len(req.prompt),
                                             req.priority)
                self.telemetry.on_admit(req.rid)
                self._prefill_slot(s, req)
                return True
        return False

    def _reset_slot(self, s: int):
        """Invalidate a slot's cache state before reuse by a new request."""
        c = self.cache
        if c.kv is not None:
            # pos: [..., B, cache_len] (layer dims may be 1- or 2-level stacked)
            c = c._replace(kv=c.kv._replace(pos=c.kv.pos.at[..., s, :].set(-1)))
        if c.ssm is not None:
            c = c._replace(ssm=c.ssm.at[:, s].set(0.0))
        if c.prev1 is not None:
            c = c._replace(prev1=c.prev1.at[:, s].set(0.0))
        if c.prev2 is not None:
            c = c._replace(prev2=c.prev2.at[:, s].set(0.0))
        self.cache = c
        self.pos[s] = 0
        self.last_token[s] = 0

    def _prefill_slot(self, s: int, req: Request):
        req.out_tokens = []
        self._reset_slot(s)
        self.active[s] = req
        # the prefill emits the first generated token, so it spends 1 budget
        self.budget[s] = req.max_new_tokens - 1
        # token-by-token prefill through the decode path — exact, and kept
        # (behind legacy_prefill / non-paged families) as the equivalence
        # oracle for the chunked path. Other active slots are masked out of
        # the cache merge: only slot s's rows are adopted, so prefilling
        # here no longer rewrites their KV at an unchanged position.
        others = any(r is not None and i != s
                     for i, r in enumerate(self.active))
        for t, tok in enumerate(req.prompt):
            toks = jnp.asarray(self.last_token, jnp.int32).at[s].set(int(tok))
            poss = jnp.asarray(self.pos, jnp.int32)
            logits, new_cache = self._decode(self.params, self.cache, toks,
                                             poss)
            self.cache = (_merge_slot_cache(self.cache, new_cache, s, self.cfg)
                          if others else new_cache)
            self.pos[s] += 1
            self.ticks += 1
            self.telemetry.ticks = self.ticks
            self.telemetry.prefill_tokens += 1
        nxt = int(np.argmax(np.asarray(logits)[s]))
        self.last_token[s] = nxt
        req.out_tokens.append(nxt)
        self.telemetry.on_first_token(req.rid)
        if self.budget[s] <= 0:
            req.done = True
            self.telemetry.on_finish(req.rid, len(req.out_tokens))
            self.active[s] = None

    # -- decode tick --------------------------------------------------------
    def step(self):
        if self.paged:
            return self.tick()
        if not any(a is not None for a in self.active):
            return
        self.ticks += 1
        self.telemetry.ticks = self.ticks
        toks = jnp.asarray(self.last_token, jnp.int32)
        poss = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, poss)
        logits = np.asarray(logits)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            self.telemetry.decode_tokens += 1
            nxt = int(np.argmax(logits[s]))
            self.last_token[s] = nxt
            req.out_tokens.append(nxt)
            self.budget[s] -= 1
            if self.budget[s] <= 0 or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.telemetry.on_finish(req.rid, len(req.out_tokens))
                self.active[s] = None
                self.pos[s] = 0  # slot reset (ring caches tolerate reuse)

    def stats(self) -> dict:
        """Serving counters + host-side planning cache state.

        ``plan_cache.task_decompositions`` staying flat across ticks is the
        amortization invariant: repeated serve steps over the same sparse
        structures must never re-run host-side planning (nor, with a mesh,
        the structure-aware partitioner — ``plan_cache.partition_misses``).
        ``sparse_shards`` lists the shard-balance of every cached partition
        — per-shard stored work and the worst/mean ratio. Like the other
        cache counters it is process-global: partitions created outside
        this engine (another engine, benchmarks) appear too.
        ``pipeline_depths`` (also on ``tuning_cache``) counts how many
        kernel plans resolved each §III-A gather-pipeline depth Q — the
        dashboard view of whether the measured auto-tune (or an explicit
        ``OpConfig(pipeline_depth=...)``) is actually steering the hot
        path. ``value_codecs`` is the sibling counter for the value-codec
        layer: how many plans resolved each codec ("none" = raw values),
        i.e. the per-layer codec selections actually serving traffic.
        ``codec_bytes`` models what those selections save: per quantized
        (structure, codec) plan, baseline-vs-compressed sparse-operand
        bytes moved (payload + per-group f32 scales; see
        ``repro.ops.codec_bytes_report``). ``cache_stats`` is the one
        unified aggregator over every counter above
        (``repro.ops.cache_stats`` — fixed key naming; the legacy
        per-cache dataclasses remain for existing dashboards).

        ``structure_deltas`` is the dynamic-sparsity view
        (``cache_stats()["delta"]``): structure edits applied
        (``appends``/``retires``), plan/partition cache entries derived by
        *patching* the base structure's entry (``plan_patched`` /
        ``partition_patched``) instead of a full rebuild, codec value
        groups spliced bitwise vs requantized, and mesh shards reused vs
        reshipped. The growing-mask amortization invariant
        (``docs/serving.md``): after warmup, a decode loop whose attention
        mask grows every step advances ``plan_patched`` while
        ``plan_cache.misses`` stays flat — zero full re-plans.

        ``combine`` is the sharded chunked-combine view
        (``cache_stats()["combine"]``): sharded spmm calls that traced the
        chunked overlapped combine vs the blocking single collective, the
        chunk-count tally, schedule/chunk-array build-vs-reuse counters,
        and the ``hierarchical_psum`` call/fallback tallies for
        ``reduce="hier"`` meshes.

        ``spmv`` is the skinny-N dispatch view (``cache_stats()["spmv"]``):
        sparse calls routed to the GEMV (``repro.ops.spmv``) kernel family
        vs kept on the full-tile SpMM kernels. Decode ticks run skinny
        activation batches, so a healthy engine shows ``dispatched``
        advancing with ``decode_tokens`` while prefill traffic lands in
        ``full_tile`` (the crossover is ``OpConfig.spmv_threshold`` —
        "auto" adopts the measured ``autotune_spmm`` route; see
        docs/performance.md).

        ``tune_db`` reports the persistent-tuning warm-start state (None
        when the engine was built without one): the DB summary
        (path / entries / stale_entries / quarantined / env) merged with
        the process-wide ``db_hits`` / ``db_misses`` / ``db_stale`` /
        ``sweeps`` counters and the model's sparse-param coverage. The
        warm-start invariant a farm-produced DB must satisfy:
        ``db_hits > 0 and sweeps == 0`` — the replica adopted measured
        winners and never paid an in-process sweep.

        Serving-runtime keys (``docs/serving.md``): ``mode``
        ("paged"/"legacy"), ``queue_depth`` (requests waiting for
        admission), ``page_utilization`` + ``pages`` (paged-pool
        occupancy; 0.0/None under legacy), ``ttft`` (p50/p95
        time-to-first-token in engine ticks and seconds),
        ``prefill_tokens`` / ``decode_tokens`` / ``ticks``.
        """
        from repro.ops import (cache_stats, codec_bytes_report,
                               partition_balance_report, plan_cache_info,
                               tuning_cache_info)

        tuning = tuning_cache_info()
        tune_db = None
        if self.tune_db is not None:
            tune_db = dict(self.tune_db.stats(),
                           db_hits=tuning.db_hits,
                           db_misses=tuning.db_misses,
                           db_stale=tuning.db_stale,
                           sweeps=tuning.sweeps,
                           **getattr(self, "_tune_coverage", {}))
        cs = cache_stats()
        return {
            "active_slots": sum(a is not None for a in self.active),
            "free_slots": sum(a is None for a in self.active),
            "plan_cache": plan_cache_info(),
            "tuning_cache": tuning,
            "pipeline_depths": tuning.pipeline_depths,
            "value_codecs": tuning.value_codecs,
            "codec_bytes": codec_bytes_report(),
            "cache_stats": cs,
            "structure_deltas": cs["delta"],
            "spmv": cs["spmv"],
            "combine": cs["combine"],
            "tune_db": tune_db,
            "sparse_shards": partition_balance_report(),
            "mode": "paged" if self.paged else "legacy",
            "queue_depth": len(self.queue),
            "page_utilization": (self.pool.utilization() if self.paged
                                 else 0.0),
            "pages": self.pool.stats() if self.paged else None,
            "ttft": self.telemetry.ttft_percentiles(),
            "prefill_tokens": self.telemetry.prefill_tokens,
            "decode_tokens": self.telemetry.decode_tokens,
            "ticks": self.ticks,
        }

    def run(self, requests: List[Request], max_ticks: int = 10_000):
        done: List[Request] = []
        if self.paged:
            for r in requests:
                self.submit(r)
            start = self.ticks
            while ((len(self.queue) or any(a is not None
                                           for a in self.active))
                   and self.ticks - start < max_ticks):
                self.tick()
            return [r for r in requests if r.done]
        for r in requests:  # stamp arrivals so legacy TTFT spans queue wait
            if r.rid not in self.telemetry.records:
                self.telemetry.on_submit(r.rid, len(r.prompt), r.priority)
        pending = list(requests)
        ticks = 0
        while (pending or any(a is not None for a in self.active)) and ticks < max_ticks:
            while pending and self.try_admit(pending[0]):
                pending.pop(0)
            self.step()
            done.extend(r for r in requests if r.done and r not in done)
            ticks += 1
        return done
