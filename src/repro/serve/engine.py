"""Batched serving engine: prefill + decode with continuous-batching-lite.

The engine keeps a fixed pool of decode slots. Requests are admitted into
free slots (their prompt prefilled into the slot's cache region), decode
steps run the whole pool every tick, finished sequences free their slots.
This is the serving-side end-to-end driver for the paper's inference story
(§IV-D): the FFN can be block-sparse and the prefill attention block-sparse.

Sparse-op amortization: ops traced under the engine inherit its
``op_config`` (``repro.ops`` precedence), and any host-side planning they
trigger — §IV-C tile selection, the WCSR §III-C task decomposition — is
memoized per ``SparseStructure`` in the ``repro.ops.make_plan`` cache, so a
deployment plans each layer once and decodes forever. ``stats()`` surfaces
those cache counters for serving dashboards.

Multi-device serving: pass ``mesh=`` and decode steps trace inside a
``repro.parallel.sparse.use_sparse_mesh`` scope — every ``SparseTensor``
spmm in the model auto-shards over the mesh (partitioned by nonzero work
via the ``make_partition`` cache, so the partitioner too runs once per
layer). ``stats()["sparse_shards"]`` reports the per-layer shard-balance
(worst/mean stored-work ratio per cached partition).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ops import OpConfig, use_config


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [P] i32
    max_new_tokens: int
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 4, max_len: int = 512,
                 frontend_inputs: Optional[dict] = None, greedy: bool = True,
                 op_config: Optional[OpConfig] = None,
                 mesh=None, mesh_axis: str = "data"):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        # sparse-op execution config applied while decode steps trace, so a
        # serving deployment can flip kernel backends engine-wide without
        # touching the model code (repro.ops.use_config semantics)
        self.op_config = op_config
        # device mesh for sharded sparse operands: decode traces under
        # use_sparse_mesh so SparseTensor spmm distributes over mesh_axis
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        kw = frontend_inputs or {}
        self.cache = model.init_decode_cache(slots, max_len, **kw)
        self.pos = np.zeros(slots, np.int64)  # next position per slot
        self.active: List[Optional[Request]] = [None] * slots
        self.budget = np.zeros(slots, np.int64)
        self.greedy = greedy
        self._decode_jit = jax.jit(
            lambda p, c, tok, pos: model.decode_step(p, c, tok, pos)
        )
        self.last_token = np.zeros(slots, np.int64)

    def _decode(self, p, c, tok, pos):
        with contextlib.ExitStack() as stack:
            if self.op_config is not None:
                stack.enter_context(use_config(self.op_config))
            if self.mesh is not None:
                from repro.parallel.sparse import use_sparse_mesh

                stack.enter_context(use_sparse_mesh(self.mesh,
                                                    self.mesh_axis))
            return self._decode_jit(p, c, tok, pos)

    # -- admission ---------------------------------------------------------
    def try_admit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                self._prefill_slot(s, req)
                return True
        return False

    def _reset_slot(self, s: int):
        """Invalidate a slot's cache state before reuse by a new request."""
        c = self.cache
        if c.kv is not None:
            # pos: [..., B, cache_len] (layer dims may be 1- or 2-level stacked)
            c = c._replace(kv=c.kv._replace(pos=c.kv.pos.at[..., s, :].set(-1)))
        if c.ssm is not None:
            c = c._replace(ssm=c.ssm.at[:, s].set(0.0))
        if c.prev1 is not None:
            c = c._replace(prev1=c.prev1.at[:, s].set(0.0))
        if c.prev2 is not None:
            c = c._replace(prev2=c.prev2.at[:, s].set(0.0))
        self.cache = c
        self.pos[s] = 0
        self.last_token[s] = 0

    def _prefill_slot(self, s: int, req: Request):
        req.out_tokens = []
        self._reset_slot(s)
        self.active[s] = req
        # the prefill emits the first generated token, so it spends 1 budget
        self.budget[s] = req.max_new_tokens - 1
        # token-by-token prefill through the decode path: exact and reuses
        # the slot's cache region. (A bulk prefill kernel is a serving
        # optimization; exactness is what matters for the engine tests.)
        for t, tok in enumerate(req.prompt):
            toks = jnp.asarray(self.last_token, jnp.int32).at[s].set(int(tok))
            poss = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache, toks, poss)
            self.pos[s] += 1
        nxt = int(np.argmax(np.asarray(logits)[s]))
        self.last_token[s] = nxt
        req.out_tokens.append(nxt)
        if self.budget[s] <= 0:
            req.done = True
            self.active[s] = None

    # -- decode tick --------------------------------------------------------
    def step(self):
        if not any(a is not None for a in self.active):
            return
        toks = jnp.asarray(self.last_token, jnp.int32)
        poss = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, poss)
        logits = np.asarray(logits)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            nxt = int(np.argmax(logits[s]))
            self.last_token[s] = nxt
            req.out_tokens.append(nxt)
            self.budget[s] -= 1
            if self.budget[s] <= 0 or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.active[s] = None
                self.pos[s] = 0  # slot reset (ring caches tolerate reuse)

    def stats(self) -> dict:
        """Serving counters + host-side planning cache state.

        ``plan_cache.task_decompositions`` staying flat across ticks is the
        amortization invariant: repeated serve steps over the same sparse
        structures must never re-run host-side planning (nor, with a mesh,
        the structure-aware partitioner — ``plan_cache.partition_misses``).
        ``sparse_shards`` lists the shard-balance of every cached partition
        — per-shard stored work and the worst/mean ratio. Like the other
        cache counters it is process-global: partitions created outside
        this engine (another engine, benchmarks) appear too.
        ``pipeline_depths`` (also on ``tuning_cache``) counts how many
        kernel plans resolved each §III-A gather-pipeline depth Q — the
        dashboard view of whether the measured auto-tune (or an explicit
        ``OpConfig(pipeline_depth=...)``) is actually steering the hot
        path. ``value_codecs`` is the sibling counter for the value-codec
        layer: how many plans resolved each codec ("none" = raw values),
        i.e. the per-layer codec selections actually serving traffic.
        ``codec_bytes`` models what those selections save: per quantized
        (structure, codec) plan, baseline-vs-compressed sparse-operand
        bytes moved (payload + per-group f32 scales; see
        ``repro.ops.codec_bytes_report``). ``cache_stats`` is the one
        unified aggregator over every counter above
        (``repro.ops.cache_stats`` — fixed key naming; the legacy
        per-cache dataclasses remain for existing dashboards).
        """
        from repro.ops import (cache_stats, codec_bytes_report,
                               partition_balance_report, plan_cache_info,
                               tuning_cache_info)

        tuning = tuning_cache_info()
        return {
            "active_slots": sum(a is not None for a in self.active),
            "free_slots": sum(a is None for a in self.active),
            "plan_cache": plan_cache_info(),
            "tuning_cache": tuning,
            "pipeline_depths": tuning.pipeline_depths,
            "value_codecs": tuning.value_codecs,
            "codec_bytes": codec_bytes_report(),
            "cache_stats": cache_stats(),
            "sparse_shards": partition_balance_report(),
        }

    def run(self, requests: List[Request], max_ticks: int = 10_000):
        pending = list(requests)
        done: List[Request] = []
        ticks = 0
        while (pending or any(a is not None for a in self.active)) and ticks < max_ticks:
            while pending and self.try_admit(pending[0]):
                pending.pop(0)
            self.step()
            done.extend(r for r in requests if r.done and r not in done)
            ticks += 1
        return done
