"""Serving runtime: chunked block-sparse prefill, paged KV cache, and a
continuous-batching scheduler (docs/serving.md).

Import surface:
  ServeEngine / Request    — the tick-loop engine (engine.py)
  PagedKVCache             — block-granular KV allocator (kvcache.py)
  ChunkedPrefiller         — fixed-shape bulk prefill (prefill.py)
  WaitQueue / Telemetry    — admission + latency ledger (scheduler.py)
"""

from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import PageAllocationError, PagedKVCache
from repro.serve.prefill import ChunkedPrefiller
from repro.serve.scheduler import Telemetry, WaitQueue

__all__ = ["Request", "ServeEngine", "PagedKVCache", "PageAllocationError",
           "ChunkedPrefiller", "WaitQueue", "Telemetry"]
