"""Paged KV cache for the serving runtime (vLLM-style block granularity).

One physical pool of fixed-size token *pages* is shared by every admitted
sequence, decoupling sequence length from fixed ``max_len`` slot regions: a
sequence holds exactly ``ceil(len / page_size)`` pages, allocated on admit
(enough for the prompt) and one at a time as decode crosses page
boundaries, and freed — zeroed, positions invalidated — on completion. The
device-side layout mirrors ``models/attention.py``'s paged helpers:

  k/v      [L, P+1, page_size, KVH, D]   per-layer page pool
  pos      [P+1, page_size] i32          absolute position per slot (-1 empty;
                                         shared across layers)
  table()  [B, W] i32                    page-table rows, null-page padded

Physical page ``P`` (the last one) is the *null page*: it is never
allocated, pads every short page-table row, and absorbs the writes of
masked batch rows in the pooled decode step. Because freed and null pages
carry ``pos = -1``, a recycled page can never leak a previous request's KV
into attention — the staleness regression tests pin this down.

The allocator itself is host-side and O(1) per op (a free list); only the
zero-on-free touches the device arrays.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import jax.numpy as jnp

from repro.models.common import DTYPES


class PageAllocationError(RuntimeError):
    """Raised when a request needs more pages than the pool can ever hold."""


class PagedKVCache:
    def __init__(self, cfg, num_pages: int, page_size: int = 64):
        if num_pages < 1:
            raise ValueError("num_pages must be >= 1")
        self.cfg = cfg
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.null_page = self.num_pages  # physical id of the write sink
        L = cfg.num_layers
        kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        dtype = DTYPES[cfg.dtype]
        shape = (L, self.num_pages + 1, self.page_size, kvh, hd)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self.pos = -jnp.ones((self.num_pages + 1, self.page_size), jnp.int32)
        self._free: List[int] = list(range(self.num_pages))

    # -- accounting ---------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    def pages_needed(self, tokens: int) -> int:
        return max(1, -(-int(tokens) // self.page_size))

    # -- alloc / free -------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` physical page ids, or raise if the pool is exhausted.

        Transient exhaustion (other sequences hold the pages) raises
        ``PageAllocationError`` too — the scheduler treats it as
        backpressure (queue / stall), not as a request failure; only
        ``ServeEngine.submit`` turns *permanent* infeasibility (request
        larger than the whole pool) into a user-facing error.
        """
        if n > len(self._free):
            raise PageAllocationError(
                f"need {n} pages, {len(self._free)} free of {self.num_pages}")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        """Return pages to the pool, zeroing KV and invalidating positions.

        Zeroing is the defense-in-depth half of the staleness story: the
        ``pos = -1`` mask alone already blocks attention to recycled pages,
        and the zeros make any masking bug show up as an obviously-wrong
        all-zero value rather than a plausible stale one.
        """
        if not pages:
            return
        idx = jnp.asarray(list(pages), jnp.int32)
        self.k = self.k.at[:, idx].set(0)
        self.v = self.v.at[:, idx].set(0)
        self.pos = self.pos.at[idx].set(-1)
        for p in pages:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"page {p} out of range")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)

    # -- page tables --------------------------------------------------------
    def table(self, page_lists: Sequence[Sequence[int]], width: int):
        """Stack per-sequence page lists into a [B, width] i32 table.

        Rows are null-page padded; an empty list yields an all-null row
        (the masked-slot row for the pooled decode step).
        """
        rows = []
        for pl in page_lists:
            if len(pl) > width:
                raise ValueError(f"page list of {len(pl)} exceeds width {width}")
            rows.append(list(pl) + [self.null_page] * (width - len(pl)))
        return jnp.asarray(rows, jnp.int32)

    def stats(self) -> Dict[str, float]:
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "used_pages": self.used_pages,
            "free_pages": self.free_pages,
            "utilization": self.utilization(),
        }
