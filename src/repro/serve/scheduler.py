"""Continuous-batching scheduler pieces: priority wait queue + telemetry.

The queue replaces first-free-slot admission: requests wait in a priority
heap (lower ``priority`` first, FIFO within a priority) until both a slot
and enough KV pages are free — admission backpressure instead of drops.
The head of the queue gates admission (no starvation by smaller requests
skipping ahead within a priority class).

``Telemetry`` is the per-request latency/throughput ledger behind
``ServeEngine.stats()``: arrival/admit/first-token/finish are stamped in
engine ticks *and* wall-clock seconds, and TTFT percentiles are computed
over finished-or-started requests. Same balancing idea as the paper's
§III-C row-window task decomposition, one level up: the chunk budget
spreads long-prompt work across ticks so prefill never starves decode.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional


class WaitQueue:
    """Priority admission queue (lower priority value = served first)."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0

    def push(self, req, priority: int = 0) -> None:
        heapq.heappush(self._heap, (int(priority), self._seq, req))
        self._seq += 1

    def peek(self):
        return self._heap[0][2] if self._heap else None

    def pop(self):
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)


@dataclasses.dataclass
class RequestRecord:
    rid: int
    prompt_tokens: int
    priority: int = 0
    arrival_tick: int = 0
    arrival_time: float = 0.0
    admit_tick: Optional[int] = None
    first_token_tick: Optional[int] = None
    first_token_time: Optional[float] = None
    finish_tick: Optional[int] = None
    finish_time: Optional[float] = None
    new_tokens: int = 0

    @property
    def ttft_ticks(self) -> Optional[int]:
        if self.first_token_tick is None:
            return None
        return self.first_token_tick - self.arrival_tick

    @property
    def ttft_seconds(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time


class Telemetry:
    """Engine-side accounting: per-request records + token counters."""

    def __init__(self, clock=time.perf_counter):
        self.records: Dict[int, RequestRecord] = {}
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.ticks = 0
        self._clock = clock

    def on_submit(self, rid: int, prompt_tokens: int, priority: int = 0):
        self.records[rid] = RequestRecord(
            rid=rid, prompt_tokens=prompt_tokens, priority=priority,
            arrival_tick=self.ticks, arrival_time=self._clock())

    def on_admit(self, rid: int):
        self.records[rid].admit_tick = self.ticks

    def on_first_token(self, rid: int):
        r = self.records[rid]
        if r.first_token_tick is None:
            r.first_token_tick = self.ticks
            r.first_token_time = self._clock()

    def on_finish(self, rid: int, new_tokens: int):
        r = self.records[rid]
        r.finish_tick = self.ticks
        r.finish_time = self._clock()
        r.new_tokens = new_tokens

    def ttft_percentiles(self, pcts=(50, 95)) -> Dict[str, float]:
        """p50/p95 time-to-first-token, in ticks and seconds."""
        ticks = [r.ttft_ticks for r in self.records.values()
                 if r.ttft_ticks is not None]
        secs = [r.ttft_seconds for r in self.records.values()
                if r.ttft_seconds is not None]
        out: Dict[str, float] = {}
        for p in pcts:
            out[f"p{p}_ticks"] = _percentile(ticks, p)
            out[f"p{p}_s"] = _percentile(secs, p)
        return out

    def finished(self) -> List[RequestRecord]:
        return [r for r in self.records.values() if r.finish_tick is not None]


def _percentile(xs, p) -> float:
    """Linear-interpolated percentile; NaN-free empty case (no numpy dep
    at import time keeps this usable from stubbed-engine tests)."""
    if not xs:
        return float("nan")
    xs = sorted(float(x) for x in xs)
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1 - frac) + xs[hi] * frac
