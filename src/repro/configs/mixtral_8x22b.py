"""Mixtral 8x22B — MoE, 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    ffn_activation="swiglu",
    num_experts=8,
    top_k=2,
    # 8 experts < 16 model shards: TP inside each expert (DESIGN.md §6)
    expert_partition="ffn",
    sliding_window=4096,
    rope_theta=1e6,
)
