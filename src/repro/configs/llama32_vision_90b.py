"""Llama 3.2 Vision 90B backbone — 100 layers with cross-attention image
layers every 5th layer; vision frontend is a stub supplying precomputed
patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    ffn_activation="swiglu",
    cross_attn_every=5,  # 20 of 100 layers are cross-attention layers
    num_vision_tokens=4096,  # stubbed patch-embedding count
    rope_theta=5e5,
    fsdp=True,
)
