"""Minitron 4B — width/depth-pruned Nemotron-4, squared-ReLU MLP, GQA kv=8.
[arXiv:2407.14679; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    ffn_activation="sq_relu",
)
