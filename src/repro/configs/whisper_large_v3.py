"""Whisper large-v3 backbone — encoder-decoder, MHA (kv=20), GELU MLP.
The conv/mel frontend is a stub: ``input_specs`` feeds precomputed frame
embeddings to the encoder (per the assignment note). Positional encoding is
RoPE-adapted (deviation from learned absolute positions, noted in DESIGN.md).
[arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,  # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,  # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    ffn_activation="gelu",
)
