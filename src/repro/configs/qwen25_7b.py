"""Qwen2.5-7B — the paper's §IV-D case-study model (28L, h=3584, SwiGLU
d_ff=18944; gate/up/down all divisible by the 128x128 block)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    ffn_activation="swiglu",
    rope_theta=1e6,
)
