"""Model / run configuration dataclasses and the arch registry hooks."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "reduced_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads

    # attention
    attn_type: str = "gqa"  # gqa | none (attention-free)
    sliding_window: Optional[int] = None  # tokens (SWA archs)
    rope_theta: float = 10000.0

    # FFN
    ffn_activation: str = "swiglu"  # swiglu | sq_relu | gelu

    # MoE
    num_experts: int = 0
    top_k: int = 0
    expert_partition: str = "ffn"  # "expert" (EP) | "ffn" (TP inside expert)
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0

    # encoder-decoder (whisper): encoder_layers > 0
    encoder_layers: int = 0

    # VLM: insert a cross-attention layer every k layers (llama-3.2-vision)
    cross_attn_every: int = 0
    num_vision_tokens: int = 0

    # --- the paper's technique ---
    ffn_sparsity: float = 0.0  # fraction of FFN weight blocks dropped
    sparse_block: Tuple[int, int] = (128, 128)
    attn_sparsity_budget: float = 0.0  # 0 => dense attention in prefill

    # numerics / parallelism-dependent layout
    dtype: str = "bf16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    tp_shards: int = 1  # model-axis size baked into sparse/expert layouts
    fsdp: bool = False  # shard params over the data axis too (ZeRO-3-ish)
    remat: bool = True  # activation checkpointing per layer
    scan_layers: bool = True  # lax.scan over stacked layer params
    attn_unroll: bool = False  # python-loop q chunks (cost probes)
    attn_block_q: int = 256  # q-chunk size (bounds f32 score memory)
    loss_chunk: int = 8192  # tokens per loss chunk (bounds logits memory)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the embedding/logits shard over the model axis
        (production practice for odd vocab sizes, e.g. granite's 49155).
        Padded logit columns are masked to -inf in the loss and in decode."""
        if self.tp_shards <= 1:
            return self.vocab_size
        mult = 128 * self.tp_shards
        return -(-self.vocab_size // mult) * mult

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run very long contexts (long_500k)?"""
        return self.attn_type == "none" or self.sliding_window is not None or (
            self.family in ("ssm", "hybrid")
        )

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings + layers)."""
        d, hd = self.d_model, self.resolved_head_dim
        qkv = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        attn = qkv + o
        n_ffn_mats = 3 if self.ffn_activation == "swiglu" else 2
        ffn_dense = n_ffn_mats * d * self.d_ff
        if self.is_moe:
            ffn = self.num_experts * ffn_dense + d * self.num_experts  # + router
        else:
            ffn = ffn_dense
        if self.attn_type == "none":  # rwkv6: token-mix ~ 4*d*d + decay params
            attn = 4 * d * d + 4 * d
        per_layer = attn + ffn + 2 * d
        layers = self.num_layers + self.encoder_layers
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            # cross-attn layers already included in num_layers; add their kv
            per_cross = attn + ffn_dense + 2 * d
            layers = self.num_layers - n_cross
            return (
                self.vocab_size * d
                + layers * per_layer
                + n_cross * per_cross
                + (0 if self.tie_embeddings else self.vocab_size * d)
            )
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        n_ffn_mats = 3 if self.ffn_activation == "swiglu" else 2
        ffn_dense = n_ffn_mats * d * self.d_ff
        total = self.param_count()
        inactive = (self.num_experts - self.top_k) * ffn_dense * self.num_layers
        return total - inactive


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Family-preserving smoke-test reduction (small widths, CPU-runnable)."""
    small = dict(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4) if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        encoder_layers=2 if cfg.is_encdec else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        num_vision_tokens=16 if cfg.cross_attn_every else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        sliding_window=64 if cfg.sliding_window else None,
        sparse_block=(32, 32),
        dtype="f32",
        tp_shards=1,
        fsdp=False,
        remat=False,
        scan_layers=cfg.scan_layers,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
