"""Assigned input shapes. ``decode_*``/``long_*`` lower ``serve_step``
(single new token against a KV cache of ``seq_len``); others lower
``train_step``."""

from __future__ import annotations

import dataclasses

__all__ = ["InputShape", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason if skipped (DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (see DESIGN.md §8)"
        )
    return True, ""
