"""Arch registry: ``--arch <id>`` resolves here."""

from repro.configs.base import ModelConfig, reduced_config
from repro.configs.shapes import SHAPES, InputShape, shape_applicable

from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.minitron_4b import CONFIG as _minitron
from repro.configs.h2o_danube_1p8b import CONFIG as _danube
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.llama32_vision_90b import CONFIG as _llamav
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.hymba_1p5b import CONFIG as _hymba
from repro.configs.rwkv6_1p6b import CONFIG as _rwkv6
from repro.configs.qwen25_7b import CONFIG as _qwen

ARCHS = {
    c.name: c
    for c in [
        _mixtral,
        _kimi,
        _minitron,
        _danube,
        _nemotron,
        _granite,
        _llamav,
        _whisper,
        _hymba,
        _rwkv6,
        _qwen,
    ]
}

ASSIGNED = [c for c in ARCHS if c != "qwen2.5-7b"]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
