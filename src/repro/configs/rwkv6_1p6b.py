"""RWKV-6 (Finch) 1.6B — attention-free, data-dependent decay token mixing.
[arXiv:2404.05892; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # wkv heads of size 64
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attn_type="none",
    ffn_activation="relu",  # rwkv channel-mix uses relu^2; see models/ssm.py
    ssm_state=64,
)
