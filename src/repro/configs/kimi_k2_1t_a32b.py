"""Kimi K2 — trillion-param fine-grained MoE, 384 experts top-8, GQA kv=8.
[arXiv:2501.kimi2; unverified paper-table config]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,  # d_model / num_heads per the assigned table
    d_ff=2048,  # per-expert FFN width (fine-grained MoE)
    vocab_size=163840,
    ffn_activation="swiglu",
    num_experts=384,
    top_k=8,
    expert_partition="expert",  # 384 experts / 16 shards = 24 per shard (EP)
    rope_theta=5e6,
    fsdp=True,  # 1T params: shard params over data axis too
)
