"""Hymba 1.5B — hybrid: parallel attention + Mamba(SSM) heads in each layer,
GQA kv=5, sliding-window on most attention layers. [arXiv:2411.13676; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ffn_activation="swiglu",
    ssm_state=16,
    sliding_window=1024,
)
