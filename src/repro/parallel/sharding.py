"""Logical-axis -> mesh-axis rules and param-sharding construction.

Logical axes used by the model zoo (models/*/ *_axes functions):

  batch / tokens : data-parallel dims            -> ("pod", "data") | ("data",)
  vocab / heads / mlp / expert / model_shard : tensor-parallel dims -> "model"
  embed          : d_model dim                   -> None, or "data" under FSDP
  fsdp           : explicit FSDP dim for big tensors -> "data" under FSDP
  layers / expert_lead / seq : never sharded by default
  sparse_shard   : leading shard dim of stacked sparse-operand slices
                   (repro.parallel.sparse) -> "data"

FSDP (ZeRO-3-ish): parameters additionally sharded over the data axis on
their non-TP dim; GSPMD inserts the all-gathers in forward/backward and the
reduce-scatters on gradients. Used for the >=80B archs (see
docs/architecture.md, parallel layer).

Sparse operands: a ``ShardedSparseTensor`` stacks its per-shard value /
index slices on a leading shard dim; ``sparse_operand_sharding`` is the
placement rule for those leaves (shard dim over one mesh axis, everything
else replicated) — the sparse analogue of ``param_shardings``.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import logical_to_pspec

__all__ = ["make_rules", "param_shardings", "batch_shardings",
           "make_mesh_rules", "sparse_operand_sharding",
           "sparse_operand_shardings"]


def make_rules(multi_pod: bool, fsdp: bool = False,
               seq_shard: bool = False) -> Dict[str, Optional[object]]:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    rules = {
        "batch": batch_axes,
        "tokens": batch_axes,
        "seq": "data" if seq_shard else None,
        "vocab": "model",
        "heads": "model",
        "mlp": "model",
        "expert": "model",
        "expert_d": "data",  # serving MoE layout (expert_partition=expert_data)
        "model_shard": "model",
        "embed": "data" if fsdp else None,
        "fsdp": "data" if fsdp else None,
        "expert_lead": None,
        "layers": None,
        # Megatron-SP-style: layer-boundary activations shard seq over model
        # (dropped automatically when seq doesn't divide, e.g. decode S=1)
        "seq_sp": "model",
        # flash-decoding-style: KV-cache sequence dim over model
        "kv_seq": "model",
        # stacked per-device sparse-operand shards (repro.parallel.sparse)
        "sparse_shard": "data",
    }
    return rules


def _fits(shape, spec, mesh) -> bool:
    """Check divisibility of dims by their assigned mesh axes."""
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            continue
        names = names if isinstance(names, tuple) else (names,)
        total = 1
        for n in names:
            total *= mesh.shape[n]
        if dim % total:
            return False
    return True


def param_shardings(mesh, params_or_shapes, axes_tree, rules):
    """NamedSharding tree for params. Falls back to dropping axes whose mesh
    extent does not divide the dim (e.g. tiny smoke configs)."""

    def one(leaf, axes):
        shape = leaf.shape
        axes = tuple(axes)[: len(shape)]
        axes = axes + (None,) * (len(shape) - len(axes))
        spec = [rules.get(a) if a is not None else None for a in axes]
        # drop non-dividing assignments rather than failing
        for i, names in enumerate(spec):
            if names is None:
                continue
            nn = names if isinstance(names, tuple) else (names,)
            ext = 1
            for n in nn:
                ext *= mesh.shape[n]
            if shape[i] % ext:
                spec[i] = None
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(
        one, params_or_shapes, axes_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def batch_shardings(mesh, batch_spec, rules):
    """Shard every batch input on its leading (batch) dim. Falls back to a
    dividing prefix of the batch axes (or replication) for tiny batches
    (long_500k has global_batch=1)."""

    def one(leaf):
        names = rules["batch"]
        nn = names if isinstance(names, tuple) else (names,)
        # use the longest prefix of the batch axes that divides dim 0
        chosen = None
        for end in range(len(nn), 0, -1):
            ext = 1
            for n in nn[:end]:
                ext *= mesh.shape[n]
            if leaf.shape[0] % ext == 0:
                chosen = nn[:end] if end > 1 else nn[0]
                break
        spec = [chosen] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_spec, is_leaf=lambda x: hasattr(x, "shape"))


def make_mesh_rules(mesh, fsdp: bool = False, seq_shard: bool = False):
    multi_pod = "pod" in mesh.axis_names
    return make_rules(multi_pod, fsdp=fsdp, seq_shard=seq_shard)


def sparse_operand_sharding(mesh, axis="data") -> NamedSharding:
    """Placement for one stacked sparse-operand leaf: shard dim 0 on ``axis``.

    The ``sparse_shard`` logical-axis rule as a concrete ``NamedSharding``:
    a ``ShardedSparseTensor``'s stacked value/index arrays carry their
    per-device slices on the leading dim, which maps to one mesh axis — or,
    for a 2-D ``(data, model)`` sharded operand, a tuple of axes laid out
    major-to-minor on the shard dim; all trailing dims are replicated.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    for ax in axes:
        if ax not in mesh.shape:
            raise ValueError(f"sparse_operand_sharding: axis {ax!r} not in "
                             f"mesh axes {tuple(mesh.axis_names)}")
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))


def sparse_operand_shardings(mesh, sharded, axis=None):
    """Sharding tuple for a ``ShardedSparseTensor``'s data leaves."""
    sh = sparse_operand_sharding(mesh, axis if axis is not None
                                 else sharded.axis)
    return tuple(sh for _ in sharded.data)
