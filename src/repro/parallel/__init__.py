"""``repro.parallel`` — mesh-scale distribution (see docs/architecture.md).

Submodules (imported explicitly; this package has no re-exports so that
importing one layer never drags in another's jax state):

* ``repro.parallel.sharding`` — logical-axis -> mesh-axis rules and
  ``NamedSharding`` construction for params, batches and sparse operands.
* ``repro.parallel.collectives`` — explicit shard_map collectives:
  hierarchical psum, bf16/int8 compressed reductions.
* ``repro.parallel.pipeline`` — GPipe-style pipeline parallelism.
* ``repro.parallel.sparse`` — structure-aware sharded SpMM: the
  nonzero-balanced partitioner, ``ShardedSparseTensor``,
  ``use_sparse_mesh`` and the shard_map spmm path.
"""
