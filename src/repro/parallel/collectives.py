"""Explicit collectives for shard_map contexts: hierarchical and compressed
gradient reduction (distributed-optimization tricks; see
docs/architecture.md, parallel layer). The sharded SpMM path
(``repro.parallel.sparse``) combines its partial outputs through these as
well (``reduce="bf16"`` -> ``compressed_psum_bf16``).

* ``hierarchical_psum``    — reduce-scatter inside the pod, all-reduce across
                             pods, all-gather back in-pod: crosses the (slow)
                             inter-pod links with 1/pod_size of the bytes.
* ``compressed_psum_bf16`` — cast-to-bf16 all-reduce (2x inter-chip bytes
                             saved vs f32 master grads).
* ``compressed_psum_int8_ef`` — int8 quantized all-reduce with error-feedback
                             state (residual carried to the next step), the
                             standard 4x compression trick with unbiased-ish
                             long-run behavior.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def hierarchical_psum(x: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """psum over (inner x outer) via RS(inner) -> AR(outer) -> AG(inner).

    Mathematically identical to psum over both axes; the decomposition sends
    only 1/inner_size of the bytes over the outer (inter-pod) links.
    """
    n_inner = axis_size(inner_axis)
    lead = x.shape[0]
    if lead % n_inner:
        # fall back for non-dividing shapes
        return jax.lax.psum(x, (inner_axis, outer_axis))
    xs = x.reshape(n_inner, lead // n_inner, *x.shape[1:])
    piece = jax.lax.psum_scatter(xs, inner_axis, scatter_dimension=0, tiled=False)
    piece = jax.lax.psum(piece, outer_axis)
    out = jax.lax.all_gather(piece, inner_axis, axis=0, tiled=False)
    return out.reshape(x.shape)


def compressed_psum_bf16(x: jax.Array, axis) -> jax.Array:
    return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(x.dtype)


def compressed_psum_int8_ef(
    x: jax.Array, axis, err: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """int8 block-quantized psum with error feedback.

    Returns (reduced, new_error). ``err`` is the carried residual from the
    previous step (same shape as x; None -> zeros).
    """
    x32 = x.astype(jnp.float32)
    if err is not None:
        x32 = x32 + err
    # negotiate a shared scale (scalar pmax — negligible traffic), then the
    # integer psum is exact under that scale
    amax = jax.lax.pmax(jnp.max(jnp.abs(x32)), axis)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = x32 - deq
    # reduce quantized values in int32 to avoid overflow, rescale after
    red = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32)
    return (red * scale).astype(x.dtype), new_err


def tree_compressed_psum(tree, axis, method: str = "bf16", err_tree=None):
    """Apply compressed psum leaf-wise over a gradient pytree."""
    if method == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis), tree), err_tree
    if method == "bf16":
        return jax.tree.map(lambda g: compressed_psum_bf16(g, axis), tree), err_tree
    if method == "int8_ef":
        if err_tree is None:
            err_tree = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), tree)
        out = jax.tree.map(
            lambda g, e: compressed_psum_int8_ef(g, axis, e), tree, err_tree
        )
        red = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda o: isinstance(o, tuple))
        err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda o: isinstance(o, tuple))
        return red, err
    raise ValueError(f"unknown compression {method!r}")
