"""Explicit collectives for shard_map contexts: hierarchical and compressed
gradient reduction (distributed-optimization tricks; see
docs/architecture.md, parallel layer). The sharded SpMM path
(``repro.parallel.sparse``) combines its partial outputs through these as
well (``reduce="bf16"`` -> ``compressed_psum_bf16``).

* ``hierarchical_psum``    — reduce-scatter inside the pod, all-reduce across
                             pods, all-gather back in-pod: crosses the (slow)
                             inter-pod links with 1/pod_size of the bytes.
* ``compressed_psum_bf16`` — cast-to-bf16 all-reduce (2x inter-chip bytes
                             saved vs f32 master grads).
* ``compressed_psum_int8_ef`` — int8 quantized all-reduce with error-feedback
                             state (residual carried to the next step), the
                             standard 4x compression trick with unbiased-ish
                             long-run behavior.
"""

from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

# hierarchical_psum trace-time tallies: total calls vs calls that degraded
# to the flat two-axis psum because the leading dim didn't divide the inner
# axis. Surfaced via collective_counters() -> cache_stats()["combine"] so a
# dashboard can see when the bandwidth-saving decomposition silently isn't
# running; reset by repro.ops.clear_tuning_cache.
_COUNTERS: Dict[str, int] = {"hier_calls": 0, "hier_fallback": 0}
_WARNED_FALLBACK = False


def collective_counters() -> Dict[str, int]:
    """``{"hier_calls", "hier_fallback"}`` trace-time tallies (see above)."""
    return dict(_COUNTERS)


def reset_collective_counters() -> None:
    """Zero the hierarchical-psum tallies (``clear_tuning_cache`` calls
    this); the one-shot fallback warning re-arms too."""
    global _WARNED_FALLBACK
    _COUNTERS.update(hier_calls=0, hier_fallback=0)
    _WARNED_FALLBACK = False


def hierarchical_psum(x: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """psum over (inner x outer) via RS(inner) -> AR(outer) -> AG(inner).

    Mathematically identical to psum over both axes; the decomposition sends
    only 1/inner_size of the bytes over the outer (inter-pod) links.

    **Divisibility requirement:** the decomposition needs ``x.shape[0]`` to
    be a multiple of the inner axis size (the reduce-scatter splits the
    leading dim into ``inner_size`` equal pieces). When it doesn't divide,
    the call silently degrades to a flat ``psum`` over both axes — correct,
    but the inter-pod bandwidth saving is lost. The degradation is counted
    (``collective_counters()["hier_fallback"]``, surfaced in
    ``cache_stats()["combine"]``) and warned about once per process; pad
    the leading dim to a multiple of ``inner_size`` to stay on the
    hierarchical path.
    """
    global _WARNED_FALLBACK
    n_inner = axis_size(inner_axis)
    lead = x.shape[0]
    _COUNTERS["hier_calls"] += 1
    if lead % n_inner:
        # fall back for non-dividing shapes (counted: correctness is kept,
        # but the 1/inner_size inter-pod byte saving silently isn't)
        _COUNTERS["hier_fallback"] += 1
        if not _WARNED_FALLBACK:
            _WARNED_FALLBACK = True
            warnings.warn(
                f"hierarchical_psum: leading dim {lead} does not divide "
                f"inner axis {inner_axis!r} (size {n_inner}); falling back "
                "to a flat two-axis psum (correct, but without the "
                "hierarchical bandwidth saving). Pad the leading dim to a "
                f"multiple of {n_inner} to stay on the hierarchical path. "
                "Further fallbacks are counted in "
                "cache_stats()['combine']['hier_fallback'] without warning.",
                stacklevel=2)
        return jax.lax.psum(x, (inner_axis, outer_axis))
    xs = x.reshape(n_inner, lead // n_inner, *x.shape[1:])
    piece = jax.lax.psum_scatter(xs, inner_axis, scatter_dimension=0, tiled=False)
    piece = jax.lax.psum(piece, outer_axis)
    out = jax.lax.all_gather(piece, inner_axis, axis=0, tiled=False)
    return out.reshape(x.shape)


def compressed_psum_bf16(x: jax.Array, axis) -> jax.Array:
    return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(x.dtype)


def compressed_psum_int8_ef(
    x: jax.Array, axis, err: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """int8 block-quantized psum with error feedback.

    Returns (reduced, new_error). ``err`` is the carried residual from the
    previous step (same shape as x; None -> zeros).
    """
    x32 = x.astype(jnp.float32)
    if err is not None:
        x32 = x32 + err
    # negotiate a shared scale (scalar pmax — negligible traffic), then the
    # integer psum is exact under that scale
    amax = jax.lax.pmax(jnp.max(jnp.abs(x32)), axis)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = x32 - deq
    # reduce quantized values in int32 to avoid overflow, rescale after
    red = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32)
    return (red * scale).astype(x.dtype), new_err


def tree_compressed_psum(tree, axis, method: str = "bf16", err_tree=None):
    """Apply compressed psum leaf-wise over a gradient pytree."""
    if method == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis), tree), err_tree
    if method == "bf16":
        return jax.tree.map(lambda g: compressed_psum_bf16(g, axis), tree), err_tree
    if method == "int8_ef":
        if err_tree is None:
            err_tree = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), tree)
        out = jax.tree.map(
            lambda g, e: compressed_psum_int8_ef(g, axis, e), tree, err_tree
        )
        red = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda o: isinstance(o, tuple))
        err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda o: isinstance(o, tuple))
        return red, err
    raise ValueError(f"unknown compression {method!r}")
