"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Stages are laid out along an axis (default ``"pod"``); activations flow
stage -> stage+1 via ``ppermute`` each tick. With M microbatches and S
stages the schedule runs M + S - 1 ticks (bubble fraction (S-1)/(M+S-1)).
Autodiff flows through ppermute, so the same schedule trains.

This is the optional PP layout: the production default keeps the pod axis as
data-parallel (see docs/architecture.md, parallel layer);
``launch/train.py --pipeline`` and the tests
exercise this module.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map


def gpipe(
    stage_fn: Callable,  # (stage_params, x) -> y   (same shape as x)
    mesh,
    axis: str = "pod",
    data_axes=("data",),
):
    """Build a pipelined apply: (stacked_params [S, ...], x [M, mb, ...]) -> y.

    stacked_params' leading dim indexes stages; x's leading dim indexes
    microbatches. Returns y with the same [M, mb, ...] layout (outputs of the
    last stage, gathered back to all stages for downstream loss code).
    """

    def sharded(params_stacked, x):
        s = axis_size(axis)
        idx = jax.lax.axis_index(axis)
        p_local = jax.tree.map(lambda t: t[0], params_stacked)  # [1, ...] -> local
        m = x.shape[0]
        ticks = m + s - 1
        perm = [(i, (i + 1) % s) for i in range(s)]

        def tick(carry, t):
            act, outs = carry
            # stage 0 ingests microbatch t (or zeros once drained)
            mb_idx = jnp.clip(t, 0, m - 1)
            feed = jnp.where(t < m, x[mb_idx], jnp.zeros_like(x[0]))
            inp = jnp.where(idx == 0, feed, act)
            y = stage_fn(p_local, inp)
            # last stage emits microbatch t - (s - 1)
            out_idx = jnp.clip(t - (s - 1), 0, m - 1)
            emit = jnp.logical_and(idx == s - 1, t >= s - 1)
            outs = outs.at[out_idx].set(
                jnp.where(emit, y, outs[out_idx])
            )
            act = jax.lax.ppermute(y, axis, perm)
            return (act, outs), None

        outs0 = jnp.zeros_like(x)
        (_, outs), _ = jax.lax.scan(
            tick, (jnp.zeros_like(x[0]), outs0), jnp.arange(ticks)
        )
        # broadcast last stage's outputs to every stage (loss runs replicated)
        outs = jax.lax.psum(
            jnp.where(idx == s - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    return shard_map(
        sharded,
        mesh=mesh,
        # params: stage dim over the pipeline axis; x: [M, mb, ...] with the
        # microbatch dim replicated and the batch dim over the data axes
        in_specs=(P(axis), P(None, data_axes)),
        out_specs=P(None, data_axes),
        check_vma=False,
    )


def split_stages(tree, n_stages: int):
    """Reshape stacked layer params [L, ...] -> [S, L/S, ...] for gpipe."""
    return jax.tree.map(
        lambda t: t.reshape(n_stages, t.shape[0] // n_stages, *t.shape[1:]), tree
    )
