"""``repro.parallel.sparse`` — structure-aware sharded SpMM over a mesh.

The paper's WCSR kernel wins on irregular sparsity by splitting large
row-windows across thread blocks so every block carries the same amount of
nonzero work (§III-C). This module applies the same principle one level up:
distributing one SpMM across a device mesh, partitioned **by stored nonzero
work, not by row count** (the merge-based balancing of Yang et al. and the
workload-aware split of Acc-SpMM, at mesh scale).

Three pieces:

* ``partition_structure(structure, num_shards)`` — the structure-aware
  partitioner. WCSR is split at packed-column-chunk granularity (a giant
  window splits across devices, exactly like the paper's intra-GPU task
  split); BCSR at stored-block granularity. Split boundaries snap to
  window / block-row starts when the snap costs less than ``snap_tol`` of a
  mean shard, so shards stay row-aligned whenever balance allows — giving a
  worst-shard guarantee of ``<= (1 + 2*snap_tol) * mean + one work unit``
  stored elements (a chunk of ``b_row*b_col`` values for WCSR, one block
  for BCSR). The unit term only matters when a layer has so little stored
  work that units per shard are single digits — there the partition is
  still optimal for integral units, but the *ratio* can exceed 1.5 (one
  chunk over four devices is a ratio of 4 by definition).
  Partitions are memoized per structure via ``repro.ops.make_partition``
  (the plan-cache contract: partition once, swap values freely).

* ``ShardedSparseTensor`` / ``SparseTensor.shard(mesh, axis)`` — the
  device-sharded operand: per-shard value slices stacked on a leading shard
  dim and placed along one mesh axis; per-shard index arrays ride along as
  partition metadata (uploaded once).

* the sharded ``spmm`` path — ``repro.ops.spmm`` dispatches here for
  sharded operands (and auto-shards plain ``SparseTensor`` operands inside
  a ``use_sparse_mesh(...)`` scope). Each device runs the existing local
  kernel (BCSR block-streaming / WCSR window-gather, same backends and
  §IV-C tile selection) on its shard's partial problem, and partial outputs
  are combined with ``repro.parallel.collectives`` (plain ``psum`` or the
  bf16-compressed variant)::

      mesh = jax.make_mesh((4,), ("data",))
      sst = st.shard(mesh, "data")        # partitioned by nonzero work
      y = repro.ops.spmm(sst, b)          # == st @ b, computed on 4 devices

      with use_sparse_mesh(mesh):         # or flip a whole model/engine
          y = st @ b                      # auto-sharded, partition cached
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import contextlib
import contextvars

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.kernels.bcsr.kernel import bcsr_spmm_kernel, bcsr_spmv_kernel
from repro.kernels.bcsr.ref import bcsr_spmm_ref
from repro.kernels.wcsr.kernel import wcsr_spmm_kernel, wcsr_spmv_kernel
from repro.kernels.wcsr.ref import wcsr_spmm_ref
from repro.ops.config import OpConfig, resolve_interpret
from repro.ops.plan import make_partition, make_plan
from repro.ops.registry import on_tpu, register_backend, resolve_backend
from repro.ops.tiling import (pad_cols, resolve_bn, resolve_combine_chunks,
                              resolve_spmv_route, unpad_cols)
from repro.parallel.collectives import (compressed_psum_bf16,
                                        hierarchical_psum)
from repro.sparse.formats import BCSR, WCSR
from repro.sparse.structure import SparseStructure
from repro.sparse.tensor import SparseTensor

__all__ = [
    "SparsePartition",
    "partition_structure",
    "patch_partition",
    "CombineSchedule",
    "combine_group_bounds",
    "combine_schedule_counters",
    "ShardedSparseTensor",
    "shard_tensor",
    "use_sparse_mesh",
    "current_sparse_mesh",
    "sharded_spmm",
]


def _axis_tuple(axis) -> Tuple[str, ...]:
    """Normalize a mesh-axis argument (one name or a tuple) to a tuple."""
    return (axis,) if isinstance(axis, str) else tuple(axis)


# ---------------------------------------------------------------------------
# Structure-aware partitioner
# ---------------------------------------------------------------------------


def _balanced_boundaries(total: int, num_shards: int, snap: np.ndarray,
                         snap_tol: float) -> np.ndarray:
    """Contiguous split of ``total`` uniform work units into ``num_shards``.

    Ideal boundaries land every ``total / num_shards`` units; each one snaps
    to the nearest value in ``snap`` (window / block-row starts) if that
    moves it by at most ``snap_tol`` mean shards. Boundaries are forced
    non-decreasing, so empty shards are possible (tiny matrices) but never
    mis-ordered.
    """
    mean = total / max(num_shards, 1)
    tol = snap_tol * mean
    snap = np.unique(np.asarray(snap, np.int64))
    bounds = np.zeros(num_shards + 1, np.int64)
    bounds[-1] = total
    for i in range(1, num_shards):
        ideal = round(i * mean)
        j = int(np.searchsorted(snap, ideal))
        cands = [c for c in (snap[j - 1] if j > 0 else None,
                             snap[j] if j < len(snap) else None)
                 if c is not None]
        best = min(cands, key=lambda c: abs(c - ideal)) if cands else ideal
        bounds[i] = best if abs(best - ideal) <= tol else ideal
        bounds[i] = min(max(bounds[i], bounds[i - 1]), total)
    return bounds


class SparsePartition:
    """Per-device shards of one ``SparseStructure``, balanced by stored work.

    Immutable; identity is (structure, num_shards) — the memoization key of
    ``repro.ops.make_partition``. Holds the per-shard ``SparseStructure``
    list (each a valid local structure over the full logical shape, so the
    existing ``make_plan`` cache plans each shard once) plus the stacked
    index arrays the sharded kernels consume (uploaded to device once).
    """

    __slots__ = ("structure", "num_shards", "bounds", "shards", "_dev",
                 "_combine")

    def __init__(self, structure: SparseStructure, num_shards: int,
                 bounds: np.ndarray, shards: List[SparseStructure]):
        self.structure = structure
        self.num_shards = int(num_shards)
        self.bounds = bounds
        self.shards = tuple(shards)
        self._dev = None
        self._combine: Dict[int, "CombineSchedule"] = {}

    def combine_schedule(self, num_chunks: int) -> "CombineSchedule":
        """Memoized row-chunk schedule for the chunked overlapped combine."""
        cc = max(1, int(num_chunks))
        sched = self._combine.get(cc)
        if sched is None:
            sched = CombineSchedule(self, cc)
            self._combine[cc] = sched
            _SCHED_COUNTERS["schedules_built"] += 1
        return sched

    def __eq__(self, other):
        if not isinstance(other, SparsePartition):
            return NotImplemented
        return (self.structure, self.num_shards) == (other.structure,
                                                     other.num_shards)

    def __hash__(self):
        return hash((self.structure, self.num_shards))

    def __repr__(self):
        b = self.balance()
        return (f"SparsePartition({self.structure.fmt}, "
                f"shards={self.num_shards}, ratio={b['ratio']:.3f})")

    # -- balance accounting -------------------------------------------------
    @property
    def stored_per_shard(self) -> List[int]:
        """Stored elements (incl. format padding) carried by each shard."""
        return [s.stored_elements for s in self.shards]

    def balance(self) -> Dict[str, object]:
        """Worst/mean shard-load report (``serve.engine.stats()`` surface)."""
        stored = self.stored_per_shard
        mean = sum(stored) / max(len(stored), 1)
        return {
            "fmt": self.structure.fmt,
            "shape": self.structure.shape,
            "num_shards": self.num_shards,
            "stored_per_shard": stored,
            "mean_stored": mean,
            "max_stored": max(stored) if stored else 0,
            "ratio": (max(stored) / mean) if mean else 1.0,
        }

    # -- padded sizes (uniform across shards: SPMD needs one program) -------
    @property
    def _shard_units(self) -> List[Tuple[int, int]]:
        """Per-shard (start, end) in stored units (chunks*b_col or blocks)."""
        scale = self.structure.block[1] if self.structure.fmt == "wcsr" else 1
        return [(int(self.bounds[s]) * scale, int(self.bounds[s + 1]) * scale)
                for s in range(self.num_shards)]

    @property
    def padded_size(self) -> int:
        """Common padded per-shard extent (packed cols / stored blocks)."""
        sizes = [e - s for s, e in self._shard_units]
        floor = self.structure.block[1] if self.structure.fmt == "wcsr" else 1
        return max(max(sizes, default=0), floor)

    # -- stacked device index arrays (uploaded once) ------------------------
    def index_arrays(self) -> Dict[str, jax.Array]:
        """Stacked per-shard index arrays, leading dim = num_shards.

        Memoized only when built eagerly; under an enclosing trace the
        arrays become traced constants, which must not outlive the trace.
        """
        if self._dev is not None:
            return self._dev
        arrs = {k: jnp.asarray(v) for k, v in self._host_index_arrays().items()}
        if not any(isinstance(a, jax.core.Tracer) for a in arrs.values()):
            self._dev = arrs
        return arrs

    def _host_index_arrays(self) -> Dict[str, np.ndarray]:
        g = self.structure
        size = self.padded_size
        if g.fmt == "wcsr":
            ci = np.full((self.num_shards, size), -1, np.int32)
            wp = np.zeros((self.num_shards, len(g.ptrs)), np.int32)
            for s, (c0, c1) in enumerate(self._shard_units):
                ci[s, : c1 - c0] = g.indices[0][c0:c1]
                wp[s] = np.clip(g.ptrs, c0, c1) - c0
            return {"col_idx": ci, "window_ptr": wp}
        else:
            m_blocks = g.shape[0] // g.block[0]
            rows = np.zeros((self.num_shards, size), np.int32)
            cols = np.zeros((self.num_shards, size), np.int32)
            ptr = np.zeros((self.num_shards, m_blocks + 1), np.int32)
            mask = np.zeros((self.num_shards, g.shape[0]), bool)
            for s, (s0, s1) in enumerate(self._shard_units):
                r = g.indices[0][s0:s1]
                rows[s, : s1 - s0] = r
                # padding repeats the last covered block-row (same scheme as
                # bcsr_from_mask: the kernel revisits an already-open tile)
                rows[s, s1 - s0:] = r[-1] if len(r) else 0
                cols[s, : s1 - s0] = g.indices[1][s0:s1]
                ptr[s] = np.clip(g.ptrs, s0, s1) - s0
                cover = np.zeros(m_blocks, bool)
                if len(r):
                    cover[np.unique(r)] = True
                mask[s] = np.repeat(cover, g.block[0])
            return {"block_rows": rows, "block_cols": cols,
                    "block_row_ptr": ptr, "row_mask": mask}

    # -- value slicing ------------------------------------------------------
    def stack_values(self, data: Tuple[jax.Array, ...]) -> Tuple[jax.Array, ...]:
        """Slice global value leaves into stacked per-shard leaves.

        Slice offsets are static (from the structure), so this traces under
        ``jit`` — value swaps inside a compiled step re-slice for free.
        A second leaf (per-group codec scales, ``repro.sparse.codecs``) is
        sliced at group granularity so each shard ships its compressed
        payload together with exactly the f32 scales of its own
        chunks/blocks — the shards travel in compressed form.
        """
        size = self.padded_size
        if self.structure.fmt == "wcsr":
            values = data[0]  # [b_row, C] (codec payload when quantized)
            parts = []
            for c0, c1 in self._shard_units:
                v = values[:, c0:c1]
                parts.append(jnp.pad(v, ((0, 0), (0, size - (c1 - c0)))))
            out = [jnp.stack(parts)]
            if len(data) == 2:
                scales = data[1]  # [1, C // b_col] f32, one per chunk
                b_col = self.structure.block[1]
                nc = size // b_col
                sparts = []
                for c0, c1 in self._shard_units:
                    s0, s1 = c0 // b_col, c1 // b_col
                    sparts.append(jnp.pad(scales[:, s0:s1],
                                          ((0, 0), (0, nc - (s1 - s0)))))
                out.append(jnp.stack(sparts))
            return tuple(out)
        blocks = data[0]  # [nnz_padded, bm, bk]; slice only real blocks
        parts = []
        for s0, s1 in self._shard_units:
            v = blocks[s0:s1]
            parts.append(jnp.pad(v, ((0, size - (s1 - s0)), (0, 0), (0, 0))))
        out = [jnp.stack(parts)]
        if len(data) == 2:
            scales = data[1]  # [nnz_padded, 1] f32, one per stored block
            sparts = []
            for s0, s1 in self._shard_units:
                sparts.append(jnp.pad(scales[s0:s1],
                                      ((0, size - (s1 - s0)), (0, 0))))
            out.append(jnp.stack(sparts))
        return tuple(out)


def partition_structure(structure: SparseStructure, num_shards: int, *,
                        snap_tol: float = 0.2) -> SparsePartition:
    """Split a ``SparseStructure`` into ``num_shards`` balanced shards.

    WCSR: 1D row-window partition at packed-column-chunk granularity —
    contiguous chunk ranges of near-equal stored work, so a single giant
    window splits across devices (the paper's §III-C split at mesh scale)
    and empty windows cost nothing. BCSR: block-row partition at stored-
    block granularity, boundaries snapped to block-row starts when balance
    allows. Every shard keeps the full logical ``shape``; shards therefore
    produce *partial* outputs that the sharded spmm path sums.

    Prefer ``repro.ops.make_partition`` — it memoizes this per
    (structure, num_shards), the same once-per-structure contract as
    ``make_plan``.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    g = structure
    bounds = _partition_bounds(g, num_shards, snap_tol)
    shards = [_shard_structure(g, int(bounds[s]), int(bounds[s + 1]))
              for s in range(num_shards)]
    return SparsePartition(g, num_shards, bounds, shards)


def _partition_bounds(g: SparseStructure, num_shards: int,
                      snap_tol: float) -> np.ndarray:
    """Balanced shard boundaries in stored units (chunks / blocks)."""
    if g.fmt == "wcsr":
        b_col = g.block[1]
        return _balanced_boundaries(g.nnz // b_col, num_shards,
                                    np.asarray(g.ptrs, np.int64) // b_col,
                                    snap_tol)
    if g.fmt == "bcsr":
        return _balanced_boundaries(g.nnz, num_shards,
                                    np.asarray(g.ptrs, np.int64), snap_tol)
    raise ValueError(f"partition_structure: unsupported format {g.fmt!r}")


def _shard_structure(g: SparseStructure, u0: int, u1: int) -> SparseStructure:
    """One shard's local structure over unit range ``[u0, u1)``."""
    if g.fmt == "wcsr":
        b_col = g.block[1]
        c0, c1 = u0 * b_col, u1 * b_col
        return SparseStructure(
            fmt="wcsr", shape=g.shape, block=g.block, nnz=c1 - c0,
            ptrs=np.clip(g.ptrs, c0, c1) - c0,
            indices=(g.indices[0][c0:c1],))
    return SparseStructure(
        fmt="bcsr", shape=g.shape, block=g.block, nnz=u1 - u0,
        ptrs=np.clip(g.ptrs, u0, u1) - u0,
        indices=(g.indices[0][u0:u1], g.indices[1][u0:u1]))


def patch_partition(delta, base: SparsePartition, *,
                    snap_tol: float = 0.2) -> SparsePartition:
    """Patch a cached partition across a structure delta.

    Boundaries are recomputed exactly as ``partition_structure`` would (the
    balance pass is O(num_shards · log windows) — cheap), so the patched
    partition is *structurally identical* to a from-scratch rebuild of the
    new structure. The saving is in the shards: a shard whose unit range
    lies entirely before the delta's changed span (and kept its bounds), or
    entirely after it (bounds shifted by exactly the span's size change),
    has bitwise-identical local structure content — the base shard object
    is reused, and with it its memoized device uploads *and* its per-shard
    ``make_plan`` entries. Only shards whose chunk/block assignment
    actually changed are rebuilt — those are the ones a mesh must reship
    (``shards_reused`` / ``shards_reshipped`` in ``delta_stats()``).

    Why suffix shards can be reused: for every row the clipped local ptr
    ``clip(ptr_new, n0, n1) - n0`` equals ``clip(ptr_base, b0, b1) - b0``
    when ``(n0, n1) == (b0 + shift, b1 + shift)`` and the range sits past
    the span — rows before the touched span clip to the lower bound on
    both sides, rows after it carry the same uniform shift as the bounds —
    and the index-array slice is the base slice verbatim.

    Called by ``repro.ops.make_partition`` (counted as
    ``partition_patched``); not meant for direct use.
    """
    from repro.sparse.delta import _count

    g = delta.new
    u0b, u1b = delta.span_base
    shift = delta.unit_shift
    bounds = _partition_bounds(g, base.num_shards, snap_tol)
    shards = []
    reused = reshipped = 0
    for s in range(base.num_shards):
        b0, b1 = int(base.bounds[s]), int(base.bounds[s + 1])
        n0, n1 = int(bounds[s]), int(bounds[s + 1])
        if (n0, n1) == (b0, b1) and b1 <= u0b:
            shards.append(base.shards[s])
            reused += 1
        elif (n0, n1) == (b0 + shift, b1 + shift) and b0 >= u1b:
            shards.append(base.shards[s])
            reused += 1
        else:
            shards.append(_shard_structure(g, n0, n1))
            reshipped += 1
    _count("shards_reused", reused)
    _count("shards_reshipped", reshipped)
    return SparsePartition(g, base.num_shards, bounds, shards)


# ---------------------------------------------------------------------------
# Chunked-combine schedules (compute/collective overlap)
# ---------------------------------------------------------------------------

# Host-side build tallies for the chunked combine, surfaced via
# cache_stats()["combine"]: schedules_built counts CombineSchedule
# constructions (memoized per partition x chunk count), shard_chunks_built /
# shard_chunks_reused count per-shard chunk-array builds vs content hits in
# _SHARD_CHUNK_MEMO — after a structure delta, shards the partition patcher
# reused hit the memo, so untouched chunks cost nothing to re-derive.
_SCHED_COUNTERS: Dict[str, int] = {
    "schedules_built": 0, "shard_chunks_built": 0, "shard_chunks_reused": 0}

# per-shard chunk arrays keyed by (shard structure, kind, chunk bounds):
# SparseStructure hashes by content, so a patched partition that kept a
# shard's local structure (and the re-balance kept the chunk bounds) reuses
# the shard's chunk arrays without rebuilding them
_SHARD_CHUNK_MEMO: Dict[tuple, object] = {}


def combine_schedule_counters() -> Dict[str, int]:
    """Chunked-combine build tallies (see ``_SCHED_COUNTERS``)."""
    return dict(_SCHED_COUNTERS)


def reset_combine_schedule_counters() -> None:
    """Zero the tallies (``repro.ops.clear_tuning_cache`` calls this)."""
    _SCHED_COUNTERS.update(
        schedules_built=0, shard_chunks_built=0, shard_chunks_reused=0)


def clear_combine_schedules() -> None:
    """Drop memoized per-shard chunk arrays (``clear_plan_cache`` probe)."""
    _SHARD_CHUNK_MEMO.clear()


def combine_group_bounds(g: SparseStructure, num_chunks: int) -> np.ndarray:
    """Row-chunk boundaries in *group* indices (windows / block-rows).

    Reuses the partitioner's balance pass over stored units with boundaries
    snapped (unconditionally — a chunk boundary must be row-aligned, unlike
    a shard boundary) to window / block-row starts, then maps unit bounds
    back to group indices. Non-decreasing, ``bounds[0] == 0`` and
    ``bounds[-1] == num_groups`` so chunks tile every output row; empty
    chunks are possible for tiny matrices and get skipped by the schedule.
    """
    bm = g.block[0]
    num_groups = g.shape[0] // bm
    if g.fmt == "wcsr":
        unit_starts = np.asarray(g.ptrs, np.int64) // g.block[1]
    elif g.fmt == "bcsr":
        unit_starts = np.asarray(g.ptrs, np.int64)
    else:
        raise ValueError(f"combine_group_bounds: unsupported format {g.fmt!r}")
    total = int(unit_starts[-1])
    # snap_tol=num_chunks makes the tolerance exactly `total` units: every
    # boundary snaps to the nearest group start, no matter how far
    ub = _balanced_boundaries(total, max(int(num_chunks), 1), unit_starts,
                              snap_tol=float(max(int(num_chunks), 1)))
    bounds = np.searchsorted(unit_starts[:-1], ub, side="left").astype(np.int64)
    bounds[0] = 0
    bounds[-1] = num_groups
    return np.maximum.accumulate(bounds)


def _shard_task_chunks(shard: SparseStructure, tasks, cpt: int,
                       spans, bounds_key):
    """Per-chunk (t_win, t_start, t_n) slices of one shard's task list."""
    key = (shard, "tasks", cpt, bounds_key)
    hit = _SHARD_CHUNK_MEMO.get(key)
    if hit is not None:
        _SCHED_COUNTERS["shard_chunks_reused"] += 1
        return hit
    _SCHED_COUNTERS["shard_chunks_built"] += 1
    w, st_, nn = (np.asarray(x, np.int32) for x in tasks)
    out = []
    for r0, r1 in spans:
        lo, hi = np.searchsorted(w, (r0, r1), side="left")
        out.append((w[lo:hi], st_[lo:hi], nn[lo:hi]))
    _SHARD_CHUNK_MEMO[key] = out
    return out


def _shard_block_chunks(shard: SparseStructure, spans, bounds_key):
    """Per-chunk (start, count, rel_rows, cols) of one shard's block list."""
    key = (shard, "blocks", bounds_key)
    hit = _SHARD_CHUNK_MEMO.get(key)
    if hit is not None:
        _SCHED_COUNTERS["shard_chunks_reused"] += 1
        return hit
    _SCHED_COUNTERS["shard_chunks_built"] += 1
    ptr = np.asarray(shard.ptrs, np.int64)
    rows = np.asarray(shard.indices[0], np.int32)
    cols = np.asarray(shard.indices[1], np.int32)
    out = []
    for r0, r1 in spans:
        lo, hi = int(ptr[r0]), int(ptr[r1])
        out.append((lo, hi - lo, rows[lo:hi] - r0, cols[lo:hi]))
    _SHARD_CHUNK_MEMO[key] = out
    return out


class CombineSchedule:
    """Row-chunk schedule for one partition's chunked, overlapped combine.

    Splits the output rows into ``num_chunks`` contiguous group (window /
    block-row) spans of near-equal stored work, so the sharded spmm path can
    emit an independent local-compute -> collective chain per chunk and let
    the compiler's latency-hiding scheduler overlap chunk ``k``'s
    all-reduce with chunk ``k+1``'s kernels. Memoized per partition via
    ``SparsePartition.combine_schedule`` (identity: partition x chunk
    count); the per-shard chunk arrays are additionally memoized by shard
    *content*, so delta-patched partitions rebuild only touched shards.
    """

    __slots__ = ("partition", "num_chunks", "bounds", "spans",
                 "_wcsr", "_bcsr")

    def __init__(self, partition: SparsePartition, num_chunks: int):
        self.partition = partition
        self.bounds = combine_group_bounds(partition.structure, num_chunks)
        self.spans = tuple(
            (int(self.bounds[c]), int(self.bounds[c + 1]))
            for c in range(len(self.bounds) - 1)
            if self.bounds[c + 1] > self.bounds[c])
        self.num_chunks = len(self.spans)
        self._wcsr: Dict[int, list] = {}
        self._bcsr = None

    def _bounds_key(self):
        return tuple(int(x) for x in self.bounds)

    def wcsr_task_chunks(self, plans) -> list:
        """Per-chunk stacked ``(t_win, t_start, t_n)`` device arrays.

        ``t_start`` stays absolute into each shard's packed columns (only
        the task list is chunked; col_idx/values are passed whole), so the
        existing WCSR kernels run unchanged per chunk. Padding tasks carry
        ``t_n == 0`` (kernel no-ops) at the chunk's first window.
        """
        cpt = int(plans[0].chunks_per_task)
        hit = self._wcsr.get(cpt)
        if hit is not None:
            return hit
        bkey = self._bounds_key()
        per_shard = [_shard_task_chunks(s, p.tasks, cpt, self.spans, bkey)
                     for s, p in zip(self.partition.shards, plans)]
        S = self.partition.num_shards
        chunks = []
        for c, (r0, r1) in enumerate(self.spans):
            tc = max(max(len(ps[c][0]) for ps in per_shard), 1)
            tw = np.full((S, tc), r0, np.int32)
            ts = np.zeros((S, tc), np.int32)
            tn = np.zeros((S, tc), np.int32)  # 0 => no-op task
            for s, ps in enumerate(per_shard):
                w, st_, nn = ps[c]
                tw[s, : len(w)], ts[s, : len(w)], tn[s, : len(w)] = w, st_, nn
            chunks.append(tuple(jnp.asarray(x) for x in (tw, ts, tn)))
        self._wcsr[cpt] = chunks
        return chunks

    def bcsr_block_chunks(self):
        """Per-chunk BCSR index arrays + value-slice metadata.

        Returns ``(chunks, pad_blocks)``: each chunk is a dict with stacked
        chunk-relative ``rows`` / ``cols`` ``[S, size]``, a per-chunk
        ``row_mask`` ``[S, span_rows]``, per-shard ``start`` / ``count``
        ``[S]`` into the shard's padded value array, and the static
        ``size``. Values themselves are sliced inside ``shard_map`` with a
        ``dynamic_slice`` at ``start`` (sizes are uniform per chunk across
        shards — SPMD needs one program), after zero-padding the value dim
        by ``pad_blocks`` so the slice never clamps; blocks past ``count``
        are zeroed before the kernel sees them.
        """
        if self._bcsr is not None:
            return self._bcsr
        g = self.partition.structure
        bm = g.block[0]
        bkey = self._bounds_key()
        per_shard = [_shard_block_chunks(s, self.spans, bkey)
                     for s in self.partition.shards]
        S = self.partition.num_shards
        chunks = []
        for c, (r0, r1) in enumerate(self.spans):
            size = max(max(ps[c][1] for ps in per_shard), 1)
            rows = np.zeros((S, size), np.int32)
            cols = np.zeros((S, size), np.int32)
            mask = np.zeros((S, (r1 - r0) * bm), bool)
            start = np.zeros(S, np.int32)
            count = np.zeros(S, np.int32)
            for s, ps in enumerate(per_shard):
                lo, cnt, r, cl = ps[c]
                rows[s, :cnt] = r
                # padding repeats the last covered row (blocks are zeroed)
                rows[s, cnt:] = r[-1] if cnt else 0
                cols[s, :cnt] = cl
                start[s], count[s] = lo, cnt
                cover = np.zeros(r1 - r0, bool)
                if cnt:
                    cover[np.unique(r)] = True
                mask[s] = np.repeat(cover, bm)
            chunks.append({
                "rows": jnp.asarray(rows), "cols": jnp.asarray(cols),
                "mask": jnp.asarray(mask), "start": jnp.asarray(start),
                "count": jnp.asarray(count), "size": size,
            })
        pad_blocks = max(ch["size"] for ch in chunks)
        self._bcsr = (chunks, pad_blocks)
        return self._bcsr


# ---------------------------------------------------------------------------
# Sharded operand + mesh context
# ---------------------------------------------------------------------------


class ShardedSparseTensor:
    """A ``SparseTensor`` distributed over one mesh axis by stored work.

    ``data`` holds the per-shard value slices stacked on a leading shard
    dim (the only pytree leaves); structure, partition, mesh, axis and the
    value codec ride along as static aux data, so a sharded operand flows
    through ``jit`` exactly like a ``SparseTensor`` does. Built via
    ``SparseTensor.shard(mesh, axis)``. Under a codec the leaves are
    ``(payload, scales)`` — shards ship compressed, each with the f32
    scales of its own chunks/blocks.
    """

    __slots__ = ("structure", "partition", "mesh", "axis", "data", "codec")

    def __init__(self, structure: SparseStructure, partition: SparsePartition,
                 mesh, axis, data, codec: str = "none"):
        self.structure = structure
        self.partition = partition
        self.mesh = mesh
        # one axis name, or a tuple of names for 2-D (data, model) sharding:
        # the leading shard dim is laid out major-to-minor over the tuple
        self.axis = (str(axis) if isinstance(axis, str)
                     else tuple(str(x) for x in axis))
        self.data = tuple(data)
        self.codec = str(codec)

    @property
    def format(self) -> str:
        return self.structure.fmt

    @property
    def shape(self) -> Tuple[int, int]:
        return self.structure.shape

    @property
    def block(self) -> Tuple[int, int]:
        return self.structure.block

    @property
    def dtype(self):
        return self.data[0].dtype

    @property
    def num_shards(self) -> int:
        return self.partition.num_shards

    def balance(self) -> Dict[str, object]:
        """Per-shard stored-work report (worst/mean ratio and friends)."""
        return self.partition.balance()

    def with_values(self, *global_data) -> "ShardedSparseTensor":
        """Same partition, new *global* value leaves — never re-partitions.

        Under a codec pass the global ``(payload, scales)`` pair.
        """
        return ShardedSparseTensor(
            self.structure, self.partition, self.mesh, self.axis,
            self.partition.stack_values(tuple(global_data)),
            codec=self.codec)

    def astype(self, dtype) -> "ShardedSparseTensor":
        if self.codec != "none":
            raise TypeError(
                "astype on a quantized ShardedSparseTensor would cast the "
                "codec payload; re-quantize the unsharded tensor "
                "(st.astype(dtype).quantize(codec).shard(mesh, axis)) "
                "instead")
        return ShardedSparseTensor(
            self.structure, self.partition, self.mesh, self.axis,
            tuple(x.astype(dtype) for x in self.data))

    def __matmul__(self, b) -> jax.Array:
        """``self @ B`` via the sharded ``repro.ops.spmm`` path."""
        from repro.ops import spmm

        return spmm(self, b)

    def matmul(self, b, **kw) -> jax.Array:
        """Sharded ``spmm`` with per-call keyword overrides (impl=, ...)."""
        from repro.ops import spmm

        return spmm(self, b, **kw)

    def __repr__(self):
        return (f"ShardedSparseTensor({self.format}, shape={self.shape}, "
                f"shards={self.num_shards}, axis={self.axis!r}, "
                f"dtype={self.dtype})")


jax.tree_util.register_pytree_node(
    ShardedSparseTensor,
    lambda t: (t.data, (t.structure, t.partition, t.mesh, t.axis, t.codec)),
    lambda aux, data: ShardedSparseTensor(
        aux[0], aux[1], aux[2], aux[3], data, codec=aux[4]),
)


def _is_traced(data) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in data)


def shard_tensor(st: SparseTensor, mesh, axis="data"
                 ) -> ShardedSparseTensor:
    """Partition a ``SparseTensor`` over mesh axes by stored work.

    ``axis`` is one mesh-axis name, or a tuple of names for 2-D sharding —
    ``st.shard(mesh, ("data", "model"))`` splits into
    ``mesh.shape["data"] * mesh.shape["model"]`` shards laid out data-major
    on the stacked leading dim (shard ``s`` lives at mesh position
    ``(s // n_model, s % n_model)``), enabling ``reduce="hier"`` combines.

    The partition comes from the ``repro.ops.make_partition`` cache (once
    per structure); value slicing is static, so this also works on traced
    tensors inside ``jit`` (the eager path additionally places the stacked
    leaves along the mesh axes via ``parallel.sharding`` rules).
    """
    axes = _axis_tuple(axis)
    for ax in axes:
        if ax not in mesh.shape:
            raise ValueError(
                f"shard_tensor: axis {ax!r} not in mesh axes "
                f"{tuple(mesh.axis_names)}")
    num_shards = 1
    for ax in axes:
        num_shards *= int(mesh.shape[ax])
    part = make_partition(st.structure, num_shards)
    data = part.stack_values(st.data)
    sst = ShardedSparseTensor(st.structure, part, mesh,
                              axes[0] if len(axes) == 1 else axes, data,
                              codec=st.codec)
    if not _is_traced(data):
        from repro.parallel.sharding import sparse_operand_shardings

        sst.data = tuple(jax.device_put(x, sh) for x, sh in
                         zip(data, sparse_operand_shardings(mesh, sst)))
    return sst


_SPARSE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sparse_mesh", default=None)


@contextlib.contextmanager
def use_sparse_mesh(mesh, axis="data"):
    """Route ``SparseTensor`` spmm through the sharded path in this scope.

    Inside the context, ``repro.ops.spmm`` (and ``st @ b``) auto-shards
    plain ``SparseTensor`` operands over ``mesh``'s ``axis`` (one name, or
    a tuple like ``("data", "model")`` for 2-D sharding) — partitions are
    memoized per structure, so repeated calls (a serving loop) pay the
    partitioner once. ``ShardedSparseTensor`` operands are unaffected (they
    carry their own mesh).

    Like ``use_config``, the scope applies when an op *traces*: a function
    already compiled outside the scope keeps its single-device program
    inside it (and vice versa) — enter the scope before the first traced
    call, or shard explicitly with ``st.shard(mesh, axis)`` so the sharded
    operand itself keys the jit cache.
    """
    axes = _axis_tuple(axis)
    for ax in axes:
        if ax not in mesh.shape:
            raise ValueError(f"use_sparse_mesh: axis {ax!r} not in mesh "
                             f"axes {tuple(mesh.axis_names)}")
    token = _SPARSE_MESH.set(
        (mesh, axes[0] if len(axes) == 1 else axes))
    try:
        yield
    finally:
        _SPARSE_MESH.reset(token)


def current_sparse_mesh() -> Optional[Tuple[object, str]]:
    """The active ``use_sparse_mesh`` (mesh, axis), or None."""
    return _SPARSE_MESH.get()


# ---------------------------------------------------------------------------
# Sharded spmm execution
# ---------------------------------------------------------------------------


def _reduce(x: jax.Array, axis, method: str) -> jax.Array:
    """Cross-device partial-output combine (repro.parallel.collectives).

    ``axis`` is one mesh-axis name or a tuple (2-D sharded operands reduce
    over both). ``"hier"`` runs ``hierarchical_psum`` with the second axis
    as the inner (fast) links — 2-axis operands only.
    """
    if method in (None, "psum"):
        return jax.lax.psum(x, axis)
    if method == "bf16":
        return compressed_psum_bf16(x, axis)
    if method == "hier":
        axes = _axis_tuple(axis)
        if len(axes) != 2:
            raise ValueError(
                "reduce='hier' needs an operand sharded over exactly two "
                f"mesh axes (outer, inner); got {axes!r} — shard with "
                "st.shard(mesh, (outer, inner)) first")
        return hierarchical_psum(x, axes[1], axes[0])
    raise ValueError(f"unknown sharded-spmm reduce {method!r} "
                     "(use 'psum', 'bf16' or 'hier')")


def sharded_spmm(a: ShardedSparseTensor, b: jax.Array, cfg: OpConfig, *,
                 inner_impl: Optional[str] = None,
                 reduce: str = "psum") -> jax.Array:
    """``C = A_sharded @ B`` over ``a.mesh``: local kernels + collective sum.

    Each device runs the single-device backend (resolved from
    ``inner_impl`` / ``cfg.impl`` exactly like unsharded ``spmm``) on its
    shard's partial problem — same §IV-C tile width as the unsharded call,
    per-shard §III-C task plans from the ``make_plan`` cache — then partial
    [m, n] outputs are combined with ``reduce`` ("psum", or "bf16" for the
    compressed collective) over the mesh axis. The result is replicated.

    Quantized operands stay compressed end-to-end: each shard ships its
    codec payload with the f32 scales of its own chunks/blocks, the local
    kernels fuse the dequant in-register, and the partial outputs reuse
    the same collective machinery — including the bf16-compressed
    ``reduce="bf16"`` — as the raw-value path.

    **Chunked compute/collective overlap** (``cfg.combine_chunks``): when
    the resolved chunk count is > 1, the output rows are split into
    row-chunks snapped to window / block-row starts (``CombineSchedule``)
    and the local program emits an independent compute -> ``reduce`` chain
    per chunk — the compiler's latency-hiding scheduler can then run the
    collective for chunk ``k`` while chunk ``k+1``'s kernels execute.
    Numerics are identical to the blocking combine (same local partials,
    same reduction, just row-partitioned). ``combine_chunks=1`` keeps the
    single fused combine.

    2-D meshes: an operand sharded over two axes (``st.shard(mesh,
    ("data", "model"))``) reduces over both; ``reduce="hier"`` routes the
    combine through ``hierarchical_psum`` (inner = second axis).
    """
    g = a.structure
    mesh, axis = a.mesh, a.axis
    codec = a.codec
    impl = resolve_backend(f"spmm/{g.fmt}", inner_impl or cfg.impl).name
    m, k = g.shape
    if b.shape[0] != k:
        raise ValueError(f"A {g.shape} @ B {b.shape}: inner dims differ")
    n = b.shape[1]
    bm, bk = g.block
    # one global skinny-N route, resolved once like bn/depth below (shards
    # must run one SPMD program): distributed decode rides the same GEMV
    # kernels as the single-device dispatch instead of silently falling
    # back to full-tile SpMM
    route = resolve_spmv_route(cfg.spmv_threshold, n, op="spmm", fmt=g.fmt,
                               shape=g.shape, block=g.block, dtype=a.dtype)
    # one global tile width, identical to the unsharded selection (shards
    # must run one SPMD program; per-shard bn would diverge the grid)
    bn = resolve_bn(cfg.bn, n, bm, bk, a.dtype, op="spmm", fmt=g.fmt,
                    shape=g.shape, impl="kernel")
    if route == "spmv":
        # no bn tiling on the vector path, hence nothing to pad
        b_pad, bn_eff, pad = b, None, 0
    else:
        (b_pad,), bn_eff, pad = pad_cols([b], n, bn)
    interpret = resolve_interpret(cfg, True if impl == "kernel_interpret"
                                  else not on_tpu())
    if reduce == "hier" and len(_axis_tuple(axis)) != 2:
        raise ValueError(
            "reduce='hier' needs an operand sharded over two mesh axes "
            f"(got axis={axis!r}); use st.shard(mesh, ('data', 'model'))")
    # one global chunk count, resolved like bn/route above (one SPMD
    # program): >1 splits the combine into overlapped row-chunk chains
    cc = resolve_combine_chunks(
        cfg.combine_chunks, n, num_groups=m // bm, num_shards=a.num_shards,
        op="spmm", fmt=g.fmt, shape=g.shape, block=g.block, dtype=a.dtype)
    idx = a.partition.index_arrays()
    specs = lambda n_ops: (P(axis),) * n_ops + (P(),)

    def _decode_local(payload, sc):
        """Per-device dequant for the ref path (kernels fuse it instead)."""
        from repro.sparse.codecs import decode_format_values

        return decode_format_values(g.fmt, (bm, bk), payload, sc)

    if g.fmt == "wcsr":
        cfg_bn = dataclasses.replace(cfg, bn=bn)
        plans = [make_plan(s, n, cfg_bn, dtype=a.dtype, codec=codec,
                           route=route, combine_chunks=cc)
                 for s in a.partition.shards]
        cpt = plans[0].chunks_per_task
        # one global §III-A depth, like bn: shards run one SPMD program
        depth = plans[0].pipeline_depth
        num_tasks = max(p.num_tasks for p in plans)
        t_win = np.zeros((a.num_shards, num_tasks), np.int32)
        t_start = np.zeros((a.num_shards, num_tasks), np.int32)
        t_n = np.zeros((a.num_shards, num_tasks), np.int32)  # 0 => no-op task
        for s, p in enumerate(plans):
            w, st_, nn = p.tasks
            t_win[s, : len(w)], t_start[s, : len(w)], t_n[s, : len(w)] = \
                w, st_, nn
        padded_cols = a.partition.padded_size
        num_windows = g.num_windows

        def _wcsr_partial(ts, tn, ci, v, sc, bmat):
            if route == "spmv":
                return wcsr_spmv_kernel(
                    ts, tn, ci, v, bmat, sc, b_row=bm, b_col=bk,
                    chunks_per_task=cpt, out_dtype=jnp.float32,
                    interpret=interpret, pipeline_depth=depth, codec=codec)
            return wcsr_spmm_kernel(
                ts, tn, ci, v, bmat, sc, b_row=bm, b_col=bk,
                bn=bn_eff, chunks_per_task=cpt, out_dtype=jnp.float32,
                interpret=interpret, pipeline_depth=depth, codec=codec)

        if cc > 1:
            sched = a.partition.combine_schedule(cc)
            spans = sched.spans
            chunk_ops = sched.wcsr_task_chunks(plans)

            def local(chunks, ci, wp, v, sc, bmat):
                ci, wp, v = ci[0], wp[0], v[0]
                sc = None if sc is None else sc[0]
                if impl == "ref":
                    # ref has no task list: one full local partial, then
                    # per-chunk row slices ride the chunked combine
                    vd = _decode_local(v, sc) if codec != "none" else v
                    w_loc = WCSR(values=vd, col_idx=ci, window_ptr=wp,
                                 shape=(m, k), b_row=bm, b_col=bk,
                                 padded_cols=padded_cols)
                    full = wcsr_spmm_ref(w_loc, bmat, out_dtype=jnp.float32)
                    return jnp.concatenate(
                        [_reduce(full[r0 * bm:r1 * bm], axis, reduce)
                         for r0, r1 in spans], axis=0)
                outs = []
                for (r0, r1), (tw, ts, tn) in zip(spans, chunks):
                    tw, ts, tn = tw[0], ts[0], tn[0]
                    partial = _wcsr_partial(ts, tn, ci, v, sc, bmat)
                    o = jax.ops.segment_sum(partial, tw - r0,
                                            num_segments=r1 - r0)
                    outs.append(_reduce(o.reshape((r1 - r0) * bm, -1),
                                        axis, reduce))
                return jnp.concatenate(outs, axis=0)

            out = shard_map(
                local, mesh=mesh, in_specs=specs(5), out_specs=P(),
                check_vma=False,
            )(chunk_ops, idx["col_idx"], idx["window_ptr"], a.data[0],
              a.data[1] if codec != "none" else None, b_pad)
        else:
            def local(tw, ts, tn, ci, wp, v, sc, bmat):
                tw, ts, tn, ci, wp, v = (x[0] for x in (tw, ts, tn, ci, wp, v))
                sc = None if sc is None else sc[0]
                if impl == "ref":
                    if codec != "none":
                        v = _decode_local(v, sc)
                    w_loc = WCSR(values=v, col_idx=ci, window_ptr=wp,
                                 shape=(m, k), b_row=bm, b_col=bk,
                                 padded_cols=padded_cols)
                    out = wcsr_spmm_ref(w_loc, bmat, out_dtype=jnp.float32)
                else:
                    partial = _wcsr_partial(ts, tn, ci, v, sc, bmat)
                    out = jax.ops.segment_sum(partial, tw,
                                              num_segments=num_windows)
                    out = out.reshape(m, -1)
                return _reduce(out, axis, reduce)

            # the scales slot always exists (None when codec is off — an
            # empty pytree, so its P(axis) spec binds no leaves)
            out = shard_map(
                local, mesh=mesh, in_specs=specs(7), out_specs=P(),
                check_vma=False,
            )(jnp.asarray(t_win), jnp.asarray(t_start), jnp.asarray(t_n),
              idx["col_idx"], idx["window_ptr"], a.data[0],
              a.data[1] if codec != "none" else None, b_pad)
    else:
        nnz_p = a.partition.padded_size
        m_blocks = m // bm
        if cc > 1 and impl == "ref":
            # ref path: one full local partial, per-chunk row slices ride
            # the chunked combine (plumbing parity with the kernel path)
            sched = a.partition.combine_schedule(cc)
            spans = sched.spans

            def local(r, c, pt, bl, sc, bmat):
                r, c, pt, bl = (x[0] for x in (r, c, pt, bl))
                sc = None if sc is None else sc[0]
                if codec != "none":
                    bl = _decode_local(bl, sc)
                a_loc = BCSR(blocks=bl, block_rows=r, block_cols=c,
                             block_row_ptr=pt, shape=(m, k), block=(bm, bk),
                             nnz_blocks=nnz_p)
                full = bcsr_spmm_ref(a_loc, bmat, out_dtype=jnp.float32)
                return jnp.concatenate(
                    [_reduce(full[r0 * bm:r1 * bm], axis, reduce)
                     for r0, r1 in spans], axis=0)

            out = shard_map(
                local, mesh=mesh, in_specs=specs(5), out_specs=P(),
                check_vma=False,
            )(idx["block_rows"], idx["block_cols"], idx["block_row_ptr"],
              a.data[0], a.data[1] if codec != "none" else None, b_pad)
        elif cc > 1:
            sched = a.partition.combine_schedule(cc)
            spans = sched.spans
            bchunks, pad_blocks = sched.bcsr_block_chunks()
            idx_ops = [(ch["rows"], ch["cols"], ch["mask"], ch["start"],
                        ch["count"]) for ch in bchunks]
            sizes = [ch["size"] for ch in bchunks]
            # zero-pad the block dim so per-chunk dynamic slices never clamp
            v_pad = jnp.pad(a.data[0],
                            ((0, 0), (0, pad_blocks), (0, 0), (0, 0)))
            sc_pad = (jnp.pad(a.data[1], ((0, 0), (0, pad_blocks), (0, 0)))
                      if codec != "none" else None)

            def local(chunks, v, sc, bmat):
                v = v[0]
                sc = None if sc is None else sc[0]
                outs = []
                for (r0, r1), (r, c, msk, st0, cnt), size in zip(
                        spans, chunks, sizes):
                    r, c, msk, st0, cnt = (r[0], c[0], msk[0],
                                           st0[0], cnt[0])
                    bl = jax.lax.dynamic_slice_in_dim(v, st0, size, 0)
                    # blocks past this shard's count belong to the next
                    # chunk: zero them (their padded row ids are harmless)
                    valid = jnp.arange(size) < cnt
                    bl = jnp.where(valid[:, None, None], bl, 0)
                    scc = None
                    if sc is not None:
                        scc = jax.lax.dynamic_slice_in_dim(sc, st0, size, 0)
                        scc = jnp.where(valid[:, None], scc, 0)
                    mb = r1 - r0
                    if route == "spmv":
                        # spmv kernel zero-fills its accumulator: no mask
                        o = bcsr_spmv_kernel(
                            r, c, bl, bmat, scc, m_blocks=mb,
                            block=(bm, bk), out_dtype=jnp.float32,
                            interpret=interpret, codec=codec)
                    else:
                        o = bcsr_spmm_kernel(
                            r, c, bl, bmat, scc, m_blocks=mb,
                            block=(bm, bk), bn=bn_eff,
                            out_dtype=jnp.float32, interpret=interpret,
                            codec=codec)
                        o = jnp.where(msk[:, None], o, 0.0)
                    outs.append(_reduce(o, axis, reduce))
                return jnp.concatenate(outs, axis=0)

            out = shard_map(
                local, mesh=mesh, in_specs=specs(3), out_specs=P(),
                check_vma=False,
            )(idx_ops, v_pad, sc_pad, b_pad)
        else:
            def local(r, c, pt, mask, bl, sc, bmat):
                r, c, pt, mask, bl = (x[0] for x in (r, c, pt, mask, bl))
                sc = None if sc is None else sc[0]
                if impl == "ref":
                    if codec != "none":
                        bl = _decode_local(bl, sc)
                    a_loc = BCSR(blocks=bl, block_rows=r, block_cols=c,
                                 block_row_ptr=pt, shape=(m, k),
                                 block=(bm, bk), nnz_blocks=nnz_p)
                    out = bcsr_spmm_ref(a_loc, bmat, out_dtype=jnp.float32)
                elif route == "spmv":
                    # no row mask needed: the spmv kernel zero-fills its
                    # whole accumulator, so uncovered rows are genuinely zero
                    out = bcsr_spmv_kernel(
                        r, c, bl, bmat, sc, m_blocks=m_blocks,
                        block=(bm, bk), out_dtype=jnp.float32,
                        interpret=interpret, codec=codec)
                else:
                    out = bcsr_spmm_kernel(
                        r, c, bl, bmat, sc, m_blocks=m_blocks,
                        block=(bm, bk), bn=bn_eff, out_dtype=jnp.float32,
                        interpret=interpret, codec=codec)
                    # rows no shard-block covers are never written by the
                    # kernel: select zeros there instead of trusting the
                    # buffer
                    out = jnp.where(mask[:, None], out, 0.0)
                return _reduce(out, axis, reduce)

            out = shard_map(
                local, mesh=mesh, in_specs=specs(6), out_specs=P(),
                check_vma=False,
            )(idx["block_rows"], idx["block_cols"], idx["block_row_ptr"],
              idx["row_mask"], a.data[0],
              a.data[1] if codec != "none" else None, b_pad)

    out = out.astype(cfg.out_dtype or b.dtype)
    return unpad_cols(out, n, pad)


# ---------------------------------------------------------------------------
# Registry wiring: sharded operands dispatch like any other format
# ---------------------------------------------------------------------------


def _register():
    from repro.sparse.registry import SparseFormat, register_sparse_format

    register_sparse_format(SparseFormat(
        name="sharded",
        fmt_type=ShardedSparseTensor,
        op="spmm/sharded",
        stored_elements=lambda a: a.structure.stored_elements,
    ))

    # knobs are declared keyword-only (no **kwargs) so the spmm-level
    # extras validation can reject typos instead of forwarding them blind

    @register_backend("spmm/sharded", "kernel", available=on_tpu,
                      priority=100)
    def _sharded_kernel(a, b, cfg: OpConfig, *, reduce="psum"):
        return sharded_spmm(a, b, cfg, inner_impl="kernel", reduce=reduce)

    @register_backend("spmm/sharded", "ref", priority=50)
    def _sharded_ref(a, b, cfg: OpConfig, *, reduce="psum"):
        return sharded_spmm(a, b, cfg, inner_impl="ref", reduce=reduce)

    @register_backend("spmm/sharded", "kernel_interpret", priority=10)
    def _sharded_kernel_interpret(a, b, cfg: OpConfig, *, reduce="psum"):
        return sharded_spmm(a, b, cfg, inner_impl="kernel_interpret",
                            reduce=reduce)


_register()
