"""DEPRECATED: thin shims forwarding to the ``repro.sparse`` layer.

The BCSR/WCSR containers and their host-side constructors moved to
``repro.sparse.formats``; the format-agnostic API on top (``SparseTensor``,
``convert``, ``sparsify``, the ``SparseFormat`` registry) lives in
``repro.sparse``. The class names re-export directly (they are the same
pytree types); the free functions warn on use and forward — the same
pattern as the PR-1 ``kernels/*/ops.py`` shims.
"""

from __future__ import annotations

import functools
import warnings

import repro.sparse as _sparse
from repro.sparse.formats import BCSR, WCSR  # noqa: F401  (same classes)

__all__ = [
    "BCSR",
    "WCSR",
    "bcsr_from_dense",
    "bcsr_to_dense",
    "bcsr_from_mask",
    "bcsr_transpose",
    "wcsr_from_dense",
    "wcsr_to_dense",
    "block_mask_from_dense",
    "fill_ratio",
    "rcm_permutation",
    "make_wcsr_tasks",
]


def _shim(name: str):
    new = getattr(_sparse, name)

    @functools.wraps(new)
    def fn(*args, **kwargs):
        warnings.warn(
            f"repro.core.formats.{name} is deprecated; use "
            f"repro.sparse.{name} instead",
            DeprecationWarning, stacklevel=2)
        return new(*args, **kwargs)

    return fn


bcsr_from_dense = _shim("bcsr_from_dense")
bcsr_to_dense = _shim("bcsr_to_dense")
bcsr_from_mask = _shim("bcsr_from_mask")
bcsr_transpose = _shim("bcsr_transpose")
wcsr_from_dense = _shim("wcsr_from_dense")
wcsr_to_dense = _shim("wcsr_to_dense")
block_mask_from_dense = _shim("block_mask_from_dense")
fill_ratio = _shim("fill_ratio")
rcm_permutation = _shim("rcm_permutation")
make_wcsr_tasks = _shim("make_wcsr_tasks")
