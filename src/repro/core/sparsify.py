"""DEPRECATED: thin shims forwarding to ``repro.sparse.sparsify``.

The mask helpers moved verbatim; the ``sparsify_to_bcsr`` /
``sparsify_to_wcsr`` pair is subsumed by the format-agnostic
``repro.sparse.sparsify(dense, format=..., method=...)`` (which returns a
``SparseTensor``; these shims return the raw formats as before).
"""

from __future__ import annotations

import functools
import warnings

from typing import Tuple

import numpy as np

import repro.sparse as _sparse
from repro.sparse.formats import BCSR, WCSR  # noqa: F401

__all__ = [
    "random_block_mask",
    "magnitude_block_mask",
    "banded_block_mask",
    "apply_block_mask",
    "sparsify_to_bcsr",
    "sparsify_to_wcsr",
]


def _shim(name: str):
    new = getattr(_sparse, name)

    @functools.wraps(new)
    def fn(*args, **kwargs):
        warnings.warn(
            f"repro.core.sparsify.{name} is deprecated; use "
            f"repro.sparse.{name} instead",
            DeprecationWarning, stacklevel=2)
        return new(*args, **kwargs)

    return fn


random_block_mask = _shim("random_block_mask")
magnitude_block_mask = _shim("magnitude_block_mask")
banded_block_mask = _shim("banded_block_mask")
apply_block_mask = _shim("apply_block_mask")


def sparsify_to_bcsr(
    weight: np.ndarray,
    block: Tuple[int, int],
    sparsity: float,
    method: str = "magnitude",
    seed: int = 0,
    pad_to: int | None = None,
) -> BCSR:
    """Deprecated alias of ``repro.sparse.sparsify(..., format="bcsr")``."""
    warnings.warn(
        "repro.core.sparsify.sparsify_to_bcsr is deprecated; use "
        "repro.sparse.sparsify(w, format='bcsr', ...) instead",
        DeprecationWarning, stacklevel=2)
    return _sparse.sparsify(weight, format="bcsr", sparsity=sparsity,
                            method=method, block=block, seed=seed,
                            pad_to=pad_to).raw


def sparsify_to_wcsr(
    weight: np.ndarray,
    b_row: int,
    b_col: int,
    sparsity: float,
    method: str = "magnitude",
    seed: int = 0,
) -> WCSR:
    """Deprecated alias of ``repro.sparse.sparsify(..., format="wcsr")``."""
    warnings.warn(
        "repro.core.sparsify.sparsify_to_wcsr is deprecated; use "
        "repro.sparse.sparsify(w, format='wcsr', ...) instead",
        DeprecationWarning, stacklevel=2)
    return _sparse.sparsify(weight, format="wcsr", sparsity=sparsity,
                            method=method, block=(b_row, b_col),
                            seed=seed).raw
