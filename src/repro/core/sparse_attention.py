"""MInference-lite: offline per-head block-sparse attention pattern selection.

The paper (§IV-D) integrates MInference, which "profiles heads offline to
identify dominant block-sparse patterns and dynamically applies the
best-fitting pattern at inference time". We reproduce the offline part:

* ``local_sink_mask``      — "A-shape": sliding window + attention-sink
                              blocks (StreamingLLM-style).
* ``vertical_slash_mask``  — top-k vertical (column) blocks + top-k slash
                              (diagonal) blocks from profiled scores.
* ``block_topk_mask``      — per-q-block top-k k-blocks by attention mass.
* ``select_patterns``      — per-head: pick the pattern maximizing retained
                              attention mass (recall) at a block budget.

All outputs are host-side boolean masks [H, nqb, nkb] consumed by
``repro.ops.sparse_attention`` (static structure, CSR-encoded for scalar
prefetch).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "local_sink_mask",
    "vertical_slash_mask",
    "block_topk_mask",
    "profile_block_scores",
    "select_patterns",
    "causal_block_mask",
    "mask_density",
]


def causal_block_mask(nqb: int, nkb: int) -> np.ndarray:
    return np.tril(np.ones((nqb, nkb), bool))


def local_sink_mask(
    nqb: int, nkb: int, window_blocks: int, sink_blocks: int = 1
) -> np.ndarray:
    q = np.arange(nqb)[:, None]
    k = np.arange(nkb)[None, :]
    local = (k <= q) & (k > q - window_blocks)
    sink = (k < sink_blocks) & (k <= q)
    return local | sink


def profile_block_scores(
    q: jax.Array, k: jax.Array, block: int, causal: bool = True
) -> np.ndarray:
    """[H, nqb, nkb] mean attention probability per block (offline profile).

    q: [B, H, S, D], k: [B, KVH, S, D] (kv repeated as needed).
    Computed in f32; block-averaged post-softmax, averaged over batch.
    """
    b, h, s, d = q.shape
    kvh = k.shape[1]
    kk = jnp.repeat(k, h // kvh, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32)
    scores = scores / np.sqrt(d)
    if causal:
        tri = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(tri[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    nqb, nkb = s // block, s // block
    pb = probs.reshape(b, h, nqb, block, nkb, block).sum(axis=(3, 5)) / block
    return np.asarray(jax.device_get(pb.mean(axis=0)))


def vertical_slash_mask(
    block_scores: np.ndarray, top_vertical: int, top_slash: int
) -> np.ndarray:
    """Per head: keep top columns (vertical) + top diagonals (slash)."""
    h, nqb, nkb = block_scores.shape
    out = np.zeros((h, nqb, nkb), bool)
    causal = causal_block_mask(nqb, nkb)
    for i in range(h):
        s = block_scores[i]
        col_mass = s.sum(axis=0)
        vcols = np.argsort(col_mass)[::-1][:top_vertical]
        out[i][:, vcols] = True
        diag_mass = np.array(
            [np.trace(s, offset=-o) for o in range(nqb)]
        )  # causal offsets only
        slashes = np.argsort(diag_mass)[::-1][:top_slash]
        for o in slashes:
            idx = np.arange(nqb - o)
            out[i][idx + o, idx] = True
        out[i] &= causal
        np.fill_diagonal(out[i], True)  # always keep the diagonal
    return out


def block_topk_mask(block_scores: np.ndarray, budget_per_row: int) -> np.ndarray:
    """Per (head, q-block): top ``budget_per_row`` k-blocks by mass."""
    h, nqb, nkb = block_scores.shape
    out = np.zeros((h, nqb, nkb), bool)
    causal = causal_block_mask(nqb, nkb)
    for i in range(h):
        s = np.where(causal, block_scores[i], -np.inf)
        for qb in range(nqb):
            kmax = min(budget_per_row, qb + 1)
            keep = np.argsort(s[qb])[::-1][:kmax]
            out[i, qb, keep] = True
        np.fill_diagonal(out[i], True)
    return out


@dataclasses.dataclass
class PatternChoice:
    name: str
    mask: np.ndarray  # [nqb, nkb]
    recall: float
    density: float


def mask_density(mask: np.ndarray) -> float:
    nqb, nkb = mask.shape[-2:]
    causal = causal_block_mask(nqb, nkb)
    return float(np.logical_and(mask, causal).sum() / causal.sum())


def select_patterns(
    block_scores: np.ndarray, budget: float = 0.25
) -> Tuple[np.ndarray, list]:
    """Per head, pick the pattern with the best retained-attention recall at
    roughly the given causal-density budget. Returns ([H,nqb,nkb], choices)."""
    h, nqb, nkb = block_scores.shape
    wb = max(1, int(round(budget * nkb / 2)))
    cands_global = {
        "local_sink": local_sink_mask(nqb, nkb, window_blocks=wb, sink_blocks=1),
    }
    vs = vertical_slash_mask(
        block_scores, top_vertical=max(1, wb), top_slash=max(1, wb)
    )
    tk = block_topk_mask(block_scores, budget_per_row=max(1, int(budget * nkb)))
    out = np.zeros((h, nqb, nkb), bool)
    choices = []
    causal = causal_block_mask(nqb, nkb)
    for i in range(h):
        total = block_scores[i][causal].sum()
        best = None
        for name, m in list(cands_global.items()) + [
            ("vertical_slash", vs[i]),
            ("block_topk", tk[i]),
        ]:
            mm = m & causal
            recall = float(block_scores[i][mm].sum() / max(total, 1e-9))
            c = PatternChoice(name, mm, recall, mask_density(mm))
            # prefer higher recall; break ties toward lower density
            if best is None or (c.recall - 0.02 * c.density) > (
                best.recall - 0.02 * best.density
            ):
                best = c
        out[i] = best.mask
        choices.append(best)
    return out, choices
