"""Block-sparse linear layer — the paper's technique as a first-class module.

``SparseLinear`` is the single-device / serving form: static BCSR structure
(host-side), trainable block values, forward via the Pallas BCSR kernel (or
jnp reference), backward via SDDMM + transposed SpMM (``bcsr_matmul``).

Built on the ``repro.sparse`` layer: construction goes through
``sparsify(w, format="bcsr", ...)`` and a layer converts to/from the
format-agnostic ``SparseTensor`` (``from_sparse`` / ``to_sparse``), so the
structure is extracted once per layer and value swaps (optimizer steps,
dtype casts) never re-derive it.

The SPMD training form used by the model zoo (runtime index arrays so the
layer traces once under shard_map) lives in ``repro.models.ffn``.

Computes ``y = x @ W^T`` for ``W: [out_dim, in_dim]`` block-sparse — i.e.
the paper's FFN orientation ``C = W_sparse @ X^T`` (§IV-D).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ops import BCSRStructure, bcsr_matmul, structure_of
from repro.sparse import BCSR, SparseTensor, sparsify

__all__ = ["SparseLinearSpec", "SparseLinear", "sparse_linear_from_dense"]


@dataclasses.dataclass(frozen=True)
class SparseLinearSpec:
    in_dim: int
    out_dim: int
    sparsity: float
    block: Tuple[int, int] = (128, 128)
    method: str = "magnitude"  # or "random" (the paper's §IV-D setting)
    seed: int = 0


@dataclasses.dataclass
class SparseLinear:
    """values: [nnz, bm, bk] trainable; structure: static host-side."""

    values: jax.Array
    structure: BCSRStructure

    def __call__(self, x: jax.Array, impl=None) -> jax.Array:
        # y^T = W @ x^T;  x: [..., in_dim] -> y: [..., out_dim]
        lead = x.shape[:-1]
        xt = x.reshape(-1, x.shape[-1]).T  # [in, tokens]
        yt = bcsr_matmul(self.values, xt, self.structure, impl)  # [out, tokens]
        return yt.T.reshape(*lead, self.structure.shape[0])

    @property
    def shape(self) -> Tuple[int, int]:
        return self.structure.shape

    @classmethod
    def from_sparse(cls, st: SparseTensor) -> "SparseLinear":
        """Build from a BCSR-format ``SparseTensor`` (structure kept static)."""
        if st.format != "bcsr":
            raise ValueError(
                f"SparseLinear needs a bcsr SparseTensor, got {st.format!r} "
                "(convert first: st.to('bcsr', block=...))")
        return cls(values=st.data[0], structure=structure_of(st.raw))

    def to_sparse(self) -> SparseTensor:
        """The weight as a format-agnostic ``SparseTensor``."""
        return SparseTensor.wrap(self.to_bcsr())

    def to_bcsr(self) -> BCSR:
        from repro.ops.matmul import _as_bcsr

        return _as_bcsr(self.values, self.structure)


def sparse_linear_from_dense(
    w: np.ndarray, spec: SparseLinearSpec, pad_to: int | None = None
) -> SparseLinear:
    st = sparsify(w, format="bcsr", sparsity=spec.sparsity, block=spec.block,
                  method=spec.method, seed=spec.seed, pad_to=pad_to)
    return SparseLinear.from_sparse(st)


def init_sparse_linear(key: jax.Array, spec: SparseLinearSpec) -> SparseLinear:
    """Random init + random block structure (training-from-scratch path)."""
    scale = 1.0 / np.sqrt(spec.in_dim)
    w = scale * np.asarray(
        jax.random.normal(key, (spec.out_dim, spec.in_dim), jnp.float32)
    )
    return sparse_linear_from_dense(
        w, dataclasses.replace(spec, method="random")
    )
