"""Shared layer primitives + the logical-axis sharding context.

Functional convention across the model zoo (no flax):
  * params are nested dicts of jax.Arrays,
  * every ``init_*`` has a twin ``*_axes`` returning the same-structure tree
    of logical-axis tuples (consumed by ``repro.parallel.sharding``),
  * activations are annotated in-line via ``shard_by(x, *logical_axes)``,
    which is a no-op unless a mesh context is installed (so smoke tests and
    kernels run unchanged on one device).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------

_MESH_CTX = contextvars.ContextVar("repro_mesh_ctx", default=None)


@contextlib.contextmanager
def mesh_context(mesh, rules: dict):
    """Install (mesh, logical->mesh rules) for ``shard_by`` annotations."""
    token = _MESH_CTX.set((mesh, dict(rules)))
    # jax.set_mesh is recent; older jax spells the ambient-mesh context as
    # the Mesh object itself (enters the same axis environment).
    set_mesh = getattr(jax, "set_mesh", None)
    try:
        with (set_mesh(mesh) if set_mesh is not None else mesh):
            yield
    finally:
        _MESH_CTX.reset(token)


def current_mesh_rules():
    return _MESH_CTX.get()


def logical_to_pspec(axes: Sequence[Optional[str]], rules: dict):
    from jax.sharding import PartitionSpec as P

    return P(*[rules.get(a) if a is not None else None for a in axes])


def shard_by(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate activation sharding by logical axis names (no-op w/o mesh).
    Axes whose mesh extent does not divide the dim are dropped, and a mesh
    axis is never assigned twice (first dim wins)."""
    ctx = _MESH_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = []
    used = set()
    for dim, a in zip(x.shape, tuple(axes) + (None,) * (len(x.shape) - len(axes))):
        names = rules.get(a) if a is not None else None
        if names is None:
            spec.append(None)
            continue
        nn = names if isinstance(names, tuple) else (names,)
        ext = 1
        for n in nn:
            ext *= mesh.shape[n]
        if dim % ext or any(n in used for n in nn):
            spec.append(None)
            continue
        used.update(nn)
        spec.append(names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32}


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def activation(name: str):
    if name == "swiglu_gate":  # applied to (gate, up) pair by the FFN
        raise ValueError("handled inside ffn")
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "sq_relu": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron-4
    }[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,s,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (scale * jax.random.normal(key, (in_dim, out_dim), jnp.float32)).astype(
        dtype
    )


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
