"""FFN layers: dense (SwiGLU / squared-ReLU / GELU) and **block-sparse**
(the paper's BCSR technique as a first-class, TP-sharded feature).

Sharded-BCSR layout (DESIGN.md §6): the sparse weight is stored per
TP shard with *balanced* nnz (equal stored-block count per shard, enforced at
init), so a single SPMD program handles all shards:

  gate/up  W: [f, d] sharded on f (block rows local, block cols global)
  down     W: [d, f] sharded on f (block cols local, block rows global)
             -> per-shard partial outputs, one psum over the model axis
                (the Megatron row-parallel pattern).

Index arrays are runtime tensors (not static) so the layer traces once under
shard_map/pjit; values are the trainable leaves. The compute is the same
gather + micro-GEMM + segment-sum dataflow as ``kernels/bcsr`` (on TPU the
Pallas kernel replaces the inner dataflow 1:1).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import activation, current_mesh_rules, dense_init, shard_by
from repro.sparse import random_block_mask
# the per-shard runtime-index primitive now lives in the unified ops layer
from repro.ops import local_bcsr_matmul_t  # noqa: F401  (re-exported for moe)


def make_balanced_sparse(
    key, out_dim: int, in_dim: int, shards: int, sparsity: float,
    block, dtype, shard_axis: str, seed: int = 0, extra_lead: int = 1,
):
    """Balanced sharded-BCSR init.

    shard_axis="out": shard block rows; "in": shard block cols.
    Returns dict(values [L, S, nnz, bm, bk], rows [S, nnz], cols [S, nnz])
    with L = extra_lead (1 for plain FFN; num_experts for MoE experts —
    the structure is shared across the lead dim, values differ).
    """
    bm, bk = block
    if shard_axis == "out":
        local_shape = (out_dim // shards, in_dim)
    else:
        local_shape = (out_dim, in_dim // shards)
    mb_l, kb_l = local_shape[0] // bm, local_shape[1] // bk
    nblocks = mb_l * kb_l
    keep = max(1, int(round((1.0 - sparsity) * nblocks)))
    rows = np.zeros((shards, keep), np.int32)
    cols = np.zeros((shards, keep), np.int32)
    for s in range(shards):
        mask = random_block_mask(local_shape, block, 1.0 - keep / nblocks,
                                 seed=seed * 1000 + s)
        r, c = np.nonzero(mask)
        # exact balance: trim/pad deterministically to `keep`
        r, c = r[:keep], c[:keep]
        if len(r) < keep:
            pad = keep - len(r)
            r = np.concatenate([r, np.repeat(r[-1:], pad)])
            c = np.concatenate([c, np.repeat(c[-1:], pad)])
        rows[s], cols[s] = r, c
    scale = 1.0 / np.sqrt(in_dim * (1.0 - sparsity))
    values = scale * jax.random.normal(
        key, (extra_lead, shards, keep, bm, bk), jnp.float32
    )
    return {
        "values": values.astype(dtype),
        "rows": jnp.asarray(rows),
        "cols": jnp.asarray(cols),
    }


def sparse_proj_out_sharded(p, x, mb_local: int):
    """[T, in] -> [S, out_local, T]: gate/up projection (block rows local)."""

    def per_shard(values, rows, cols):
        return local_bcsr_matmul_t(values, rows, cols, x, mb_local)

    return jax.vmap(per_shard)(p["values"][0], p["rows"], p["cols"])


def sparse_proj_in_sharded_partial(p, h_sharded, mb_global: int):
    """h_sharded: [S, in_local, T] -> partial y^T [S, out, T] (sum -> y^T)."""

    def per_shard(values, rows, cols, h_loc):
        return local_bcsr_matmul_t(values, rows, cols, h_loc.T, mb_global)

    return jax.vmap(per_shard)(p["values"][0], p["rows"], p["cols"], h_sharded)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_ffn(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    swiglu = cfg.ffn_activation == "swiglu"
    ks = jax.random.split(key, 3)
    if cfg.ffn_sparsity > 0.0:
        s = cfg.tp_shards
        blk = cfg.sparse_block
        p = {}
        if swiglu:
            p["gate"] = make_balanced_sparse(
                ks[0], f, d, s, cfg.ffn_sparsity, blk, dtype, "out", seed=1)
        p["up"] = make_balanced_sparse(
            ks[1], f, d, s, cfg.ffn_sparsity, blk, dtype, "out", seed=2)
        p["down"] = make_balanced_sparse(
            ks[2], d, f, s, cfg.ffn_sparsity, blk, dtype, "in", seed=3)
        return p
    p = {
        "w_up": dense_init(ks[1], d, f, dtype),
        "w_down": dense_init(ks[2], f, d, dtype),
    }
    if swiglu:
        p["w_gate"] = dense_init(ks[0], d, f, dtype)
    return p


def ffn_axes(cfg):
    if cfg.ffn_sparsity > 0.0:
        ax = {"values": ("expert_lead", "model_shard", None, None, None),
              "rows": ("model_shard", None), "cols": ("model_shard", None)}
        out = {"up": dict(ax), "down": dict(ax)}
        if cfg.ffn_activation == "swiglu":
            out["gate"] = dict(ax)
        return out
    out = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if cfg.ffn_activation == "swiglu":
        out["w_gate"] = ("embed", "mlp")
    return out


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def _act(cfg):
    return activation("silu" if cfg.ffn_activation == "swiglu" else cfg.ffn_activation)


def _dense_ffn(params, x, cfg):
    h = x @ params["w_up"]
    h = shard_by(h, "batch", "seq", "mlp")
    if cfg.ffn_activation == "swiglu":
        g = shard_by(x @ params["w_gate"], "batch", "seq", "mlp")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = _act(cfg)(h.astype(jnp.float32)).astype(h.dtype)
    return shard_by(h @ params["w_down"], "batch", "seq", "embed")


def _sparse_ffn_local(params, x2, cfg):
    """x2: [T, d] -> [T, d]. Runs per model-shard-group (vmap or shard_map)."""
    d, f = cfg.d_model, cfg.d_ff
    s = cfg.tp_shards
    bm, bk = cfg.sparse_block
    f_local = f // s
    h = sparse_proj_out_sharded(params["up"], x2, f_local // bm)  # [S, f_loc, T]
    h = shard_by(h, "model_shard", None, "tokens")
    if cfg.ffn_activation == "swiglu":
        g = sparse_proj_out_sharded(params["gate"], x2, f_local // bm)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = _act(cfg)(h.astype(jnp.float32)).astype(h.dtype)
    # down: block rows global over d; block size of down is (bm, bk) too
    yt_part = sparse_proj_in_sharded_partial(params["down"], h, d // bm)
    yt = jnp.sum(yt_part, axis=0)  # [d, T]; GSPMD: all-reduce over model
    return shard_by(yt, None, "tokens").T.astype(x2.dtype)


def apply_ffn(params, x, cfg):
    """x: [B, S, d] -> [B, S, d]."""
    if cfg.ffn_sparsity <= 0.0:
        return _dense_ffn(params, x, cfg)
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    y2 = _sparse_ffn_local(params, x2, cfg)
    return shard_by(y2.reshape(b, s, d), "batch", "seq", "embed")
