"""Attention-free token mixing: RWKV-6 (Finch) and a Mamba-style selective
SSM head (used by the Hymba hybrid).

RWKV-6 layer = time-mix (data-dependent per-channel decay, matrix-valued
state [H, N, N]) + channel-mix (relu^2 MLP — this is where the paper's BCSR
block sparsity applies for the ssm arch, see DESIGN.md §8).

Recurrences run as ``lax.scan`` over time in f32 state (prefill/train) and as
a single step against a state cache (decode) — states are O(1) in sequence
length, which is what makes ``long_500k`` viable for these archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, shard_by

# ---------------------------------------------------------------------------
# RWKV-6 time mix
# ---------------------------------------------------------------------------


def init_rwkv_tmix(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    n = d // h  # head size
    ks = jax.random.split(key, 7)
    return {
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        "w_decay": dense_init(ks[5], d, d, dtype),  # data-dependent decay proj
        "decay_bias": jnp.full((d,), -4.0, jnp.float32),
        "bonus": (0.5 * jax.random.normal(ks[6], (h, n), jnp.float32)),
        "mix": (0.5 * jnp.ones((5, d), jnp.float32)),  # token-shift lerp coefs
    }


def rwkv_tmix_axes(cfg):
    del cfg
    return {
        "wr": ("embed", "heads"), "wk": ("embed", "heads"),
        "wv": ("embed", "heads"), "wg": ("embed", "heads"),
        "wo": ("heads", "embed"), "w_decay": ("embed", "heads"),
        "decay_bias": (None,), "bonus": (None, None), "mix": (None, None),
    }


def _token_shift(x):
    """x_{t-1} (zeros at t=0): [B, S, d]."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


RECURRENCE_CHUNK = 128


def _chunked_recurrence(step, state, xs, s: int):
    """Two-level scan with a remat'ed inner chunk.

    A flat length-S scan saves every step's state as a backward residual
    (O(S) x state bytes — 100+GB/chip for rwkv at 4k x batch 256). Chunking
    saves only the chunk-boundary states (S/C of them) and recomputes the
    inner steps in backward — the standard memory fix for long recurrences.
    """
    c = RECURRENCE_CHUNK
    if s <= c or s % c:
        return jax.lax.scan(step, state, xs)

    def chunk_body(st, chunk_xs):
        st, outs = jax.lax.scan(step, st, chunk_xs)
        return st, outs

    chunked = jax.tree.map(lambda t: t.reshape(s // c, c, *t.shape[1:]), xs)
    state, outs = jax.lax.scan(
        jax.checkpoint(chunk_body, prevent_cse=False), state, chunked)
    outs = jax.tree.map(lambda t: t.reshape(s, *t.shape[2:]), outs)
    return state, outs


def _rwkv_projections(p, x, prev):
    """Compute r,k,v,g,w for a block of tokens. prev: x_{t-1} per token."""
    mix = p["mix"].astype(x.dtype)
    xr = x + (prev - x) * mix[0]
    xk = x + (prev - x) * mix[1]
    xv = x + (prev - x) * mix[2]
    xg = x + (prev - x) * mix[3]
    xw = x + (prev - x) * mix[4]
    r = xr @ p["wr"]
    k = xk @ p["wk"]
    v = xv @ p["wv"]
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32))
    w = jnp.exp(
        -jnp.exp(
            (xw @ p["w_decay"]).astype(jnp.float32) + p["decay_bias"]
        )
    )  # in (0, 1), data-dependent per channel
    return r, k, v, g, w


def apply_rwkv_tmix(p, x, cfg, state=None, prev_x=None):
    """x: [B, S, d]. Returns (y, (state, last_x)).

    state: [B, H, N, N] f32 matrix-valued wkv state (None -> zeros).
    prev_x: [B, d] last token of the previous segment (decode continuation).
    """
    b, s, d = x.shape
    h = cfg.num_heads
    n = d // h
    prev = _token_shift(x)
    if prev_x is not None:
        prev = prev.at[:, 0].set(prev_x.astype(x.dtype))
    r, k, v, g, w = _rwkv_projections(p, x, prev)
    rh = r.reshape(b, s, h, n).astype(jnp.float32)
    kh = k.reshape(b, s, h, n).astype(jnp.float32)
    vh = v.reshape(b, s, h, n).astype(jnp.float32)
    wh = w.reshape(b, s, h, n)
    u = p["bonus"]  # [H, N]
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)

    def step(st, inp):
        rt, kt, vt, wt = inp  # [B, H, N] each
        kv = kt[..., :, None] * vt[..., None, :]  # [B, H, N, N]
        out = jnp.einsum("bhn,bhnm->bhm", rt, st + u[None, :, :, None] * kv)
        st = st * wt[..., :, None] + kv
        return st, out

    xs = (
        jnp.moveaxis(rh, 1, 0), jnp.moveaxis(kh, 1, 0),
        jnp.moveaxis(vh, 1, 0), jnp.moveaxis(wh, 1, 0),
    )
    state, outs = _chunked_recurrence(step, state, xs, s)  # [S, B, H, N]
    y = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)
    y = (g * y).astype(x.dtype) @ p["wo"]
    return shard_by(y, "batch", "seq", "embed"), (state, x[:, -1])


# ---------------------------------------------------------------------------
# RWKV-6 channel mix (relu^2 MLP with token shift)
# ---------------------------------------------------------------------------


def init_rwkv_cmix(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wr": dense_init(ks[0], d, d, dtype),
        "mix": (0.5 * jnp.ones((2, d), jnp.float32)),
    }
    if cfg.ffn_sparsity > 0.0:
        from repro.models.ffn import make_balanced_sparse

        blk = cfg.sparse_block
        p["k"] = make_balanced_sparse(
            ks[1], f, d, cfg.tp_shards, cfg.ffn_sparsity, blk, dtype, "out", seed=21)
        p["v"] = make_balanced_sparse(
            ks[2], d, f, cfg.tp_shards, cfg.ffn_sparsity, blk, dtype, "in", seed=22)
    else:
        p["wk"] = dense_init(ks[1], d, f, dtype)
        p["wv"] = dense_init(ks[2], f, d, dtype)
    return p


def rwkv_cmix_axes(cfg):
    ax = {"wr": ("embed", "embed"), "mix": (None, None)}
    if cfg.ffn_sparsity > 0.0:
        sax = {"values": ("expert_lead", "model_shard", None, None, None),
               "rows": ("model_shard", None), "cols": ("model_shard", None)}
        ax["k"] = dict(sax)
        ax["v"] = dict(sax)
    else:
        ax["wk"] = ("embed", "mlp")
        ax["wv"] = ("mlp", "embed")
    return ax


def apply_rwkv_cmix(p, x, cfg, prev_x=None):
    b, s, d = x.shape
    prev = _token_shift(x)
    if prev_x is not None:
        prev = prev.at[:, 0].set(prev_x.astype(x.dtype))
    mix = p["mix"].astype(x.dtype)
    xk = x + (prev - x) * mix[0]
    xr = x + (prev - x) * mix[1]
    r = jax.nn.sigmoid((xr @ p["wr"]).astype(jnp.float32))
    if cfg.ffn_sparsity > 0.0:
        from repro.models.ffn import (
            sparse_proj_in_sharded_partial, sparse_proj_out_sharded)

        bm, _ = cfg.sparse_block
        x2 = xk.reshape(b * s, d)
        f_loc = cfg.d_ff // cfg.tp_shards
        kk = sparse_proj_out_sharded(p["k"], x2, f_loc // bm)  # [S, f_loc, T]
        kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
        vt = sparse_proj_in_sharded_partial(p["v"], kk, d // bm)
        y = jnp.sum(vt, axis=0).T.reshape(b, s, d)
    else:
        kk = jnp.square(jax.nn.relu((xk @ p["wk"]).astype(jnp.float32)))
        y = kk.astype(x.dtype) @ p["wv"]
    y = (r * y.astype(jnp.float32)).astype(x.dtype)
    return shard_by(y, "batch", "seq", "embed"), x[:, -1]


# ---------------------------------------------------------------------------
# Mamba-style selective SSM head (Hymba hybrid)
# ---------------------------------------------------------------------------


def init_mamba_head(key, cfg, dtype):
    d = cfg.d_model
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d, d, dtype),
        "w_gate": dense_init(ks[1], d, d, dtype),
        "w_b": dense_init(ks[2], d, n, dtype),
        "w_c": dense_init(ks[3], d, n, dtype),
        "w_dt": dense_init(ks[4], d, d, dtype),
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d, 1))
        ),  # [d, n]
        "w_out": dense_init(ks[5], d, d, dtype),
    }


def mamba_head_axes(cfg):
    del cfg
    return {
        "w_in": ("embed", "heads"), "w_gate": ("embed", "heads"),
        "w_b": ("embed", None), "w_c": ("embed", None),
        "w_dt": ("embed", "heads"), "a_log": ("heads", None),
        "w_out": ("heads", "embed"),
    }


def apply_mamba_head(p, x, cfg, state=None):
    """x: [B, S, d] -> (y, state [B, d, n] f32)."""
    b, s, d = x.shape
    n = cfg.ssm_state
    u = (x @ p["w_in"]).astype(jnp.float32)  # [B, S, d]
    gate = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32))
    bsel = (x @ p["w_b"]).astype(jnp.float32)  # [B, S, n]
    csel = (x @ p["w_c"]).astype(jnp.float32)  # [B, S, n]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32))  # [B, S, d]
    a = -jnp.exp(p["a_log"])  # [d, n]
    if state is None:
        state = jnp.zeros((b, d, n), jnp.float32)

    def step(st, inp):
        ut, bt, ct, dtt = inp  # [B,d], [B,n], [B,n], [B,d]
        da = jnp.exp(dtt[..., None] * a[None])  # [B, d, n]
        st = st * da + (dtt * ut)[..., None] * bt[:, None, :]
        yt = jnp.einsum("bdn,bn->bd", st, ct)
        return st, yt

    xs = (
        jnp.moveaxis(u, 1, 0), jnp.moveaxis(bsel, 1, 0),
        jnp.moveaxis(csel, 1, 0), jnp.moveaxis(dt, 1, 0),
    )
    state, ys = _chunked_recurrence(step, state, xs, s)  # [S, B, d]
    y = jnp.moveaxis(ys, 0, 1) * gate
    return (y.astype(x.dtype) @ p["w_out"]), state
