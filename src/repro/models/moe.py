"""Mixture-of-Experts layer with two partition strategies (DESIGN.md §6):

* ``expert_partition="expert"`` (EP; Kimi-K2: 384 experts / 16 shards = 24
  local experts): experts sharded over the model axis. Routing is computed
  replicated on every model shard (router weights are replicated, activations
  are model-replicated in the DP x TP layout), each shard dispatches only the
  tokens routed to *its* experts, and a single psum over the model axis
  combines expert outputs. No all-to-all needed in this layout; the psum is
  the same collective as the dense-FFN TP all-reduce.

* ``expert_partition="ffn"`` (Mixtral: 8 experts < 16 shards): every shard
  holds all experts but only an f-slice of each expert's FFN (TP inside the
  expert); the down-projection partial sums ride the same final psum.

Dispatch is capacity-based (Switch-style cumsum ranking, deterministic,
overflow drops) entirely in local shard code under ``shard_map``; without a
mesh context the same code runs with the full arrays (smoke tests).

Experts may themselves be **block-sparse** (the paper's technique applied to
expert FFNs): values [E, S, nnz, bm, bk] in the sharded-BCSR layout of
``models/ffn`` (block masks come from ``repro.sparse``'s pruning helpers;
the structure is shared across the expert dim, values differ per expert —
the same structure/values separation ``repro.sparse.SparseTensor`` uses).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.models.common import current_mesh_rules, dense_init, shard_by
from repro.models.ffn import make_balanced_sparse
from repro.ops import local_bcsr_matmul_t

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], d, e, jnp.float32)}
    sparse = cfg.ffn_sparsity > 0.0
    shards = cfg.tp_shards if cfg.expert_partition == "ffn" else 1
    if sparse:
        blk = cfg.sparse_block
        if cfg.ffn_activation == "swiglu":
            p["gate"] = make_balanced_sparse(
                ks[1], f, d, shards, cfg.ffn_sparsity, blk, dtype, "out",
                seed=11, extra_lead=e)
        p["up"] = make_balanced_sparse(
            ks[2], f, d, shards, cfg.ffn_sparsity, blk, dtype, "out",
            seed=12, extra_lead=e)
        p["down"] = make_balanced_sparse(
            ks[3], d, f, shards, cfg.ffn_sparsity, blk, dtype, "in",
            seed=13, extra_lead=e)
    else:
        scale = 1.0 / np.sqrt(d)
        if cfg.ffn_activation == "swiglu":
            p["w_gate"] = (scale * jax.random.normal(ks[1], (e, d, f))).astype(dtype)
        p["w_up"] = (scale * jax.random.normal(ks[2], (e, d, f))).astype(dtype)
        p["w_down"] = (
            (1.0 / np.sqrt(f)) * jax.random.normal(ks[3], (e, f, d))
        ).astype(dtype)
    return p


def moe_axes(cfg):
    ep = cfg.expert_partition == "expert"
    sparse = cfg.ffn_sparsity > 0.0
    ax = {"router": (None, None)}
    if sparse:
        # EP: experts over model, block values additionally FSDP-shardable
        vax = ("expert", None, "fsdp", None, None) if ep else (
            None, "model_shard", "fsdp", None, None)
        iax = (None, None) if ep else ("model_shard", None)
        for k in (["gate", "up", "down"] if cfg.ffn_activation == "swiglu"
                  else ["up", "down"]):
            ax[k] = {"values": vax, "rows": iax, "cols": iax}
    elif cfg.expert_partition == "expert_data":
        # serving layout (§Perf, kimi decode_32k): experts over *data*, FFN
        # inner dim over *model* — weights fully sharded with zero gathers;
        # tokens (small at decode) are all-gathered instead.
        ax["w_up"] = ("expert_d", None, "mlp")
        ax["w_down"] = ("expert_d", "mlp", None)
        if cfg.ffn_activation == "swiglu":
            ax["w_gate"] = ("expert_d", None, "mlp")
        return ax
    else:
        if ep:
            # d_model dim FSDP-shards over data ("embed" -> data under fsdp)
            w = ("expert", "embed", None)
            wd = ("expert", None, "embed")
        else:
            w = (None, "embed", "mlp")
            wd = (None, "mlp", "embed")
        if cfg.ffn_activation == "swiglu":
            ax["w_gate"] = w
        ax["w_up"] = w
        ax["w_down"] = wd
    return ax


# ---------------------------------------------------------------------------
# Local shard computation
# ---------------------------------------------------------------------------


def _expert_ffn_dense(p, xe, cfg):
    """xe: [E_loc, C, d] -> [E_loc, C, d] partial (f may be sharded)."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_up"],
                   preferred_element_type=jnp.float32).astype(xe.dtype)
    if cfg.ffn_activation == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * h.astype(jnp.float32)).astype(xe.dtype)
    else:
        from repro.models.common import activation

        h = activation(cfg.ffn_activation)(h.astype(jnp.float32)).astype(xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                      preferred_element_type=jnp.float32).astype(xe.dtype)


def _expert_ffn_sparse(p, xe, cfg):
    """Sparse experts: vmap the sharded-BCSR dataflow over (E_loc, S_loc)."""
    bm, _ = cfg.sparse_block
    e_loc, c, d = xe.shape
    s_loc = p["up"]["values"].shape[1]
    f_loc = cfg.d_ff // cfg.tp_shards if cfg.expert_partition == "ffn" else cfg.d_ff
    mb_up = f_loc // bm
    mb_down = cfg.d_model // bm

    def one(e_vals_up, e_vals_gate, e_vals_down, rows_up, cols_up,
            rows_gate, cols_gate, rows_down, cols_down, x_e):
        # vmap over the S_loc dim, summing down-proj partials
        def per_shard(vu, vg, vd, ru, cu, rg, cg, rd, cd):
            h = local_bcsr_matmul_t(vu, ru, cu, x_e, mb_up)  # [f_loc, C]
            if vg is not None:
                g = local_bcsr_matmul_t(vg, rg, cg, x_e, mb_up)
                h = (jax.nn.silu(g.astype(jnp.float32))
                     * h.astype(jnp.float32)).astype(x_e.dtype)
            else:
                from repro.models.common import activation

                h = activation(cfg.ffn_activation)(
                    h.astype(jnp.float32)).astype(x_e.dtype)
            return local_bcsr_matmul_t(vd, rd, cd, h.T, mb_down)  # [d, C]

        if e_vals_gate is None:
            yt = jax.vmap(
                lambda vu, vd, ru, cu, rd, cd: per_shard(
                    vu, None, vd, ru, cu, None, None, rd, cd)
            )(e_vals_up, e_vals_down, rows_up, cols_up, rows_down, cols_down)
        else:
            yt = jax.vmap(per_shard)(
                e_vals_up, e_vals_gate, e_vals_down, rows_up, cols_up,
                rows_gate, cols_gate, rows_down, cols_down)
        return jnp.sum(yt, axis=0).T.astype(x_e.dtype)  # [C, d]

    has_gate = "gate" in p
    gate_vals = p["gate"]["values"] if has_gate else None
    out = jax.vmap(
        lambda vu, vg, vd, xe_: one(
            vu, vg, vd, p["up"]["rows"], p["up"]["cols"],
            p["gate"]["rows"] if has_gate else None,
            p["gate"]["cols"] if has_gate else None,
            p["down"]["rows"], p["down"]["cols"], xe_),
        in_axes=(0, 0 if has_gate else None, 0, 0),
    )(p["up"]["values"], gate_vals, p["down"]["values"], xe)
    return out


def _moe_shard(router_w, expert_p, x_loc, *, cfg, model_axis: Optional[str],
               data_axis=None):
    """Per-(data, model)-shard MoE. x_loc: [b_loc, s, d].

    expert_partition="expert_data" (serving): experts live on *data* shards,
    each expert's FFN is TP-sliced over *model*. Tokens are all-gathered over
    data (tiny at decode), every (data, model) shard computes its experts'
    f-slice contribution for all tokens, and one psum over both axes
    combines. Weight movement per step: zero.
    """
    b, s, d = x_loc.shape
    e_total, k = cfg.num_experts, cfg.top_k
    ed = cfg.expert_partition == "expert_data"
    da = None
    if data_axis is not None:
        da = data_axis if isinstance(data_axis, tuple) else (data_axis,)
    if ed and da is not None:
        x_loc = jax.lax.all_gather(x_loc, da, axis=0, tiled=True)
        b = x_loc.shape[0]
    t = b * s
    x2 = x_loc.reshape(t, d)
    ep = cfg.expert_partition == "expert"
    if ep:
        if model_axis is not None:
            n_shards = axis_size(model_axis)
            midx = jax.lax.axis_index(model_axis)
        else:
            n_shards, midx = 1, 0
        e_loc = e_total // n_shards
    elif ed:
        if da is not None:
            n_shards = 1
            for a in da:
                n_shards *= axis_size(a)
            midx = jax.lax.axis_index(da)
        else:
            n_shards, midx = 1, 0
        e_loc = e_total // n_shards
    else:
        e_loc, midx = e_total, 0

    logits = (x2 @ router_w.astype(x2.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    gate, idx = jax.lax.top_k(probs, k)  # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(cfg.capacity_factor * t * k / e_total))
    cap = max(1, min(cap, t * k))

    e_off = midx * e_loc
    sel = idx - e_off  # [T, K] local expert id or out of range
    flat_sel = sel.reshape(t * k)
    local = jnp.logical_and(flat_sel >= 0, flat_sel < e_loc)
    onehot = jnp.logical_and(
        flat_sel[:, None] == jnp.arange(e_loc)[None, :], local[:, None]
    )  # [T*K, E_loc]
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    slot_pos = jnp.sum(jnp.where(onehot, pos, 0), axis=1)  # [T*K]
    kept = jnp.logical_and(local, slot_pos < cap)
    buf_idx = jnp.where(kept, jnp.clip(flat_sel, 0, e_loc - 1) * cap + slot_pos,
                        e_loc * cap)  # OOB -> dropped by scatter
    bi = buf_idx.reshape(t, k)
    buf = jnp.zeros((e_loc * cap, d), x2.dtype)
    for kk in range(k):  # per-choice scatter: avoids the [T*K, d] repeat
        buf = buf.at[bi[:, kk]].add(x2)
    xe = buf.reshape(e_loc, cap, d)

    if cfg.ffn_sparsity > 0.0:
        ye = _expert_ffn_sparse(expert_p, xe, cfg)
    else:
        ye = _expert_ffn_dense(expert_p, xe, cfg)

    ye2 = ye.reshape(e_loc * cap, d)
    kept2 = kept.reshape(t, k)
    y2 = jnp.zeros((t, d), ye2.dtype)
    for kk in range(k):  # per-choice gather + weighted combine
        rows = ye2[jnp.clip(bi[:, kk], 0, e_loc * cap - 1)]
        w_k = jnp.where(kept2[:, kk], gate[:, kk], 0.0).astype(rows.dtype)
        y2 = y2 + rows * w_k[:, None]
    if ed and da is not None:
        # partial over experts (data axes) and over f slices (model axis)
        axes = da + ((model_axis,) if model_axis is not None else ())
        y2 = jax.lax.psum(y2, axes)
        # back to this shard's tokens
        n_d = 1
        for a in da:
            n_d *= axis_size(a)
        b_loc = b // n_d
        y2 = jax.lax.dynamic_slice_in_dim(
            y2.reshape(b, s, d), midx * b_loc, b_loc, axis=0)
        return y2
    if model_axis is not None:
        y2 = jax.lax.psum(y2, model_axis)
    return y2.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Public apply
# ---------------------------------------------------------------------------


def _param_specs(cfg, rules):
    """PartitionSpecs for the expert param tree (shard_map in_specs).

    FSDP dims ("embed"/"fsdp" -> data) are deliberately mapped to None here:
    weights are *stored* data-sharded but must be whole inside the MoE shard
    body, so GSPMD all-gathers them at the shard_map boundary — exactly the
    ZeRO-3 gather-for-compute pattern (the reverse reduce-scatter happens on
    the gradients automatically)."""
    from repro.models.common import logical_to_pspec

    rules = dict(rules)
    rules["embed"] = None
    rules["fsdp"] = None
    rules.setdefault("expert_d", "data")
    ax = moe_axes(cfg)
    specs = {}
    for name, a in ax.items():
        if name == "router":
            continue
        if isinstance(a, dict):
            specs[name] = {kk: logical_to_pspec(vv, rules) for kk, vv in a.items()}
        else:
            specs[name] = logical_to_pspec(a, rules)
    return specs


def apply_moe(params, x, cfg):
    """x: [B, S, d] -> (y [B, S, d], aux load-balance loss scalar)."""
    router_w = params["router"]
    expert_p = {k: v for k, v in params.items() if k != "router"}

    # load-balance aux loss (Switch): computed on the pjit side, global.
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    logits = (x2 @ router_w.astype(x2.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.num_experts, dtype=jnp.float32), 0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.num_experts * jnp.sum(frac * mean_prob)

    ctx = current_mesh_rules()
    if ctx is None:
        y = _moe_shard(router_w, expert_p, x, cfg=cfg, model_axis=None,
                       data_axis=None)
        return y, aux
    mesh, rules = ctx
    model_axis = rules.get("mlp")
    batch_axes = rules.get("batch")
    nn = batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)
    ext = 1
    for n in nn:
        ext *= mesh.shape[n]
    if x.shape[0] % ext:  # tiny batches (e.g. long_500k B=1): replicate
        batch_axes = None
    data_axis = None
    if cfg.expert_partition == "expert_data":
        # experts over the data axes (serving layout)
        data_axis = batch_axes if batch_axes is not None else rules["batch"]
    xspec = P(batch_axes, None, None)
    in_specs = (P(None, None), _param_specs(cfg, rules), xspec)
    fn = functools.partial(_moe_shard, cfg=cfg, model_axis=model_axis,
                           data_axis=data_axis)
    y = shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=xspec, check_vma=False
    )(router_w, expert_p, x)
    return shard_by(y, "batch", "seq", "embed"), aux
