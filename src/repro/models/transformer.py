"""Decoder-only transformer assembly for every family except enc-dec
(whisper lives in ``models/encdec.py``).

Families:
  dense / moe       : [attn + (ffn | moe)] x L
  vlm (llama-3.2-v) : groups of ``cross_attn_every - 1`` self layers followed
                      by one gated cross-attention layer over vision tokens
  hybrid (hymba)    : parallel attention + mamba SSM head, then FFN
  ssm (rwkv6)       : rwkv time-mix + channel-mix (attention-free)

Layers are scanned (``lax.scan`` over stacked params) with optional
per-layer remat — both are what keep the 61L/1T dry-run compile tractable.
Decode threads per-layer caches (KV rings / SSM states) through the scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import DTYPES, embed_init, rms_norm, shard_by, split_keys


# ---------------------------------------------------------------------------
# Per-layer init / axes
# ---------------------------------------------------------------------------


def _init_self_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.family == "ssm":
        p["tmix"] = ssm_mod.init_rwkv_tmix(ks[0], cfg, dtype)
        p["cmix"] = ssm_mod.init_rwkv_cmix(ks[1], cfg, dtype)
        return p
    p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cfg.family == "hybrid":
        kss = split_keys(ks[1], 2)
        p["mamba"] = ssm_mod.init_mamba_head(kss[0], cfg, dtype)
        p["ffn"] = ffn_mod.init_ffn(kss[1], cfg, dtype)
    elif cfg.is_moe:
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = ffn_mod.init_ffn(ks[1], cfg, dtype)
    return p


def _self_layer_axes(cfg):
    ax = {"ln1": (None,), "ln2": (None,)}
    if cfg.family == "ssm":
        ax["tmix"] = ssm_mod.rwkv_tmix_axes(cfg)
        ax["cmix"] = ssm_mod.rwkv_cmix_axes(cfg)
        return ax
    ax["attn"] = attn.attention_axes(cfg)
    if cfg.family == "hybrid":
        ax["mamba"] = ssm_mod.mamba_head_axes(cfg)
        ax["ffn"] = ffn_mod.ffn_axes(cfg)
    elif cfg.is_moe:
        ax["moe"] = moe_mod.moe_axes(cfg)
    else:
        ax["ffn"] = ffn_mod.ffn_axes(cfg)
    return ax


def _init_cross_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "xattn": attn.init_cross_attention(ks[0], cfg, dtype),
        "ffn": ffn_mod.init_ffn(ks[1], cfg, dtype),
        "gate": jnp.zeros((), jnp.float32),  # llama-3.2-v gated cross-attn
    }


def _cross_layer_axes(cfg):
    return {
        "ln1": (None,), "ln2": (None,),
        "xattn": attn.attention_axes(cfg),
        "ffn": ffn_mod.ffn_axes(cfg),
        "gate": (),
    }


# ---------------------------------------------------------------------------
# Per-layer apply (train/prefill)
# ---------------------------------------------------------------------------


def _apply_self_layer(p, x, cfg, block_mask=None):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        h, _ = ssm_mod.apply_rwkv_tmix(p["tmix"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        h, _ = ssm_mod.apply_rwkv_cmix(p["cmix"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x + h, aux
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    a = attn.apply_attention(p["attn"], xn, cfg, block_mask=block_mask)
    if cfg.family == "hybrid":
        m, _ = ssm_mod.apply_mamba_head(p["mamba"], xn, cfg)
        a = 0.5 * (a + m)
    x = x + a
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        h, aux = moe_mod.apply_moe(p["moe"], xn, cfg)
    else:
        h = ffn_mod.apply_ffn(p["ffn"], xn, cfg)
    return x + h, aux


def _apply_cross_layer(p, x, enc, cfg):
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    g = jnp.tanh(p["gate"]).astype(x.dtype)
    x = x + g * attn.apply_cross_attention(p["xattn"], xn, enc, cfg)
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + ffn_mod.apply_ffn(p["ffn"], xn, cfg)


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init_model(key, cfg):
    dtype = DTYPES[cfg.dtype]
    ks = split_keys(key, 5)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype)
    if cfg.cross_attn_every:
        n_cross = cfg.num_layers // cfg.cross_attn_every
        n_self = cfg.num_layers - n_cross
        per_group = n_self // n_cross
        self_keys = jnp.stack(split_keys(ks[2], n_cross * per_group)).reshape(
            n_cross, per_group, 2
        )
        p["self_layers"] = jax.vmap(
            jax.vmap(lambda k: _init_self_layer(k, cfg, dtype))
        )(self_keys)
        p["cross_layers"] = jax.vmap(
            lambda k: _init_cross_layer(k, cfg, dtype)
        )(jnp.stack(split_keys(ks[3], n_cross)))
    else:
        p["layers"] = jax.vmap(lambda k: _init_self_layer(k, cfg, dtype))(
            jnp.stack(split_keys(ks[2], cfg.num_layers))
        )
    return p


def _stack_axes(ax):
    """Prepend the scan (layers) dim to every axes tuple in a tree."""
    return jax.tree.map(
        lambda a: ("layers",) + tuple(a),
        ax,
        is_leaf=lambda a: isinstance(a, tuple),
    )


def model_axes(cfg):
    ax: Dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "ln_f": (None,),
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = ("vocab", "embed")
    if cfg.cross_attn_every:
        ax["self_layers"] = jax.tree.map(
            lambda a: ("layers", "layers") + tuple(a),
            _self_layer_axes(cfg),
            is_leaf=lambda a: isinstance(a, tuple),
        )
        ax["cross_layers"] = _stack_axes(_cross_layer_axes(cfg))
    else:
        ax["layers"] = _stack_axes(_self_layer_axes(cfg))
    return ax


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params, batch, cfg, block_mask=None, return_hidden=False):
    """batch: {"tokens": [B, S] i32, optional "vision_embeds": [B, V, d]}.
    Returns (logits [B, S, Vp], aux) — or (hidden [B, S, d], aux) with
    ``return_hidden`` (the chunked loss computes logits itself)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]  # gather; GSPMD shards vocab dim
    x = shard_by(x, "batch", "seq", "embed")

    def self_block(carry, layer_p):
        x, aux = carry
        x, a = _apply_self_layer(layer_p, x, cfg, block_mask=block_mask)
        # Megatron-SP-style boundary: saved (remat) activations shard their
        # sequence dim over the model axis between layers
        x = shard_by(x, "batch", "seq_sp", "embed")
        return (x, aux + a), None

    block = self_block
    if cfg.remat:
        block = jax.checkpoint(self_block, prevent_cse=False)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.cross_attn_every:
        enc = batch["vision_embeds"].astype(x.dtype)

        def group_block(carry, group_p):
            x, aux = carry
            if cfg.scan_layers:
                (x, aux), _ = jax.lax.scan(block, (x, aux), group_p["self"])
            else:
                for i in range(jax.tree.leaves(group_p["self"])[0].shape[0]):
                    (x, aux), _ = block((x, aux), jax.tree.map(lambda t: t[i], group_p["self"]))
            x = _apply_cross_layer(group_p["cross"], x, enc, cfg)
            return (x, aux), None

        gblock = jax.checkpoint(group_block, prevent_cse=False) if cfg.remat else group_block
        groups = {"self": params["self_layers"], "cross": params["cross_layers"]}
        if cfg.scan_layers:
            (x, aux0), _ = jax.lax.scan(gblock, (x, aux0), groups)
        else:
            n = jax.tree.leaves(params["cross_layers"])[0].shape[0]
            for i in range(n):
                (x, aux0), _ = gblock((x, aux0), jax.tree.map(lambda t: t[i], groups))
    else:
        if cfg.scan_layers:
            (x, aux0), _ = jax.lax.scan(block, (x, aux0), params["layers"])
        else:
            for i in range(cfg.num_layers):
                (x, aux0), _ = block(
                    (x, aux0), jax.tree.map(lambda t: t[i], params["layers"])
                )

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, aux0
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head, preferred_element_type=jnp.float32)
    logits = shard_by(logits, "batch", "seq", "vocab")
    return logits, aux0


def lm_head_weights(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# Decode (single token against caches)
# ---------------------------------------------------------------------------


class DecodeCache(NamedTuple):
    kv: Optional[attn.KVCache]  # stacked [L, ...] (None for ssm family)
    ssm: Optional[jax.Array]  # hybrid: [L, B, d, n] | ssm: [L, B, H, N, N]
    prev1: Optional[jax.Array]  # rwkv tmix token-shift state [L, B, d]
    prev2: Optional[jax.Array]  # rwkv cmix token-shift state [L, B, d]
    xkv: Optional[Any]  # vlm/encdec precomputed cross K/V (or enc states)


def init_decode_cache(cfg, batch: int, max_len: int, vision_embeds=None):
    dtype = DTYPES[cfg.dtype]
    kv_heads, hd, d = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    cache_len = min(max_len, cfg.sliding_window or max_len)
    if cfg.cross_attn_every:
        n_cross = cfg.num_layers // cfg.cross_attn_every
        per_group = (cfg.num_layers - n_cross) // n_cross
        kv = jax.vmap(
            jax.vmap(
                lambda _: attn.init_kv_cache(batch, cache_len, kv_heads, hd, dtype)
            )
        )(jnp.zeros((n_cross, per_group)))
        return DecodeCache(kv=kv, ssm=None, prev1=None, prev2=None,
                           xkv=vision_embeds)
    L = cfg.num_layers
    mk_kv = lambda n: jax.vmap(
        lambda _: attn.init_kv_cache(batch, cache_len, kv_heads, hd, dtype)
    )(jnp.arange(n))
    if cfg.family == "ssm":
        h = cfg.num_heads
        n = d // h
        return DecodeCache(
            kv=None,
            ssm=jnp.zeros((L, batch, h, n, n), jnp.float32),
            prev1=jnp.zeros((L, batch, d), dtype),
            prev2=jnp.zeros((L, batch, d), dtype),
            xkv=None,
        )
    if cfg.family == "hybrid":
        return DecodeCache(
            kv=mk_kv(L),
            ssm=jnp.zeros((L, batch, d, cfg.ssm_state), jnp.float32),
            prev1=None, prev2=None, xkv=None,
        )
    return DecodeCache(kv=mk_kv(L), ssm=None, prev1=None, prev2=None, xkv=None)


def _decode_self_layer(p, x, cfg, kv, ssm, prev1, prev2, pos):
    """x: [B, 1, d]. Returns (x, (kv, ssm, prev1, prev2))."""
    if cfg.family == "ssm":
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        h, (ssm, p1) = ssm_mod.apply_rwkv_tmix(p["tmix"], xn, cfg, state=ssm,
                                               prev_x=prev1)
        x = x + h
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        h, p2 = ssm_mod.apply_rwkv_cmix(p["cmix"], xn, cfg, prev_x=prev2)
        return x + h, (kv, ssm, p1, p2)
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, kv = attn.apply_attention_decode(p["attn"], xn, cfg, kv, pos)
    if cfg.family == "hybrid":
        m, ssm = ssm_mod.apply_mamba_head(p["mamba"], xn, cfg, state=ssm)
        a = 0.5 * (a + m)
    x = x + a
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        h, _ = moe_mod.apply_moe(p["moe"], xn, cfg)
    else:
        h = ffn_mod.apply_ffn(p["ffn"], xn, cfg)
    return x + h, (kv, ssm, prev1, prev2)


def decode_step(params, cache: DecodeCache, token: jax.Array, pos: jax.Array, cfg):
    """token: [B] i32; pos: [B] absolute positions. Returns (logits, cache)."""
    x = params["embed"][token][:, None, :]  # [B, 1, d]

    if cfg.cross_attn_every:
        enc = cache.xkv

        def inner(x, inp):
            lp, kv = inp
            x, (kv, _, _, _) = _decode_self_layer(lp, x, cfg, kv, None, None,
                                                  None, pos)
            return x, kv

        def group(x, inp):
            gp, kv_g = inp  # gp: group params; kv_g: [per_group, ...] caches
            if cfg.scan_layers:
                x, kv_g = jax.lax.scan(inner, x, (gp["self"], kv_g))
            else:
                outs = []
                n_inner = jax.tree.leaves(gp["self"])[0].shape[0]
                for i in range(n_inner):
                    x, kv_i = inner(
                        x, jax.tree.map(lambda t: t[i], (gp["self"], kv_g)))
                    outs.append(kv_i)
                kv_g = jax.tree.map(lambda *z: jnp.stack(z), *outs)
            x = _apply_cross_layer(gp["cross"], x, enc, cfg)
            return x, kv_g

        groups = {"self": params["self_layers"], "cross": params["cross_layers"]}
        if cfg.scan_layers:
            x, kv = jax.lax.scan(group, x, (groups, cache.kv))
        else:  # cost probes
            n_cross = cfg.num_layers // cfg.cross_attn_every
            kvs = []
            for gi in range(n_cross):
                x, kv_g = group(
                    x, jax.tree.map(lambda t: t[gi], (groups, cache.kv)))
                # inner scan also unrolled for the probes
                kvs.append(kv_g)
            kv = jax.tree.map(lambda *z: jnp.stack(z), *kvs)
        cache = cache._replace(kv=kv)
    else:

        def body(x, inp):
            lp, kv, ssm, p1, p2 = inp
            x, st = _decode_self_layer(lp, x, cfg, kv, ssm, p1, p2, pos)
            return x, st

        L = cfg.num_layers
        xs = (
            params["layers"],
            cache.kv if cache.kv is not None else jnp.zeros((L,)),
            cache.ssm if cache.ssm is not None else jnp.zeros((L,)),
            cache.prev1 if cache.prev1 is not None else jnp.zeros((L,)),
            cache.prev2 if cache.prev2 is not None else jnp.zeros((L,)),
        )
        if cfg.scan_layers:
            x, (kv, ssm, p1, p2) = jax.lax.scan(body, x, xs)
        else:  # cost probes: per-layer ops visible to cost_analysis
            ys = []
            for i in range(L):
                x, st = body(x, jax.tree.map(lambda t: t[i], xs))
                ys.append(st)
            kv, ssm, p1, p2 = jax.tree.map(lambda *z: jnp.stack(z), *ys)
        cache = cache._replace(
            kv=kv if cache.kv is not None else None,
            ssm=ssm if cache.ssm is not None else None,
            prev1=p1 if cache.prev1 is not None else None,
            prev2=p2 if cache.prev2 is not None else None,
        )

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head, preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:  # mask vocab-padding columns
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Paged decode + chunked prefill (serving runtime; dense/moe families only —
# SSM/hybrid state and cross-attention have no paged analogue here, those
# families stay on the ring-cache engine path)
# ---------------------------------------------------------------------------


def _mask_vocab_pad(logits, cfg):
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def decode_step_paged(params, kstore, vstore, pos_tab, token, pos, pages,
                      valid, cfg):
    """One pooled decode tick against the paged KV pool.

    kstore/vstore: [L, P+1, ps, KVH, D]; pos_tab: [P+1, ps] i32 (-1 = empty,
    shared across layers); token/pos: [B]; pages: [B, W] page-table rows
    (rows of non-decoding slots must be all-null-page); valid: [B] bool.
    Returns (logits [B, Vp], kstore, vstore, pos_tab). Invalid rows write
    only into the null page and their pos_tab stamp is forced to -1, so
    they perturb nothing another sequence can attend to.
    """
    ps = kstore.shape[2]
    page_idx = jnp.clip((pos // ps).astype(jnp.int32), 0, pages.shape[1] - 1)
    phys = jnp.take_along_axis(pages, page_idx[:, None], axis=1)[:, 0]
    within = (pos % ps).astype(jnp.int32)
    pos_tab = pos_tab.at[phys, within].set(
        jnp.where(valid, pos.astype(jnp.int32), -1))

    x = params["embed"][token][:, None, :]  # [B, 1, d]

    def body(x, inp):
        lp, kl, vl = inp
        xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, kl, vl = attn.apply_attention_decode_paged(
            lp["attn"], xn, cfg, kl, vl, pos_tab, pages, pos)
        x = x + a
        xn = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            h, _ = moe_mod.apply_moe(lp["moe"], xn, cfg)
        else:
            h = ffn_mod.apply_ffn(lp["ffn"], xn, cfg)
        return x + h, (kl, vl)

    x, (kstore, vstore) = jax.lax.scan(body, x, (params["layers"], kstore,
                                                 vstore))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return _mask_vocab_pad(logits, cfg)[:, 0], kstore, vstore, pos_tab


def prefill_chunk(params, kstore, vstore, pos_tab, pages_row, tokens,
                  positions, scatter_page, within, pos_vals, mask_csr, cfg, *,
                  block_q, block_k, with_logits=False, attn_impl=None):
    """Run one whole prompt chunk through every layer in a single call.

    tokens: [1, C]; positions/scatter_page/within/pos_vals: [C] (padding
    rows carry the null page and pos_vals = -1); pages_row: [W]; mask_csr:
    ``(ptr, kcols)`` causal-band block CSR for this chunk. Each layer's
    attention is the block-sparse ``sparse_attention`` pipeline over the
    gathered paged prefix — the §IV-D prefill path — so a C-token chunk
    costs one forward instead of C decode ticks. Returns
    (logits [C, Vp] | None, kstore, vstore, pos_tab); logits are only
    materialized on the final chunk (``with_logits``), where the last valid
    row seeds decoding.
    """
    pos_tab = pos_tab.at[scatter_page, within].set(
        jnp.asarray(pos_vals, jnp.int32))
    x = params["embed"][tokens]  # [1, C, d]

    def body(x, inp):
        lp, kl, vl = inp
        xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, kl, vl = attn.apply_attention_prefill_chunk(
            lp["attn"], xn, cfg, kl, vl, pos_tab, pages_row, positions,
            scatter_page, within, mask_csr, block_q=block_q, block_k=block_k,
            attn_impl=attn_impl)
        x = x + a
        xn = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            h, _ = moe_mod.apply_moe(lp["moe"], xn, cfg)
        else:
            h = ffn_mod.apply_ffn(lp["ffn"], xn, cfg)
        return x + h, (kl, vl)

    x, (kstore, vstore) = jax.lax.scan(body, x, (params["layers"], kstore,
                                                 vstore))
    if not with_logits:
        return None, kstore, vstore, pos_tab
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return _mask_vocab_pad(logits, cfg)[0], kstore, vstore, pos_tab
