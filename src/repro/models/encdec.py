"""Encoder-decoder backbone (Whisper large-v3).

The conv/mel frontend is a STUB: the encoder consumes precomputed frame
embeddings [B, S_enc, d] supplied by ``input_specs`` (per the assignment
note). Encoder = non-causal self-attention stack; decoder = causal
self-attention (KV-cached) + cross-attention to encoder states + GELU FFN.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.common import DTYPES, embed_init, rms_norm, shard_by, split_keys
from repro.models.transformer import DecodeCache


def _init_enc_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(ks[0], cfg, dtype),
        "ffn": ffn_mod.init_ffn(ks[1], cfg, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ks = split_keys(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "lnx": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(ks[0], cfg, dtype),
        "xattn": attn.init_cross_attention(ks[1], cfg, dtype),
        "ffn": ffn_mod.init_ffn(ks[2], cfg, dtype),
    }


def init_model(key, cfg):
    dtype = DTYPES[cfg.dtype]
    ks = split_keys(key, 4)
    return {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "lm_head": embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "ln_enc": jnp.ones((cfg.d_model,), jnp.float32),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jnp.stack(split_keys(ks[2], cfg.encoder_layers))
        ),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            jnp.stack(split_keys(ks[3], cfg.num_layers))
        ),
    }


def model_axes(cfg):
    lax_ = lambda ax: jax.tree.map(
        lambda a: ("layers",) + tuple(a), ax, is_leaf=lambda a: isinstance(a, tuple)
    )
    enc_ax = {
        "ln1": (None,), "ln2": (None,),
        "attn": attn.attention_axes(cfg), "ffn": ffn_mod.ffn_axes(cfg),
    }
    dec_ax = {
        "ln1": (None,), "lnx": (None,), "ln2": (None,),
        "attn": attn.attention_axes(cfg),
        "xattn": attn.attention_axes(cfg),
        "ffn": ffn_mod.ffn_axes(cfg),
    }
    return {
        "embed": ("vocab", "embed"),
        "lm_head": ("vocab", "embed"),
        "ln_f": (None,), "ln_enc": (None,),
        "enc_layers": lax_(enc_ax),
        "dec_layers": lax_(dec_ax),
    }


def encode(params, frames: jax.Array, cfg):
    """frames: [B, S_enc, d] stubbed embeddings -> encoder states."""
    x = shard_by(frames.astype(DTYPES[cfg.dtype]), "batch", "seq", "embed")

    def block(x, p):
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.apply_cross_attention(p["attn"], xn, xn, cfg)  # non-causal self
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + ffn_mod.apply_ffn(p["ffn"], xn, cfg), None

    if cfg.remat:
        block = jax.checkpoint(block, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(block, x, params["enc_layers"])
    else:  # cost probes: per-layer ops visible to cost_analysis
        for i in range(cfg.encoder_layers):
            x, _ = block(x, jax.tree.map(lambda t: t[i], params["enc_layers"]))
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def forward(params, batch: Dict[str, Any], cfg, block_mask=None,
            return_hidden=False):
    """batch: {"tokens": [B, S_dec], "frames": [B, S_enc, d]}."""
    enc = encode(params, batch["frames"], cfg)
    x = params["embed"][batch["tokens"]]
    x = shard_by(x, "batch", "seq", "embed")

    def block(x, p):
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.apply_attention(p["attn"], xn, cfg, block_mask=block_mask)
        xn = rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.apply_cross_attention(p["xattn"], xn, enc, cfg)
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + ffn_mod.apply_ffn(p["ffn"], xn, cfg)
        return shard_by(x, "batch", "seq_sp", "embed"), None

    if cfg.remat:
        block = jax.checkpoint(block, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(block, x, params["dec_layers"])
    else:
        for i in range(cfg.num_layers):
            x, _ = block(x, jax.tree.map(lambda t: t[i], params["dec_layers"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if return_hidden:
        return x, aux
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return shard_by(logits, "batch", "seq", "vocab"), aux


def lm_head_weights(params, cfg):
    del cfg
    return params["lm_head"]


def init_decode_cache(cfg, batch: int, max_len: int, enc_states=None):
    dtype = DTYPES[cfg.dtype]
    kv = jax.vmap(
        lambda _: attn.init_kv_cache(
            batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim, dtype)
    )(jnp.arange(cfg.num_layers))
    return DecodeCache(kv=kv, ssm=None, prev1=None, prev2=None, xkv=enc_states)


def decode_step(params, cache: DecodeCache, token, pos, cfg):
    x = params["embed"][token][:, None, :]
    enc = cache.xkv

    def body(x, inp):
        p, kv = inp
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, kv = attn.apply_attention_decode(p["attn"], xn, cfg, kv, pos)
        x = x + a
        xn = rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.apply_cross_attention(p["xattn"], xn, enc, cfg)
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + ffn_mod.apply_ffn(p["ffn"], xn, cfg), kv

    if cfg.scan_layers:
        x, kv = jax.lax.scan(body, x, (params["dec_layers"], cache.kv))
    else:
        kvs = []
        for i in range(cfg.num_layers):
            x, kv_i = body(x, jax.tree.map(
                lambda t: t[i], (params["dec_layers"], cache.kv)))
            kvs.append(kv_i)
        kv = jax.tree.map(lambda *z: jnp.stack(z), *kvs)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits[:, 0], cache._replace(kv=kv)
