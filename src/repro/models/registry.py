"""Model registry: a uniform functional API over every architecture family.

``build_model(cfg)`` returns a ``ModelApi`` with:
  init(key) -> params
  param_axes() -> logical-axis tree (same structure as params)
  forward(params, batch) -> (logits [B,S,V], aux_loss)
  loss(params, batch) -> scalar (causal LM xent + MoE aux)
  input_spec(shape) -> dict of ShapeDtypeStructs for the dry-run
  init_decode_cache(batch, max_len, **frontend) -> cache
  decode_step(params, cache, token, pos) -> (logits [B,V], cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import encdec, transformer
from repro.models.common import DTYPES


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    param_axes: Callable
    forward: Callable
    loss: Callable
    input_spec: Callable
    init_decode_cache: Callable
    decode_step: Callable


def _xent_chunk(x_c, labels_c, head, vocab_real):
    """x_c: [B, S_c, d]; labels_c: [B, S_c]. Returns (nll_sum, count)."""
    logits = jnp.einsum("bsd,vd->bsv", x_c, head,
                        preferred_element_type=jnp.float32)
    if head.shape[0] != vocab_real:  # mask vocab padding columns
        logits = jnp.where(jnp.arange(head.shape[0]) < vocab_real, logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels_c, 0)[..., None], axis=-1)[..., 0]
    mask = labels_c >= 0
    nll = jnp.where(mask, logz - gold, 0.0)
    return nll.sum(), mask.sum()


def _chunked_lm_loss(hidden, labels, aux, head, cfg) -> jax.Array:
    """Causal LM xent in f32, computed in sequence chunks so the [B, S, V]
    logits tensor never materializes. Chunks slice the seq dim only —
    flattening (b, s) would merge two differently-sharded dims and force
    GSPMD to replicate the hidden states. + 0.01 * MoE load-balance aux."""
    from repro.models.common import shard_by

    b, s, d = hidden.shape
    hidden = shard_by(hidden, "batch", None, "embed")  # seq whole per shard
    # floor of 256 seq positions per chunk: bounds how often the [V, d] head
    # weights are re-read from HBM (§Perf iteration, granite train_4k)
    chunk = max(1, min(max(cfg.loss_chunk // max(b, 1), 256), s))
    if s % chunk:
        chunk = s  # fall back to single chunk for odd tiny shapes
    n = s // chunk
    if n == 1:
        nll, cnt = _xent_chunk(hidden, labels, head, cfg.vocab_size)
        return nll / jnp.maximum(cnt, 1) + 0.01 * aux

    def body(carry, i):
        nll_a, cnt_a = carry
        x_c = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, 1)
        l_c = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        nll, cnt = _xent_chunk(x_c, l_c, head, cfg.vocab_size)
        return (nll_a + nll, cnt_a + cnt), None

    # checkpoint: recompute each chunk's logits in backward instead of
    # saving [B, chunk, V] f32 residuals for every chunk
    (nll, cnt), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (jnp.zeros(()), jnp.zeros((), jnp.int32)), jnp.arange(n))
    return nll / jnp.maximum(cnt, 1) + 0.01 * aux


def build_model(cfg: ModelConfig, block_mask=None) -> ModelApi:
    dtype = DTYPES[cfg.dtype]

    if cfg.is_encdec:
        mod = encdec

        def forward(params, batch):
            return encdec.forward(params, batch, cfg, block_mask=block_mask)

        def input_spec(shape: InputShape) -> Dict[str, Any]:
            b = shape.global_batch
            if shape.kind == "train":
                # encoder frames : decoder tokens at 1:1 for the dry-run
                return {
                    "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
                    "frames": jax.ShapeDtypeStruct(
                        (b, shape.seq_len, cfg.d_model), dtype),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
                "frames": jax.ShapeDtypeStruct(
                    (b, shape.seq_len, cfg.d_model), dtype),
            }

        def init_cache(batch, max_len, enc_states=None):
            return encdec.init_decode_cache(cfg, batch, max_len, enc_states)

        def decode_step(params, cache, token, pos):
            return encdec.decode_step(params, cache, token, pos, cfg)

    else:
        mod = transformer

        def forward(params, batch):
            return transformer.forward(params, batch, cfg, block_mask=block_mask)

        def input_spec(shape: InputShape) -> Dict[str, Any]:
            b = shape.global_batch
            spec = {
                "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            }
            if shape.kind == "train":
                spec["labels"] = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
            if cfg.cross_attn_every:
                spec["vision_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.num_vision_tokens, cfg.d_model), dtype)
            return spec

        def init_cache(batch, max_len, vision_embeds=None):
            return transformer.init_decode_cache(cfg, batch, max_len,
                                                 vision_embeds)

        def decode_step(params, cache, token, pos):
            return transformer.decode_step(params, cache, token, pos, cfg)

    def loss(params, batch):
        if cfg.is_encdec:
            hidden, aux = encdec.forward(params, batch, cfg,
                                         block_mask=block_mask,
                                         return_hidden=True)
            head = encdec.lm_head_weights(params, cfg)
        else:
            hidden, aux = transformer.forward(params, batch, cfg,
                                              block_mask=block_mask,
                                              return_hidden=True)
            head = transformer.lm_head_weights(params, cfg)
        return _chunked_lm_loss(hidden, batch["labels"], aux, head, cfg)

    return ModelApi(
        cfg=cfg,
        init=lambda key: mod.init_model(key, cfg),
        param_axes=lambda: mod.model_axes(cfg),
        forward=forward,
        loss=loss,
        input_spec=input_spec,
        init_decode_cache=init_cache,
        decode_step=decode_step,
    )
