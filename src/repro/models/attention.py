"""GQA attention: training/prefill (chunked online-softmax), decode (KV cache,
ring buffer for sliding-window archs), cross-attention (VLM/enc-dec), and the
block-sparse prefill path (the paper's §IV-D MInference integration).

The chunked implementation is a pure-JAX flash-attention analogue: a scan
over query chunks bounds the live score tensor to [bq, kv_span] instead of
[S, S]. Sliding-window archs additionally restrict kv_span to a static band
(window + bq), making SWA attention linear in S — this is what makes very
long contexts feasible and is exactly the sub-quadratic structure the paper
exploits with block-sparse attention.

GQA is computed natively (q reshaped to [.., kv_heads, group, d]) so K/V are
never materialized at q-head width — an 8x activation-memory saving for the
kv=8 archs at 32k context.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import apply_rope, dense_init, shard_by
# unified sparse-op API: impl=None defers to use_config /
# REPRO_SPARSE_IMPL / registry auto-resolution
from repro.ops import sparse_attention

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }


def attention_axes(cfg):
    del cfg
    return {
        "wq": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "wo": ("heads", "embed"),
    }


# ---------------------------------------------------------------------------
# Core scaled-dot-product paths (GQA-native einsums)
# ---------------------------------------------------------------------------


def _sdpa_block(qc, kc, vc, mask, scale):
    """qc: [B,bq,KV,G,D], kc/vc: [B,span,KV,D], mask: [bq,span] or None.

    §Perf iterations (granite train_4k, memory-bound on the [bq, span] f32
    score tensor):
      * the softmax scale folds into q (a [bq, D] tensor) instead of a full
        multiply pass over the scores;
      * normalization divides the [bq, D] *output* by the softmax denominator
        instead of the [bq, span] probability tensor (flash-style deferred
        normalization) — one fewer read+write pass over the scores.
    (A jax.nn.softmax(where=...) variant was tried and REFUTED: +7.7% HBM
    bytes; see EXPERIMENTS.md §Perf.)
    """
    qs = (qc.astype(jnp.float32) * scale).astype(qc.dtype)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qs, kc,
                        preferred_element_type=jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - jax.lax.stop_gradient(m))
    denom = jnp.sum(p, axis=-1, keepdims=True)  # [B,KV,G,bq,1]
    oc = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
    # deferred normalization on the small output tensor
    inv = 1.0 / jnp.maximum(denom, 1e-30)
    oc = oc * jnp.moveaxis(inv, 3, 1)[..., 0][..., None]
    return oc.astype(qc.dtype)


def _chunked_sdpa(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, Skv, KV, D]
    v: jax.Array,  # [B, Skv, KV, D]
    *,
    causal: bool,
    window: Optional[int],
    block_q: int = 512,
    unroll: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    group = h // kvh
    scale = 1.0 / np.sqrt(d)
    bq = min(block_q, s)
    nq = s // bq
    assert s % bq == 0, (s, bq)
    q5 = q.reshape(b, s, kvh, group, d)

    banded = window is not None and (window + bq) < skv and skv == s

    if banded:
        # static chunk-diagonal banding: q chunk i attends kv chunks
        # [i - wc, i] via wc+1 *statically shifted* chunk pairings. No
        # dynamic_slice with a traced start — which GSPMD can only partition
        # by replicating the whole kv tensor ("involuntary full
        # rematerialization"); see EXPERIMENTS.md §Perf qwen_it2/it3.
        wc = -(-window // bq)  # kv chunks back from the diagonal
        q6 = q5.reshape(b, nq, bq, kvh, group, d)
        k6 = k.reshape(b, nq, bq, kvh, d)
        v6 = v.reshape(b, nq, bq, kvh, d)
        acc = None
        denom_parts = []
        # offset j: q chunk i vs kv chunk i-j (static slices of the chunk dim)
        qpos_in = jnp.arange(bq)[:, None]
        kpos_in = jnp.arange(bq)[None, :]
        outs = jnp.zeros((b, nq, bq, kvh, group, d), jnp.float32)
        denom = jnp.zeros((b, nq, bq, kvh, group), jnp.float32)
        mx = jnp.full((b, nq, bq, kvh, group), NEG_INF, jnp.float32)
        # two-pass (max then exp-sum) per offset would re-read scores; with
        # window <= a few chunks we instead accumulate unnormalized per
        # offset with a shared running max computed from the diagonal chunk
        # (scores are scale*q.k with bounded magnitude; diagonal max is the
        # standard stable reference for banded softmax)
        contribs = []
        for j in range(wc + 1):
            qs = q6[:, j:] if j else q6  # chunks i >= j
            ks = k6[:, : nq - j] if j else k6
            sc = jnp.einsum("bnqhgd,bnkhd->bnhgqk",
                            (qs.astype(jnp.float32) * scale).astype(qs.dtype),
                            ks, preferred_element_type=jnp.float32)
            dist = j * bq + qpos_in - kpos_in  # q_global - k_global
            m = (dist >= 0) if causal else (dist > -(1 << 30))
            m = jnp.logical_and(m, dist < window)
            sc = jnp.where(m[None, None, None, None], sc, NEG_INF)
            contribs.append(sc)
        # running max across offsets per q row
        mxs = [jnp.max(c, axis=-1) for c in contribs]  # [b, nq-j, h, g, q]
        for j, mm in enumerate(mxs):
            pad = jnp.full((b, j, kvh, group, bq), NEG_INF)
            mm = jnp.moveaxis(mm, -1, -1)  # [b, nq-j, h, g, q]
            mm = jnp.concatenate([pad, mm], axis=1) if j else mm
            mx = jnp.maximum(mx, jnp.moveaxis(mm, [2, 3, 4], [3, 4, 2]))
        for j, sc in enumerate(contribs):
            mref = mx[:, j:] if j else mx  # [b, nq-j, q, h, g]
            mref = jnp.moveaxis(mref, [2, 3, 4], [4, 2, 3])[..., None]
            p = jnp.exp(sc - mref)
            vs = v6[:, : nq - j] if j else v6
            oc = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p.astype(vs.dtype), vs,
                            preferred_element_type=jnp.float32)
            dn = jnp.sum(p, axis=-1)  # [b, nq-j, h, g, q]
            dn = jnp.moveaxis(dn, [2, 3, 4], [3, 4, 2])  # [b, nq-j, q, h, g]
            if j:
                zpad_o = jnp.zeros((b, j) + oc.shape[2:], jnp.float32)
                oc = jnp.concatenate([zpad_o, oc], axis=1)
                zpad_d = jnp.zeros((b, j) + dn.shape[2:], jnp.float32)
                dn = jnp.concatenate([zpad_d, dn], axis=1)
            outs = outs + oc
            denom = denom + dn
        outs = outs / jnp.maximum(denom, 1e-30)[..., None]
        return outs.astype(q.dtype).reshape(b, s, h, d)

    if False:
        pass
    else:

        def body(carry, qi):
            q_start = qi * bq
            qc = jax.lax.dynamic_slice_in_dim(q5, q_start, bq, axis=1)
            qpos = q_start + jnp.arange(bq)[:, None]
            kpos = jnp.arange(skv)[None, :]
            m = None
            if causal:
                m = kpos <= qpos
            if window is not None:
                mm = qpos - kpos < window
                m = mm if m is None else jnp.logical_and(m, mm)
            return carry, _sdpa_block(qc, k, v, m, scale)

    if nq == 1:
        _, oc = body(None, jnp.asarray(0))
        return oc.reshape(b, s, h, d)
    if unroll:  # cost probes: every chunk visible to cost_analysis
        chunks = jnp.stack([body(None, jnp.asarray(i))[1] for i in range(nq)])
    else:
        # re-materialize per chunk in backward: without this the scan saves
        # every chunk's f32 score tensor as residuals (tens of GB at 4k+ seq)
        _, chunks = jax.lax.scan(
            jax.checkpoint(body, prevent_cse=False), None, jnp.arange(nq))
    # chunks: [nq, B, bq, KV, G, D] -> [B, S, H, D]
    return jnp.moveaxis(chunks, 0, 1).reshape(b, s, h, d)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Ring-buffered KV cache. ``cache_len`` == window for SWA archs, else
    the full max context. ``k``/``v``: [B, cache_len, KV, D]; ``pos``:
    [B, cache_len] absolute position per slot (-1 = empty)."""

    k: jax.Array
    v: jax.Array
    pos: jax.Array


def init_kv_cache(batch, cache_len, kv_heads, head_dim, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, cache_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, cache_len, kv_heads, head_dim), dtype),
        pos=jnp.full((batch, cache_len), -1, jnp.int32),
    )


def decode_sdpa(
    q: jax.Array,  # [B, 1, H, D] (already roped)
    cache: KVCache,
    cur_pos: jax.Array,  # [B] absolute position of the new token
    window: Optional[int],
) -> jax.Array:
    b, _, h, d = q.shape
    kvh = cache.k.shape[2]
    group = h // kvh
    scale = 1.0 / np.sqrt(d)
    q5 = q.reshape(b, 1, kvh, group, d)
    scores = (
        jnp.einsum("bqhgd,bkhd->bhgqk", q5, cache.k,
                   preferred_element_type=jnp.float32) * scale
    )  # [B, KV, G, 1, L]
    valid = jnp.logical_and(cache.pos >= 0, cache.pos <= cur_pos[:, None])
    if window is not None:
        valid = jnp.logical_and(valid, cur_pos[:, None] - cache.pos < window)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype).reshape(b, 1, h, d)


def cache_update(cache: KVCache, k_new, v_new, cur_pos) -> KVCache:
    """Insert one roped (k, v) token per batch element at slot pos % len."""
    cache_len = cache.k.shape[1]
    slot = (cur_pos % cache_len).astype(jnp.int32)  # [B]
    bidx = jnp.arange(cache.k.shape[0])
    k = cache.k.at[bidx, slot].set(k_new[:, 0])
    v = cache.v.at[bidx, slot].set(v_new[:, 0])
    pos = cache.pos.at[bidx, slot].set(cur_pos.astype(jnp.int32))
    return KVCache(k, v, pos)


# ---------------------------------------------------------------------------
# Paged KV cache (serving runtime: repro.serve.kvcache owns the allocator)
# ---------------------------------------------------------------------------
#
# Physical layout per layer: ``kl``/``vl`` [P+1, page_size, KVH, D] — a pool
# of fixed-size token pages shared by every sequence, plus one *null page*
# (physical id P) that absorbs writes from masked batch rows and pads short
# page-table rows. ``pos_tab`` [P+1, page_size] holds the absolute position
# of each stored token (-1 = empty) and is shared across layers (every layer
# stores the same token set). A sequence's logical view is its page-table
# row ``pages`` [W]: physical page ids in logical order, null-padded — so
# the gathered [W*page_size] view is *position-linear* (linear index ==
# absolute position), which is what lets chunked prefill reuse the
# block-sparse kernel's causal masking unchanged.


def paged_gather(kl, vl, pos_tab, pages) -> KVCache:
    """Linearize page-table rows into a masked KVCache view.

    kl/vl: [P+1, ps, KVH, D]; pages: [B, W] physical ids (null-padded).
    Returns KVCache with k/v [B, W*ps, KVH, D] and pos [B, W*ps]; entries
    whose ``pos_tab`` slot is -1 (empty / null page) stay masked out of
    attention exactly like empty ring-cache slots.
    """
    b, w = pages.shape
    ps, kvh, hd = kl.shape[1], kl.shape[2], kl.shape[3]
    kg = kl[pages].reshape(b, w * ps, kvh, hd)
    vg = vl[pages].reshape(b, w * ps, kvh, hd)
    pos = pos_tab[pages].reshape(b, w * ps)
    return KVCache(k=kg, v=vg, pos=pos)


def paged_update(kl, vl, k_new, v_new, pages, cur_pos):
    """Scatter one roped (k, v) token per batch row into its page slot.

    Masked rows must point at the null page (their writes land there and
    the null page's ``pos_tab`` entries stay/become -1, so nothing ever
    attends to them).
    """
    ps = kl.shape[1]
    page_idx = (cur_pos // ps).astype(jnp.int32)[:, None]
    phys = jnp.take_along_axis(pages, jnp.clip(page_idx, 0, pages.shape[1] - 1),
                               axis=1)[:, 0]
    within = (cur_pos % ps).astype(jnp.int32)
    kl = kl.at[phys, within].set(k_new[:, 0])
    vl = vl.at[phys, within].set(v_new[:, 0])
    return kl, vl


def apply_attention_decode_paged(params, x, cfg, kl, vl, pos_tab, pages,
                                 cur_pos):
    """Decode one token per batch row against the paged KV pool.

    x: [B, 1, d_model]; returns (out [B, 1, d_model], kl, vl). ``pos_tab``
    must already carry ``cur_pos`` for valid rows (``decode_step_paged``
    stamps it once, outside the layer scan).
    """
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kv, hd)
    v = (x @ params["wv"]).reshape(b, 1, kv, hd)
    pos2 = cur_pos[:, None]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)
    kl, vl = paged_update(kl, vl, k, v, pages, cur_pos)
    cache = paged_gather(kl, vl, pos_tab, pages)
    out = decode_sdpa(q, cache, cur_pos, cfg.sliding_window)
    return out.reshape(b, 1, h * hd) @ params["wo"], kl, vl


def apply_attention_prefill_chunk(params, x, cfg, kl, vl, pos_tab, pages_row,
                                  positions, scatter_page, within, mask_csr,
                                  *, block_q, block_k, attn_impl=None):
    """Bulk-prefill one prompt chunk against the paged KV pool (§IV-D).

    x: [1, C, d_model] chunk hidden states; ``positions`` [C] absolute token
    positions (chunk start + i); ``scatter_page``/``within`` [C] physical
    destination of each chunk token (null page for padding rows);
    ``pages_row`` [W] the sequence's page-table row; ``mask_csr`` a
    ``(ptr, kcols)`` causal-band CSR over [C//block_q, W*ps//block_k]
    blocks. The chunk attends to the whole gathered prefix (earlier chunks
    + itself, causally) through ``repro.ops.sparse_attention`` — i.e. the
    block_attn pipeline emitter, inheriting the ambient ``OpConfig``
    (impl / pipeline_depth / value_codec) exactly like every other op the
    engine traces. Returns (out [1, C, d_model], kl, vl).
    """
    b, c, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, c, h, hd)
    k = (x @ params["wk"]).reshape(b, c, kv, hd)
    v = (x @ params["wv"]).reshape(b, c, kv, hd)
    q = apply_rope(q, positions[None], cfg.rope_theta)
    k = apply_rope(k, positions[None], cfg.rope_theta)
    kl = kl.at[scatter_page, within].set(k[0])
    vl = vl.at[scatter_page, within].set(v[0])
    view = paged_gather(kl, vl, pos_tab, pages_row[None])
    # the gathered view is position-linear and its invalid tail sits at
    # linear indices strictly greater than any valid q position, so the
    # kernel's causal mask (with q_offset = positions[0]) subsumes the
    # pos >= 0 validity mask the decode path needs
    out = sparse_attention(
        q.transpose(0, 2, 1, 3),
        view.k.transpose(0, 2, 1, 3),
        view.v.transpose(0, 2, 1, 3),
        mask_csr,
        block_q=block_q,
        block_k=block_k,
        causal=True,
        impl=attn_impl,
        q_offset=positions[0],
        pad_active_to=view.k.shape[1] // block_k,
    ).transpose(0, 2, 1, 3)
    return out.reshape(b, c, h * hd) @ params["wo"], kl, vl


# ---------------------------------------------------------------------------
# Full layers
# ---------------------------------------------------------------------------


def apply_attention(
    params,
    x: jax.Array,  # [B, S, d_model]
    cfg,
    *,
    positions: Optional[jax.Array] = None,
    block_mask: Optional[np.ndarray] = None,
    attn_impl: Optional[str] = None,
) -> jax.Array:
    """Training/prefill self-attention."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q = shard_by((x @ params["wq"]).reshape(b, s, h, hd), "batch", "seq", "heads", None)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if block_mask is not None:
        out = sparse_attention(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            block_mask,
            causal=True,
            impl=attn_impl,
        ).transpose(0, 2, 1, 3)
    else:
        out = _chunked_sdpa(q, k, v, causal=True, window=cfg.sliding_window,
                            block_q=cfg.attn_block_q, unroll=cfg.attn_unroll)
    out = shard_by(out, "batch", "seq", "heads", None)
    return out.reshape(b, s, h * hd) @ params["wo"]


def apply_attention_decode(params, x, cfg, cache: KVCache, cur_pos):
    """x: [B, 1, d_model]; returns (out [B,1,d_model], updated cache)."""
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kv, hd)
    v = (x @ params["wv"]).reshape(b, 1, kv, hd)
    pos2 = cur_pos[:, None]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)
    cache = cache_update(cache, k, v, cur_pos)
    out = decode_sdpa(q, cache, cur_pos, cfg.sliding_window)
    return out.reshape(b, 1, h * hd) @ params["wo"], cache


def init_cross_attention(key, cfg, dtype):
    return init_attention(key, cfg, dtype)


def apply_cross_attention(params, x, enc: jax.Array, cfg):
    """x: [B, S, d]; enc: [B, S_enc, d] (no causal mask, no rope)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    se = enc.shape[1]
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (enc @ params["wk"]).reshape(b, se, kv, hd)
    v = (enc @ params["wv"]).reshape(b, se, kv, hd)
    out = _chunked_sdpa(q, k, v, causal=False, window=None,
                        block_q=cfg.attn_block_q, unroll=cfg.attn_unroll)
    return out.reshape(b, s, h * hd) @ params["wo"]
