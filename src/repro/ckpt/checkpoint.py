"""Sharded, atomic, fault-tolerant checkpointing (no orbax dependency).

Layout:  <dir>/step_<n>/   arrays.npz  (flattened pytree leaves)
                           manifest.json (treedef, shapes, dtypes, meta)
         <dir>/step_<n>.tmp.<pid>/  during write, renamed atomically.

Features:
  * atomic commit via rename — a crash mid-write never corrupts the latest
    intact checkpoint (restart scans for the newest manifest that validates);
  * async save (background thread) so the training loop never blocks on I/O;
  * keep-last-k retention;
  * **elastic restore**: arrays are stored unsharded (gathered); on load they
    are re-dropped onto whatever mesh/sharding the *new* job supplies, so a
    job restarted on a different device count resumes seamlessly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer"]


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat[0]]
    return leaves, flat[1]


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "meta": meta or {}, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        if leaf is None:
            manifest["leaves"].append({"key": name, "none": True})
            continue
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays[f"a{i}"] = arr.view(np.uint16)
            manifest["leaves"].append(
                {"key": name, "id": f"a{i}", "dtype": "bfloat16",
                 "shape": list(arr.shape)})
        else:
            arrays[f"a{i}"] = arr
            manifest["leaves"].append(
                {"key": name, "id": f"a{i}", "dtype": str(arr.dtype),
                 "shape": list(arr.shape)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or ".tmp." in name:
            continue
        path = os.path.join(ckpt_dir, name, "manifest.json")
        if not os.path.exists(path):
            continue  # incomplete (crashed mid-write before rename)
        try:
            with open(path) as f:
                json.load(f)
        except Exception:
            continue
        step = int(name.split("_")[1])
        best = step if best is None else max(best, step)
    return best


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None):
    """Restore into the structure of ``like``. ``shardings``: optional
    matching tree of NamedShardings for elastic placement on a new mesh."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))
    by_key = {e["key"]: e for e in manifest["leaves"]}

    leaves, treedef = _flatten_with_paths(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _flatten_with_paths(shardings)[0]]

    out = []
    for i, (name, leaf) in enumerate(leaves):
        e = by_key.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        if e.get("none"):
            out.append(None)
            continue
        arr = data[e["id"]]
        if e["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {leaf.shape}")
        if shard_leaves is not None and shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and ".tmp." not in n
        and os.path.exists(os.path.join(ckpt_dir, n, "manifest.json"))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    # sweep stale tmp dirs from crashed writers
    for n in os.listdir(ckpt_dir):
        if ".tmp." in n:
            shutil.rmtree(os.path.join(ckpt_dir, n), ignore_errors=True)


class Checkpointer:
    """Async, keep-k checkpoint manager."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any, meta=None):
        self.wait()
        # snapshot to host synchronously (cheap), write in background
        host_tree = jax.tree.map(
            lambda x: None if x is None else np.asarray(jax.device_get(x)), tree,
            is_leaf=lambda x: x is None,
        )

        def work():
            save(self.dir, step, host_tree, meta)
            _gc(self.dir, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest(self):
        return latest_step(self.dir)

    def restore(self, step: int, like, shardings=None):
        return restore(self.dir, step, like, shardings)
