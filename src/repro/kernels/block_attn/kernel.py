"""Pallas TPU block-sparse flash attention (MInference-analogue, paper §IV-D).

Per (head, q-block) the set of active k-blocks is CSR-encoded and scalar-
prefetched; K/V are *indirect* operands (block index chased through
``kcols``). Two load paths:

* ``pipeline_depth=0`` (default) — the K/V BlockSpec index_maps chase the
  active list so *only active blocks are DMA'd* (Mosaic double-buffers the
  stream) — the TPU equivalent of MInference's Triton kernel computing
  "only the dynamically selected sparse subset of query-key blocks".
  Padding steps (j >= the q-block's active count) re-DMA the last active
  block and are compute-masked.
* ``pipeline_depth>=1`` — K/V stay in HBM (ANY memory space) and each
  active block pair is gathered by the shared Q-deep producer/consumer
  emitter (``repro.kernels.pipeline``, paper §III-A): the K/V DMAs of
  active block ``j+Q`` overlap the softmax/MXU work of block ``j``, and
  padding steps issue no DMA at all.

Online softmax runs in VMEM scratch across the active-block grid dimension.
Grid = (B*H, num_q_blocks, max_active_kblocks).

Prefill-chunk entry (serving runtime): q and K/V may have different sequence
lengths (``s_q`` = one prompt chunk, ``s_kv`` = the whole gathered prefix),
and ``q_offset`` — the chunk's absolute start position, a *traced* scalar
prefetched alongside the CSR arrays — shifts the causal mask so chunk
``i`` of a long prompt reuses the compiled kernel of chunk ``i-1`` (only
array contents change per chunk, never shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.pipeline import (dequant_tile, emit_gather_pipeline,
                                    gather_slots, validate_depth)

NEG_INF = -1e30


def _scores(q, k_blk, kidx, *, bq, bk, qb, q_off, causal, scale):
    """Scaled (and causally masked) QK^T scores for one active k-block.

    ``q_off`` is the absolute position of q row 0 (a traced scalar for the
    prefill-chunk entry; 0 for the classic square case).
    """
    s = (
        jax.lax.dot_general(
            q,
            k_blk,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        * scale
    )  # [bq, bk]
    if causal:
        qpos = q_off + qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kidx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    return s


def _finish_store(o_ref, m_ref, l_ref, acc_ref):
    """Normalize the online-softmax accumulator into the output tile.

    Fully-masked rows (l == 0, e.g. a q-block with no active k-blocks)
    emit zeros.
    """
    del m_ref
    l = l_ref[:, :1]
    norm = jnp.where(l > 0, 1.0 / jnp.where(l > 0, l, 1.0), 0.0)
    o_ref[0] = (acc_ref[...] * norm).astype(o_ref.dtype)


def _softmax_step(s, m_ref, l_ref, acc_ref, v, v_dtype):
    """One online-softmax update with scores ``s`` and value block ``v``."""
    m_prev = m_ref[:, :1]  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)  # [bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # [bq, bk]
    # rows that are still fully masked keep exp(NEG_INF - NEG_INF) = 1
    # on masked lanes; kill them explicitly
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v_dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)


def _kernel(
    ptr_ref,  # [H*nqb + 1] i32 CSR pointers into kcols
    kcols_ref,  # [total_active] i32 active k-block indices
    qoff_ref,  # [1] i32 absolute position of q row 0 (prefill-chunk entry)
    q_ref,  # [1, bq, d]
    k_ref,  # [1, bk, d] (codec payload when quantized)
    v_ref,  # [1, bk, d] (codec payload when quantized)
    *rest,  # [ks_ref, vs_ref (codec only)], o_ref, m_ref, l_ref, acc_ref
    bq: int,
    bk: int,
    max_active: int,
    heads: int,
    nqb: int,
    causal: bool,
    scale: float,
    codec: str = "none",
):
    if codec == "none":
        o_ref, m_ref, l_ref, acc_ref = rest
        ks_ref = vs_ref = None
    else:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    bh = pl.program_id(0)
    qb = pl.program_id(1)
    j = pl.program_id(2)
    h = bh % heads
    base = ptr_ref[h * nqb + qb]
    count = ptr_ref[h * nqb + qb + 1] - base
    active = j < count

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active)
    def _step():
        kidx = kcols_ref[base + jnp.minimum(j, count - 1)]
        k_blk = dequant_tile(k_ref[0], codec,
                             None if ks_ref is None else ks_ref[0, 0])
        v_blk = dequant_tile(v_ref[0], codec,
                             None if vs_ref is None else vs_ref[0, 0])
        s = _scores(q_ref[0], k_blk, kidx, bq=bq, bk=bk, qb=qb,
                    q_off=qoff_ref[0], causal=causal, scale=scale)
        _softmax_step(s, m_ref, l_ref, acc_ref, v_blk,
                      v_ref.dtype if codec == "none" else jnp.float32)

    @pl.when(j == max_active - 1)
    def _finish():
        _finish_store(o_ref, m_ref, l_ref, acc_ref)


def _kernel_pipelined(
    ptr_ref,  # [H*nqb + 1] i32 CSR pointers into kcols
    kcols_ref,  # [total_active] i32 active k-block indices
    qoff_ref,  # [1] i32 absolute position of q row 0 (prefill-chunk entry)
    q_ref,  # [1, bq, d]
    k_hbm_ref,  # [B*KVH, S, D] (ANY/HBM — gathered; codec payload)
    v_hbm_ref,  # [B*KVH, S, D] (ANY/HBM; codec payload)
    *rest,  # [ks_ref, vs_ref (codec only)], o_ref, k_slots, v_slots, sem,
            # m_ref, l_ref, acc_ref
    bq: int,
    bk: int,
    max_active: int,
    heads: int,
    kv_heads: int,
    nqb: int,
    causal: bool,
    scale: float,
    depth: int,
    codec: str = "none",
):
    if codec == "none":
        (o_ref, k_slots_ref, v_slots_ref, sem, m_ref, l_ref, acc_ref) = rest
        ks_ref = vs_ref = None
    else:
        (ks_ref, vs_ref, o_ref, k_slots_ref, v_slots_ref, sem, m_ref, l_ref,
         acc_ref) = rest
    bh = pl.program_id(0)
    qb = pl.program_id(1)
    j = pl.program_id(2)
    h = bh % heads
    kv_row = (bh // heads) * kv_heads + h // (heads // kv_heads)
    base = ptr_ref[h * nqb + qb]
    count = ptr_ref[h * nqb + qb + 1] - base
    total = kcols_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def kidx_of(chunk):
        # lookahead chunks run past the active count (and count may be 0);
        # clamp into both this q-block's list and the global kcols array
        c = jnp.maximum(base + jnp.minimum(chunk, count - 1), 0)
        return kcols_ref[jnp.minimum(c, total - 1)]

    def copies(chunk, slot):
        kidx = kidx_of(chunk)
        return [
            pltpu.make_async_copy(
                k_hbm_ref.at[kv_row, pl.ds(kidx * bk, bk), :],
                k_slots_ref.at[slot],
                sem.at[slot],
            ),
            pltpu.make_async_copy(
                v_hbm_ref.at[kv_row, pl.ds(kidx * bk, bk), :],
                v_slots_ref.at[slot],
                sem.at[slot],
            ),
        ]

    def compute(chunk, slot):
        # fused dequant after the K/V gather lands: the DMAs above moved
        # the compressed payload; the block scales stream via BlockSpec
        k_blk = dequant_tile(k_slots_ref[slot], codec,
                             None if ks_ref is None else ks_ref[0, 0])
        v_blk = dequant_tile(v_slots_ref[slot], codec,
                             None if vs_ref is None else vs_ref[0, 0])
        s = _scores(q_ref[0], k_blk, kidx_of(chunk), bq=bq,
                    bk=bk, qb=qb, q_off=qoff_ref[0], causal=causal,
                    scale=scale)
        _softmax_step(s, m_ref, l_ref, acc_ref, v_blk,
                      v_slots_ref.dtype if codec == "none" else jnp.float32)

    emit_gather_pipeline(step=j, nchunks=count, depth=depth,
                         copies=copies, compute=compute)

    @pl.when(j == max_active - 1)
    def _finish():
        _finish_store(o_ref, m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit,
    static_argnames=(
        "heads",
        "kv_heads",
        "block_q",
        "block_k",
        "max_active",
        "causal",
        "scale",
        "interpret",
        "pipeline_depth",
        "codec",
    ),
)
def block_sparse_attention_kernel(
    ptr: jax.Array,  # [H*nqb + 1] i32
    kcols: jax.Array,  # [total_active] i32
    q: jax.Array,  # [B*H, Sq, D]
    k: jax.Array,  # [B*KVH, Skv, D] (codec payload when quantized)
    v: jax.Array,  # [B*KVH, Skv, D] (codec payload when quantized)
    kscales: jax.Array = None,  # [B*KVH, Skv // block_k] f32 per-block scales
    vscales: jax.Array = None,  # [B*KVH, Skv // block_k] f32 per-block scales
    *,
    heads: int,
    kv_heads: int,
    block_q: int,
    block_k: int,
    max_active: int,
    causal: bool,
    scale: float,
    interpret: bool = True,
    pipeline_depth: int = 0,
    codec: str = "none",
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    depth = validate_depth(pipeline_depth, allow_zero=True)
    if codec != "none" and (kscales is None or vscales is None):
        raise ValueError(
            f"block_sparse_attention_kernel: codec {codec!r} needs "
            "kscales and vscales")
    bh, s, d = q.shape
    nqb = s // block_q
    group = heads // kv_heads
    grid = (bh, nqb, max_active)
    # traced scalar: chunk i and chunk i+1 of a serving prefill hit the same
    # compiled kernel (shapes identical, only ptr/kcols/qoff contents change)
    qoff = jnp.full((1,), q_offset, jnp.int32) if isinstance(q_offset, int) \
        else jnp.asarray(q_offset, jnp.int32).reshape(1)
    q_spec = pl.BlockSpec((1, block_q, d),
                          lambda b, qb, j, ptr, kcols, qo: (b, qb, 0))

    def _kv_lookup(b, qb, j, ptr, kcols, qo):
        # kv row for this q head; padding steps clamp to the last active
        # block (and an empty list clamps to its base entry)
        del qo
        row = (b // heads) * kv_heads + (b % heads) // group
        base = ptr[(b % heads) * nqb + qb]
        cnt = ptr[(b % heads) * nqb + qb + 1] - base
        col = kcols[base + jnp.minimum(j, jnp.maximum(cnt - 1, 0))]
        return row, col

    # the K/V block scales always stream via BlockSpec — at depth 0 next to
    # their payload blocks, at depth >= 1 as the only streamed K/V operand
    # (the payload itself rides the explicit gather pipeline)
    scale_spec = pl.BlockSpec((1, 1), _kv_lookup)
    if depth == 0:
        kv_index = lambda b, qb, j, ptr, kcols, qo: (
            *_kv_lookup(b, qb, j, ptr, kcols, qo), 0)
        body = functools.partial(
            _kernel,
            bq=block_q,
            bk=block_k,
            max_active=max_active,
            heads=heads,
            nqb=nqb,
            causal=causal,
            scale=scale,
            codec=codec,
        )
        in_specs = [
            q_spec,
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ]
        scratch = []
    else:
        body = functools.partial(
            _kernel_pipelined,
            bq=block_q,
            bk=block_k,
            max_active=max_active,
            heads=heads,
            kv_heads=kv_heads,
            nqb=nqb,
            causal=causal,
            scale=scale,
            depth=depth,
            codec=codec,
        )
        in_specs = [
            q_spec,
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ]
        k_slots, kv_sems = gather_slots(depth, (block_k, d), k.dtype)
        v_slots, _ = gather_slots(depth, (block_k, d), v.dtype)
        scratch = [k_slots, v_slots, kv_sems]
    operands = [q, k, v]
    if codec != "none":
        in_specs += [scale_spec, scale_spec]
        operands += [kscales, vscales]
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, block_q, d), lambda b, qb, j, ptr, kcols, qo: (b, qb, 0)
            ),
            scratch_shapes=scratch + [
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(ptr, kcols, qoff, *operands)
