"""Pallas TPU block-sparse flash attention (MInference-analogue, paper §IV-D).

Per (head, q-block) the set of active k-blocks is CSR-encoded and scalar-
prefetched; the K/V BlockSpec index_maps chase the active list so *only
active blocks are DMA'd* — the TPU equivalent of MInference's Triton kernel
computing "only the dynamically selected sparse subset of query-key blocks".
Online softmax runs in VMEM scratch across the active-block grid dimension.

Grid = (B*H, num_q_blocks, max_active_kblocks); padding steps (j >= the
q-block's active count) re-DMA the last active block and are compute-masked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(
    ptr_ref,  # [H*nqb + 1] i32 CSR pointers into kcols
    kcols_ref,  # [total_active] i32 active k-block indices
    q_ref,  # [1, bq, d]
    k_ref,  # [1, bk, d]
    v_ref,  # [1, bk, d]
    o_ref,  # [1, bq, d]
    m_ref,  # [bq, 128] f32 running max
    l_ref,  # [bq, 128] f32 running denominator
    acc_ref,  # [bq, d] f32 running numerator
    *,
    bq: int,
    bk: int,
    max_active: int,
    heads: int,
    nqb: int,
    causal: bool,
    scale: float,
):
    bh = pl.program_id(0)
    qb = pl.program_id(1)
    j = pl.program_id(2)
    h = bh % heads
    base = ptr_ref[h * nqb + qb]
    count = ptr_ref[h * nqb + qb + 1] - base
    active = j < count

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active)
    def _step():
        kidx = kcols_ref[base + jnp.minimum(j, count - 1)]
        s = (
            jax.lax.dot_general(
                q_ref[0],
                k_ref[0],
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            * scale
        )  # [bq, bk]
        if causal:
            qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kidx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[:, :1]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [bq, bk]
        # rows that are still fully masked keep exp(NEG_INF - NEG_INF) = 1
        # on masked lanes; kill them explicitly
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == max_active - 1)
    def _finish():
        l = l_ref[:, :1]
        norm = jnp.where(l > 0, 1.0 / jnp.where(l > 0, l, 1.0), 0.0)
        o_ref[0] = (acc_ref[...] * norm).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "heads",
        "kv_heads",
        "block_q",
        "block_k",
        "max_active",
        "causal",
        "scale",
        "interpret",
    ),
)
def block_sparse_attention_kernel(
    ptr: jax.Array,  # [H*nqb + 1] i32
    kcols: jax.Array,  # [total_active] i32
    q: jax.Array,  # [B*H, S, D]
    k: jax.Array,  # [B*KVH, S, D]
    v: jax.Array,  # [B*KVH, S, D]
    *,
    heads: int,
    kv_heads: int,
    block_q: int,
    block_k: int,
    max_active: int,
    causal: bool,
    scale: float,
    interpret: bool = True,
) -> jax.Array:
    bh, s, d = q.shape
    nqb = s // block_q
    group = heads // kv_heads
    grid = (bh, nqb, max_active)
    kv_index = lambda b, qb, j, ptr, kcols: (
        # kv row for this q head; padding steps clamp to the last active block
        (b // heads) * kv_heads + (b % heads) // group,
        kcols[
            ptr[(b % heads) * nqb + qb]
            + jnp.minimum(
                j,
                jnp.maximum(
                    ptr[(b % heads) * nqb + qb + 1]
                    - ptr[(b % heads) * nqb + qb]
                    - 1,
                    0,
                ),
            )
        ],
        0,
    )
    return pl.pallas_call(
        functools.partial(
            _kernel,
            bq=block_q,
            bk=block_k,
            max_active=max_active,
            heads=heads,
            nqb=nqb,
            causal=causal,
            scale=scale,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, d), lambda b, qb, j, ptr, kcols: (b, qb, 0)),
                pl.BlockSpec((1, block_k, d), kv_index),
                pl.BlockSpec((1, block_k, d), kv_index),
            ],
            out_specs=pl.BlockSpec(
                (1, block_q, d), lambda b, qb, j, ptr, kcols: (b, qb, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(ptr, kcols, q, k, v)
