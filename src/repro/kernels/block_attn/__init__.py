from repro.kernels.block_attn.ops import block_sparse_attention
from repro.kernels.block_attn.ref import block_sparse_attention_ref
