"""Pure-jnp oracle for block-sparse attention.

Dense attention with -inf applied outside the allowed (q-block, k-block)
pairs, plus optional causal masking. The block mask is per *kv-head group*
(MInference selects patterns per head).

Rectangular (prefill-chunk) support mirrors the kernel: q may cover a chunk
of ``s_q`` tokens starting at absolute position ``q_offset`` while K/V span
the whole ``s_kv``-token prefix; the block mask is then [H, s_q//block_q,
s_kv//block_k] and may be a traced ``jnp`` array (the serving runtime builds
it on-device per chunk).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def block_sparse_attention_ref(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, KVH, Skv, D]
    v: jax.Array,  # [B, KVH, Skv, D]
    block_mask,  # [H, nqb, nkb] bool (np or jnp)
    *,
    block_q: int,
    block_k: int,
    causal: bool = True,
    scale: float | None = None,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    group = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q, kk, preferred_element_type=jnp.float32
    ) * scale
    mask = jnp.asarray(block_mask).astype(bool)
    mask_el = jnp.repeat(jnp.repeat(mask, block_q, axis=1), block_k, axis=2)
    mask_el = mask_el[:, :sq, :skv]
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        mask_el = jnp.logical_and(mask_el, (kpos <= qpos)[None])
    scores = jnp.where(mask_el[None], scores, -jnp.inf)
    # rows with no allowed key at all produce zeros, not NaNs
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
