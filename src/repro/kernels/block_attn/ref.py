"""Pure-jnp oracle for block-sparse attention.

Dense attention with -inf applied outside the allowed (q-block, k-block)
pairs, plus optional causal masking. The block mask is per *kv-head group*
(MInference selects patterns per head).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def block_sparse_attention_ref(
    q: jax.Array,  # [B, H, S, D]
    k: jax.Array,  # [B, KVH, S, D]
    v: jax.Array,  # [B, KVH, S, D]
    block_mask: np.ndarray,  # [H, nqb, nkb] bool
    *,
    block_q: int,
    block_k: int,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    b, h, s, d = q.shape
    kvh = k.shape[1]
    group = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q, kk, preferred_element_type=jnp.float32
    ) * scale
    mask = jnp.asarray(np.asarray(block_mask, bool))
    mask_el = jnp.repeat(jnp.repeat(mask, block_q, axis=1), block_k, axis=2)
    mask_el = mask_el[:, :s, :s]
    if causal:
        tri = jnp.tril(jnp.ones((s, s), bool))
        mask_el = jnp.logical_and(mask_el, tri[None])
    scores = jnp.where(mask_el[None], scores, -jnp.inf)
    # rows with no allowed key at all produce zeros, not NaNs
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
