"""Dispatcher for block-sparse attention: CSR-encode the block mask, pad,
call the kernel (or the dense-masked reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_attn.kernel import block_sparse_attention_kernel
from repro.kernels.block_attn.ref import block_sparse_attention_ref

__all__ = ["block_sparse_attention", "csr_encode_block_mask"]


def csr_encode_block_mask(block_mask: np.ndarray):
    """[H, nqb, nkb] bool -> (ptr [H*nqb+1], kcols [total], max_active)."""
    bm = np.asarray(block_mask, bool)
    h, nqb, nkb = bm.shape
    counts = bm.sum(axis=2).reshape(-1)
    ptr = np.zeros(h * nqb + 1, np.int32)
    ptr[1:] = np.cumsum(counts)
    kcols = np.nonzero(bm.reshape(h * nqb, nkb))[1].astype(np.int32)
    if len(kcols) == 0:
        kcols = np.zeros(1, np.int32)
    max_active = int(counts.max()) if counts.size else 1
    return ptr, kcols, max(max_active, 1)


def block_sparse_attention(
    q: jax.Array,  # [B, H, S, D]
    k: jax.Array,  # [B, KVH, S, D]
    v: jax.Array,  # [B, KVH, S, D]
    block_mask: np.ndarray,  # [H, nqb, nkb] bool (host-side / static)
    *,
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = True,
    scale: float | None = None,
    impl: str = "auto",
) -> jax.Array:
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return block_sparse_attention_ref(
            q, k, v, block_mask, block_q=block_q, block_k=block_k,
            causal=causal, scale=scale,
        )
    interpret = impl == "kernel_interpret" or jax.default_backend() != "tpu"
    b, h, s, d = q.shape
    kvh = k.shape[1]
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    ptr, kcols, max_active = csr_encode_block_mask(block_mask)
    out = block_sparse_attention_kernel(
        jnp.asarray(ptr),
        jnp.asarray(kcols),
        q.reshape(b * h, s, d),
        k.reshape(b * kvh, s, d),
        v.reshape(b * kvh, s, d),
        heads=h,
        kv_heads=kvh,
        block_q=block_q,
        block_k=block_k,
        max_active=max_active,
        causal=causal,
        scale=scale,
        interpret=interpret,
    )
    return out.reshape(b, h, s, d)
