"""DEPRECATED: thin shims forwarding to the unified ``repro.ops`` API.

``block_sparse_attention`` is now ``repro.ops.sparse_attention``;
``csr_encode_block_mask`` lives in ``repro.ops`` as well.
"""

from __future__ import annotations

import warnings

import jax
import numpy as np

__all__ = ["block_sparse_attention", "csr_encode_block_mask"]


def csr_encode_block_mask(block_mask: np.ndarray):
    """Deprecated alias of ``repro.ops.csr_encode_block_mask``."""
    from repro.ops import csr_encode_block_mask as _enc

    return _enc(block_mask)


def block_sparse_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    block_mask: np.ndarray,
    *,
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = True,
    scale=None,
    impl: str = "auto",
) -> jax.Array:
    """Deprecated alias of ``repro.ops.sparse_attention``."""
    warnings.warn(
        "repro.kernels.block_attn.ops.block_sparse_attention is deprecated; "
        "use repro.ops.sparse_attention instead", DeprecationWarning,
        stacklevel=2)
    from repro.ops import sparse_attention

    return sparse_attention(q, k, v, block_mask, block_q=block_q,
                            block_k=block_k, causal=causal, scale=scale,
                            impl=impl)
