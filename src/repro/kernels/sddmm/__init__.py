from repro.kernels.sddmm.ops import sddmm
from repro.kernels.sddmm.ref import sddmm_ref
