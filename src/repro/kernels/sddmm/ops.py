"""DEPRECATED: thin shim forwarding to the unified ``repro.ops`` API."""

from __future__ import annotations

import warnings

import jax

from repro.sparse.formats import BCSR

__all__ = ["sddmm"]


def sddmm(
    dc: jax.Array,
    b: jax.Array,
    a_struct: BCSR,
    *,
    impl: str = "auto",
    bn=None,
    out_dtype=None,
) -> jax.Array:
    """Deprecated alias of ``repro.ops.sddmm``."""
    warnings.warn(
        "repro.kernels.sddmm.ops.sddmm is deprecated; use repro.ops.sddmm "
        "instead", DeprecationWarning, stacklevel=2)
    from repro.ops import sddmm as _sddmm

    return _sddmm(dc, b, a_struct, impl=impl, bn=bn, out_dtype=out_dtype)
