"""Dispatcher for the SDDMM op (kernel vs reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BCSR
from repro.kernels.sddmm.kernel import sddmm_kernel
from repro.kernels.sddmm.ref import sddmm_ref

__all__ = ["sddmm"]


def sddmm(
    dc: jax.Array,
    b: jax.Array,
    a_struct: BCSR,
    *,
    impl: str = "auto",
    bn: int = 512,
    out_dtype=None,
) -> jax.Array:
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return sddmm_ref(dc, b, a_struct, out_dtype=out_dtype)
    interpret = impl == "kernel_interpret" or jax.default_backend() != "tpu"
    n = dc.shape[1]
    bn_eff = min(bn, n) if n >= 128 else n
    pad = -n % bn_eff
    if pad:
        dc = jnp.pad(dc, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad)))
    return sddmm_kernel(
        a_struct.block_rows,
        a_struct.block_cols,
        dc,
        b,
        block=a_struct.block,
        nnz=a_struct.nnz_blocks,
        bn=bn_eff,
        out_dtype=out_dtype,
        interpret=interpret,
    )
