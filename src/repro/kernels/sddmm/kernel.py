"""Pallas TPU kernel for block-sampled SDDMM (weight grad of BCSR layers).

Grid = (nnz_padded, n_tiles), n innermost: each stored block (r, c)
accumulates dC[r-tile, n-slice] @ B[c-tile, n-slice]^T over the n slices in a
VMEM accumulator, then stores its [bm, bk] block.

Two load paths for the indirect B operand (``block_cols``-indexed tiles):

* ``pipeline_depth=0`` (default) — BlockSpec-driven stream, double-buffered
  by Mosaic: the same implicit TMA-analogue machinery as the forward BCSR
  kernel.
* ``pipeline_depth>=1`` — B stays in HBM (ANY memory space) and its tiles
  are gathered by the shared Q-deep producer/consumer emitter
  (``repro.kernels.pipeline``, paper §III-A): the DMA of n-slice ``nt+Q``
  overlaps the MXU contraction of slice ``nt``. Depth 1 is the serial
  load-then-compute instance; the dC stream stays on Mosaic's pipeline
  either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.pipeline import (dequant_tile, emit_gather_pipeline,
                                    gather_slots, validate_depth)


def _contract(dc, b):
    """dC[bm, bn] @ B[bk, bn]^T -> [bm, bk] f32."""
    return jax.lax.dot_general(
        dc,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _kernel(rows_ref, cols_ref, dc_ref, b_ref, *rest, n_tiles, nnz,
            codec="none"):
    if codec == "none":
        o_ref, acc_ref = rest
        s_ref = None
    else:
        s_ref, o_ref, acc_ref = rest
    del rows_ref, cols_ref
    nt = pl.program_id(1)
    i = pl.program_id(0)

    @pl.when(nt == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b_tile = dequant_tile(b_ref[...], codec,
                          None if s_ref is None else s_ref[0, 0])
    acc_ref[...] += _contract(dc_ref[...], b_tile)

    @pl.when(nt == n_tiles - 1)
    def _store():
        valid = i < nnz  # padding blocks must not produce gradient
        o_ref[0] = jnp.where(valid, acc_ref[...], 0).astype(o_ref.dtype)


def _kernel_pipelined(rows_ref, cols_ref, dc_ref, b_hbm_ref, *rest,
                      n_tiles, nnz, bk, bn, depth, codec="none"):
    if codec == "none":
        o_ref, b_slots_ref, sem, acc_ref = rest
        s_ref = None
    else:
        s_ref, o_ref, b_slots_ref, sem, acc_ref = rest
    del rows_ref  # dc is BlockSpec-streamed; rows drive its index_map only
    nt = pl.program_id(1)
    i = pl.program_id(0)

    @pl.when(nt == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def copies(chunk, slot):
        # lookahead chunks run past the last n-tile; clamp the column slice
        c = jnp.minimum(chunk, n_tiles - 1)
        return [pltpu.make_async_copy(
            b_hbm_ref.at[pl.ds(cols_ref[i] * bk, bk), pl.ds(c * bn, bn)],
            b_slots_ref.at[slot],
            sem.at[slot],
        )]

    def compute(chunk, slot):
        del chunk  # dc_ref already holds this n-slice
        # fused dequant after the gather lands: DMA moved compressed bytes
        b_tile = dequant_tile(b_slots_ref[slot], codec,
                              None if s_ref is None else s_ref[0, 0])
        acc_ref[...] += _contract(dc_ref[...], b_tile)

    emit_gather_pipeline(step=nt, nchunks=n_tiles, depth=depth,
                         copies=copies, compute=compute)

    @pl.when(nt == n_tiles - 1)
    def _store():
        valid = i < nnz
        o_ref[0] = jnp.where(valid, acc_ref[...], 0).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block", "nnz", "bn", "out_dtype", "interpret",
                     "pipeline_depth", "codec"),
)
def sddmm_kernel(
    block_rows: jax.Array,
    block_cols: jax.Array,
    dc: jax.Array,  # [m, n]
    b: jax.Array,  # [k, n] (codec payload when quantized)
    scales: jax.Array = None,  # [k // bk, 1] f32 per-row-block codec scales
    *,
    block: tuple,
    nnz: int,
    bn: int = 512,
    out_dtype=None,
    interpret: bool = True,
    pipeline_depth: int = 0,
    codec: str = "none",
) -> jax.Array:
    depth = validate_depth(pipeline_depth, allow_zero=True)
    bm, bk = block
    nnz_p = block_rows.shape[0]
    m, n = dc.shape
    if n % bn:
        raise ValueError(f"n={n} must be a multiple of bn={bn}")
    if codec != "none" and scales is None:
        raise ValueError(f"sddmm_kernel: codec {codec!r} needs scales")
    n_tiles = n // bn
    out_dtype = out_dtype or dc.dtype
    if depth == 0:
        body = functools.partial(_kernel, n_tiles=n_tiles, nnz=nnz,
                                 codec=codec)
        b_spec = pl.BlockSpec((bk, bn), lambda i, nt, rows, cols: (cols[i], nt))
        scratch = [pltpu.VMEM((bm, bk), jnp.float32)]
    else:
        body = functools.partial(_kernel_pipelined, n_tiles=n_tiles, nnz=nnz,
                                 bk=bk, bn=bn, depth=depth, codec=codec)
        b_spec = pl.BlockSpec(memory_space=pl.ANY)
        slots, sems = gather_slots(depth, (bk, bn), b.dtype)
        scratch = [slots, sems, pltpu.VMEM((bm, bk), jnp.float32)]
    in_specs = [
        pl.BlockSpec((bm, bn), lambda i, nt, rows, cols: (rows[i], nt)),
        b_spec,
    ]
    operands = [dc, b]
    if codec != "none":
        # the gathered tile's row-block scale streams on its own BlockSpec
        # (tiny f32) while the payload tile rides the gather path
        in_specs.append(
            pl.BlockSpec((1, 1), lambda i, nt, rows, cols: (cols[i], 0)))
        operands.append(scales)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nnz_p, n_tiles),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, bk), lambda i, nt, rows, cols: (i, 0, 0)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((nnz_p, bm, bk), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(block_rows, block_cols, *operands)
