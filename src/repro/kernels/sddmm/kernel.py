"""Pallas TPU kernel for block-sampled SDDMM (weight grad of BCSR layers).

Grid = (nnz_padded, n_tiles), n innermost: each stored block (r, c)
accumulates dC[r-tile, n-slice] @ B[c-tile, n-slice]^T over the n slices in a
VMEM accumulator, then stores its [bm, bk] block. Both operand streams are
BlockSpec-driven (scalar-prefetched block indices), double-buffered by
Mosaic — the same TMA-analogue machinery as the forward kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(rows_ref, cols_ref, dc_ref, b_ref, o_ref, acc_ref, *, n_tiles, nnz):
    del rows_ref, cols_ref
    nt = pl.program_id(1)
    i = pl.program_id(0)

    @pl.when(nt == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        dc_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(nt == n_tiles - 1)
    def _store():
        valid = i < nnz  # padding blocks must not produce gradient
        o_ref[0] = jnp.where(valid, acc_ref[...], 0).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "nnz", "bn", "out_dtype", "interpret")
)
def sddmm_kernel(
    block_rows: jax.Array,
    block_cols: jax.Array,
    dc: jax.Array,  # [m, n]
    b: jax.Array,  # [k, n]
    *,
    block: tuple,
    nnz: int,
    bn: int = 512,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    bm, bk = block
    nnz_p = block_rows.shape[0]
    m, n = dc.shape
    if n % bn:
        raise ValueError(f"n={n} must be a multiple of bn={bn}")
    n_tiles = n // bn
    out_dtype = out_dtype or dc.dtype
    return pl.pallas_call(
        functools.partial(_kernel, n_tiles=n_tiles, nnz=nnz),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nnz_p, n_tiles),
            in_specs=[
                pl.BlockSpec((bm, bn), lambda i, nt, rows, cols: (rows[i], nt)),
                pl.BlockSpec((bk, bn), lambda i, nt, rows, cols: (cols[i], nt)),
            ],
            out_specs=pl.BlockSpec((1, bm, bk), lambda i, nt, rows, cols: (i, 0, 0)),
            scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nnz_p, bm, bk), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(block_rows, block_cols, dc, b)
