"""Pure-jnp oracle for block-sampled dense-dense matmul (SDDMM).

dA_blocks[i] = dC[rows_i * bm : (rows_i+1) * bm, :] @ B[cols_i * bk :, :]^T

This is the weight-gradient op for block-sparse layers: only the stored
blocks of the sparse weight receive gradient.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import BCSR


def sddmm_ref(dc: jax.Array, b: jax.Array, a_struct: BCSR, out_dtype=None):
    m, n = dc.shape
    bm, bk = a_struct.block
    dc_tiles = dc.reshape(m // bm, bm, n)[a_struct.block_rows]  # [nnz_p, bm, n]
    b_tiles = b.reshape(b.shape[0] // bk, bk, n)[a_struct.block_cols]
    out = jnp.einsum(
        "zin,zjn->zij", dc_tiles, b_tiles, preferred_element_type=jnp.float32
    )
    nnz = a_struct.nnz_blocks
    valid = (jnp.arange(a_struct.nnz_padded) < nnz)[:, None, None]
    out = jnp.where(valid, out, 0)
    return out.astype(out_dtype or dc.dtype)
