from repro.kernels.wcsr.ops import wcsr_spmm
from repro.kernels.wcsr.ref import wcsr_spmm_ref
