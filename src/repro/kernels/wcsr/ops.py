"""Dispatcher + task-splitting wrapper for WCSR SpMM."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import WCSR, make_wcsr_tasks
from repro.kernels.wcsr.kernel import wcsr_spmm_kernel
from repro.kernels.wcsr.ref import wcsr_spmm_ref

__all__ = ["wcsr_spmm"]


def wcsr_spmm(
    a: WCSR,
    b: jax.Array,
    *,
    impl: str = "auto",
    bn: int = 256,
    chunks_per_task: int = 8,
    out_dtype=None,
    pipeline_gather: bool = False,
) -> jax.Array:
    """C = A_wcsr @ B with window splitting + deterministic combine.

    Note: the kernel path derives the (static) task decomposition from the
    concrete window pointers, so it must be called outside an enclosing jit;
    the ``ref`` path is fully traceable.
    """
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return wcsr_spmm_ref(a, b, out_dtype=out_dtype)
    interpret = impl == "kernel_interpret" or jax.default_backend() != "tpu"

    t_win, t_start, t_n = make_wcsr_tasks(a, chunks_per_task)
    n = b.shape[1]
    bn_eff = min(bn, n) if n >= 128 else n
    pad = -n % bn_eff
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
    partial = wcsr_spmm_kernel(
        jnp.asarray(t_start),
        jnp.asarray(t_n),
        a.col_idx,
        a.values,
        b,
        b_row=a.b_row,
        b_col=a.b_col,
        bn=bn_eff,
        chunks_per_task=chunks_per_task,
        out_dtype=jnp.float32,
        interpret=interpret,
        pipeline_gather=pipeline_gather,
    )  # [T, b_row, n_padded]
    # deterministic combine of split-window partials (atomicAdd analogue)
    out = jax.ops.segment_sum(
        partial, jnp.asarray(t_win), num_segments=a.num_windows
    )
    out = out.reshape(a.shape[0], -1).astype(out_dtype or b.dtype)
    return out[:, :n] if pad else out
