"""DEPRECATED: thin shim forwarding to the unified ``repro.ops`` API."""

from __future__ import annotations

import warnings

import jax

from repro.sparse.formats import WCSR

__all__ = ["wcsr_spmm"]


def wcsr_spmm(
    a: WCSR,
    b: jax.Array,
    *,
    impl: str = "auto",
    bn=None,
    chunks_per_task: int = 8,
    out_dtype=None,
    pipeline_gather: bool = False,
) -> jax.Array:
    """Deprecated alias of ``repro.ops.spmm`` for WCSR operands."""
    warnings.warn(
        "repro.kernels.wcsr.ops.wcsr_spmm is deprecated; use repro.ops.spmm "
        "instead", DeprecationWarning, stacklevel=2)
    from repro.ops import spmm

    return spmm(a, b, impl=impl, bn=bn, chunks_per_task=chunks_per_task,
                out_dtype=out_dtype, pipeline_gather=pipeline_gather)
