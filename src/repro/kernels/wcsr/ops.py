"""DEPRECATED: thin shim forwarding to the unified ``repro.ops`` API."""

from __future__ import annotations

import warnings

import jax

from repro.sparse.formats import WCSR

__all__ = ["wcsr_spmm"]


def wcsr_spmm(
    a: WCSR,
    b: jax.Array,
    *,
    impl: str = "auto",
    bn=None,
    chunks_per_task: int = 8,
    out_dtype=None,
    pipeline_gather: bool = False,
) -> jax.Array:
    """Deprecated alias of ``repro.ops.spmm`` for WCSR operands."""
    warnings.warn(
        "repro.kernels.wcsr.ops.wcsr_spmm is deprecated; use repro.ops.spmm "
        "instead", DeprecationWarning, stacklevel=2)
    from repro.ops import spmm

    # legacy bool -> explicit §III-A depth (2 = the old double buffer);
    # translated here so legacy callers don't also trip the spmm-level
    # pipeline_gather deprecation warning. The default False maps to None
    # (inherit), so an ambient use_config(pipeline_depth=...) still reaches
    # legacy call sites; with no ambient scope the kernel default is the
    # same serial gather as before.
    return spmm(a, b, impl=impl, bn=bn, chunks_per_task=chunks_per_task,
                out_dtype=out_dtype,
                pipeline_depth=2 if pipeline_gather else None)
