"""Pallas TPU kernel for WCSR SpMM (paper §III-B/C, TPU-native).

The defining constraint of WCSR (paper §III-B): the packed A values are
contiguous (bulk-DMA-able, like TMA), but the matching B rows are *indirect*
through ``col_idx`` — an access TMA cannot express, and neither can a
BlockSpec. The paper falls back to a cooperative thread gather; the TPU
analogue implemented here is a **scalar-core-driven row gather**: per packed
column, a ``pltpu.make_async_copy`` DMA from the HBM-resident B (ANY memory
space) into a VMEM scratch, indexed by the scalar-prefetched ``col_idx``.
Like the paper's WCSR kernel, each iteration is load-then-compute within a
single "warpgroup" (no producer/consumer split — §III-C explains why that
does not pay off when the gather occupies all lanes); the contiguous A
stream is still pipelined by Mosaic.

Load balancing (paper §III-C): windows are pre-split into fixed-size tasks of
at most ``chunks_per_task`` packed-column chunks; ``program_id(0)`` indexes
*tasks*, not windows. Partial window outputs land in a [num_tasks, b_row, bn]
buffer and are segment-summed into windows by the wrapper — the deterministic
TPU replacement for the paper's atomicAdd combine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(
    # scalar prefetch
    task_start_ref,  # [T] i32 chunk offset (in b_col units) of each task
    task_nchunks_ref,  # [T] i32 number of active chunks of each task
    col_idx_ref,  # [C] i32 original B row per packed column (-1 pad)
    # operands
    a_ref,  # [b_row, b_col] current packed-value chunk (VMEM)
    b_hbm_ref,  # [k, n] dense B (ANY/HBM — indirectly gathered)
    # output
    o_ref,  # [1, b_row, bn] partial output tile of this task
    # scratch
    gather_ref,  # [b_col, bn] VMEM gather buffer for B rows
    sem,  # DMA semaphore
    acc_ref,  # [b_row, bn] f32 accumulator
    *,
    b_col: int,
    bn: int,
    chunks_per_task: int,
):
    g = pl.program_id(2)
    nt = pl.program_id(1)
    t = pl.program_id(0)

    @pl.when(g == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    active = g < task_nchunks_ref[t]

    @pl.when(active)
    def _gather_and_mac():
        # --- load phase: gather b_col rows of B (cooperative gather analogue)
        base = (task_start_ref[t] + g) * b_col
        copies = []
        for j in range(b_col):  # static unroll: one row DMA per packed column
            src_row = jnp.maximum(col_idx_ref[base + j], 0)
            cp = pltpu.make_async_copy(
                b_hbm_ref.at[pl.ds(src_row, 1), pl.ds(nt * bn, bn)],
                gather_ref.at[pl.ds(j, 1), :],
                sem,
            )
            cp.start()
            copies.append(cp)
        for cp in copies:  # barrier: wait for the whole chunk
            cp.wait()
        # --- compute phase: micro-GEMM on the MXU (WGMMA analogue)
        acc_ref[...] += jnp.dot(
            a_ref[...], gather_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(g == chunks_per_task - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _kernel_db(
    task_start_ref,
    task_nchunks_ref,
    col_idx_ref,
    a_ref,
    b_hbm_ref,
    o_ref,
    gather0_ref,  # double-buffered gather scratch, slot 0
    gather1_ref,  # slot 1
    sem0,
    sem1,
    acc_ref,
    *,
    b_col: int,
    bn: int,
    chunks_per_task: int,
):
    """Beyond-paper variant (EXPERIMENTS.md §Perf): double-buffered gather.

    The paper's WCSR kernel serializes gather -> matmul within each
    iteration (§III-C). On TPU the gather is issued by the single scalar
    core, so serialization costs ~30ns x b_col per chunk. Here chunk g+1's
    row DMAs are issued *before* computing chunk g, overlapping the gather
    with the MXU — the producer/consumer idea of the paper's BCSR pipeline
    applied to the indirect operand.
    """
    g = pl.program_id(2)
    nt = pl.program_id(1)
    t = pl.program_id(0)
    nchunks = task_nchunks_ref[t]

    def copies_for(chunk, buf, sem):
        base = (task_start_ref[t] + chunk) * b_col
        out = []
        for j in range(b_col):
            src_row = jnp.maximum(col_idx_ref[base + j], 0)
            out.append(pltpu.make_async_copy(
                b_hbm_ref.at[pl.ds(src_row, 1), pl.ds(nt * bn, bn)],
                buf.at[pl.ds(j, 1), :],
                sem,
            ))
        return out

    @pl.when(g == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(g == 0, nchunks > 0))
    def _prime():  # issue chunk 0's gather (slot 0)
        for cp in copies_for(0, gather0_ref, sem0):
            cp.start()

    active = g < nchunks
    even = (g % 2) == 0

    # producer: issue chunk g+1 into the other slot while g is in flight
    @pl.when(jnp.logical_and(active, jnp.logical_and(g + 1 < nchunks, even)))
    def _prefetch_odd():
        for cp in copies_for(g + 1, gather1_ref, sem1):
            cp.start()

    @pl.when(jnp.logical_and(active,
                             jnp.logical_and(g + 1 < nchunks,
                                             jnp.logical_not(even))))
    def _prefetch_even():
        for cp in copies_for(g + 1, gather0_ref, sem0):
            cp.start()

    # consumer: wait for chunk g's slot, then MXU
    @pl.when(jnp.logical_and(active, even))
    def _consume_even():
        for cp in copies_for(g, gather0_ref, sem0):
            cp.wait()
        acc_ref[...] += jnp.dot(
            a_ref[...], gather0_ref[...], preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(active, jnp.logical_not(even)))
    def _consume_odd():
        for cp in copies_for(g, gather1_ref, sem1):
            cp.wait()
        acc_ref[...] += jnp.dot(
            a_ref[...], gather1_ref[...], preferred_element_type=jnp.float32)

    @pl.when(g == chunks_per_task - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "b_row",
        "b_col",
        "bn",
        "chunks_per_task",
        "out_dtype",
        "interpret",
        "pipeline_gather",
    ),
)
def wcsr_spmm_kernel(
    task_start: jax.Array,  # [T] i32
    task_nchunks: jax.Array,  # [T] i32
    col_idx: jax.Array,  # [C] i32
    values: jax.Array,  # [b_row, C]
    b: jax.Array,  # [k, n], n multiple of bn
    *,
    b_row: int,
    b_col: int,
    bn: int,
    chunks_per_task: int,
    out_dtype=None,
    interpret: bool = True,
    pipeline_gather: bool = False,
) -> jax.Array:
    num_tasks = task_start.shape[0]
    k, n = b.shape
    if n % bn:
        raise ValueError(f"n={n} must be a multiple of bn={bn}")
    out_dtype = out_dtype or b.dtype
    grid = (num_tasks, n // bn, chunks_per_task)
    if pipeline_gather:
        body = functools.partial(
            _kernel_db, b_col=b_col, bn=bn, chunks_per_task=chunks_per_task)
        scratch = [
            pltpu.VMEM((b_col, bn), b.dtype),
            pltpu.VMEM((b_col, bn), b.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.VMEM((b_row, bn), jnp.float32),
        ]
    else:
        body = functools.partial(
            _kernel, b_col=b_col, bn=bn, chunks_per_task=chunks_per_task)
        scratch = [
            pltpu.VMEM((b_col, bn), b.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.VMEM((b_row, bn), jnp.float32),
        ]
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                # contiguous packed-value chunk: TMA-analogue BlockSpec stream.
                # Clamped so inactive tail chunks (g >= nchunks, compute
                # masked) never index past the packed array.
                pl.BlockSpec(
                    (b_row, b_col),
                    lambda t, nt, g, ts, tn, ci: (
                        0,
                        jnp.minimum(ts[t] + g, values.shape[1] // b_col - 1),
                    ),
                ),
                # B stays in HBM; gathered manually inside the kernel
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec(
                (1, b_row, bn), lambda t, nt, g, ts, tn, ci: (t, 0, nt)
            ),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((num_tasks, b_row, n), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(task_start, task_nchunks, col_idx, values, b)
