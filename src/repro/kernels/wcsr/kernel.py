"""Pallas TPU kernel for WCSR SpMM (paper §III-B/C, TPU-native).

The defining constraint of WCSR (paper §III-B): the packed A values are
contiguous (bulk-DMA-able, like TMA), but the matching B rows are *indirect*
through ``col_idx`` — an access TMA cannot express, and neither can a
BlockSpec. The paper falls back to a cooperative thread gather; the TPU
analogue implemented here is a **scalar-core-driven row gather**: per packed
column, a ``pltpu.make_async_copy`` DMA from the HBM-resident B (ANY memory
space) into a VMEM scratch, indexed by the scalar-prefetched ``col_idx``.

The gather runs through the shared Q-deep producer/consumer emitter
(``repro.kernels.pipeline``, paper §III-A):

* ``pipeline_depth=1`` — load-then-compute within each step, the paper's
  WCSR choice (§III-C explains why a producer/consumer split does not pay
  off when the gather occupies all lanes);
* ``pipeline_depth=2`` — the double-buffered gather (formerly the
  ``pipeline_gather`` flag): chunk ``g+1``'s row DMAs are in flight while
  chunk ``g`` runs on the MXU — the producer/consumer idea of the paper's
  BCSR pipeline applied to the indirect operand;
* ``pipeline_depth=3`` — the paper's Q=3 circular buffer.

All depths share one kernel body; the emitter generates the
prime/produce/consume/drain phases, so there are no per-slot (even/odd)
branch copies. The contiguous A stream is still pipelined by Mosaic.

Value codecs: when the packed A values arrive quantized
(``repro.sparse.codecs`` — int8 / emulated fp8 with one f32 scale per
packed-column chunk), the scale streams in lock-step with its payload
chunk and the consumer body dequantizes in-register
(``pipeline.dequant_tile``) before the micro-GEMM. Because every depth
shares the one consumer body, one hook covers the serial, double-buffered
and Q-deep gathers alike; A's DMA traffic is the compressed payload.

Load balancing (paper §III-C): windows are pre-split into fixed-size tasks of
at most ``chunks_per_task`` packed-column chunks; ``program_id(0)`` indexes
*tasks*, not windows. Partial window outputs land in a [num_tasks, b_row, bn]
buffer and are segment-summed into windows by the wrapper — the deterministic
TPU replacement for the paper's atomicAdd combine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.pipeline import (dequant_tile, emit_gather_pipeline,
                                    gather_slots, validate_depth)


def _kernel(
    # scalar prefetch
    task_start_ref,  # [T] i32 chunk offset (in b_col units) of each task
    task_nchunks_ref,  # [T] i32 number of active chunks of each task
    col_idx_ref,  # [C] i32 original B row per packed column (-1 pad)
    # operands
    a_ref,  # [b_row, b_col] current packed-value chunk (VMEM; codec payload)
    *rest,  # [s_ref (codec only)], b_hbm_ref, o_ref, gather_ref, sem, acc_ref
    b_col: int,
    bn: int,
    chunks_per_task: int,
    depth: int,
    codec: str = "none",
):
    if codec == "none":
        b_hbm_ref, o_ref, gather_ref, sem, acc_ref = rest
        s_ref = None
    else:
        s_ref, b_hbm_ref, o_ref, gather_ref, sem, acc_ref = rest
    g = pl.program_id(2)
    nt = pl.program_id(1)
    t = pl.program_id(0)
    nchunks = task_nchunks_ref[t]
    num_cols = col_idx_ref.shape[0]

    @pl.when(g == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def copies(chunk, slot):
        # --- load phase: gather b_col rows of B (cooperative gather
        # analogue). The emitter probes lookahead chunks past the task end;
        # clamp the col_idx loads (and -1 padding) to a safe row.
        base = (task_start_ref[t] + chunk) * b_col
        out = []
        for j in range(b_col):  # static unroll: one row DMA per packed column
            idx = jnp.minimum(base + j, num_cols - 1)
            src_row = jnp.maximum(col_idx_ref[idx], 0)
            out.append(pltpu.make_async_copy(
                b_hbm_ref.at[pl.ds(src_row, 1), pl.ds(nt * bn, bn)],
                gather_ref.at[slot, pl.ds(j, 1), :],
                sem.at[slot],
            ))
        return out

    def compute(chunk, slot):
        del chunk  # a_ref already holds this step's packed-value chunk
        # --- compute phase: micro-GEMM on the MXU (WGMMA analogue).
        # One dequant hook covers every pipeline depth: the emitter calls
        # this consumer body whether the gather was serial, double- or
        # Q-buffered, so the per-chunk scale is applied in-register right
        # here and the DMA side only ever moved the compressed payload.
        a = dequant_tile(a_ref[...], codec,
                         None if s_ref is None else s_ref[0, 0])
        acc_ref[...] += jnp.dot(
            a, gather_ref[slot], preferred_element_type=jnp.float32
        )

    emit_gather_pipeline(step=g, nchunks=nchunks, depth=depth,
                         copies=copies, compute=compute)

    @pl.when(g == chunks_per_task - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _spmv_kernel(
    # scalar prefetch
    task_start_ref,  # [T] i32 chunk offset (in b_col units) of each task
    task_nchunks_ref,  # [T] i32 number of active chunks of each task
    col_idx_ref,  # [C] i32 original B row per packed column (-1 pad)
    *rest,  # v_hbm, [s_hbm], b_ref, o_ref, val_slots, [s_slots], sem, acc
    b_col: int,
    chunks_per_task: int,
    depth: int,
    codec: str,
    nchunks_total: int,
):
    if codec == "none":
        v_hbm_ref, b_ref, o_ref, val_ref, sem, acc_ref = rest
        s_hbm_ref = s_ref = None
    else:
        (v_hbm_ref, s_hbm_ref, b_ref, o_ref, val_ref, s_ref, sem,
         acc_ref) = rest
    g = pl.program_id(1)
    t = pl.program_id(0)
    nchunks = task_nchunks_ref[t]
    num_cols = col_idx_ref.shape[0]

    @pl.when(g == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def copies(chunk, slot):
        # --- load phase: the *values* stream is the pipelined operand here
        # (the spmm kernel pipelines the B-row gather instead). One payload
        # DMA (+ its scale under a codec) per chunk, vs b_col row DMAs on
        # the full-tile path. Lookahead chunks past the task end are
        # clamped to a safe chunk.
        c = jnp.minimum(task_start_ref[t] + chunk, nchunks_total - 1)
        out = [pltpu.make_async_copy(
            v_hbm_ref.at[:, pl.ds(c * b_col, b_col)],
            val_ref.at[slot],
            sem.at[slot],
        )]
        if s_hbm_ref is not None:
            out.append(pltpu.make_async_copy(
                s_hbm_ref.at[:, pl.ds(c, 1)],
                s_ref.at[slot],
                sem.at[slot],
            ))
        return out

    def compute(chunk, slot):
        # --- compute phase: row-split multiply-accumulate (VPU GEMV
        # analogue) instead of a bn-wide MXU tile. B is VMEM-resident (the
        # whole skinny operand is one tile), so the gather is an in-register
        # dynamic row read per packed column — no per-row DMA at all.
        a = dequant_tile(val_ref[slot], codec,
                         None if s_ref is None else s_ref[slot][0, 0])
        base = (task_start_ref[t] + chunk) * b_col
        rows = []
        for j in range(b_col):  # static unroll over packed columns
            idx = jnp.minimum(base + j, num_cols - 1)
            src_row = jnp.maximum(col_idx_ref[idx], 0)
            rows.append(b_ref[pl.ds(src_row, 1), :])
        gmat = jnp.concatenate(rows, axis=0)  # [b_col, n]
        acc_ref[...] += jnp.sum(
            a.astype(jnp.float32)[:, :, None]
            * gmat.astype(jnp.float32)[None, :, :],
            axis=1)

    emit_gather_pipeline(step=g, nchunks=nchunks, depth=depth,
                         copies=copies, compute=compute)

    @pl.when(g == chunks_per_task - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "b_row",
        "b_col",
        "chunks_per_task",
        "out_dtype",
        "interpret",
        "pipeline_depth",
        "codec",
    ),
)
def wcsr_spmv_kernel(
    task_start: jax.Array,  # [T] i32
    task_nchunks: jax.Array,  # [T] i32
    col_idx: jax.Array,  # [C] i32
    values: jax.Array,  # [b_row, C] (codec payload when quantized)
    b: jax.Array,  # [k, n], n skinny (decode activations; no bn tiling)
    scales: jax.Array = None,  # [1, C // b_col] f32 per-chunk codec scales
    *,
    b_row: int,
    b_col: int,
    chunks_per_task: int,
    out_dtype=None,
    interpret: bool = True,
    pipeline_depth: int = 1,
    codec: str = "none",
) -> jax.Array:
    """Skinny-N (SpMV/GEMV) variant of :func:`wcsr_spmm_kernel`.

    For decode-shaped RHS (n of a few columns) the full-tile kernel wastes
    the entire ``bn`` tile on one activation vector and pays ``b_col`` row
    DMAs per chunk for a B operand that trivially fits VMEM. This body
    flips the dataflow: B stays resident in VMEM (one tile = the whole
    operand, gathered in-register per packed column), while the contiguous
    packed-*values* stream becomes the pipelined operand — one payload DMA
    per chunk through the same §III-A Q-deep emitter, with the same
    per-chunk ``dequant_tile`` codec hook. The MMA tile is replaced by a
    row-split multiply-accumulate (the SpMV row-split form of Yang et
    al.), and the §III-C task split / segment-sum combine are unchanged.
    """
    depth = validate_depth(pipeline_depth)
    num_tasks = task_start.shape[0]
    k, n = b.shape
    if codec != "none" and scales is None:
        raise ValueError(f"wcsr_spmv_kernel: codec {codec!r} needs scales")
    out_dtype = out_dtype or b.dtype
    nchunks_total = values.shape[1] // b_col
    grid = (num_tasks, chunks_per_task)
    body = functools.partial(
        _spmv_kernel, b_col=b_col, chunks_per_task=chunks_per_task,
        depth=depth, codec=codec, nchunks_total=nchunks_total)
    val_slots, sems = gather_slots(depth, (b_row, b_col), values.dtype)
    # values (and scales) live in HBM; the emitter DMAs them chunk by chunk
    in_specs = [pl.BlockSpec(memory_space=pl.ANY)]
    operands = [values]
    scratch = [val_slots]
    if codec != "none":
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
        operands.append(scales)
        s_slots, _ = gather_slots(depth, (1, 1), scales.dtype)
        scratch.append(s_slots)
    # the skinny B is one resident VMEM tile — no bn tiling dimension
    in_specs.append(pl.BlockSpec((k, n), lambda t, g, ts, tn, ci: (0, 0)))
    operands.append(b)
    scratch += [sems, pltpu.VMEM((b_row, n), jnp.float32)]
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, b_row, n), lambda t, g, ts, tn, ci: (t, 0, 0)
            ),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((num_tasks, b_row, n), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(task_start, task_nchunks, col_idx, *operands)


@functools.partial(
    jax.jit,
    static_argnames=(
        "b_row",
        "b_col",
        "bn",
        "chunks_per_task",
        "out_dtype",
        "interpret",
        "pipeline_depth",
        "codec",
    ),
)
def wcsr_spmm_kernel(
    task_start: jax.Array,  # [T] i32
    task_nchunks: jax.Array,  # [T] i32
    col_idx: jax.Array,  # [C] i32
    values: jax.Array,  # [b_row, C] (codec payload when quantized)
    b: jax.Array,  # [k, n], n multiple of bn
    scales: jax.Array = None,  # [1, C // b_col] f32 per-chunk codec scales
    *,
    b_row: int,
    b_col: int,
    bn: int,
    chunks_per_task: int,
    out_dtype=None,
    interpret: bool = True,
    pipeline_depth: int = 1,
    codec: str = "none",
) -> jax.Array:
    depth = validate_depth(pipeline_depth)
    num_tasks = task_start.shape[0]
    k, n = b.shape
    if n % bn:
        raise ValueError(f"n={n} must be a multiple of bn={bn}")
    if codec != "none" and scales is None:
        raise ValueError(f"wcsr_spmm_kernel: codec {codec!r} needs scales")
    out_dtype = out_dtype or b.dtype
    grid = (num_tasks, n // bn, chunks_per_task)
    body = functools.partial(
        _kernel, b_col=b_col, bn=bn, chunks_per_task=chunks_per_task,
        depth=depth, codec=codec)
    slots, sems = gather_slots(depth, (b_col, bn), b.dtype)
    nchunks_total = values.shape[1] // b_col
    in_specs = [
        # contiguous packed-value chunk: TMA-analogue BlockSpec stream.
        # Clamped so inactive tail chunks (g >= nchunks, compute
        # masked) never index past the packed array.
        pl.BlockSpec(
            (b_row, b_col),
            lambda t, nt, g, ts, tn, ci: (
                0,
                jnp.minimum(ts[t] + g, nchunks_total - 1),
            ),
        ),
    ]
    operands = [values]
    if codec != "none":
        # the chunk's f32 scale streams in lock-step with its payload
        in_specs.append(pl.BlockSpec(
            (1, 1),
            lambda t, nt, g, ts, tn, ci: (
                0, jnp.minimum(ts[t] + g, nchunks_total - 1)),
        ))
        operands.append(scales)
    # B stays in HBM; gathered manually inside the kernel
    in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
    operands.append(b)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, b_row, bn), lambda t, nt, g, ts, tn, ci: (t, 0, nt)
            ),
            scratch_shapes=[
                slots,
                sems,
                pltpu.VMEM((b_row, bn), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((num_tasks, b_row, n), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(task_start, task_nchunks, col_idx, *operands)
