"""Pure-jnp oracle for WCSR SpMM: C = A_wcsr @ B.

Gather the B rows named by ``col_idx`` (clamped; padding columns have zero
values so their contribution vanishes), multiply with the packed column
vectors, segment-sum packed columns into their windows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import WCSR


def wcsr_spmm_ref(a: WCSR, b: jax.Array, out_dtype=None) -> jax.Array:
    m, k = a.shape
    if b.shape[0] != k:
        raise ValueError(f"A {a.shape} @ B {b.shape}: inner dims differ")
    n = b.shape[1]
    out_dtype = out_dtype or b.dtype
    idx = jnp.maximum(a.col_idx, 0)  # padding cols gather row 0, values are 0
    b_rows = b[idx]  # [C, n]
    # window of each packed column
    win = jnp.searchsorted(a.window_ptr, jnp.arange(a.padded_cols), side="right") - 1
    win = jnp.clip(win, 0, a.num_windows - 1)
    # per-column outer products summed per window:
    # out[w, r, n] = sum_{c in w} values[r, c] * b_rows[c, n]
    contrib = jnp.einsum(
        "rc,cn->crn", a.values, b_rows, preferred_element_type=jnp.float32
    )
    out = jax.ops.segment_sum(contrib, win, num_segments=a.num_windows)
    return out.reshape(m, n).astype(out_dtype)


def wcsr_spmm_dense_ref(a: WCSR, b: jax.Array, out_dtype=None) -> jax.Array:
    """Second, independent oracle: densify then matmul."""
    from repro.sparse.formats import wcsr_to_dense

    dense = wcsr_to_dense(a)
    return jnp.dot(dense, b, preferred_element_type=jnp.float32).astype(
        out_dtype or b.dtype
    )
