"""Tile-size selection (paper §IV-C, adapted to TPU).

The paper's free parameter WGMMA_N (-> BN = 2*WGMMA_N) maps to our kernel's
``bn`` (output-tile width). The paper's §IV-C findings transfer directly:

* larger bn amortizes per-step DMA + grid overhead and raises useful work
  per loaded A block;
* bn that doesn't divide N forces zero-padding waste proportional to
  (ceil(N/bn)*bn - N)/N;
* the resource ceiling is VMEM (their register/SMEM occupancy analogue):
  Q-stage double buffers of the A block and B tile plus the f32 accumulator
  must fit.

``select_bn`` implements the paper's final policy: the largest candidate
that divides N, subject to the VMEM budget; otherwise minimize padding waste.
"""

from __future__ import annotations

VMEM_BYTES = 16 * 1024 * 1024  # v5e per-core VMEM
DEFAULT_STAGES = 2  # Mosaic double buffering


def vmem_usage(bm: int, bk: int, bn: int, dtype_bytes: int = 2,
               stages: int = DEFAULT_STAGES) -> int:
    a = stages * bm * bk * dtype_bytes
    b = stages * bk * bn * dtype_bytes
    acc = bm * bn * 4
    out = bm * bn * dtype_bytes
    return a + b + acc + out


def padding_waste(n: int, bn: int) -> float:
    padded = -(-n // bn) * bn
    return (padded - n) / padded


def select_bn(
    n: int,
    bm: int = 128,
    bk: int = 128,
    dtype_bytes: int = 2,
    candidates=(1024, 512, 384, 256, 128),
    vmem_budget: int = VMEM_BYTES,
) -> int:
    """Paper §IV-C policy: max bn dividing N within the VMEM budget."""
    fitting = [
        c
        for c in candidates
        if vmem_usage(bm, bk, c, dtype_bytes) <= vmem_budget and c <= max(n, 128)
    ]
    if not fitting:
        return 128
    divisors = [c for c in fitting if n % c == 0]
    if divisors:
        return max(divisors)
    # no exact divisor: pick the candidate minimizing padding waste, ties to
    # the larger tile (amortization wins, §IV-C Fig. 7)
    return min(fitting, key=lambda c: (padding_waste(n, c), -c))
