"""Pure-jnp oracle for BCSR SpMM: C[m,n] = A_bcsr[m,k] @ B[k,n].

This is also the "dense-compute path" used by the distributed models in the
dry-run: gather B tiles by block column, batched micro-GEMM, segment-sum by
block row. Its FLOP/byte footprint matches the Pallas kernel's, so roofline
terms derived from it are representative of the kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import BCSR


def bcsr_spmm_ref(a: BCSR, b: jax.Array, out_dtype=None) -> jax.Array:
    """Reference SpMM via gather + einsum + segment-sum."""
    m, k = a.shape
    if b.shape[0] != k:
        raise ValueError(f"A {a.shape} @ B {b.shape}: inner dims differ")
    n = b.shape[1]
    bm, bk = a.block
    mb = m // bm
    out_dtype = out_dtype or b.dtype
    b_tiles = b.reshape(k // bk, bk, n)[a.block_cols]  # [nnz_p, bk, n]
    partial = jnp.einsum(
        "zij,zjn->zin", a.blocks, b_tiles, preferred_element_type=jnp.float32
    )  # [nnz_p, bm, n]
    out = jax.ops.segment_sum(partial, a.block_rows, num_segments=mb)
    return out.reshape(m, n).astype(out_dtype)


def bcsr_spmm_dense_ref(a: BCSR, b: jax.Array, out_dtype=None) -> jax.Array:
    """Second, independent oracle: densify then matmul."""
    from repro.sparse.formats import bcsr_to_dense

    dense = bcsr_to_dense(a)
    out = jnp.dot(dense, b, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or b.dtype)


def sddmm_ref(
    dc: jax.Array, b: jax.Array, a_struct: BCSR, out_dtype=None
) -> jax.Array:
    """Sampled dense-dense: dA_blocks[i] = dC[rows_i-tile] @ B[cols_i-tile]^T.

    Used for the weight gradient of block-sparse layers. Returns
    [nnz_padded, bm, bk] block values matching ``a_struct``'s layout.
    """
    m, n = dc.shape
    bm, bk = a_struct.block
    dc_tiles = dc.reshape(m // bm, bm, n)[a_struct.block_rows]  # [nnz_p, bm, n]
    b_tiles = b.reshape(b.shape[0] // bk, bk, n)[a_struct.block_cols]
    out = jnp.einsum(
        "zin,zjn->zij", dc_tiles, b_tiles, preferred_element_type=jnp.float32
    )
    # zero the padding entries so they never leak into parameter updates
    nnz = a_struct.nnz_blocks
    valid = (jnp.arange(a_struct.nnz_padded) < nnz)[:, None, None]
    out = jnp.where(valid, out, 0)
    return out.astype(out_dtype or dc.dtype)
