from repro.kernels.bcsr.ops import bcsr_spmm, bcsr_matmul
from repro.kernels.bcsr.ref import bcsr_spmm_ref
