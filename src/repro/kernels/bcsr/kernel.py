"""Pallas TPU kernel for BCSR SpMM (paper §III-A..C, TPU-native).

Hopper mapping (see DESIGN.md §2):
  * TMA descriptor loads of A blocks / B tiles  -> BlockSpec index_maps driven
    by scalar-prefetched ``block_rows``/``block_cols`` (data-dependent DMA).
  * WGMMA m64nBNk16                             -> MXU ``jnp.dot`` on
    (b_row, b_col) x (b_col, bn) tiles, f32 accumulation.
  * producer/consumer circular buffer (Q=3)     -> Mosaic's automatic
    multi-buffered grid pipeline (DMA of step i+1 overlaps compute of step i).
  * ScaleD=0 accumulator zero-elision (opt5)    -> ``@pl.when(row-start)``
    zero-init of the VMEM accumulator.

Grid = (n_tiles, nnz_padded_blocks); the nnz dimension is innermost so all
blocks of one block-row revisit the same output tile consecutively and the
accumulator stays resident in VMEM (the paper's register-resident C).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(
    rows_ref,  # [nnz_p] i32, scalar prefetch
    cols_ref,  # [nnz_p] i32, scalar prefetch
    a_ref,  # [1, bm, bk] current A block (VMEM)
    b_ref,  # [bk, bn]   current B tile (VMEM)
    o_ref,  # [bm, bn]   output tile (VMEM, revisited per block-row)
    acc_ref,  # [bm, bn] f32 scratch accumulator
    *,
    nnz_total: int,
):
    del cols_ref  # only used by the index_maps
    i = pl.program_id(1)
    row = rows_ref[i]
    prev_row = rows_ref[jnp.maximum(i - 1, 0)]
    next_row = rows_ref[jnp.minimum(i + 1, nnz_total - 1)]
    is_first = jnp.logical_or(i == 0, row != prev_row)
    is_last = jnp.logical_or(i == nnz_total - 1, row != next_row)

    @pl.when(is_first)
    def _zero():  # the paper's ScaleD=0 on the first WGMMA of a row
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(is_last)
    def _store():  # TMA bulk store analogue: single write per output tile
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("m_blocks", "block", "bn", "out_dtype", "interpret"),
)
def bcsr_spmm_kernel(
    block_rows: jax.Array,  # [nnz_p] i32 (sorted; padding repeats last row)
    block_cols: jax.Array,  # [nnz_p] i32
    blocks: jax.Array,  # [nnz_p, bm, bk]
    b: jax.Array,  # [k, n] dense, n a multiple of bn
    *,
    m_blocks: int,
    block: tuple,
    bn: int = 512,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    bm, bk = block
    nnz_p = blocks.shape[0]
    _, n = b.shape
    if n % bn:
        raise ValueError(f"n={n} must be padded to a multiple of bn={bn}")
    out_dtype = out_dtype or b.dtype
    return pl.pallas_call(
        functools.partial(_kernel, nnz_total=nnz_p),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n // bn, nnz_p),
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda nt, i, rows, cols: (i, 0, 0)),
                pl.BlockSpec((bk, bn), lambda nt, i, rows, cols: (cols[i], nt)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda nt, i, rows, cols: (rows[i], nt)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m_blocks * bm, n), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_rows, block_cols, blocks, b)


def run_bcsr_spmm(
    a_struct,
    b: jax.Array,
    *,
    bn: int = 512,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """Convenience entry: takes a BCSR pytree, handles N padding."""
    bm, _ = a_struct.block
    m, _ = a_struct.shape
    n = b.shape[1]
    bn_eff = min(bn, n) if n >= 128 else n
    n_pad = -n % bn_eff
    if n_pad:
        b = jnp.pad(b, ((0, 0), (0, n_pad)))
    out = bcsr_spmm_kernel(
        a_struct.block_rows,
        a_struct.block_cols,
        a_struct.blocks,
        b,
        m_blocks=m // bm,
        block=a_struct.block,
        bn=bn_eff,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:, :n] if n_pad else out
