"""DEPRECATED: thin shims forwarding to the unified ``repro.ops`` API.

``bcsr_spmm`` is now ``repro.ops.spmm`` (format-polymorphic) and
``bcsr_matmul`` / ``BCSRStructure`` / ``structure_of`` live in
``repro.ops``. These wrappers keep old call sites working and emit a
``DeprecationWarning`` on use.
"""

from __future__ import annotations

import warnings

import jax

from repro.sparse.formats import BCSR

__all__ = ["bcsr_spmm", "BCSRStructure", "structure_of", "bcsr_matmul"]


def bcsr_spmm(a: BCSR, b: jax.Array, *, impl: str = "auto", bn=None,
              out_dtype=None) -> jax.Array:
    """Deprecated alias of ``repro.ops.spmm`` for BCSR operands."""
    # inline warn with stacklevel=2, like the other three shims, so the
    # warning points at the caller (a helper would need stacklevel=3)
    warnings.warn(
        "repro.kernels.bcsr.ops.bcsr_spmm is deprecated; use repro.ops.spmm "
        "instead", DeprecationWarning, stacklevel=2)
    from repro.ops import spmm

    return spmm(a, b, impl=impl, bn=bn, out_dtype=out_dtype)


def bcsr_matmul(values, b, structure, impl="auto"):
    """Deprecated alias of ``repro.ops.bcsr_matmul`` (still differentiable)."""
    warnings.warn(
        "repro.kernels.bcsr.ops.bcsr_matmul is deprecated; use "
        "repro.ops.bcsr_matmul instead", DeprecationWarning, stacklevel=2)
    from repro.ops import bcsr_matmul as _bcsr_matmul

    return _bcsr_matmul(values, b, structure, impl)


_MOVED = {"BCSRStructure", "structure_of", "_as_bcsr"}


def __getattr__(name):
    # lazy forwarding avoids an import cycle during repro.ops package init
    if name in _MOVED:
        from repro.ops import matmul

        return getattr(matmul, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
