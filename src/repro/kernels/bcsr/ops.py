"""Jitted + differentiable wrappers around the BCSR SpMM kernel.

Two entry points:

* ``bcsr_spmm(a, b)`` — inference-style op on a ``BCSR`` pytree. Dispatches
  to the Pallas kernel (interpret mode on CPU) or the jnp reference.
* ``bcsr_matmul(values, b, structure)`` — training-style op with a
  ``custom_vjp``: the sparse *structure* (block indices) is static, the block
  *values* are a differentiable parameter. Backward computes
  ``dB = A^T @ dC`` (transposed-structure SpMM) and
  ``dvalues = SDDMM(dC, B)`` sampled at the stored blocks.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BCSR
from repro.kernels.bcsr import ref as bcsr_ref
from repro.kernels.bcsr.kernel import run_bcsr_spmm

__all__ = ["bcsr_spmm", "BCSRStructure", "structure_of", "bcsr_matmul"]


def _default_impl() -> str:
    # Pallas-Mosaic kernels only lower on TPU; CPU uses interpret for tests
    # and the jnp reference for anything perf-sensitive or distributed.
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def bcsr_spmm(
    a: BCSR, b: jax.Array, *, impl: str = "auto", bn: int = 512, out_dtype=None
) -> jax.Array:
    """C[m,n] = A_bcsr @ B. ``impl`` in {auto, kernel, kernel_interpret, ref}."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "ref":
        return bcsr_ref.bcsr_spmm_ref(a, b, out_dtype=out_dtype)
    interpret = impl == "kernel_interpret" or jax.default_backend() != "tpu"
    return run_bcsr_spmm(a, b, bn=bn, out_dtype=out_dtype, interpret=interpret)


# ---------------------------------------------------------------------------
# Differentiable op over static structure
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BCSRStructure:
    """Host-side (static) BCSR structure + its transpose, hashable by content.

    Kept out of the pytree on purpose: autodiff and pjit only ever see the
    block *values*; index arrays are embedded as constants.
    """

    shape: Tuple[int, int]
    block: Tuple[int, int]
    nnz_blocks: int
    rows: tuple  # tuple[int] for hashability
    cols: tuple
    # transposed structure: rows_t sorted ascending, every block-row of A^T
    # covered (coverage entries have src_t == -1 -> zero block values)
    rows_t: tuple
    cols_t: tuple
    src_t: tuple  # index into values, or -1 for inserted zero coverage block

    @property
    def nnz_padded(self) -> int:
        return len(self.rows)

    def rows_a(self):
        return jnp.asarray(np.asarray(self.rows, np.int32))

    def cols_a(self):
        return jnp.asarray(np.asarray(self.cols, np.int32))


def structure_of(a: BCSR) -> BCSRStructure:
    """Extract the static structure (and transpose permutation) of a BCSR."""
    rows = np.asarray(jax.device_get(a.block_rows), np.int32)
    cols = np.asarray(jax.device_get(a.block_cols), np.int32)
    nnz = a.nnz_blocks
    kb = a.shape[1] // a.block[1]
    # transposed entries: (row_t=col, col_t=row, src=value index)
    entries = [(int(cols[i]), int(rows[i]), i) for i in range(nnz)]
    present = {int(c) for c in cols[:nnz]}
    # cover empty block-rows of A^T so the kernel zero-fills them (the GPU
    # kernel's C-initialization analogue; see bcsr_from_mask)
    entries += [(r, 0, -1) for r in range(kb) if r not in present]
    entries.sort(key=lambda e: (e[0], e[1]))
    return BCSRStructure(
        shape=a.shape,
        block=a.block,
        nnz_blocks=nnz,
        rows=tuple(int(x) for x in rows),
        cols=tuple(int(x) for x in cols),
        rows_t=tuple(e[0] for e in entries),
        cols_t=tuple(e[1] for e in entries),
        src_t=tuple(e[2] for e in entries),
    )


def _as_bcsr(values: jax.Array, s: BCSRStructure, transposed: bool = False) -> BCSR:
    if transposed:
        src = np.asarray(s.src_t, np.int32)
        take = jnp.asarray(np.maximum(src, 0))
        vals = values[take].transpose(0, 2, 1)
        vals = jnp.where((src >= 0)[:, None, None], vals, 0)
        rows = np.asarray(s.rows_t, np.int32)
        cols = np.asarray(s.cols_t, np.int32)
        shape = (s.shape[1], s.shape[0])
        block = (s.block[1], s.block[0])
        nnz = len(rows)  # all entries (incl. coverage zeros) are "real"
    else:
        vals, shape, block = values, s.shape, s.block
        rows = np.asarray(s.rows, np.int32)
        cols = np.asarray(s.cols, np.int32)
        nnz = s.nnz_blocks
    mb = shape[0] // block[0]
    ptr = np.zeros(mb + 1, np.int32)
    np.add.at(ptr, rows[:nnz] + 1, 1)
    ptr = np.cumsum(ptr).astype(np.int32)
    return BCSR(
        blocks=vals,
        block_rows=jnp.asarray(rows),
        block_cols=jnp.asarray(cols),
        block_row_ptr=jnp.asarray(ptr),
        shape=shape,
        block=block,
        nnz_blocks=nnz,
    )


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def bcsr_matmul(
    values: jax.Array, b: jax.Array, structure: BCSRStructure, impl: str = "auto"
) -> jax.Array:
    """Differentiable C = A_bcsr(values; structure) @ B."""
    return bcsr_spmm(_as_bcsr(values, structure), b, impl=impl)


def _fwd(values, b, structure, impl):
    return bcsr_matmul(values, b, structure, impl), (values, b)


def _bwd(structure, impl, res, dc):
    values, b = res
    dc = dc.astype(jnp.float32)
    # dB = A^T @ dC  (transposed-structure SpMM; paper's format is closed
    # under transposition given the static permutation)
    at = _as_bcsr(values.astype(jnp.float32), structure, transposed=True)
    db = bcsr_spmm(at, dc, impl="ref" if impl == "ref" else impl).astype(b.dtype)
    # dvalues = SDDMM(dC, B) sampled at the stored blocks
    from repro.kernels.sddmm.ops import sddmm

    dvals = sddmm(dc, b.astype(jnp.float32), _as_bcsr(values, structure), impl=impl)
    return dvals.astype(values.dtype), db


bcsr_matmul.defvjp(_fwd, _bwd)
