"""DEPRECATED: thin shims forwarding to the unified ``repro.ops`` API.

``bcsr_spmm`` is now ``repro.ops.spmm`` (format-polymorphic) and
``bcsr_matmul`` / ``BCSRStructure`` / ``structure_of`` live in
``repro.ops``. These wrappers keep old call sites working and emit a
``DeprecationWarning`` on use.
"""

from __future__ import annotations

import warnings

import jax

from repro.core.formats import BCSR

__all__ = ["bcsr_spmm", "BCSRStructure", "structure_of", "bcsr_matmul"]


def _warn(old: str, new: str) -> None:
    warnings.warn(
        f"repro.kernels.bcsr.ops.{old} is deprecated; use {new} instead",
        DeprecationWarning, stacklevel=3)


def bcsr_spmm(a: BCSR, b: jax.Array, *, impl: str = "auto", bn=None,
              out_dtype=None) -> jax.Array:
    """Deprecated alias of ``repro.ops.spmm`` for BCSR operands."""
    _warn("bcsr_spmm", "repro.ops.spmm")
    from repro.ops import spmm

    return spmm(a, b, impl=impl, bn=bn, out_dtype=out_dtype)


def bcsr_matmul(values, b, structure, impl="auto"):
    """Deprecated alias of ``repro.ops.bcsr_matmul`` (still differentiable)."""
    _warn("bcsr_matmul", "repro.ops.bcsr_matmul")
    from repro.ops import bcsr_matmul as _bcsr_matmul

    return _bcsr_matmul(values, b, structure, impl)


_MOVED = {"BCSRStructure", "structure_of", "_as_bcsr"}


def __getattr__(name):
    # lazy forwarding avoids an import cycle during repro.ops package init
    if name in _MOVED:
        from repro.ops import matmul

        return getattr(matmul, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
