"""Pallas-TPU API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels import the alias from here so both names work.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
