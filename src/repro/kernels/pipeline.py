"""Reusable Q-deep producer/consumer gather pipeline (paper §III-A).

The paper's headline mechanism is the *asynchronous* producer/consumer
pipeline: TMA loads of step ``i+Q`` overlap WGMMA compute of step ``i``
through a ``Q=3`` circular buffer (§III-A; Table 2 ablates exactly this).
On TPU the analogue for *indirect* operands — accesses a BlockSpec cannot
express, like the WCSR row gather — is a scalar-core-issued
``pltpu.make_async_copy`` stream into a ``Q``-slot VMEM scratch, with one
DMA semaphore per slot.

``emit_gather_pipeline`` generates all four pipeline phases from a single
body description:

* **prime**    — at step 0, issue the copies for chunks ``0..Q-1``;
* **produce**  — at step ``g``, issue chunk ``g+Q`` into the slot chunk
  ``g`` just vacated (the TMA-of-step-i+Q analogue);
* **consume**  — wait chunk ``g``'s slot, then run the caller's compute
  (the WGMMA analogue);
* **drain**    — steps past ``nchunks`` (grids are padded to a static
  trip count) do nothing: every issued copy has been consumed.

Because chunk ``g`` and chunk ``g+Q`` occupy the *same* slot
(``(g+Q) % Q == g % Q``), one handle list serves both sides of the step:
the consumer waits on the very handles the producer holds — a DMA wait
depends only on the destination slice and semaphore, never the source —
so the wait side does not re-construct descriptors (the old double-buffer
kernel re-derived every ``make_async_copy`` on its wait branches).

Depth semantics:

* ``depth=1`` — serial load-then-compute (the paper's WCSR §III-C choice):
  one slot, the wait immediately follows the issue, no overlap.
* ``depth=2`` — the classic double buffer (the old ``pipeline_gather``).
* ``depth>=3`` — the paper's Q-deep circular buffer (§III-A uses Q=3).

All phases are emitted from one trace of the caller's callbacks, so there
is no per-slot branch duplication: the even/odd ``_prefetch_*`` /
``_consume_*`` pairs of the old WCSR double-buffer kernel collapse into a
dynamic ``step % depth`` slot index into a stacked ``[Q, ...]`` scratch
buffer and a ``SemaphoreType.DMA((Q,))`` array.

BCSR note: the block-streaming kernels (``kernels/bcsr``, and the
default paths of ``kernels/sddmm`` / ``kernels/block_attn``) keep their
*contiguous* operands on Mosaic's implicit multi-buffered grid pipeline,
which is this same producer/consumer scheme applied automatically to
BlockSpec streams; this module is for the operands BlockSpecs cannot
reach.
"""

from __future__ import annotations

from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["emit_gather_pipeline", "gather_slots", "validate_depth",
           "dequant_tile", "MAX_DEPTH"]

# VMEM is the binding resource (§IV-C): each extra slot costs a full
# gather buffer. 4 covers the paper's Q=3 plus one experiment slot.
MAX_DEPTH = 4


def validate_depth(depth: int, *, allow_zero: bool = False) -> int:
    """Check a static pipeline depth; returns it as a plain int."""
    depth = int(depth)
    lo = 0 if allow_zero else 1
    if not lo <= depth <= MAX_DEPTH:
        raise ValueError(
            f"pipeline depth must be in [{lo}, {MAX_DEPTH}], got {depth}")
    return depth


def gather_slots(depth: int, shape: Sequence[int], dtype):
    """Scratch shapes for one ``depth``-deep gather pipeline.

    Returns ``(vmem_slots, dma_sems)`` to splice into ``scratch_shapes``:
    a stacked ``[depth, *shape]`` VMEM buffer and a matching DMA-semaphore
    array. Kernels using several pipelined operands call this once per
    operand (slots may share a semaphore array only if every slot's copies
    are always waited together).
    """
    from jax.experimental.pallas import tpu as pltpu

    depth = validate_depth(depth)
    return (pltpu.VMEM((depth, *shape), dtype),
            pltpu.SemaphoreType.DMA((depth,)))


def dequant_tile(tile, codec: str, scale=None, compute_dtype=jnp.float32):
    """Fused in-register dequantization: the consumer-body codec hook.

    The single place a value codec (``repro.sparse.codecs``) meets kernel
    code: every consumer body — the WCSR ``compute`` callback at any
    pipeline depth, the BCSR/SDDMM accumulate steps, the block-attention
    softmax step — dequantizes its just-landed tile with this one helper,
    so DMA traffic is the compressed payload and the MXU-side math stays
    ``compute_dtype``. ``codec == "none"`` is the identity (no cast, no
    multiply); otherwise ``scale`` is the tile's group scale (a scalar read
    from the streamed scales operand) and the result is
    ``tile.astype(compute_dtype) * scale`` — never a materialized
    dequantized copy in HBM.
    """
    if codec == "none":
        return tile
    if scale is None:
        raise ValueError(f"dequant_tile: codec {codec!r} requires a scale")
    return tile.astype(compute_dtype) * scale


def emit_gather_pipeline(
    *,
    step,
    nchunks,
    depth: int,
    copies: Callable[[object, object], List],
    compute: Callable[[object, object], None],
) -> None:
    """Emit prime/produce/consume/drain for a Q-deep circular buffer.

    Designed to be called once inside a Pallas kernel body whose innermost
    grid dimension is the chunk loop (one grid step per chunk, padded to a
    static trip count).

    Args:
      step: the traced chunk index of this grid step (the pipeline clock).
      nchunks: number of active chunks (traced or static). Steps with
        ``step >= nchunks`` are drain steps: no wait, no compute, no issue.
        ``nchunks`` may be 0 (empty task) and may be smaller than
        ``depth`` — the prime phase guards each chunk individually.
      depth: static pipeline depth Q (1 = serial, 2 = double buffer,
        3 = the paper's circular buffer).
      copies: ``copies(chunk, slot) -> [handle, ...]`` builds the
        *un-started* async-copy handles that move chunk ``chunk``'s
        indirect operand into buffer slot ``slot`` (a traced index into
        the stacked scratch from ``gather_slots``). It is invoked with
        lookahead chunks up to ``nchunks + depth - 1``, so implementations
        must clamp any data-dependent index loads. Every handle's
        destination and semaphore must depend on ``slot`` only (not
        ``chunk``): that invariant is what lets the consumer wait on the
        producer's handles.
      compute: ``compute(chunk, slot)`` — the consume body; runs after
        chunk ``chunk`` is resident in slot ``slot``.
    """
    depth = validate_depth(depth)

    # prime: fill the Q slots with the first Q chunks (chunk d -> slot d)
    @pl.when(step == 0)
    def _prime():
        for d in range(depth):

            @pl.when(d < nchunks)
            def _start(d=d):
                for cp in copies(d, d):
                    cp.start()

    slot = jax.lax.rem(step, depth) if depth > 1 else 0
    active = step < nchunks
    # chunk `step` and chunk `step + depth` share slot `step % depth`, so
    # this one handle list is both the consumer's wait set (dst/sem are
    # slot-determined) and the producer's issue set.
    handles = copies(step + depth, slot)

    @pl.when(active)
    def _consume():
        for h in handles:
            h.wait()
        compute(step, slot)

    @pl.when(jnp.logical_and(active, step + depth < nchunks))
    def _produce():
        for h in handles:
            h.start()
