"""Adafactor (factored second moment), the default optimizer above ~30B
params: the factored statistics make the optimizer-state HBM cost negligible
relative to Adam's 2x-f32, which is what lets the 1T-param arch fit the
512-chip mesh (DESIGN.md §6).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: dict  # row statistics (or full v for <2D leaves)
    vc: dict  # col statistics (None for <2D leaves)


def _trainable(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params) -> AdafactorState:
    def vr0(p):
        if not _trainable(p):
            return jnp.zeros((), jnp.float32)
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros_like(p, jnp.float32)

    def vc0(p):
        if not _trainable(p) or not _factored(p):
            return jnp.zeros((), jnp.float32)
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(vr0, params),
        vc=jax.tree.map(vc0, params),
    )


def apply(params, grads, state: AdafactorState, lr, *, decay=0.8,
          eps=1e-30, clip_threshold=1.0, weight_decay=0.0, grad_scale=1.0):
    step = state.step + 1
    beta = 1.0 - step.astype(jnp.float32) ** (-decay)

    def kernel(p, g, vr, vc):
        g32 = g.astype(jnp.float32) * grad_scale
        sq = g32 * g32 + eps
        if _factored(p):
            vr = beta * vr + (1 - beta) * jnp.mean(sq, axis=-1)
            vc = beta * vc + (1 - beta) * jnp.mean(sq, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :] + eps)
        else:
            vr = beta * vr + (1 - beta) * sq
            u = g32 / (jnp.sqrt(vr) + eps)
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc

    def upd(p, g, vr, vc):
        if not _trainable(p):
            return p, vr, vc
        if p.ndim >= 4 and p.shape[0] >= 8:  # layer-stacked leaf
            return jax.lax.map(lambda a: kernel(*a), (p, g, vr, vc))
        return kernel(p, g, vr, vc)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_vr = tdef.flatten_up_to(state.vr)
    flat_vc = tdef.flatten_up_to(state.vc)
    out = [upd(p, g, r, c) for p, g, r, c in zip(flat_p, flat_g, flat_vr, flat_vc)]
    return (
        tdef.unflatten([o[0] for o in out]),
        AdafactorState(
            step=step,
            vr=tdef.unflatten([o[1] for o in out]),
            vc=tdef.unflatten([o[2] for o in out]),
        ),
    )
