"""AdamW, from scratch, pytree-native.

Integer/bool leaves (sparse-structure index buffers) are *carried, not
updated*: their grads are float0 under ``jax.grad(..., allow_int=True)``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def _trainable(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)


def init(params) -> AdamWState:
    # non-trainable (integer) leaves carry a scalar sentinel so the state
    # tree stays regular (shardings/checkpoints map leaf-for-leaf)
    zeros = lambda p: (
        jnp.zeros_like(p, jnp.float32) if _trainable(p)
        else jnp.zeros((), jnp.float32)
    )
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def apply(
    params, grads, state: AdamWState, lr, *, b1=0.9, b2=0.95, eps=1e-8,
    weight_decay=0.1, grad_scale=1.0,
):
    """``grad_scale`` folds global-norm clipping into the update so the
    scaled-gradient tree is never materialized. Stacked-layer leaves
    (ndim >= 3, large leading dim) update via ``lax.map`` over the layer dim
    to bound f32 transients to one layer slice."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def kernel(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * grad_scale
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        u = (mu / c1) / (jnp.sqrt(nu / c2) + eps) + weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    def upd(p, g, mu, nu):
        if not _trainable(p):
            return p, mu, nu
        if p.ndim >= 3 and p.shape[0] >= 8:  # layer-stacked leaf
            return jax.lax.map(lambda a: kernel(*a), (p, g, mu, nu))
        return kernel(p, g, mu, nu)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu)
