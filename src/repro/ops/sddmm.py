"""SDDMM under the unified API: sample ``dC @ B^T`` at stored BCSR blocks.

The backward-pass half of the paper's training story (§III): the gradient
of the block values is a sampled dense-dense product evaluated only at the
stored block positions.
"""

from __future__ import annotations

import jax

from repro.kernels.sddmm.kernel import sddmm_kernel
from repro.kernels.sddmm.ref import sddmm_ref
from repro.ops.config import (OpConfig, resolve_interpret,
                              resolved_config)
from repro.ops.registry import on_tpu, register_backend, resolve_backend
from repro.ops.tiling import pad_cols, resolve_bn, resolve_pipeline_depth
from repro.sparse.codecs import (encode_rowblocks, fake_quant_rowblocks,
                                 resolve_codec_name)
from repro.sparse.formats import BCSR
from repro.sparse.tensor import SparseTensor

__all__ = ["sddmm"]


def sddmm(dc: jax.Array, b: jax.Array, a_struct: BCSR, *, impl=None, bn=None,
          out_dtype=None, interpret=None, pipeline_depth=None,
          value_codec=None) -> jax.Array:
    """``dvalues[nnz, bm, bk] = (dC @ B^T)`` sampled at ``a_struct``'s blocks.

    ``pipeline_depth`` >= 1 routes the indirect B tiles through the shared
    §III-A gather pipeline (``repro.kernels.pipeline``); the default (0 /
    "auto" with no tuned entry) keeps them on Mosaic's BlockSpec stream.
    ``value_codec`` compresses the *gathered* B operand per row-block
    (``repro.sparse.codecs``) — the kernel moves int8/fp8 tiles and
    dequantizes in-register after the gather lands; the reference backend
    mirrors the numerics with a quantize-dequantize round trip.
    """
    cfg = resolved_config(impl=impl, bn=bn, out_dtype=out_dtype,
                          interpret=interpret, pipeline_depth=pipeline_depth,
                          value_codec=value_codec)
    if isinstance(a_struct, SparseTensor):
        a_struct = a_struct.raw
    backend = resolve_backend("sddmm", cfg.impl)
    return backend.fn(dc, b, a_struct, cfg)



@register_backend("sddmm", "ref", priority=50)
def _sddmm_ref(dc, b, a_struct: BCSR, cfg: OpConfig):
    codec = resolve_codec_name(cfg.value_codec)
    if codec != "none":
        b = fake_quant_rowblocks(b, a_struct.block[1], codec)
    return sddmm_ref(dc, b, a_struct, out_dtype=cfg.out_dtype)


def _sddmm_pallas(dc, b, a_struct: BCSR, cfg: OpConfig, interpret: bool):
    bm, bk = a_struct.block
    n = dc.shape[1]
    bn = resolve_bn(cfg.bn, n, bm, bk, a_struct.dtype, op="sddmm", fmt="bcsr",
                    shape=a_struct.shape, impl="kernel")
    depth = resolve_pipeline_depth(
        cfg.pipeline_depth, default=0, op="sddmm", fmt="bcsr",
        shape=a_struct.shape, n=n, block=a_struct.block, dtype=a_struct.dtype)
    codec = resolve_codec_name(cfg.value_codec)
    scales = None
    if codec != "none":
        # compress the gathered operand; the scales ride a tiny BlockSpec
        b, scales = encode_rowblocks(b, bk, codec)
    (dc, b), bn_eff, _ = pad_cols([dc, b], n, bn)
    return sddmm_kernel(
        a_struct.block_rows,
        a_struct.block_cols,
        dc,
        b,
        scales,
        block=a_struct.block,
        nnz=a_struct.nnz_blocks,
        bn=bn_eff,
        out_dtype=cfg.out_dtype,
        interpret=interpret,
        pipeline_depth=depth,
        codec=codec,
    )


@register_backend("sddmm", "kernel", available=on_tpu, priority=100)
def _sddmm_kernel(dc, b, a_struct: BCSR, cfg: OpConfig):
    return _sddmm_pallas(dc, b, a_struct, cfg, resolve_interpret(cfg, not on_tpu()))


@register_backend("sddmm", "kernel_interpret", priority=10)
def _sddmm_kernel_interpret(dc, b, a_struct: BCSR, cfg: OpConfig):
    return _sddmm_pallas(dc, b, a_struct, cfg, resolve_interpret(cfg, True))
