"""Execution configuration for the unified sparse-op API.

``OpConfig`` is a frozen bag of execution knobs shared by every op in
``repro.ops`` (impl, tile width, output dtype, task chunking, interpret
mode). Fields left as ``None`` mean "inherit from the next layer down".

Resolution order, highest precedence first:

1. explicit keyword arguments at the call site (``spmm(a, b, impl="ref")``),
2. the innermost active ``use_config(...)`` context, then outer contexts,
3. the ``REPRO_SPARSE_IMPL`` environment variable (impl only — the global
   flip-switch for benchmarks/serving; read at op-call time),
4. package defaults (``impl=None`` -> registry auto-resolution,
   ``bn="auto"`` -> §IV-C tile selection, ``pipeline_depth="auto"`` ->
   measured-autotune winner or the kernel default, ``chunks_per_task``
   unset -> autotune winner or 8, resolved in ``make_plan``).

Configs are resolved when an op *traces*: flipping a config inside an
already-compiled ``jax.jit`` cache entry does not retrace it.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os
from typing import Any, Optional, Union

__all__ = ["OpConfig", "use_config", "current_config", "resolved_config",
           "ENV_IMPL_VAR"]

ENV_IMPL_VAR = "REPRO_SPARSE_IMPL"


@dataclasses.dataclass(frozen=True)
class OpConfig:
    """Execution knobs for ``repro.ops``. ``None`` fields inherit."""

    impl: Optional[str] = None  # backend name, or None/"auto" for registry pick
    bn: Union[int, str, None] = None  # output-tile width, or "auto" (§IV-C)
    out_dtype: Any = None
    chunks_per_task: Optional[int] = None  # WCSR task splitting (§III-C)
    interpret: Optional[bool] = None  # force Pallas interpret mode
    # Q-deep producer/consumer gather pipeline (paper §III-A; the paper's
    # circular buffer uses Q=3). An int pins the depth; "auto" consults the
    # measured auto-tune cache (ops.tiling.autotune_spmm) and falls back to
    # each kernel's own default (WCSR: 1, the §III-C serial gather; SDDMM /
    # block attention: 0 = Mosaic's implicit grid pipeline).
    pipeline_depth: Union[int, str, None] = None
    # Value codec for the low-precision operand payload
    # (repro.sparse.codecs: "none" | "int8" | "fp8_e4m3"). A name quantizes
    # the sparse operand (spmm; memoized per SparseTensor) / the gathered
    # dense operand (sddmm: B row-blocks; sparse_attention: K/V blocks) on
    # the way into the kernel, which dequantizes in-register. "auto" adopts
    # a measured autotune_spmm winner that passed the accuracy guard; the
    # package default is "none" — codecs are opt-in. An operand that is
    # already quantized (SparseTensor.quantize) always keeps its own codec.
    value_codec: Optional[str] = None
    # Skinny-N (SpMV/GEMV) dispatch crossover: ``spmm`` reroutes to the
    # ``spmv`` op family when the RHS has <= this many columns. An int pins
    # the crossover (0 disables the fast path entirely); "auto" adopts the
    # measured route from a ``TuneDB``/``autotune_spmm`` winner when one
    # exists for the shape, falling back to ``tiling.DEFAULT_SPMV_THRESHOLD``.
    spmv_threshold: Union[int, str, None] = None
    # Sharded-spmm chunked combine (repro.parallel.sparse): split the
    # output rows into this many row-chunks (snapped to window / block-row
    # boundaries) and issue each chunk's collective reduction as soon as
    # its local kernel finishes, so the all-reduce of chunk k overlaps the
    # compute of chunk k+1 — the paper's §III-A latency hiding lifted from
    # the DMA level to the collective level. An int pins the chunk count
    # (1 = the blocking single-collective combine); "auto" adopts a
    # measured ``autotune_spmm`` winner when one exists, else the static
    # policy in ``tiling.resolve_combine_chunks``. Ignored by unsharded
    # calls.
    combine_chunks: Union[int, str, None] = None

    def merged_under(self, override: "OpConfig") -> "OpConfig":
        """Layer ``override`` on top of self: non-None override fields win."""
        return OpConfig(**{
            f.name: (ov if ov is not None else getattr(self, f.name))
            for f in dataclasses.fields(self)
            for ov in [getattr(override, f.name)]
        })


# chunks_per_task stays None at the default layer (not a concrete 8) so
# make_plan can distinguish "user pinned it" from "free to adopt a measured
# autotune_spmm winner"; the 8 fallback lives in make_plan. value_codec
# defaults to "none" (not "auto"): quantization changes numerics, so
# adopting a tuned codec requires the caller to opt in with "auto".
_DEFAULTS = OpConfig(impl=None, bn="auto", out_dtype=None,
                     chunks_per_task=None, interpret=None,
                     pipeline_depth="auto", value_codec="none",
                     spmv_threshold="auto", combine_chunks="auto")

_STACK: contextvars.ContextVar = contextvars.ContextVar(
    "repro_ops_config_stack", default=())


@contextlib.contextmanager
def use_config(config: Optional[OpConfig] = None, **overrides):
    """Push an ``OpConfig`` for the dynamic extent of the ``with`` block.

    Accepts either a ready-made ``OpConfig`` or field keywords::

        with use_config(impl="kernel_interpret", bn=256):
            y = repro.ops.spmm(a, b)   # no call-site changes needed

    Contexts nest; inner non-None fields shadow outer ones.
    """
    if config is not None and overrides:
        raise TypeError("pass either an OpConfig or field keywords, not both")
    cfg = config if config is not None else OpConfig(**overrides)
    token = _STACK.set(_STACK.get() + (cfg,))
    try:
        yield cfg
    finally:
        _STACK.reset(token)


def _env_config() -> OpConfig:
    impl = os.environ.get(ENV_IMPL_VAR)
    return OpConfig(impl=impl) if impl else OpConfig()


def current_config() -> OpConfig:
    """The fully-layered config visible right now (defaults -> env -> contexts)."""
    cfg = _DEFAULTS.merged_under(_env_config())
    for layer in _STACK.get():
        cfg = cfg.merged_under(layer)
    return cfg


def resolved_config(**call_kwargs) -> OpConfig:
    """``current_config()`` with call-site keywords layered on top."""
    known = {f.name for f in dataclasses.fields(OpConfig)}
    unknown = set(call_kwargs) - known
    if unknown:
        raise TypeError(f"unknown OpConfig fields: {sorted(unknown)}")
    # an explicit impl="auto" means "resolve automatically", i.e. it must not
    # shadow the env var / contexts the way a concrete backend name does
    # (legacy shims forward their old impl="auto" default here)
    if call_kwargs.get("impl") == "auto":
        call_kwargs["impl"] = None
    return current_config().merged_under(OpConfig(**call_kwargs))


def resolve_interpret(cfg: OpConfig, default: bool) -> bool:
    """Backend helper: an explicit ``interpret`` config wins over the
    backend's own default (interpret off on TPU, on elsewhere)."""
    return default if cfg.interpret is None else cfg.interpret
