"""Backend + format registries for the unified sparse-op API.

Two registries:

* **Backend registry** — per op (``"spmm/bcsr"``, ``"spmm/wcsr"``,
  ``"sddmm"``, ``"sparse_attention"``), named implementations register with
  an availability predicate and a priority. ``impl=None``/``"auto"``
  resolves to the highest-priority available backend; a name resolves to
  that backend (with a clear error listing what is registered). This
  replaces the per-dispatcher ``_default_impl()`` copies.

* **Format registry** — maps a sparse-format pytree type (``BCSR``,
  ``WCSR``, ...) to its op family, making ``spmm(a, b)`` polymorphic in the
  format of ``a``. New formats plug in with ``register_format``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax

__all__ = [
    "Backend",
    "register_backend",
    "resolve_backend",
    "available_backends",
    "registered_backends",
    "register_format",
    "resolve_format",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _always() -> bool:
    return True


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    fn: Callable
    is_available: Callable[[], bool]
    priority: int


_BACKENDS: Dict[str, Dict[str, Backend]] = {}


def register_backend(op: str, name: str, *,
                     available: Callable[[], bool] = _always,
                     priority: int = 0):
    """Decorator: register ``fn`` as backend ``name`` for ``op``."""

    def deco(fn):
        _BACKENDS.setdefault(op, {})[name] = Backend(name, fn, available,
                                                     priority)
        return fn

    return deco


def resolve_backend(op: str, impl: Optional[str] = None) -> Backend:
    """Pick a backend: by name, or highest-priority available for auto."""
    table = _BACKENDS.get(op)
    if not table:
        raise KeyError(f"no backends registered for op {op!r}")
    if impl is None or impl == "auto":
        avail = [b for b in table.values() if b.is_available()]
        if not avail:
            raise RuntimeError(
                f"no available backend for op {op!r} on "
                f"jax backend {jax.default_backend()!r}; registered: "
                f"{sorted(table)}")
        return max(avail, key=lambda b: b.priority)
    try:
        return table[impl]
    except KeyError:
        raise ValueError(
            f"unknown impl {impl!r} for op {op!r}; registered backends: "
            f"{sorted(table)}") from None


def available_backends(op: str) -> List[str]:
    """Names of currently-available backends, best first."""
    table = _BACKENDS.get(op, {})
    avail = [b for b in table.values() if b.is_available()]
    return [b.name for b in sorted(avail, key=lambda b: -b.priority)]


def registered_backends(op: str) -> List[str]:
    """All backend names registered for ``op``, available or not."""
    return sorted(_BACKENDS.get(op, {}))


# ---------------------------------------------------------------------------
# Format dispatch (spmm polymorphism)
# ---------------------------------------------------------------------------
#
# The per-type table moved into the SparseFormat registry
# (repro.sparse.registry): each format descriptor names its spmm op family,
# so dispatch, fill-ratio accounting and conversion share one registration.
# The imports are lazy to keep repro.ops importable before repro.sparse.


def register_format(fmt_type: type, op: str) -> None:
    """Route ``spmm`` calls whose sparse operand is ``fmt_type`` to ``op``.

    Compatibility hook: registers a minimal ``SparseFormat`` descriptor (or
    re-points an existing one's op family) in ``repro.sparse.registry``.
    """
    from repro.sparse import registry as sreg

    existing = sreg._BY_TYPE.get(fmt_type)
    if existing is not None:
        sreg.register_sparse_format(dataclasses.replace(existing, op=op))
    else:
        sreg.register_sparse_format(sreg.SparseFormat(
            name=fmt_type.__name__.lower(), fmt_type=fmt_type, op=op))


def resolve_format(a) -> str:
    """Op family for a sparse operand, via the ``SparseFormat`` registry."""
    from repro.sparse.registry import format_of, registered_sparse_formats

    try:
        fmt = format_of(a)
    except TypeError:
        fmt = None
    if fmt is None or fmt.op is None:
        raise TypeError(
            f"spmm: unsupported sparse format {type(a).__name__}; "
            f"registered formats: {registered_sparse_formats()}")
    return fmt.op
