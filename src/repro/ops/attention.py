"""Block-sparse attention under the unified API (paper §IV-D).

``sparse_attention(q, k, v, block_mask)`` CSR-encodes the host-side block
mask for scalar prefetch and dispatches to the Pallas kernel or the
dense-masked reference through the same registry/config machinery as
``spmm``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_attn.kernel import block_sparse_attention_kernel
from repro.kernels.block_attn.ref import block_sparse_attention_ref
from repro.ops.config import (OpConfig, resolve_interpret,
                              resolved_config)
from repro.ops.registry import on_tpu, register_backend, resolve_backend
from repro.ops.tiling import resolve_pipeline_depth
from repro.sparse.codecs import (encode_seq_blocks, fake_quant_seq_blocks,
                                 resolve_codec_name)

__all__ = ["sparse_attention", "csr_encode_block_mask", "csr_mask_to_dense"]


def csr_encode_block_mask(block_mask: np.ndarray):
    """[H, nqb, nkb] bool -> (ptr [H*nqb+1], kcols [total], max_active)."""
    bm = np.asarray(block_mask, bool)
    h, nqb, nkb = bm.shape
    counts = bm.sum(axis=2).reshape(-1)
    ptr = np.zeros(h * nqb + 1, np.int32)
    ptr[1:] = np.cumsum(counts)
    kcols = np.nonzero(bm.reshape(h * nqb, nkb))[1].astype(np.int32)
    if len(kcols) == 0:
        kcols = np.zeros(1, np.int32)
    max_active = int(counts.max()) if counts.size else 1
    return ptr, kcols, max(max_active, 1)


def csr_mask_to_dense(ptr, kcols, heads: int, nqb: int, nkb: int):
    """Inverse of ``csr_encode_block_mask`` — works on traced arrays.

    The serving prefill path builds its causal-band CSR on-device; the
    reference backend reconstructs the dense [H, nqb, nkb] mask from it.
    Entries past ``ptr[-1]`` (shape padding) are ignored.
    """
    ptr = jnp.asarray(ptr, jnp.int32)
    kcols = jnp.asarray(kcols, jnp.int32)
    p = jnp.arange(kcols.shape[0])
    row = jnp.clip(jnp.searchsorted(ptr, p, side="right") - 1, 0,
                   heads * nqb - 1)
    valid = p < ptr[-1]
    dense = jnp.zeros((heads * nqb, nkb), jnp.bool_)
    dense = dense.at[row, jnp.clip(kcols, 0, nkb - 1)].max(valid)
    return dense.reshape(heads, nqb, nkb)


def sparse_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, KVH, Skv, D]
    v: jax.Array,  # [B, KVH, Skv, D]
    block_mask,  # [H, nqb, nkb] bool (static) | (ptr, kcols) CSR arrays
    *,
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = True,
    scale=None,
    impl=None,
    interpret=None,
    pipeline_depth=None,
    value_codec=None,
    q_offset: "jax.Array | int" = 0,
    pad_active_to=None,
) -> jax.Array:
    """Block-sparse flash attention over a static per-head block mask.

    ``pipeline_depth`` >= 1 gathers the indirect K/V blocks through the
    shared §III-A producer/consumer pipeline; the default (0) streams them
    via BlockSpec index_maps on Mosaic's implicit pipeline. ``value_codec``
    compresses the gathered K/V operands per seq block
    (``repro.sparse.codecs`` — the KV-cache-quantization analogue): the
    kernel moves int8/fp8 blocks plus one f32 scale each and dequantizes
    in-register before the softmax step.

    Prefill-chunk entry (serving runtime): q may cover ``Sq`` chunk tokens
    starting at absolute position ``q_offset`` (int or traced scalar) while
    K/V span the full ``Skv``-token prefix. ``block_mask`` may then be a
    pre-encoded ``(ptr, kcols)`` pair of (possibly traced) arrays — built
    per chunk on-device — instead of a host-side dense mask, and
    ``pad_active_to`` pins the kernel's active-block grid extent so every
    chunk of a prompt reuses one compiled kernel (padding steps are
    compute-masked; with ``pipeline_depth >= 1`` they issue no DMA).
    """
    cfg = resolved_config(impl=impl, interpret=interpret,
                          pipeline_depth=pipeline_depth,
                          value_codec=value_codec)
    backend = resolve_backend("sparse_attention", cfg.impl)
    return backend.fn(q, k, v, block_mask, cfg, block_q=block_q,
                      block_k=block_k, causal=causal, scale=scale,
                      q_offset=q_offset, pad_active_to=pad_active_to)



def _resolve_mask(block_mask, *, heads, nqb, nkb, pad_active_to):
    """Normalize either mask form to (ptr, kcols, max_active).

    ``kcols`` is shape-padded to the next power of two (edge values; the
    kernel reads only ``[base, base + count)`` per row) so masks whose
    active count drifts — serving prefill chunks — hit a bounded number of
    jit cache entries instead of one per distinct count.
    """
    if isinstance(block_mask, tuple):
        ptr, kcols = block_mask
        return (jnp.asarray(ptr, jnp.int32), jnp.asarray(kcols, jnp.int32),
                int(pad_active_to or nkb))
    ptr, kcols, max_active = csr_encode_block_mask(block_mask)
    if pad_active_to:
        max_active = max(max_active, int(pad_active_to))
    padded = 1 << (len(kcols) - 1).bit_length()
    kcols = np.pad(kcols, (0, padded - len(kcols)), mode="edge")
    return jnp.asarray(ptr), jnp.asarray(kcols), max_active


@register_backend("sparse_attention", "ref", priority=50)
def _attn_ref(q, k, v, block_mask, cfg: OpConfig, *, block_q, block_k,
              causal, scale, q_offset=0, pad_active_to=None):
    del pad_active_to  # grid sizing is a kernel-path concern
    codec = resolve_codec_name(cfg.value_codec)
    if codec != "none":
        b, kvh, s, d = k.shape
        k = fake_quant_seq_blocks(
            k.reshape(b * kvh, s, d), block_k, codec).reshape(k.shape)
        v = fake_quant_seq_blocks(
            v.reshape(b * kvh, s, d), block_k, codec).reshape(v.shape)
    if isinstance(block_mask, tuple):
        h, sq, skv = q.shape[1], q.shape[2], k.shape[2]
        block_mask = csr_mask_to_dense(*block_mask, heads=h,
                                       nqb=sq // block_q, nkb=skv // block_k)
    return block_sparse_attention_ref(
        q, k, v, block_mask, block_q=block_q, block_k=block_k, causal=causal,
        scale=scale, q_offset=q_offset)


def _attn_pallas(q, k, v, block_mask, interpret, *, block_q, block_k, causal,
                 scale, cfg: OpConfig, q_offset=0, pad_active_to=None):
    b, h, s, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    depth = resolve_pipeline_depth(
        cfg.pipeline_depth, default=0, op="sparse_attention", fmt="block",
        shape=(h, s), n=s, block=(block_q, block_k), dtype=q.dtype)
    ptr, kcols, max_active = _resolve_mask(
        block_mask, heads=h, nqb=s // block_q, nkb=skv // block_k,
        pad_active_to=pad_active_to)
    codec = resolve_codec_name(cfg.value_codec)
    k3 = k.reshape(b * kvh, skv, d)
    v3 = v.reshape(b * kvh, skv, d)
    kscales = vscales = None
    if codec != "none":
        k3, kscales = encode_seq_blocks(k3, block_k, codec)
        v3, vscales = encode_seq_blocks(v3, block_k, codec)
    out = block_sparse_attention_kernel(
        ptr,
        kcols,
        q.reshape(b * h, s, d),
        k3,
        v3,
        kscales,
        vscales,
        heads=h,
        kv_heads=kvh,
        block_q=block_q,
        block_k=block_k,
        max_active=max_active,
        causal=causal,
        scale=scale,
        interpret=interpret,
        pipeline_depth=depth,
        codec=codec,
        q_offset=q_offset,
    )
    return out.reshape(b, h, s, d)


@register_backend("sparse_attention", "kernel", available=on_tpu,
                  priority=100)
def _attn_kernel(q, k, v, block_mask, cfg: OpConfig, **kw):
    return _attn_pallas(q, k, v, block_mask, resolve_interpret(cfg, not on_tpu()),
                        cfg=cfg, **kw)


@register_backend("sparse_attention", "kernel_interpret", priority=10)
def _attn_kernel_interpret(q, k, v, block_mask, cfg: OpConfig, **kw):
    return _attn_pallas(q, k, v, block_mask, resolve_interpret(cfg, True),
                        cfg=cfg, **kw)
