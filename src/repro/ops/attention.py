"""Block-sparse attention under the unified API (paper §IV-D).

``sparse_attention(q, k, v, block_mask)`` CSR-encodes the host-side block
mask for scalar prefetch and dispatches to the Pallas kernel or the
dense-masked reference through the same registry/config machinery as
``spmm``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_attn.kernel import block_sparse_attention_kernel
from repro.kernels.block_attn.ref import block_sparse_attention_ref
from repro.ops.config import (OpConfig, resolve_interpret,
                              resolved_config)
from repro.ops.registry import on_tpu, register_backend, resolve_backend
from repro.ops.tiling import resolve_pipeline_depth
from repro.sparse.codecs import (encode_seq_blocks, fake_quant_seq_blocks,
                                 resolve_codec_name)

__all__ = ["sparse_attention", "csr_encode_block_mask"]


def csr_encode_block_mask(block_mask: np.ndarray):
    """[H, nqb, nkb] bool -> (ptr [H*nqb+1], kcols [total], max_active)."""
    bm = np.asarray(block_mask, bool)
    h, nqb, nkb = bm.shape
    counts = bm.sum(axis=2).reshape(-1)
    ptr = np.zeros(h * nqb + 1, np.int32)
    ptr[1:] = np.cumsum(counts)
    kcols = np.nonzero(bm.reshape(h * nqb, nkb))[1].astype(np.int32)
    if len(kcols) == 0:
        kcols = np.zeros(1, np.int32)
    max_active = int(counts.max()) if counts.size else 1
    return ptr, kcols, max(max_active, 1)


def sparse_attention(
    q: jax.Array,  # [B, H, S, D]
    k: jax.Array,  # [B, KVH, S, D]
    v: jax.Array,  # [B, KVH, S, D]
    block_mask: np.ndarray,  # [H, nqb, nkb] bool (host-side / static)
    *,
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = True,
    scale=None,
    impl=None,
    interpret=None,
    pipeline_depth=None,
    value_codec=None,
) -> jax.Array:
    """Block-sparse flash attention over a static per-head block mask.

    ``pipeline_depth`` >= 1 gathers the indirect K/V blocks through the
    shared §III-A producer/consumer pipeline; the default (0) streams them
    via BlockSpec index_maps on Mosaic's implicit pipeline. ``value_codec``
    compresses the gathered K/V operands per seq block
    (``repro.sparse.codecs`` — the KV-cache-quantization analogue): the
    kernel moves int8/fp8 blocks plus one f32 scale each and dequantizes
    in-register before the softmax step.
    """
    cfg = resolved_config(impl=impl, interpret=interpret,
                          pipeline_depth=pipeline_depth,
                          value_codec=value_codec)
    backend = resolve_backend("sparse_attention", cfg.impl)
    return backend.fn(q, k, v, block_mask, cfg, block_q=block_q,
                      block_k=block_k, causal=causal, scale=scale)



@register_backend("sparse_attention", "ref", priority=50)
def _attn_ref(q, k, v, block_mask, cfg: OpConfig, *, block_q, block_k,
              causal, scale):
    codec = resolve_codec_name(cfg.value_codec)
    if codec != "none":
        b, kvh, s, d = k.shape
        k = fake_quant_seq_blocks(
            k.reshape(b * kvh, s, d), block_k, codec).reshape(k.shape)
        v = fake_quant_seq_blocks(
            v.reshape(b * kvh, s, d), block_k, codec).reshape(v.shape)
    return block_sparse_attention_ref(
        q, k, v, block_mask, block_q=block_q, block_k=block_k, causal=causal,
        scale=scale)


def _attn_pallas(q, k, v, block_mask, interpret, *, block_q, block_k, causal,
                 scale, cfg: OpConfig):
    b, h, s, d = q.shape
    kvh = k.shape[1]
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    depth = resolve_pipeline_depth(
        cfg.pipeline_depth, default=0, op="sparse_attention", fmt="block",
        shape=(h, s), n=s, block=(block_q, block_k), dtype=q.dtype)
    ptr, kcols, max_active = csr_encode_block_mask(block_mask)
    codec = resolve_codec_name(cfg.value_codec)
    k3 = k.reshape(b * kvh, s, d)
    v3 = v.reshape(b * kvh, s, d)
    kscales = vscales = None
    if codec != "none":
        k3, kscales = encode_seq_blocks(k3, block_k, codec)
        v3, vscales = encode_seq_blocks(v3, block_k, codec)
    out = block_sparse_attention_kernel(
        jnp.asarray(ptr),
        jnp.asarray(kcols),
        q.reshape(b * h, s, d),
        k3,
        v3,
        kscales,
        vscales,
        heads=h,
        kv_heads=kvh,
        block_q=block_q,
        block_k=block_k,
        max_active=max_active,
        causal=causal,
        scale=scale,
        interpret=interpret,
        pipeline_depth=depth,
        codec=codec,
    )
    return out.reshape(b, h, s, d)


@register_backend("sparse_attention", "kernel", available=on_tpu,
                  priority=100)
def _attn_kernel(q, k, v, block_mask, cfg: OpConfig, **kw):
    return _attn_pallas(q, k, v, block_mask, resolve_interpret(cfg, not on_tpu()),
                        cfg=cfg, **kw)


@register_backend("sparse_attention", "kernel_interpret", priority=10)
def _attn_kernel_interpret(q, k, v, block_mask, cfg: OpConfig, **kw):
    return _attn_pallas(q, k, v, block_mask, resolve_interpret(cfg, True),
                        cfg=cfg, **kw)
