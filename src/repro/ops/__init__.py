"""``repro.ops`` — the unified public API for all sparse ops.

One polymorphic entry point per op family, with registry-based backend
dispatch, ambient execution config, and §IV-C auto-tiling:

* ``spmm(a, b)`` — SpMM for any registered sparse format (BCSR, WCSR, or
  a ``repro.sparse.SparseTensor``, whose static structure routes host-side
  planning through the ``make_plan`` cache).
* ``sddmm(dc, b, a_struct)`` — sampled dense-dense matmul (training bwd).
* ``sparse_attention(q, k, v, block_mask)`` — block-sparse prefill attention.
* ``bcsr_matmul(values, b, structure)`` — differentiable SpMM over static
  structure (``custom_vjp``: SDDMM + transposed SpMM backward).

Backends flip globally without touching call sites::

    with repro.ops.use_config(impl="kernel_interpret"):
        y = repro.ops.spmm(a, b)

    REPRO_SPARSE_IMPL=ref python serve.py   # env-var flip

Tile widths default to ``bn="auto"`` (paper §IV-C selection), memoized in
a per-process tuning cache keyed by (op, format, shape, dtype, impl).
``make_plan(structure, n, cfg)`` memoizes all host-side planning (tile
selection + the WCSR §III-C task decomposition) per ``SparseStructure`` —
serving plans once per layer and swaps values freely. ``make_partition``
does the same for the mesh-scale shard split (``repro.parallel.sparse``).

Exported symbols (one-liners; see each docstring for the full story):

**Ops** — every entry point accepts call-site keyword overrides
(``impl=``, ``bn=``, ...) that win over the ambient config:

* ``spmm(a, b)`` — sparse @ dense for any registered format:
  ``spmm(a_bcsr, x)``; sharded operands run multi-device. Skinny RHS
  (``n_cols <= spmv_threshold``) auto-dispatches to the ``spmv`` family.
* ``spmv(a, b)`` — sparse @ vector (GEMV row-split kernels, the decode
  fast path); ``b`` may be ``[k]`` or ``[k, n]``. Usually reached via
  ``spmm`` auto-dispatch rather than called directly.
* ``sddmm(dc, b, a_struct)`` — sampled dense-dense matmul onto a block
  structure: ``sddmm(grad_c, b, a)`` (training backward).
* ``sparse_attention(q, k, v, block_mask)`` — block-sparse prefill
  attention over a CSR-encoded block mask.
* ``bcsr_matmul(values, b, structure)`` — differentiable SpMM; values
  carry gradients via SDDMM + transposed-SpMM ``custom_vjp``.
* ``local_bcsr_matmul_t(values, x, structure)`` — shard-local transposed
  SpMM used inside ``shard_map`` model code.
* ``csr_encode_block_mask(mask)`` — boolean block mask -> CSR arrays for
  ``sparse_attention``.

**Structure** — ``BCSRStructure`` (static host-side block layout) and
``structure_of(a)`` (extract it from a BCSR: ``s = structure_of(a)``).

**Config** — ``OpConfig`` (frozen knob bag), ``use_config(impl=...)``
(ambient context: ``with use_config(impl="ref"): ...``),
``current_config()`` / ``resolved_config(**kw)`` (layered resolution),
``ENV_IMPL_VAR`` (the ``REPRO_SPARSE_IMPL`` env-var name).

**Registry** — ``register_backend(op, name)`` (decorator:
``@register_backend("spmm/bcsr", "ref")``), ``register_format(type, op)``,
``resolve_backend(op, impl)``, ``resolve_format(a)``,
``available_backends(op)`` / ``registered_backends(op)`` (introspection).

**Planning + tiling** — ``Plan`` / ``make_plan(structure, n)`` (memoized
host-side plan: ``make_plan(st.structure, n).bn``), ``make_partition(
structure, num_shards)`` (memoized mesh shard split),
``plan_cache_info()`` / ``clear_plan_cache()`` (counters),
``partition_balance_report()`` (per-partition shard-load stats),
``cache_stats()`` (the one unified counter aggregator dashboards consume),
``codec_bytes_report()`` (modeled bytes-moved savings per quantized plan),
``auto_bn(n)`` / ``resolve_bn(bn, n, ...)`` (§IV-C tile width),
``tuning_cache_info()`` / ``clear_tuning_cache()``,
``autotune_spmm(a, b)`` (measured sweep over
``(bn, chunks_per_task, pipeline_depth, value_codec)`` with an accuracy
guard, whose winner steers every ``"auto"`` knob), ``tuned_entry(...)`` /
``resolve_pipeline_depth(...)`` (lookups the planners use),
``set_tune_db(db)`` / ``active_tune_db()`` / ``adopt_tuned_entries(...)``
(persistent tuning-DB wiring: winners survive the process in a
``repro.tune.TuneDB`` — ``REPRO_TUNE_DB`` points every replica at one —
and ``autotune_spmm`` / ``tuned_entry`` consult it before sweeping),
``resolve_spmv_route(threshold, n, ...)`` / ``spmv_dispatch_info()`` /
``DEFAULT_SPMV_THRESHOLD`` (the skinny-N dispatch: route resolution,
its counters, and the fallback crossover),
``resolve_combine_chunks(value, n, ...)`` / ``combine_dispatch_info()`` /
``DEFAULT_COMBINE_CHUNKS`` (the sharded chunked-combine overlap: chunk
count resolution, its counters, and the auto-policy cap).
"""

from repro.ops.attention import csr_encode_block_mask, sparse_attention
from repro.ops.config import (ENV_IMPL_VAR, OpConfig, current_config,
                              resolve_interpret, resolved_config, use_config)
from repro.ops.matmul import (BCSRStructure, bcsr_matmul,
                              local_bcsr_matmul_t, structure_of)
from repro.ops.plan import (Plan, cache_stats, clear_plan_cache,
                            codec_bytes_report, make_partition,
                            make_plan, partition_balance_report,
                            plan_cache_info)
from repro.ops.registry import (available_backends, register_backend,
                                register_format, registered_backends,
                                resolve_backend, resolve_format)
from repro.ops.sddmm import sddmm
from repro.ops.spmm import spmm
from repro.ops.spmv import spmv
from repro.ops.tiling import (DEFAULT_COMBINE_CHUNKS,
                              DEFAULT_SPMV_THRESHOLD, active_tune_db,
                              adopt_tuned_entries, auto_bn,
                              autotune_spmm, clear_tuning_cache,
                              combine_dispatch_info,
                              resolve_bn, resolve_combine_chunks,
                              resolve_pipeline_depth,
                              resolve_spmv_route, set_tune_db,
                              spmv_dispatch_info, tuned_entry,
                              tuning_cache_info)

__all__ = [
    # ops
    "spmm", "spmv", "sddmm", "sparse_attention", "bcsr_matmul",
    "local_bcsr_matmul_t", "csr_encode_block_mask",
    # structure
    "BCSRStructure", "structure_of",
    # config
    "OpConfig", "use_config", "current_config", "resolved_config",
    "ENV_IMPL_VAR",
    # registry
    "register_backend", "register_format", "resolve_backend",
    "resolve_format", "available_backends", "registered_backends",
    # planning + tiling
    "Plan", "make_plan", "make_partition", "plan_cache_info",
    "partition_balance_report", "clear_plan_cache",
    "cache_stats", "codec_bytes_report",
    "auto_bn", "resolve_bn", "tuning_cache_info", "clear_tuning_cache",
    "autotune_spmm", "tuned_entry", "resolve_pipeline_depth",
    # skinny-N (spmv) dispatch
    "resolve_spmv_route", "spmv_dispatch_info", "DEFAULT_SPMV_THRESHOLD",
    # sharded chunked-combine overlap
    "resolve_combine_chunks", "combine_dispatch_info",
    "DEFAULT_COMBINE_CHUNKS",
    # persistent tuning DB (repro.tune) wiring
    "set_tune_db", "active_tune_db", "adopt_tuned_entries",
]
