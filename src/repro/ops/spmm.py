"""Format-polymorphic SpMM: ``spmm(a, b)`` for BCSR / WCSR / SparseTensor.

The single public entry point for the paper's two co-designed kernels
(§III): ``BCSR`` operands route to the block-streaming kernel, ``WCSR``
operands to the window-gather kernel, each with ``kernel`` /
``kernel_interpret`` / ``ref`` backends in the registry. Tile width
defaults to ``bn="auto"`` (§IV-C selection, tuning-cached per shape).

``SparseTensor`` operands (the ``repro.sparse`` layer) are unwrapped here:
their pre-extracted ``SparseStructure`` rides along to the backend, so all
host-side planning (tile selection, the WCSR §III-C task decomposition)
hits the ``make_plan`` cache — planned once per layer, reused every step.
Because that structure is concrete static metadata, a ``SparseTensor`` also
makes the WCSR kernel path traceable under ``jit`` (raw WCSR operands still
raise: their ``window_ptr`` would be a tracer).

Dynamic structures are transparent here: when the operand's structure came
from a ``repro.sparse.delta`` edit, the ``make_plan`` call below patches
the base structure's cached plan (task splice + shifted offsets) instead
of re-planning from scratch — spmm call sites never distinguish a grown
mask from a fresh one.

Multi-device: a ``repro.parallel.sparse.ShardedSparseTensor`` operand
dispatches to the ``"spmm/sharded"`` op family (local kernels per device +
collective combine), and inside a ``use_sparse_mesh(mesh)`` scope plain
``SparseTensor`` operands are auto-sharded over the active mesh — the
partition comes from the ``make_partition`` cache, so repeated calls pay
the structure-aware partitioner once.
"""

from __future__ import annotations

import dataclasses
import inspect
import sys
import warnings

import jax
import jax.numpy as jnp

from repro.kernels.bcsr.kernel import bcsr_spmm_kernel
from repro.kernels.bcsr.ref import bcsr_spmm_ref
from repro.kernels.wcsr.kernel import wcsr_spmm_kernel
from repro.kernels.wcsr.ref import wcsr_spmm_ref
from repro.ops.config import (OpConfig, resolve_interpret,
                              resolved_config)
from repro.ops.plan import make_plan
from repro.ops.registry import (on_tpu, register_backend,
                                resolve_backend, resolve_format)
from repro.ops.tiling import pad_cols, resolve_bn, unpad_cols
from repro.sparse.formats import BCSR, WCSR
from repro.sparse.structure import wcsr_planning_structure
from repro.sparse.tensor import SparseTensor

__all__ = ["spmm"]


def spmm(a, b: jax.Array, *, impl=None, bn=None, out_dtype=None,
         chunks_per_task=None, interpret=None, pipeline_depth=None,
         value_codec=None, spmv_threshold=None, combine_chunks=None,
         **extras) -> jax.Array:
    """``C[m, n] = A_sparse @ B`` for any registered sparse format of ``a``.

    Keyword arguments override the ambient ``use_config(...)`` /
    ``REPRO_SPARSE_IMPL`` configuration for this call only.
    ``pipeline_depth`` sets the §III-A gather-pipeline depth Q on kernel
    paths with an indirect operand (WCSR: 1 = serial, 2 = double buffer,
    3 = the paper's circular buffer; ``"auto"`` consults the measured
    ``autotune_spmm`` cache). ``value_codec`` selects the low-precision
    value representation of the sparse operand (``repro.sparse.codecs``):
    a quantized ``SparseTensor`` always runs under its own codec; an
    unquantized one is quantized here when a codec name is given
    (memoized per tensor), and ``"auto"`` adopts a measured
    ``autotune_spmm`` winner that passed the accuracy guard. Kernels
    receive the compressed payload + per-group scales and dequantize
    in-register — the dequantized matrix is never materialized.
    ``spmv_threshold`` governs the skinny-N fast path: when the RHS has
    ``n_cols <= threshold`` the call auto-dispatches to the ``spmv``
    (GEMV row-split) op family — same numerics, decode-shaped dataflow
    (an int pins the crossover, 0 disables it, ``"auto"`` adopts the
    measured ``autotune_spmm`` route or ``DEFAULT_SPMV_THRESHOLD``).
    ``combine_chunks`` governs the sharded path's chunked
    compute/collective overlap: the output rows split into that many
    chunks whose collectives overlap the next chunk's kernels (1 =
    blocking single combine, ``"auto"`` adopts a tuned winner or the
    size-based policy; ignored for unsharded operands).
    Remaining ``extras`` are forwarded to the backend (e.g. the sharded
    path's ``reduce=``) and validated against its signature — unknown
    keywords raise instead of being silently swallowed.
    """
    if "pipeline_gather" in extras:
        warnings.warn(
            "spmm(pipeline_gather=...) is deprecated; use "
            "pipeline_depth=2 (double buffer) / pipeline_depth=1 (serial) "
            "or OpConfig(pipeline_depth=...)",
            DeprecationWarning, stacklevel=2)
        gather = extras.pop("pipeline_gather")
        if pipeline_depth is None:
            pipeline_depth = 2 if gather else 1
    cfg = resolved_config(impl=impl, bn=bn, out_dtype=out_dtype,
                          chunks_per_task=chunks_per_task,
                          interpret=interpret,
                          pipeline_depth=pipeline_depth,
                          value_codec=value_codec,
                          spmv_threshold=spmv_threshold,
                          combine_chunks=combine_chunks)
    if isinstance(a, SparseTensor):
        a = _resolve_value_codec(a, cfg, int(b.shape[1]))
        a = _maybe_autoshard(a)
    elif cfg.value_codec not in (None, "none", "auto"):
        # an explicit codec must never be a silent no-op (the knob class
        # PR 4's extras validation exists to eliminate): raw BCSR/WCSR
        # containers can't carry payload+scales, so quantize through a
        # one-shot SparseTensor wrap; anything else that can't take the
        # codec raises. ("auto" stays SparseTensor-only — adoption is
        # memoized on the tensor.)
        if isinstance(a, (BCSR, WCSR)):
            a = SparseTensor.wrap(a).quantize(cfg.value_codec)
        elif getattr(a, "codec", "none") != cfg.value_codec:
            raise TypeError(
                f"spmm: value_codec={cfg.value_codec!r} cannot be applied "
                f"to a {type(a).__name__} operand (its codec is "
                f"{getattr(a, 'codec', 'none')!r}); quantize a SparseTensor "
                "(st.quantize(codec)) before sharding/dispatch")
    if isinstance(a, SparseTensor):
        extras.setdefault("structure", a.structure)
        if a.codec != "none":
            # ship the compressed payload; the raw container is only a
            # carrier here — its "values" are the codec payload, and the
            # scales ride to the kernel as a first-class operand
            extras.setdefault("codec", a.codec)
            extras.setdefault("scales", a.scales)
            a = a.structure.attach_values(a.payload)
        else:
            a = a.raw
    op = resolve_format(a)
    if op in ("spmm/bcsr", "spmm/wcsr"):
        op = _dispatch_route(op, a, b, cfg, extras)
    backend = resolve_backend(op, cfg.impl)
    _validate_extras(backend, extras)
    return backend.fn(a, b, cfg, **extras)


def _dispatch_route(op: str, a, b, cfg: OpConfig, extras) -> str:
    """Reroute a skinny-N call to the ``spmv`` op family (decode fast path).

    The crossover comes from ``resolve_spmv_route`` (explicit
    ``spmv_threshold`` int, or the measured ``autotune_spmm`` route /
    ``DEFAULT_SPMV_THRESHOLD`` under ``"auto"``); each decision is tallied
    in ``cache_stats()["spmv"]``. Sharded operands skip this hook — their
    per-device local calls route inside ``sharded_spmm``.
    """
    from repro.ops.tiling import resolve_spmv_route

    fmt = op.split("/", 1)[1]
    st = extras.get("structure")
    if st is not None:
        shape, block = st.shape, st.block
    elif fmt == "wcsr":
        shape, block = a.shape, (a.b_row, a.b_col)
    else:
        shape, block = a.shape, a.block
    route = resolve_spmv_route(cfg.spmv_threshold, b.shape[1], op="spmm",
                               fmt=fmt, shape=shape, block=block,
                               dtype=a.dtype)
    if route == "spmv":
        import repro.ops.spmv  # noqa: F401 — registers the spmv backends

        return f"spmv/{fmt}"
    return op


def _resolve_value_codec(a: SparseTensor, cfg: OpConfig, n: int
                         ) -> SparseTensor:
    """Apply the config's ``value_codec`` to an unquantized operand.

    The operand's own codec always wins (an explicitly quantized tensor is
    a statement about its storage); ``"auto"`` consults the measured
    ``autotune_spmm`` winner for this problem and adopts its codec only if
    one was tuned *and* survived the accuracy guard. Quantized variants
    are memoized on the tensor, so serving pays the encode once per layer.
    """
    if a.codec != "none":
        return a
    want = cfg.value_codec
    if want in (None, "none"):
        return a
    if want == "auto":
        from repro.ops.tiling import tuned_entry

        tuned = tuned_entry("spmm", a.format, a.shape, n, a.block, a.dtype)
        want = (tuned or {}).get("value_codec")
        if want in (None, "none"):
            return a
    return a.quantize(want)


def _validate_extras(backend, extras) -> None:
    """Reject keywords the selected backend does not accept.

    ``**extras`` used to be forwarded blind, so a typo'd knob
    (``pipline_gather=True``) was a silent no-op. Accepted knobs are the
    backend's keyword-accepting parameters beyond the fixed
    ``(a, b, cfg)`` prefix — keyword-only or plain defaults, so externally
    registered backends keep working; anything else raises here. A backend
    with a ``**kwargs`` catch-all opts out entirely.
    """
    if not extras:
        return
    try:
        params = list(inspect.signature(backend.fn).parameters.values())
    except (TypeError, ValueError):  # builtins / C callables: can't check
        return
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return
    positional = [p for p in params
                  if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                                inspect.Parameter.POSITIONAL_OR_KEYWORD)]
    accepted = {p.name for p in params
                if p.kind is inspect.Parameter.KEYWORD_ONLY}
    accepted |= {p.name for p in positional[3:]}  # knobs after (a, b, cfg)
    unknown = sorted(set(extras) - accepted)
    if unknown:
        raise TypeError(
            f"spmm: unknown keyword argument(s) {unknown} for backend "
            f"{backend.name!r}; it accepts {sorted(accepted) or 'none'}")


def _maybe_autoshard(a: SparseTensor):
    """Shard ``a`` over the active ``use_sparse_mesh`` mesh, if any.

    The sparse-mesh context lives in ``repro.parallel.sparse``; if that
    module was never imported no context can be active, so the
    ``sys.modules`` probe keeps ``repro.ops`` free of a hard dependency on
    the parallel layer.
    """
    ps = sys.modules.get("repro.parallel.sparse")
    if ps is None:
        return a
    ctx = ps.current_sparse_mesh()
    if ctx is None:
        return a
    mesh, axis = ctx
    return a.shard(mesh, axis)


# ---------------------------------------------------------------------------
# BCSR backends
# ---------------------------------------------------------------------------
#
# Every backend declares codec support in its signature: ``codec`` names
# the value codec of the (then compressed) ``a`` payload and ``scales``
# carries the per-group f32 scales. Kernel paths fuse the dequant
# in-register; the jnp references materialize the decode (they *are* the
# accuracy oracle for the fused path).


@register_backend("spmm/bcsr", "ref", priority=50)
def _bcsr_spmm_ref(a: BCSR, b, cfg: OpConfig, *, structure=None,
                   codec="none", scales=None):
    del structure  # planning applies to the kernel paths only
    if codec != "none":
        from repro.sparse.codecs import decode_format_values

        a = dataclasses.replace(a, blocks=decode_format_values(
            "bcsr", a.block, a.blocks, scales))
    return bcsr_spmm_ref(a, b, out_dtype=cfg.out_dtype)


def _bcsr_spmm_pallas(a: BCSR, b, cfg: OpConfig, interpret: bool,
                      structure=None, codec="none", scales=None):
    bm, bk = a.block
    n = b.shape[1]
    if structure is not None:
        # same resolve_bn inputs as below -> bit-identical tile selection
        bn = make_plan(structure, n, cfg, dtype=a.dtype, codec=codec).bn
    else:
        bn = resolve_bn(cfg.bn, n, bm, bk, a.dtype, op="spmm", fmt="bcsr",
                        shape=a.shape, impl="kernel")
    (b,), bn_eff, pad = pad_cols([b], n, bn)
    out = bcsr_spmm_kernel(
        a.block_rows,
        a.block_cols,
        a.blocks,
        b,
        scales,
        m_blocks=a.shape[0] // bm,
        block=a.block,
        bn=bn_eff,
        out_dtype=cfg.out_dtype,
        interpret=interpret,
        codec=codec,
    )
    return unpad_cols(out, n, pad)


@register_backend("spmm/bcsr", "kernel", available=on_tpu, priority=100)
def _bcsr_spmm_kernel(a: BCSR, b, cfg: OpConfig, *, structure=None,
                      codec="none", scales=None):
    return _bcsr_spmm_pallas(a, b, cfg, resolve_interpret(cfg, not on_tpu()),
                             structure, codec, scales)


@register_backend("spmm/bcsr", "kernel_interpret", priority=10)
def _bcsr_spmm_kernel_interpret(a: BCSR, b, cfg: OpConfig, *, structure=None,
                                codec="none", scales=None):
    return _bcsr_spmm_pallas(a, b, cfg, resolve_interpret(cfg, True),
                             structure, codec, scales)


# ---------------------------------------------------------------------------
# WCSR backends
# ---------------------------------------------------------------------------


@register_backend("spmm/wcsr", "ref", priority=50)
def _wcsr_spmm_ref(a: WCSR, b, cfg: OpConfig, *, structure=None,
                   codec="none", scales=None):
    del structure  # kernel-path knob; irrelevant to jnp ref
    if codec != "none":
        from repro.sparse.codecs import decode_format_values

        a = dataclasses.replace(a, values=decode_format_values(
            "wcsr", (a.b_row, a.b_col), a.values, scales))
    return wcsr_spmm_ref(a, b, out_dtype=cfg.out_dtype)


def _wcsr_spmm_pallas(a: WCSR, b, cfg: OpConfig, interpret: bool,
                      structure=None, codec="none", scales=None):
    if structure is None:
        if isinstance(a.window_ptr, jax.core.Tracer):
            raise ValueError(
                "spmm on WCSR with impl='kernel'/'kernel_interpret' derives "
                "its static task decomposition from concrete window_ptr "
                "values, so it cannot run under an enclosing jit/vmap trace. "
                "Call it outside jit, wrap the operand in a SparseTensor "
                "(its static structure makes this path traceable), or use "
                "impl='ref' (fully traceable).")
        # ptrs-only structure: O(num_windows) per call, like the old
        # make_wcsr_tasks loop (SparseTensor callers amortize even this)
        structure = wcsr_planning_structure(a)
    n = b.shape[1]
    plan = make_plan(structure, n, cfg, dtype=a.dtype, codec=codec)
    t_win, t_start, t_n = plan.tasks
    (b,), bn_eff, pad = pad_cols([b], n, plan.bn)
    partial = wcsr_spmm_kernel(
        jnp.asarray(t_start),
        jnp.asarray(t_n),
        a.col_idx,
        a.values,
        b,
        scales,
        b_row=a.b_row,
        b_col=a.b_col,
        bn=bn_eff,
        chunks_per_task=plan.chunks_per_task,
        out_dtype=jnp.float32,
        interpret=interpret,
        pipeline_depth=plan.pipeline_depth,
        codec=codec,
    )  # [T, b_row, n_padded]
    # deterministic combine of split-window partials (atomicAdd analogue)
    out = jax.ops.segment_sum(
        partial, jnp.asarray(t_win), num_segments=a.num_windows)
    out = out.reshape(a.shape[0], -1).astype(cfg.out_dtype or b.dtype)
    return unpad_cols(out, n, pad)


@register_backend("spmm/wcsr", "kernel", available=on_tpu, priority=100)
def _wcsr_spmm_kernel(a: WCSR, b, cfg: OpConfig, *, structure=None,
                      codec="none", scales=None):
    return _wcsr_spmm_pallas(a, b, cfg, resolve_interpret(cfg, not on_tpu()),
                             structure, codec, scales)


@register_backend("spmm/wcsr", "kernel_interpret", priority=10)
def _wcsr_spmm_kernel_interpret(a: WCSR, b, cfg: OpConfig, *,
                                structure=None, codec="none", scales=None):
    return _wcsr_spmm_pallas(a, b, cfg, resolve_interpret(cfg, True),
                             structure, codec, scales)
