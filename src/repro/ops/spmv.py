"""Skinny-N SpMV/GEMV op family: the decode-loop fast path.

``spmm`` wastes a full ``bn`` MMA tile on an N=1 decode activation; Yang et
al. (*Design Principles for Sparse Matrix Multiplication on the GPU*) show
sparse@vector wants a structurally different kernel — row-split
multiply-accumulate — and Acc-SpMM's workload grid likewise measures
sparse@vector as its own op family. ``spmv`` is that family here: the same
registry contract as ``spmm`` (``ref`` / ``kernel`` / ``kernel_interpret``
backends per format, ``OpConfig`` knobs, plan-cache amortization, codec
payloads dequantized in-register) over the GEMV kernel bodies in
``repro.kernels`` (``wcsr_spmv_kernel`` / ``bcsr_spmv_kernel``).

Callers rarely invoke ``spmv`` directly: ``spmm`` auto-dispatches here when
``n_cols <= spmv_threshold`` (see ``tiling.resolve_spmv_route``), so the
serve decode tick and ``models.transformer.decode_step`` ride the fast path
with zero call-site changes. The public ``spmv(a, b)`` entry exists for
explicit use and additionally accepts a 1-D ``b`` vector.

The jnp references are shared with ``spmm`` — a GEMV is an N-column SpMM,
so the full-tile refs *are* the accuracy oracle for the vector kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bcsr.kernel import bcsr_spmv_kernel
from repro.kernels.wcsr.kernel import wcsr_spmv_kernel
from repro.ops.config import OpConfig, resolve_interpret
from repro.ops.plan import make_plan
from repro.ops.registry import on_tpu, register_backend
from repro.ops.spmm import _bcsr_spmm_ref, _wcsr_spmm_ref, spmm
from repro.sparse.formats import BCSR, WCSR
from repro.sparse.structure import wcsr_planning_structure

__all__ = ["spmv"]

# any finite RHS width routes to the vector family under this threshold
_FORCE_SPMV = 1 << 30


def spmv(a, b: jax.Array, **knobs) -> jax.Array:
    """``y = A_sparse @ b`` on the GEMV (row-split) kernel family.

    Same operand/knob contract as :func:`repro.ops.spmm` (``impl``, codec
    knobs, ``SparseTensor`` unwrapping, extras validation all shared), but
    the route is pinned to the skinny-N family regardless of width — use
    it when the caller *knows* the RHS is decode-shaped. ``b`` may be a
    1-D ``[k]`` vector (returns ``[m]``) or a ``[k, n]`` matrix.
    """
    if "spmv_threshold" in knobs:
        raise TypeError("spmv() pins the route; pass spmv_threshold to "
                        "spmm() instead")
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    out = spmm(a, b, spmv_threshold=_FORCE_SPMV, **knobs)
    return out[:, 0] if squeeze else out


# ---------------------------------------------------------------------------
# Reference backends: a GEMV is an N-column SpMM, so the spmm refs are
# reused verbatim — one oracle for both routes.
# ---------------------------------------------------------------------------

register_backend("spmv/bcsr", "ref", priority=50)(_bcsr_spmm_ref)
register_backend("spmv/wcsr", "ref", priority=50)(_wcsr_spmm_ref)


# ---------------------------------------------------------------------------
# BCSR backends
# ---------------------------------------------------------------------------


def _bcsr_spmv_pallas(a: BCSR, b, cfg: OpConfig, interpret: bool,
                      structure=None, codec="none", scales=None):
    bm, _ = a.block
    n = b.shape[1]
    if structure is not None:
        # no bn to resolve on the vector path, but the plan lookup keeps
        # the route cache-keyed and the serve amortization counters honest
        make_plan(structure, n, cfg, dtype=a.dtype, codec=codec,
                  route="spmv")
    return bcsr_spmv_kernel(
        a.block_rows,
        a.block_cols,
        a.blocks,
        b,
        scales,
        m_blocks=a.shape[0] // bm,
        block=a.block,
        out_dtype=cfg.out_dtype,
        interpret=interpret,
        codec=codec,
    )


@register_backend("spmv/bcsr", "kernel", available=on_tpu, priority=100)
def _bcsr_spmv_kernel(a: BCSR, b, cfg: OpConfig, *, structure=None,
                      codec="none", scales=None):
    return _bcsr_spmv_pallas(a, b, cfg, resolve_interpret(cfg, not on_tpu()),
                             structure, codec, scales)


@register_backend("spmv/bcsr", "kernel_interpret", priority=10)
def _bcsr_spmv_kernel_interpret(a: BCSR, b, cfg: OpConfig, *, structure=None,
                                codec="none", scales=None):
    return _bcsr_spmv_pallas(a, b, cfg, resolve_interpret(cfg, True),
                             structure, codec, scales)


# ---------------------------------------------------------------------------
# WCSR backends
# ---------------------------------------------------------------------------


def _wcsr_spmv_pallas(a: WCSR, b, cfg: OpConfig, interpret: bool,
                      structure=None, codec="none", scales=None):
    if structure is None:
        if isinstance(a.window_ptr, jax.core.Tracer):
            raise ValueError(
                "spmv on WCSR with impl='kernel'/'kernel_interpret' derives "
                "its static task decomposition from concrete window_ptr "
                "values, so it cannot run under an enclosing jit/vmap trace. "
                "Call it outside jit, wrap the operand in a SparseTensor "
                "(its static structure makes this path traceable), or use "
                "impl='ref' (fully traceable).")
        structure = wcsr_planning_structure(a)
    n = b.shape[1]
    # same §III-C task split and §III-A depth resolution as the spmm path
    # (route-invariant, so the structure-keyed task cache is shared); the
    # route in the key keeps decode plans beside the prefill ones
    plan = make_plan(structure, n, cfg, dtype=a.dtype, codec=codec,
                     route="spmv")
    t_win, t_start, t_n = plan.tasks
    partial = wcsr_spmv_kernel(
        jnp.asarray(t_start),
        jnp.asarray(t_n),
        a.col_idx,
        a.values,
        b,
        scales,
        b_row=a.b_row,
        b_col=a.b_col,
        chunks_per_task=plan.chunks_per_task,
        out_dtype=jnp.float32,
        interpret=interpret,
        pipeline_depth=plan.pipeline_depth,
        codec=codec,
    )  # [T, b_row, n]
    out = jax.ops.segment_sum(
        partial, jnp.asarray(t_win), num_segments=a.num_windows)
    return out.reshape(a.shape[0], -1).astype(cfg.out_dtype or b.dtype)


@register_backend("spmv/wcsr", "kernel", available=on_tpu, priority=100)
def _wcsr_spmv_kernel(a: WCSR, b, cfg: OpConfig, *, structure=None,
                      codec="none", scales=None):
    return _wcsr_spmv_pallas(a, b, cfg, resolve_interpret(cfg, not on_tpu()),
                             structure, codec, scales)


@register_backend("spmv/wcsr", "kernel_interpret", priority=10)
def _wcsr_spmv_kernel_interpret(a: WCSR, b, cfg: OpConfig, *,
                                structure=None, codec="none", scales=None):
    return _wcsr_spmv_pallas(a, b, cfg, resolve_interpret(cfg, True),
                             structure, codec, scales)
