"""Auto-tiling for the unified sparse-op API (paper §IV-C, centralized).

Two pieces the per-kernel dispatchers used to duplicate:

* ``resolve_bn`` / ``auto_bn`` — ``bn="auto"`` routes through
  ``kernels.tuning.select_bn`` (the paper's tile-width policy), memoized in
  a per-process tuning cache keyed by (op, format, shape, dtype, impl) so
  repeated serving shapes skip re-selection.

* ``pad_cols`` / ``unpad_cols`` — the N-padding logic (clamp bn to N for
  narrow operands, zero-pad N up to a bn multiple, slice the pad back off)
  previously copy-pasted in the bcsr, wcsr and sddmm dispatchers.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.kernels.tuning import select_bn

__all__ = ["resolve_bn", "auto_bn", "pad_cols", "unpad_cols",
           "tuning_cache_info", "clear_tuning_cache", "TuningCacheInfo"]


@dataclasses.dataclass
class TuningCacheInfo:
    hits: int
    misses: int
    size: int


_CACHE: dict = {}
_HITS = 0
_MISSES = 0


def clear_tuning_cache() -> None:
    """Drop all memoized §IV-C tile selections; zero the counters."""
    global _HITS, _MISSES
    _CACHE.clear()
    _HITS = 0
    _MISSES = 0


def tuning_cache_info() -> TuningCacheInfo:
    """Hit/miss/size counters for the §IV-C tile-selection cache."""
    return TuningCacheInfo(hits=_HITS, misses=_MISSES, size=len(_CACHE))


def auto_bn(n: int, bm: int = 128, bk: int = 128, dtype=jnp.bfloat16, *,
            op: str = "spmm", fmt: str = "", shape: Tuple[int, ...] = (),
            impl: str = "") -> int:
    """Cached §IV-C tile selection for one (op, format, shape, dtype, impl)."""
    global _HITS, _MISSES
    dtype_bytes = np.dtype(dtype).itemsize
    key = (op, fmt, tuple(shape) + (int(n),), (bm, bk),
           str(np.dtype(dtype)), impl or "")
    hit = _CACHE.get(key)
    if hit is not None:
        _HITS += 1
        return hit
    _MISSES += 1
    bn = select_bn(int(n), bm, bk, dtype_bytes)
    _CACHE[key] = bn
    return bn


def resolve_bn(bn: Union[int, str, None], n: int, bm: int, bk: int, dtype, *,
               op: str = "spmm", fmt: str = "", shape: Tuple[int, ...] = (),
               impl: str = "") -> int:
    """An explicit ``bn`` passes through; ``"auto"``/None selects one."""
    if bn is None or bn == "auto":
        return auto_bn(n, bm, bk, dtype, op=op, fmt=fmt, shape=shape,
                       impl=impl)
    return int(bn)


def pad_cols(arrs, n: int, bn: int):
    """Zero-pad the last dim of each array from ``n`` up to a ``bn`` multiple.

    Returns ``(padded_arrays, bn_eff, pad)``. ``bn_eff`` clamps ``bn`` to
    ``n`` for narrow operands (below the 128-lane width the tile is the
    whole operand) — the rule every dispatcher previously hand-rolled.
    """
    arrs = list(arrs)
    bn_eff = min(bn, n) if n >= 128 else n
    pad = -n % bn_eff
    if pad:
        arrs = [jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) for x in arrs]
    return arrs, bn_eff, pad


def unpad_cols(out, n: int, pad: int):
    """Slice the N padding back off the last dim."""
    return out[..., :n] if pad else out
