"""Auto-tiling for the unified sparse-op API (paper §IV-C, centralized).

Three pieces the per-kernel dispatchers used to duplicate or lacked:

* ``resolve_bn`` / ``auto_bn`` — ``bn="auto"`` routes through
  ``kernels.tuning.select_bn`` (the paper's tile-width policy), memoized in
  a per-process tuning cache keyed by (op, format, shape, dtype, impl) so
  repeated serving shapes skip re-selection.

* ``pad_cols`` / ``unpad_cols`` — the N-padding logic (clamp bn to N for
  narrow operands, zero-pad N up to a bn multiple, slice the pad back off)
  previously copy-pasted in the bcsr, wcsr and sddmm dispatchers.

* ``autotune_spmm`` / ``resolve_pipeline_depth`` — the *measured* tuner
  over ``(bn, chunks_per_task, pipeline_depth)``: paper §IV-C treats tile
  width as the free parameter, and Table 2 shows the async pipeline depth
  (§III-A's Q) matters just as much; Acc-SpMM and cuTeSpMM both tune the
  two together. ``autotune_spmm`` times real ``spmm`` calls per candidate
  and memoizes the winner; ``make_plan`` (and the sddmm/attention
  dispatchers via ``resolve_pipeline_depth``) pick the tuned values up
  whenever the config leaves the knobs on ``"auto"``. Selections are
  counted per depth and surfaced in ``tuning_cache_info()`` (and thus
  ``ServeEngine.stats()``).

* **Persistent tuning DB wiring** (``repro.tune``): when a ``TuneDB`` is
  active — ``set_tune_db(...)`` or the ``REPRO_TUNE_DB`` env var —
  ``tuned_entry`` consults it on an in-process miss (adopting env-valid
  winners), ``autotune_spmm`` checks it *before* sweeping and records
  winners *after*, and ``adopt_tuned_entries`` bulk-preloads records
  (``ServeEngine(tune_db=...)`` warm-start). ``db_hits`` / ``db_misses``
  / ``db_stale`` and the measured-``sweeps`` counter land in
  ``tuning_cache_info()`` so a dashboard can prove a replica warm-started
  (``db_hits > 0, sweeps == 0``) instead of re-paying the sweep.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.kernels.pipeline import validate_depth
from repro.kernels.tuning import select_bn

__all__ = ["resolve_bn", "auto_bn", "pad_cols", "unpad_cols",
           "tuning_cache_info", "clear_tuning_cache", "TuningCacheInfo",
           "autotune_spmm", "tuned_entry", "resolve_pipeline_depth",
           "count_codec_selection", "set_tune_db", "active_tune_db",
           "adopt_tuned_entries", "resolve_spmv_route",
           "spmv_dispatch_info", "DEFAULT_SPMV_THRESHOLD",
           "resolve_combine_chunks", "combine_dispatch_info",
           "DEFAULT_COMBINE_CHUNKS", "COMBINE_MIN_CHUNK_BYTES",
           "ENV_TUNE_ITERS_VAR", "ENV_TUNE_WARMUP_VAR"]

# measured-timing overrides for autotune_spmm (stable DB entries need
# stable measurements; CI smoke can dial them down)
ENV_TUNE_ITERS_VAR = "REPRO_TUNE_ITERS"
ENV_TUNE_WARMUP_VAR = "REPRO_TUNE_WARMUP"


@dataclasses.dataclass
class TuningCacheInfo:
    hits: int
    misses: int
    size: int
    # measured (bn, chunks_per_task, pipeline_depth, value_codec)
    # auto-tune entries
    autotuned: int = 0
    # pipeline-depth selection counters: depth -> number of times a plan /
    # dispatcher resolved that depth (0 = Mosaic implicit pipeline)
    pipeline_depths: Dict[int, int] = dataclasses.field(default_factory=dict)
    # value-codec selection counters: codec name -> number of times a plan
    # resolved with that codec ("none" = raw dense-dtype values)
    value_codecs: Dict[str, int] = dataclasses.field(default_factory=dict)
    # persistent tuning DB (repro.tune) counters: warm-start adoptions,
    # consults that found nothing, consults that found only an
    # env-mismatched (stale) entry, and in-process measured sweeps run
    db_hits: int = 0
    db_misses: int = 0
    db_stale: int = 0
    sweeps: int = 0


_CACHE: dict = {}
_HITS = 0
_MISSES = 0
# measured auto-tune results: key -> {"bn", "chunks_per_task",
# "pipeline_depth", "value_codec", "us"}; key deliberately omits impl so a
# tune measured under kernel_interpret (CPU CI) steers the kernel path too.
_TUNED: dict = {}
# depth -> times resolve_pipeline_depth handed that depth to a kernel plan
_DEPTH_SELECTIONS: Dict[int, int] = {}
# codec name -> times make_plan resolved a plan carrying that codec
_CODEC_SELECTIONS: Dict[str, int] = {}
# persistent tuning DB (repro.tune) state: the explicitly-installed handle
# (set_tune_db), memoized env-var opens, keys known absent (negative cache
# so a hot tuned_entry miss doesn't re-consult the DB per call), counters
_TUNE_DB = None
_ENV_DBS: dict = {}
_DB_NEG: set = set()
_DB_HITS = 0
_DB_MISSES = 0
_DB_STALE = 0
_SWEEPS = 0

# --- skinny-N (SpMV/GEMV) dispatch -----------------------------------------
# Crossover adopted when spmv_threshold="auto" and no measured route exists
# for the shape: decode ticks batch at most a few sequences per slot-group,
# so a handful of columns still pays the full bn tile + per-row DMA costs
# the vector kernels avoid.
DEFAULT_SPMV_THRESHOLD = 4
# autotune_spmm sweeps the route only when N is plausibly skinny; beyond
# this the MMA tile always wins and the extra probes are wasted time.
SPMV_SWEEP_MAX = 16
# route decisions on spmv-eligible ops: "dispatched" = sent to the GEMV
# family, "full_tile" = kept on the bn-wide SpMM kernels
_SPMV_DISPATCH: Dict[str, int] = {"dispatched": 0, "full_tile": 0}

# --- chunked compute/collective overlap (sharded spmm) ----------------------
# Chunk count adopted when combine_chunks="auto", no measured winner exists
# and the output is big enough to amortize the extra collective launches.
DEFAULT_COMBINE_CHUNKS = 4
# "auto" never chunks below this per-chunk output size: the overlap win is
# bounded by the collective time, and a tiny [m, n] slab pays more in
# per-collective launch overhead than it can ever hide.
COMBINE_MIN_CHUNK_BYTES = 256 * 1024
# combine resolutions on sharded spmm calls: "chunked" = overlapped
# multi-chunk pipeline, "blocking" = single whole-output collective;
# "chunks" tallies the resolved chunk count per value
_COMBINE_DISPATCH: Dict[str, object] = {"chunked": 0, "blocking": 0,
                                        "chunks": {}}


def clear_tuning_cache() -> None:
    """Drop all memoized §IV-C tile selections, measured auto-tune entries,
    pipeline-depth / value-codec selection counters, and the tuning-DB
    consult counters (``db_hits``/``db_misses``/``db_stale``/``sweeps`` —
    ``tuning_cache_info()`` never reports stale tallies after a clear).
    The structure-delta counters (``delta_stats()`` and the
    ``plan_patched``/``partition_patched`` tallies) reset too — like the
    DB counters, they are serving-session telemetry, not cache contents.
    The on-disk DB itself and the active handle are untouched: subsequent
    misses consult it afresh."""
    global _HITS, _MISSES, _DB_HITS, _DB_MISSES, _DB_STALE, _SWEEPS
    import sys

    _CACHE.clear()
    _TUNED.clear()
    _DEPTH_SELECTIONS.clear()
    _CODEC_SELECTIONS.clear()
    _SPMV_DISPATCH.update(dispatched=0, full_tile=0)
    _COMBINE_DISPATCH.update(chunked=0, blocking=0, chunks={})
    _DB_NEG.clear()
    _HITS = 0
    _MISSES = 0
    _DB_HITS = 0
    _DB_MISSES = 0
    _DB_STALE = 0
    _SWEEPS = 0
    # local imports: tiling sits below plan/delta in the import graph
    from repro.ops.plan import reset_patch_counters
    from repro.sparse.delta import reset_delta_stats

    reset_patch_counters()
    reset_delta_stats()
    # sys.modules probes: the parallel layer sits above ops in the import
    # graph, so its combine-schedule / hierarchical-psum tallies are only
    # reset when those modules were actually imported
    ps = sys.modules.get("repro.parallel.sparse")
    if ps is not None:
        ps.reset_combine_schedule_counters()
    pc = sys.modules.get("repro.parallel.collectives")
    if pc is not None:
        pc.reset_collective_counters()


def tuning_cache_info() -> TuningCacheInfo:
    """Hit/miss/size counters for the §IV-C tile-selection cache, plus the
    measured auto-tune entry count, per-depth / per-codec selection
    counters, and the persistent-DB consult/sweep counters."""
    # a codec winner is mirrored under its payload dtype key (same dict
    # object), so count distinct winners, not raw entries
    return TuningCacheInfo(hits=_HITS, misses=_MISSES, size=len(_CACHE),
                           autotuned=len({id(v) for v in _TUNED.values()}),
                           pipeline_depths=dict(_DEPTH_SELECTIONS),
                           value_codecs=dict(_CODEC_SELECTIONS),
                           db_hits=_DB_HITS, db_misses=_DB_MISSES,
                           db_stale=_DB_STALE, sweeps=_SWEEPS)


# ---------------------------------------------------------------------------
# Persistent tuning DB (repro.tune) wiring
# ---------------------------------------------------------------------------


def set_tune_db(db):
    """Install (or clear, with ``None``) the process-active ``TuneDB``.

    Accepts a ``repro.tune.TuneDB`` or a path. An installed handle wins
    over the ``REPRO_TUNE_DB`` env var. Returns the handle (or None).
    """
    global _TUNE_DB
    if db is not None and not hasattr(db, "lookup"):
        from repro.tune.db import TuneDB

        db = TuneDB(str(db))
    _TUNE_DB = db
    _DB_NEG.clear()
    return db


def active_tune_db():
    """The ``TuneDB`` consulted by ``tuned_entry`` / ``autotune_spmm``.

    An explicitly installed handle (``set_tune_db`` — what
    ``ServeEngine(tune_db=...)`` uses) wins; otherwise a ``REPRO_TUNE_DB``
    path is opened lazily and memoized per path. None when neither is set
    — every DB feature then degrades to today's in-process behavior. A DB
    that fails to open (bad path, import error) also degrades to None:
    the persistent layer must never take down the op path.
    """
    if _TUNE_DB is not None:
        return _TUNE_DB
    path = os.environ.get("REPRO_TUNE_DB")
    if not path:
        return None
    db = _ENV_DBS.get(path)
    if db is None:
        try:
            from repro.tune.db import TuneDB

            db = TuneDB(path)
        except Exception:  # noqa: BLE001 — degrade, never crash the op path
            db = False
        _ENV_DBS[path] = db
    return db or None


def _install_winner(op: str, fmt: str, shape, n: int, block, dtype,
                    best: dict):
    """Memoize a winner in-process (+ payload-dtype mirror for codecs)."""
    _TUNED[_tuned_key(op, fmt, shape, n, block, dtype)] = best
    if best.get("value_codec") not in (None, "none"):
        # a quantized operand plans under its *payload* dtype; mirror the
        # winner there so "auto" bn / chunks / depth resolve for it too
        from repro.sparse.codecs import get_codec

        pdtype = get_codec(best["value_codec"]).storage_dtype
        _TUNED[_tuned_key(op, fmt, shape, n, block, pdtype)] = best


def adopt_tuned_entries(pairs) -> int:
    """Bulk-adopt DB records into the in-process tuned cache (warm-start).

    ``pairs`` is an iterable of ``(key_tuple, winner_dict)`` as returned by
    ``TuneDB.match`` / ``TuneDB.entries`` — key layout identical to
    ``_tuned_key``. Already-adopted keys are skipped (idempotent: engines
    re-preload at every admission). Each *newly* adopted entry counts one
    ``db_hit``; returns the number adopted.
    """
    global _DB_HITS
    adopted = 0
    for key, winner in pairs:
        if key in _TUNED:
            continue
        op, fmt, shape_n, block, dtype = key
        _install_winner(op, fmt, shape_n[:-1], int(shape_n[-1]), block,
                        dtype, dict(winner))
        _DB_NEG.discard(key)
        _DB_HITS += 1
        adopted += 1
    if adopted:
        from repro.ops.plan import drop_auto_plans

        drop_auto_plans()
    return adopted


def _db_consult(key) -> Optional[dict]:
    """DB lookup behind an in-process ``tuned_entry`` miss (negative-cached)."""
    global _DB_HITS, _DB_MISSES, _DB_STALE
    db = active_tune_db()
    if db is None or key in _DB_NEG:
        return None
    status, winner = db.lookup(key)
    if status == "hit":
        _DB_HITS += 1
        op, fmt, shape_n, block, dtype = key
        winner = dict(winner)
        _install_winner(op, fmt, shape_n[:-1], int(shape_n[-1]), block,
                        dtype, winner)
        return winner
    if status == "stale":
        _DB_STALE += 1
    else:
        _DB_MISSES += 1
    _DB_NEG.add(key)
    return None


def _env_tune_int(var: str, default: int, minimum: int) -> int:
    """Parse a timing env override; malformed values fall back loudly-ish
    (ignored) rather than crashing a tune in a mis-set environment."""
    raw = os.environ.get(var)
    if not raw:
        return default
    try:
        return max(minimum, int(raw))
    except ValueError:
        return default


def count_codec_selection(codec: str) -> None:
    """Count one plan resolution under ``codec`` (``make_plan`` calls this
    for every plan lookup, mirroring the pipeline-depth counters)."""
    codec = codec or "none"
    _CODEC_SELECTIONS[codec] = _CODEC_SELECTIONS.get(codec, 0) + 1


def spmv_dispatch_info() -> Dict[str, int]:
    """Skinny-N route counters: ``{"dispatched": spmv, "full_tile": spmm}``.

    Every route resolution on an spmv-eligible op bumps one side, so the
    serving dashboard can prove decode traffic actually rides the GEMV
    family (``dispatched`` grows per tick) while prefill stays on the
    tile-parallel kernels. Surfaced as ``cache_stats()["spmv"]`` and in
    ``ServeEngine.stats()``; reset by ``clear_tuning_cache``.
    """
    return dict(_SPMV_DISPATCH)


def _count_route(route: str, count: bool) -> None:
    if count:
        key = "dispatched" if route == "spmv" else "full_tile"
        _SPMV_DISPATCH[key] = _SPMV_DISPATCH[key] + 1


def resolve_spmv_route(threshold: Union[int, str, None], n: int, *,
                       op: str = "spmm", fmt: str = "", shape=None,
                       block=(128, 128), dtype=jnp.float32,
                       count: bool = True) -> str:
    """Resolve the SpMM-vs-SpMV route for an N-column RHS.

    An explicit int threshold pins the crossover (``n <= t`` routes to
    ``"spmv"``; 0 disables the vector path outright). ``"auto"``/None
    prefers a *measured* route — the ``"route"`` field of an
    ``autotune_spmm`` / ``TuneDB`` winner for this problem, when ``shape``
    is known — and otherwise falls back to the static
    ``DEFAULT_SPMV_THRESHOLD`` crossover. The decision is tallied in
    ``spmv_dispatch_info()`` unless ``count=False`` (pre-flight probes).
    """
    n = int(n)
    if threshold not in (None, "auto"):
        t = int(threshold)
        route = "spmv" if (t > 0 and n <= t) else "spmm"
        _count_route(route, count)
        return route
    if shape is not None:
        tuned = tuned_entry(op, fmt, shape, n, block, dtype)
        if tuned is not None and tuned.get("route") in ("spmm", "spmv"):
            route = tuned["route"]
            _count_route(route, count)
            return route
    route = "spmv" if n <= DEFAULT_SPMV_THRESHOLD else "spmm"
    _count_route(route, count)
    return route


def combine_dispatch_info() -> Dict[str, object]:
    """Chunked-combine counters: ``{"chunked", "blocking", "chunks"}``.

    Every ``resolve_combine_chunks`` call on a sharded spmm bumps
    ``chunked`` (resolved count > 1: the overlapped per-chunk pipeline) or
    ``blocking`` (count 1: one whole-output collective), and tallies the
    resolved count in ``chunks``. Surfaced as part of
    ``cache_stats()["combine"]`` and ``ServeEngine.stats()``; reset by
    ``clear_tuning_cache``.
    """
    out = dict(_COMBINE_DISPATCH)
    out["chunks"] = dict(_COMBINE_DISPATCH["chunks"])
    return out


def resolve_combine_chunks(value: Union[int, str, None], n: int, *,
                           num_groups: int, num_shards: int,
                           op: str = "spmm", fmt: str = "", shape=None,
                           block=(128, 128), dtype=jnp.float32,
                           count: bool = True) -> int:
    """Resolve the sharded-spmm combine chunk count for one call.

    An explicit int pins it (clamped to ``[1, num_groups]`` — a chunk must
    cover at least one window / block-row). ``"auto"``/None prefers a
    measured ``autotune_spmm`` winner's ``"combine_chunks"`` when ``shape``
    is known, else the static policy: chunk only multi-shard calls whose
    output is large enough that each chunk's ``[rows, n]`` slab clears
    ``COMBINE_MIN_CHUNK_BYTES`` (small outputs pay more in extra collective
    launches than the overlap can hide), capped at
    ``DEFAULT_COMBINE_CHUNKS``. The decision is tallied in
    ``combine_dispatch_info()`` unless ``count=False`` (pre-flight probes).
    """
    num_groups = max(int(num_groups), 1)
    if value not in (None, "auto"):
        cc = max(1, min(int(value), num_groups))
    else:
        cc = None
        if shape is not None:
            tuned = tuned_entry(op, fmt, shape, int(n), block, dtype)
            if tuned is not None and tuned.get("combine_chunks") is not None:
                cc = max(1, min(int(tuned["combine_chunks"]), num_groups))
        if cc is None:
            if int(num_shards) <= 1:
                cc = 1
            else:
                m = int(shape[0]) if shape is not None else num_groups
                out_bytes = m * int(n) * 4  # f32 partials
                cc = min(DEFAULT_COMBINE_CHUNKS, num_groups,
                         max(1, out_bytes // COMBINE_MIN_CHUNK_BYTES))
    if count:
        key = "chunked" if cc > 1 else "blocking"
        _COMBINE_DISPATCH[key] = _COMBINE_DISPATCH[key] + 1
        tally = _COMBINE_DISPATCH["chunks"]
        tally[cc] = tally.get(cc, 0) + 1
    return cc


def auto_bn(n: int, bm: int = 128, bk: int = 128, dtype=jnp.bfloat16, *,
            op: str = "spmm", fmt: str = "", shape: Tuple[int, ...] = (),
            impl: str = "") -> int:
    """Cached §IV-C tile selection for one (op, format, shape, dtype, impl)."""
    global _HITS, _MISSES
    dtype_bytes = np.dtype(dtype).itemsize
    key = (op, fmt, tuple(shape) + (int(n),), (bm, bk),
           str(np.dtype(dtype)), impl or "")
    hit = _CACHE.get(key)
    if hit is not None:
        _HITS += 1
        return hit
    _MISSES += 1
    bn = select_bn(int(n), bm, bk, dtype_bytes)
    _CACHE[key] = bn
    return bn


def resolve_bn(bn: Union[int, str, None], n: int, bm: int, bk: int, dtype, *,
               op: str = "spmm", fmt: str = "", shape: Tuple[int, ...] = (),
               impl: str = "") -> int:
    """An explicit ``bn`` passes through; ``"auto"``/None selects one —
    preferring a measured ``autotune_spmm`` winner over the §IV-C policy."""
    if bn is None or bn == "auto":
        tuned = tuned_entry(op, fmt, shape, n, (bm, bk), dtype)
        if tuned is not None:
            return int(tuned["bn"])
        return auto_bn(n, bm, bk, dtype, op=op, fmt=fmt, shape=shape,
                       impl=impl)
    return int(bn)


def pad_cols(arrs, n: int, bn: int):
    """Zero-pad the last dim of each array from ``n`` up to a ``bn`` multiple.

    Returns ``(padded_arrays, bn_eff, pad)``. ``bn_eff`` clamps ``bn`` to
    ``n`` for narrow operands (below the 128-lane width the tile is the
    whole operand) — the rule every dispatcher previously hand-rolled.
    """
    arrs = list(arrs)
    bn_eff = min(bn, n) if n >= 128 else n
    pad = -n % bn_eff
    if pad:
        arrs = [jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) for x in arrs]
    return arrs, bn_eff, pad


def unpad_cols(out, n: int, pad: int):
    """Slice the N padding back off the last dim."""
    return out[..., :n] if pad else out


# ---------------------------------------------------------------------------
# Measured auto-tune over (bn, chunks_per_task, pipeline_depth)
# ---------------------------------------------------------------------------


def _tuned_key(op: str, fmt: str, shape, n: int, block, dtype):
    return (op, fmt or "", tuple(shape) + (int(n),),
            (int(block[0]), int(block[1])), str(np.dtype(dtype)))


def tuned_entry(op: str, fmt: str, shape, n: int, block, dtype
                ) -> Optional[dict]:
    """The measured auto-tune winner for this problem, or None.

    In-process winners (this process ran ``autotune_spmm``, or a DB entry
    was already adopted) are a dict hit; otherwise the active persistent
    ``TuneDB`` (``set_tune_db`` / ``REPRO_TUNE_DB``) is consulted once per
    key — env-valid records are adopted (``db_hits``), absent or
    env-mismatched ones fall back to the analytical policies
    (``db_misses`` / ``db_stale``) and are negative-cached.
    """
    key = _tuned_key(op, fmt, shape, n, block, dtype)
    entry = _TUNED.get(key)
    if entry is not None:
        return entry
    return _db_consult(key)


def resolve_pipeline_depth(depth: Union[int, str, None], *, default: int,
                           op: str = "spmm", fmt: str = "", shape=(),
                           n: int = 0, block=(128, 128),
                           dtype=jnp.bfloat16,
                           floor: int = 0) -> int:
    """Resolve the §III-A pipeline depth Q for one kernel launch.

    An explicit int pins it; ``"auto"``/None takes a measured
    ``autotune_spmm`` winner when one is cached for this problem, else
    ``default`` (WCSR: 1 — the paper's serial gather; SDDMM / block
    attention: 0 — Mosaic's implicit grid pipeline). Depth 0 means "no
    explicit pipeline, use the kernel's implicit/serial scheme"; kernels
    with no Mosaic path for the operand (WCSR's gather) pass ``floor=1``
    so an engine-wide ``pipeline_depth=0`` degrades to the serial gather
    instead of failing inside the kernel. Every resolution is counted per
    depth in ``tuning_cache_info().pipeline_depths``.
    """
    if depth is None or depth == "auto":
        tuned = tuned_entry(op, fmt, shape, n, block, dtype)
        if tuned is not None and tuned.get("pipeline_depth") is not None:
            depth = tuned["pipeline_depth"]
        else:
            depth = default
    depth = max(validate_depth(depth, allow_zero=True), floor)
    _DEPTH_SELECTIONS[depth] = _DEPTH_SELECTIONS.get(depth, 0) + 1
    return depth


def _time_us(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median-of-``iters`` wall time (microseconds) after ``warmup`` calls.

    Median, not min: persistent DB entries are reused across replica
    lifetimes, so a winner picked off one lucky minimum would bake
    measurement noise into the fleet. ``REPRO_TUNE_ITERS`` /
    ``REPRO_TUNE_WARMUP`` raise the sample count for tunes whose winners
    are meant to be committed (``autotune_spmm`` resolves them).
    """
    import time

    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def autotune_spmm(a, b, *, depths=None, bns=None, chunks_per_task=None,
                  codecs=None, codec_tol: float = 0.05,
                  impl=None, warmup: Optional[int] = None,
                  iters: Optional[int] = None, use_db: bool = True,
                  mesh=None, mesh_axes="data",
                  combine_chunks=None) -> dict:
    """Measured sweep over ``(bn, chunks_per_task, pipeline_depth,
    value_codec)`` — plus ``combine_chunks`` when a ``mesh`` is given.

    **Sharded sweep:** pass ``mesh`` (and ``mesh_axes``, default
    ``"data"``) to time the *sharded* spmm path instead — each candidate
    combo additionally sweeps the chunked-combine count
    (``combine_chunks`` candidates; default ``(1, 2,
    DEFAULT_COMBINE_CHUNKS)``), so the winner's ``"combine_chunks"`` field
    turns the ``combine_chunks="auto"`` policy into a measured per-shape
    decision (picked up by ``resolve_combine_chunks``, persisted via the
    ``TuneDB`` like every other knob). Without a mesh the winner records
    ``"combine_chunks": None`` — unsharded calls have no combine.

    Times real ``repro.ops.spmm(a, b)`` calls for every candidate combo,
    memoizes the winner for this (format, shape, N, block, dtype) problem,
    and returns it as ``{"bn", "chunks_per_task", "pipeline_depth",
    "value_codec", "route", "us", "rejected_codecs"}``. Skinny problems
    (``n <= SPMV_SWEEP_MAX``) additionally race the GEMV (``spmv``) route
    against the tile kernels, so the winner's ``"route"`` turns the
    ``spmv_threshold="auto"`` crossover into a measured per-shape decision
    (picked up by ``resolve_spmv_route``, persisted via the ``TuneDB``). Subsequent ``make_plan`` /
    ``spmm`` calls whose config leaves ``bn`` / ``chunks_per_task`` /
    ``pipeline_depth`` on ``"auto"`` adopt the tuned values (stale
    auto-``bn`` plans are dropped so they re-resolve; task splits and mesh
    partitions are untouched). The tuned ``value_codec`` is adopted only by
    calls that opt in with ``value_codec="auto"`` — quantization changes
    numerics, so it never rides along silently.

    **Persistent DB:** with a ``TuneDB`` active (``set_tune_db`` /
    ``REPRO_TUNE_DB``) and ``use_db=True``, an env-valid DB record for
    this problem is adopted *without measuring* (a ``db_hit``; the record
    already carries its guard verdicts), and a freshly measured winner is
    committed back to the DB. ``use_db=False`` forces the in-process sweep
    and skips the commit — what the offline tune farm runs. Each candidate
    is timed as the **median** of ``iters`` runs after ``warmup`` calls;
    both default from ``REPRO_TUNE_ITERS`` / ``REPRO_TUNE_WARMUP`` (else
    3 / 1) so committed entries can be measured with more samples than an
    ad-hoc in-process tune.

    **Accuracy guard:** each non-``"none"`` codec candidate is first
    checked against the f32 ``impl="ref"`` result; a codec whose
    max-abs error exceeds ``codec_tol * max|ref|`` is rejected outright
    (reported in ``"rejected_codecs"``) and none of its combos are timed
    or eligible to win. The default tolerance (0.05) comfortably covers
    per-block int8 (~0.4% of the block max per value) and emulated
    fp8_e4m3 (~6% per value, averaging out over the contraction) on
    well-scaled data; tighten it to reject fp8 on cancellation-heavy
    matrices.

    ``a`` is a ``SparseTensor`` or raw BCSR/WCSR operand (quantized
    operands are decoded first: the tuner owns the codec choice);
    candidates default per format — WCSR sweeps all four knobs, BCSR
    (Mosaic-managed pipeline) sweeps ``bn`` and the codec. ``codecs``
    defaults to ``("none", "int8")``; pass ``("none", "int8",
    "fp8_e4m3")`` to include the emulated fp8 path. ``impl`` defaults to
    the registry pick (interpret-mode kernels on CPU), so CI can exercise
    the tuner; on TPU the same call measures compiled kernels.
    """
    global _DB_HITS, _DB_MISSES, _DB_STALE, _SWEEPS

    from repro.ops.config import use_config
    from repro.ops.plan import drop_auto_plans
    from repro.ops.spmm import spmm
    from repro.sparse.codecs import get_codec
    from repro.sparse.tensor import SparseTensor

    import jax

    base = a if isinstance(a, SparseTensor) else SparseTensor.wrap(a)
    if base.codec != "none":
        base = base.dequantize()
    st = base.structure
    n = int(b.shape[1])
    bm, bk = st.block
    dtype = base.dtype
    warmup = (_env_tune_int(ENV_TUNE_WARMUP_VAR, 1, minimum=0)
              if warmup is None else int(warmup))
    iters = (_env_tune_int(ENV_TUNE_ITERS_VAR, 3, minimum=1)
             if iters is None else int(iters))
    db = active_tune_db() if use_db else None
    key = _tuned_key("spmm", st.fmt, st.shape, n, st.block, dtype)
    if db is not None:
        status, winner = db.lookup(key)
        if status == "hit":
            _DB_HITS += 1
            winner = dict(winner)
            winner.setdefault("rejected_codecs", {})
            _install_winner("spmm", st.fmt, st.shape, n, st.block, dtype,
                            winner)
            _DB_NEG.discard(key)
            drop_auto_plans()
            return dict(winner)
        if status == "stale":
            _DB_STALE += 1
        else:
            _DB_MISSES += 1
    _SWEEPS += 1
    if bns is None:
        policy = select_bn(n, bm, bk, np.dtype(dtype).itemsize)
        bns = tuple(dict.fromkeys(
            c for c in (policy, 128, 256) if c <= max(n, 128)))
    if st.fmt == "wcsr":
        depths = (1, 2, 3) if depths is None else depths
        chunks = (4, 8) if chunks_per_task is None else chunks_per_task
    else:
        # BCSR keeps its contiguous streams on Mosaic's implicit pipeline
        # (see kernels/bcsr/kernel.py); only the tile width is tunable.
        depths = (None,) if depths is None else depths
        chunks = (None,) if chunks_per_task is None else chunks_per_task
    codecs = ("none", "int8") if codecs is None else codecs
    # skinny problems race the GEMV family against the tile kernels so the
    # spmv crossover becomes a *measured* per-shape decision (the winner's
    # "route" is what spmv_threshold="auto" adopts via resolve_spmv_route)
    routes = ("spmm", "spmv") if n <= SPMV_SWEEP_MAX else ("spmm",)
    if mesh is None:
        ccs = (None,)
    elif combine_chunks is None:
        ccs = tuple(dict.fromkeys((1, 2, DEFAULT_COMBINE_CHUNKS)))
    else:
        ccs = tuple(dict.fromkeys(int(c) for c in combine_chunks))
    best = None
    rejected = {}
    # the sweep itself resolves every candidate depth/codec/route (and its
    # spmm probes consult the DB through make_plan); snapshot the selection
    # and DB-consult counters so the dashboard reflects only what real
    # traffic runs with, not the tuner's probing
    depth_counters = dict(_DEPTH_SELECTIONS)
    codec_counters = dict(_CODEC_SELECTIONS)
    spmv_counters = dict(_SPMV_DISPATCH)
    combine_counters = combine_dispatch_info()
    db_counters = (_DB_HITS, _DB_MISSES, _DB_STALE)
    try:
        ref = None
        operands = []  # (codec_name, operand) pairs that passed the guard
        for cname in codecs:
            cname = get_codec(cname).name  # validates
            if cname == "none":
                operands.append(("none", base))
                continue
            aq = base.quantize(cname)
            if ref is None:
                ref = np.asarray(spmm(base, b, impl="ref"))
            with use_config(impl=impl):
                got = np.asarray(spmm(aq, b))
            err = float(np.max(np.abs(got - ref))
                        / (np.max(np.abs(ref)) + 1e-12))
            if err > codec_tol:
                rejected[cname] = err
                continue
            operands.append((cname, aq))
        for cname, operand in operands:
            # the sharded sweep times the mesh path the serving call runs
            # (local kernels + chunked combine); the accuracy guard above
            # stays single-device — numerics are combine-invariant
            timed = operand if mesh is None else operand.shard(mesh,
                                                               mesh_axes)
            for route in routes:
                # the vector path has no bn tile, so sweeping widths there
                # would just re-time identical launches
                route_bns = bns if route == "spmm" else bns[:1]
                thr = n if route == "spmv" else 0
                for bn in route_bns:
                    for cpt in chunks:
                        for depth in depths:
                            for cc in ccs:
                                with use_config(impl=impl, bn=bn,
                                                chunks_per_task=cpt,
                                                pipeline_depth=depth,
                                                spmv_threshold=thr,
                                                combine_chunks=cc):
                                    f = jax.jit(
                                        lambda b_: spmm(timed, b_))
                                    us = _time_us(f, b, warmup=warmup,
                                                  iters=iters)
                                cand = {"bn": int(bn),
                                        "chunks_per_task": cpt if cpt is None
                                        else int(cpt),
                                        "pipeline_depth": depth if depth is
                                        None else int(depth),
                                        "value_codec": cname,
                                        "route": route,
                                        "combine_chunks": cc if cc is None
                                        else int(cc),
                                        "us": us}
                                if best is None or us < best["us"]:
                                    best = cand
    finally:
        _DEPTH_SELECTIONS.clear()
        _DEPTH_SELECTIONS.update(depth_counters)
        _CODEC_SELECTIONS.clear()
        _CODEC_SELECTIONS.update(codec_counters)
        _SPMV_DISPATCH.clear()
        _SPMV_DISPATCH.update(spmv_counters)
        _COMBINE_DISPATCH.clear()
        _COMBINE_DISPATCH.update(combine_counters)
        _DB_HITS, _DB_MISSES, _DB_STALE = db_counters
    if best is None:
        # every candidate codec failed the guard and "none" wasn't swept:
        # nothing was timed, so there is no winner to cache
        raise ValueError(
            "autotune_spmm: every candidate codec was rejected by the "
            f"accuracy guard (codec_tol={codec_tol}): "
            + ", ".join(f"{c}: err={e:.4g}" for c, e in rejected.items())
            + "; include 'none' in codecs= or loosen codec_tol")
    best["rejected_codecs"] = rejected
    _install_winner("spmm", st.fmt, st.shape, n, st.block, dtype, best)
    if db is not None:
        # commit the freshly measured winner; the append is atomic and
        # merge-safe, and a write failure must never fail the tune itself
        try:
            db.record(key, best, structure=st.content_digest(),
                      source="autotune")
        except OSError:
            pass
        _DB_NEG.discard(key)
    # auto-plans cached before this tune baked in the old bn selection;
    # task splits, partitions and counters are tune-invariant and kept
    drop_auto_plans()
    return dict(best)
