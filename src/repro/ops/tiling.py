"""Auto-tiling for the unified sparse-op API (paper §IV-C, centralized).

Three pieces the per-kernel dispatchers used to duplicate or lacked:

* ``resolve_bn`` / ``auto_bn`` — ``bn="auto"`` routes through
  ``kernels.tuning.select_bn`` (the paper's tile-width policy), memoized in
  a per-process tuning cache keyed by (op, format, shape, dtype, impl) so
  repeated serving shapes skip re-selection.

* ``pad_cols`` / ``unpad_cols`` — the N-padding logic (clamp bn to N for
  narrow operands, zero-pad N up to a bn multiple, slice the pad back off)
  previously copy-pasted in the bcsr, wcsr and sddmm dispatchers.

* ``autotune_spmm`` / ``resolve_pipeline_depth`` — the *measured* tuner
  over ``(bn, chunks_per_task, pipeline_depth)``: paper §IV-C treats tile
  width as the free parameter, and Table 2 shows the async pipeline depth
  (§III-A's Q) matters just as much; Acc-SpMM and cuTeSpMM both tune the
  two together. ``autotune_spmm`` times real ``spmm`` calls per candidate
  and memoizes the winner; ``make_plan`` (and the sddmm/attention
  dispatchers via ``resolve_pipeline_depth``) pick the tuned values up
  whenever the config leaves the knobs on ``"auto"``. Selections are
  counted per depth and surfaced in ``tuning_cache_info()`` (and thus
  ``ServeEngine.stats()``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.kernels.pipeline import validate_depth
from repro.kernels.tuning import select_bn

__all__ = ["resolve_bn", "auto_bn", "pad_cols", "unpad_cols",
           "tuning_cache_info", "clear_tuning_cache", "TuningCacheInfo",
           "autotune_spmm", "tuned_entry", "resolve_pipeline_depth",
           "count_codec_selection"]


@dataclasses.dataclass
class TuningCacheInfo:
    hits: int
    misses: int
    size: int
    # measured (bn, chunks_per_task, pipeline_depth, value_codec)
    # auto-tune entries
    autotuned: int = 0
    # pipeline-depth selection counters: depth -> number of times a plan /
    # dispatcher resolved that depth (0 = Mosaic implicit pipeline)
    pipeline_depths: Dict[int, int] = dataclasses.field(default_factory=dict)
    # value-codec selection counters: codec name -> number of times a plan
    # resolved with that codec ("none" = raw dense-dtype values)
    value_codecs: Dict[str, int] = dataclasses.field(default_factory=dict)


_CACHE: dict = {}
_HITS = 0
_MISSES = 0
# measured auto-tune results: key -> {"bn", "chunks_per_task",
# "pipeline_depth", "value_codec", "us"}; key deliberately omits impl so a
# tune measured under kernel_interpret (CPU CI) steers the kernel path too.
_TUNED: dict = {}
# depth -> times resolve_pipeline_depth handed that depth to a kernel plan
_DEPTH_SELECTIONS: Dict[int, int] = {}
# codec name -> times make_plan resolved a plan carrying that codec
_CODEC_SELECTIONS: Dict[str, int] = {}


def clear_tuning_cache() -> None:
    """Drop all memoized §IV-C tile selections, measured auto-tune entries
    and pipeline-depth / value-codec selection counters."""
    global _HITS, _MISSES
    _CACHE.clear()
    _TUNED.clear()
    _DEPTH_SELECTIONS.clear()
    _CODEC_SELECTIONS.clear()
    _HITS = 0
    _MISSES = 0


def tuning_cache_info() -> TuningCacheInfo:
    """Hit/miss/size counters for the §IV-C tile-selection cache, plus the
    measured auto-tune entry count and per-depth / per-codec selection
    counters."""
    # a codec winner is mirrored under its payload dtype key (same dict
    # object), so count distinct winners, not raw entries
    return TuningCacheInfo(hits=_HITS, misses=_MISSES, size=len(_CACHE),
                           autotuned=len({id(v) for v in _TUNED.values()}),
                           pipeline_depths=dict(_DEPTH_SELECTIONS),
                           value_codecs=dict(_CODEC_SELECTIONS))


def count_codec_selection(codec: str) -> None:
    """Count one plan resolution under ``codec`` (``make_plan`` calls this
    for every plan lookup, mirroring the pipeline-depth counters)."""
    codec = codec or "none"
    _CODEC_SELECTIONS[codec] = _CODEC_SELECTIONS.get(codec, 0) + 1


def auto_bn(n: int, bm: int = 128, bk: int = 128, dtype=jnp.bfloat16, *,
            op: str = "spmm", fmt: str = "", shape: Tuple[int, ...] = (),
            impl: str = "") -> int:
    """Cached §IV-C tile selection for one (op, format, shape, dtype, impl)."""
    global _HITS, _MISSES
    dtype_bytes = np.dtype(dtype).itemsize
    key = (op, fmt, tuple(shape) + (int(n),), (bm, bk),
           str(np.dtype(dtype)), impl or "")
    hit = _CACHE.get(key)
    if hit is not None:
        _HITS += 1
        return hit
    _MISSES += 1
    bn = select_bn(int(n), bm, bk, dtype_bytes)
    _CACHE[key] = bn
    return bn


def resolve_bn(bn: Union[int, str, None], n: int, bm: int, bk: int, dtype, *,
               op: str = "spmm", fmt: str = "", shape: Tuple[int, ...] = (),
               impl: str = "") -> int:
    """An explicit ``bn`` passes through; ``"auto"``/None selects one —
    preferring a measured ``autotune_spmm`` winner over the §IV-C policy."""
    if bn is None or bn == "auto":
        tuned = tuned_entry(op, fmt, shape, n, (bm, bk), dtype)
        if tuned is not None:
            return int(tuned["bn"])
        return auto_bn(n, bm, bk, dtype, op=op, fmt=fmt, shape=shape,
                       impl=impl)
    return int(bn)


def pad_cols(arrs, n: int, bn: int):
    """Zero-pad the last dim of each array from ``n`` up to a ``bn`` multiple.

    Returns ``(padded_arrays, bn_eff, pad)``. ``bn_eff`` clamps ``bn`` to
    ``n`` for narrow operands (below the 128-lane width the tile is the
    whole operand) — the rule every dispatcher previously hand-rolled.
    """
    arrs = list(arrs)
    bn_eff = min(bn, n) if n >= 128 else n
    pad = -n % bn_eff
    if pad:
        arrs = [jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) for x in arrs]
    return arrs, bn_eff, pad


def unpad_cols(out, n: int, pad: int):
    """Slice the N padding back off the last dim."""
    return out[..., :n] if pad else out


# ---------------------------------------------------------------------------
# Measured auto-tune over (bn, chunks_per_task, pipeline_depth)
# ---------------------------------------------------------------------------


def _tuned_key(op: str, fmt: str, shape, n: int, block, dtype):
    return (op, fmt or "", tuple(shape) + (int(n),),
            (int(block[0]), int(block[1])), str(np.dtype(dtype)))


def tuned_entry(op: str, fmt: str, shape, n: int, block, dtype
                ) -> Optional[dict]:
    """The measured auto-tune winner for this problem, or None."""
    return _TUNED.get(_tuned_key(op, fmt, shape, n, block, dtype))


def resolve_pipeline_depth(depth: Union[int, str, None], *, default: int,
                           op: str = "spmm", fmt: str = "", shape=(),
                           n: int = 0, block=(128, 128),
                           dtype=jnp.bfloat16,
                           floor: int = 0) -> int:
    """Resolve the §III-A pipeline depth Q for one kernel launch.

    An explicit int pins it; ``"auto"``/None takes a measured
    ``autotune_spmm`` winner when one is cached for this problem, else
    ``default`` (WCSR: 1 — the paper's serial gather; SDDMM / block
    attention: 0 — Mosaic's implicit grid pipeline). Depth 0 means "no
    explicit pipeline, use the kernel's implicit/serial scheme"; kernels
    with no Mosaic path for the operand (WCSR's gather) pass ``floor=1``
    so an engine-wide ``pipeline_depth=0`` degrades to the serial gather
    instead of failing inside the kernel. Every resolution is counted per
    depth in ``tuning_cache_info().pipeline_depths``.
    """
    if depth is None or depth == "auto":
        tuned = tuned_entry(op, fmt, shape, n, block, dtype)
        if tuned is not None and tuned.get("pipeline_depth") is not None:
            depth = tuned["pipeline_depth"]
        else:
            depth = default
    depth = max(validate_depth(depth, allow_zero=True), floor)
    _DEPTH_SELECTIONS[depth] = _DEPTH_SELECTIONS.get(depth, 0) + 1
    return depth


def _time_us(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    import time

    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def autotune_spmm(a, b, *, depths=None, bns=None, chunks_per_task=None,
                  codecs=None, codec_tol: float = 0.05,
                  impl=None, warmup: int = 1, iters: int = 3) -> dict:
    """Measured sweep over ``(bn, chunks_per_task, pipeline_depth,
    value_codec)``.

    Times real ``repro.ops.spmm(a, b)`` calls for every candidate combo,
    memoizes the winner for this (format, shape, N, block, dtype) problem,
    and returns it as ``{"bn", "chunks_per_task", "pipeline_depth",
    "value_codec", "us", "rejected_codecs"}``. Subsequent ``make_plan`` /
    ``spmm`` calls whose config leaves ``bn`` / ``chunks_per_task`` /
    ``pipeline_depth`` on ``"auto"`` adopt the tuned values (stale
    auto-``bn`` plans are dropped so they re-resolve; task splits and mesh
    partitions are untouched). The tuned ``value_codec`` is adopted only by
    calls that opt in with ``value_codec="auto"`` — quantization changes
    numerics, so it never rides along silently.

    **Accuracy guard:** each non-``"none"`` codec candidate is first
    checked against the f32 ``impl="ref"`` result; a codec whose
    max-abs error exceeds ``codec_tol * max|ref|`` is rejected outright
    (reported in ``"rejected_codecs"``) and none of its combos are timed
    or eligible to win. The default tolerance (0.05) comfortably covers
    per-block int8 (~0.4% of the block max per value) and emulated
    fp8_e4m3 (~6% per value, averaging out over the contraction) on
    well-scaled data; tighten it to reject fp8 on cancellation-heavy
    matrices.

    ``a`` is a ``SparseTensor`` or raw BCSR/WCSR operand (quantized
    operands are decoded first: the tuner owns the codec choice);
    candidates default per format — WCSR sweeps all four knobs, BCSR
    (Mosaic-managed pipeline) sweeps ``bn`` and the codec. ``codecs``
    defaults to ``("none", "int8")``; pass ``("none", "int8",
    "fp8_e4m3")`` to include the emulated fp8 path. ``impl`` defaults to
    the registry pick (interpret-mode kernels on CPU), so CI can exercise
    the tuner; on TPU the same call measures compiled kernels.
    """
    from repro.ops.config import use_config
    from repro.ops.plan import drop_auto_plans
    from repro.ops.spmm import spmm
    from repro.sparse.codecs import get_codec
    from repro.sparse.tensor import SparseTensor

    import jax

    base = a if isinstance(a, SparseTensor) else SparseTensor.wrap(a)
    if base.codec != "none":
        base = base.dequantize()
    st = base.structure
    n = int(b.shape[1])
    bm, bk = st.block
    dtype = base.dtype
    if bns is None:
        policy = select_bn(n, bm, bk, np.dtype(dtype).itemsize)
        bns = tuple(dict.fromkeys(
            c for c in (policy, 128, 256) if c <= max(n, 128)))
    if st.fmt == "wcsr":
        depths = (1, 2, 3) if depths is None else depths
        chunks = (4, 8) if chunks_per_task is None else chunks_per_task
    else:
        # BCSR keeps its contiguous streams on Mosaic's implicit pipeline
        # (see kernels/bcsr/kernel.py); only the tile width is tunable.
        depths = (None,) if depths is None else depths
        chunks = (None,) if chunks_per_task is None else chunks_per_task
    codecs = ("none", "int8") if codecs is None else codecs
    best = None
    rejected = {}
    # the sweep itself resolves every candidate depth/codec; snapshot the
    # selection counters so the dashboard reflects only what real traffic
    # runs with, not the tuner's probing
    depth_counters = dict(_DEPTH_SELECTIONS)
    codec_counters = dict(_CODEC_SELECTIONS)
    try:
        ref = None
        operands = []  # (codec_name, operand) pairs that passed the guard
        for cname in codecs:
            cname = get_codec(cname).name  # validates
            if cname == "none":
                operands.append(("none", base))
                continue
            aq = base.quantize(cname)
            if ref is None:
                ref = np.asarray(spmm(base, b, impl="ref"))
            with use_config(impl=impl):
                got = np.asarray(spmm(aq, b))
            err = float(np.max(np.abs(got - ref))
                        / (np.max(np.abs(ref)) + 1e-12))
            if err > codec_tol:
                rejected[cname] = err
                continue
            operands.append((cname, aq))
        for cname, operand in operands:
            for bn in bns:
                for cpt in chunks:
                    for depth in depths:
                        with use_config(impl=impl, bn=bn,
                                        chunks_per_task=cpt,
                                        pipeline_depth=depth):
                            f = jax.jit(lambda b_: spmm(operand, b_))
                            us = _time_us(f, b, warmup=warmup, iters=iters)
                        cand = {"bn": int(bn),
                                "chunks_per_task": cpt if cpt is None
                                else int(cpt),
                                "pipeline_depth": depth if depth is None
                                else int(depth),
                                "value_codec": cname,
                                "us": us}
                        if best is None or us < best["us"]:
                            best = cand
    finally:
        _DEPTH_SELECTIONS.clear()
        _DEPTH_SELECTIONS.update(depth_counters)
        _CODEC_SELECTIONS.clear()
        _CODEC_SELECTIONS.update(codec_counters)
    if best is None:
        # every candidate codec failed the guard and "none" wasn't swept:
        # nothing was timed, so there is no winner to cache
        raise ValueError(
            "autotune_spmm: every candidate codec was rejected by the "
            f"accuracy guard (codec_tol={codec_tol}): "
            + ", ".join(f"{c}: err={e:.4g}" for c, e in rejected.items())
            + "; include 'none' in codecs= or loosen codec_tol")
    best["rejected_codecs"] = rejected
    _TUNED[_tuned_key("spmm", st.fmt, st.shape, n, st.block, dtype)] = best
    if best["value_codec"] != "none":
        # a quantized operand plans under its *payload* dtype; mirror the
        # winner there so "auto" bn / chunks / depth resolve for it too
        pdtype = get_codec(best["value_codec"]).storage_dtype
        _TUNED[_tuned_key("spmm", st.fmt, st.shape, n, st.block,
                          pdtype)] = best
    # auto-plans cached before this tune baked in the old bn selection;
    # task splits, partitions and counters are tune-invariant and kept
    drop_auto_plans()
    return dict(best)
