"""Differentiable sparse matmul over static BCSR structure.

The training-side op of the unified API: ``bcsr_matmul(values, b,
structure)`` treats the sparse *structure* (block indices) as static
host-side metadata and the block *values* as a differentiable parameter.
Backward computes ``dB = A^T @ dC`` (transposed-structure SpMM) and
``dvalues = SDDMM(dC, B)`` sampled at the stored blocks — both routed
through ``repro.ops`` so ``use_config`` / ``REPRO_SPARSE_IMPL`` apply.

Also hosts ``local_bcsr_matmul_t``, the runtime-index shard-local
primitive the SPMD model zoo (``models.ffn`` / ``models.moe``) vmaps over
TP shards.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import BCSR

__all__ = ["BCSRStructure", "structure_of", "bcsr_matmul",
           "local_bcsr_matmul_t"]


@dataclasses.dataclass(frozen=True)
class BCSRStructure:
    """Host-side (static) BCSR structure + its transpose, hashable by content.

    Kept out of the pytree on purpose: autodiff and pjit only ever see the
    block *values*; index arrays are embedded as constants.
    """

    shape: Tuple[int, int]
    block: Tuple[int, int]
    nnz_blocks: int
    rows: tuple  # tuple[int] for hashability
    cols: tuple
    # transposed structure: rows_t sorted ascending, every block-row of A^T
    # covered (coverage entries have src_t == -1 -> zero block values)
    rows_t: tuple
    cols_t: tuple
    src_t: tuple  # index into values, or -1 for inserted zero coverage block

    @property
    def nnz_padded(self) -> int:
        return len(self.rows)

    def rows_a(self):
        return jnp.asarray(np.asarray(self.rows, np.int32))

    def cols_a(self):
        return jnp.asarray(np.asarray(self.cols, np.int32))


def structure_of(a) -> BCSRStructure:
    """Extract the static structure (and transpose permutation) of a BCSR.

    Accepts a raw ``BCSR`` or a BCSR-format ``SparseTensor``. (This is the
    autodiff-side structure with the transpose permutation baked in; the
    planning-side ``repro.sparse.SparseStructure`` is format-generic.)
    """
    from repro.sparse.tensor import SparseTensor

    if isinstance(a, SparseTensor):
        a = a.raw
    if not isinstance(a, BCSR):
        raise TypeError(f"structure_of: expected BCSR, got {type(a).__name__}")
    rows = np.asarray(jax.device_get(a.block_rows), np.int32)
    cols = np.asarray(jax.device_get(a.block_cols), np.int32)
    nnz = a.nnz_blocks
    kb = a.shape[1] // a.block[1]
    # transposed entries: (row_t=col, col_t=row, src=value index)
    entries = [(int(cols[i]), int(rows[i]), i) for i in range(nnz)]
    present = {int(c) for c in cols[:nnz]}
    # cover empty block-rows of A^T so the kernel zero-fills them (the GPU
    # kernel's C-initialization analogue; see bcsr_from_mask)
    entries += [(r, 0, -1) for r in range(kb) if r not in present]
    entries.sort(key=lambda e: (e[0], e[1]))
    return BCSRStructure(
        shape=a.shape,
        block=a.block,
        nnz_blocks=nnz,
        rows=tuple(int(x) for x in rows),
        cols=tuple(int(x) for x in cols),
        rows_t=tuple(e[0] for e in entries),
        cols_t=tuple(e[1] for e in entries),
        src_t=tuple(e[2] for e in entries),
    )


def _as_bcsr(values: jax.Array, s: BCSRStructure, transposed: bool = False) -> BCSR:
    if transposed:
        src = np.asarray(s.src_t, np.int32)
        take = jnp.asarray(np.maximum(src, 0))
        vals = values[take].transpose(0, 2, 1)
        vals = jnp.where((src >= 0)[:, None, None], vals, 0)
        rows = np.asarray(s.rows_t, np.int32)
        cols = np.asarray(s.cols_t, np.int32)
        shape = (s.shape[1], s.shape[0])
        block = (s.block[1], s.block[0])
        nnz = len(rows)  # all entries (incl. coverage zeros) are "real"
    else:
        vals, shape, block = values, s.shape, s.block
        rows = np.asarray(s.rows, np.int32)
        cols = np.asarray(s.cols, np.int32)
        nnz = s.nnz_blocks
    mb = shape[0] // block[0]
    ptr = np.zeros(mb + 1, np.int32)
    np.add.at(ptr, rows[:nnz] + 1, 1)
    ptr = np.cumsum(ptr).astype(np.int32)
    return BCSR(
        blocks=vals,
        block_rows=jnp.asarray(rows),
        block_cols=jnp.asarray(cols),
        block_row_ptr=jnp.asarray(ptr),
        shape=shape,
        block=block,
        nnz_blocks=nnz,
    )


def _quantized_values(values: jax.Array, codec: str) -> jax.Array:
    """The values the forward actually multiplies with under ``codec``:
    the per-block quantize-dequantize round trip (f32)."""
    from repro.sparse.codecs import decode_format_values, encode_format_values

    bm, bk = values.shape[1], values.shape[2]
    payload, scales = encode_format_values("bcsr", (bm, bk), values, codec)
    return decode_format_values("bcsr", (bm, bk), payload, scales)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def bcsr_matmul(
    values: jax.Array, b: jax.Array, structure: BCSRStructure, impl=None,
    codec: str = "none",
) -> jax.Array:
    """Differentiable C = A_bcsr(values; structure) @ B.

    ``codec`` runs the quantize-aware forward: the dense ``values`` are
    encoded per block (``repro.sparse.codecs``) and the kernel consumes
    the compressed payload with fused in-register dequant. The backward is
    codec-aware too — ``dB = Q(A)^T @ dC`` routes through the same dequant
    path the forward used (not the raw dense-dtype values), and
    ``dvalues`` flows straight through the quantizer (the standard
    straight-through estimator), so gradients are consistent with what
    the forward computed.
    """
    from repro.ops.spmm import spmm

    if codec == "none":
        return spmm(_as_bcsr(values, structure), b, impl=impl)
    from repro.sparse.codecs import encode_format_values

    bm, bk = values.shape[1], values.shape[2]
    payload, scales = encode_format_values("bcsr", (bm, bk), values, codec)
    return spmm(_as_bcsr(payload, structure), b, impl=impl, codec=codec,
                scales=scales)


def _fwd(values, b, structure, impl, codec):
    return bcsr_matmul(values, b, structure, impl, codec), (values, b)


def _bwd(structure, impl, codec, res, dc):
    from repro.ops.sddmm import sddmm
    from repro.ops.spmm import spmm

    values, b = res
    dc = dc.astype(jnp.float32)
    # dB = A^T @ dC (transposed-structure SpMM; paper's format is closed
    # under transposition given the static permutation). Under a codec the
    # forward multiplied the *dequantized* values, so the backward must
    # transpose exactly those — the codec-aware dequant path — or dB picks
    # up the quantization error twice.
    veff = (values.astype(jnp.float32) if codec == "none"
            else _quantized_values(values, codec))
    at = _as_bcsr(veff, structure, transposed=True)
    db = spmm(at, dc, impl=impl).astype(b.dtype)
    # dvalues = SDDMM(dC, B) sampled at the stored blocks; the quantizer
    # is a straight-through identity for the parameter gradient
    dvals = sddmm(dc, b.astype(jnp.float32), _as_bcsr(values, structure),
                  impl=impl)
    return dvals.astype(values.dtype), db


bcsr_matmul.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# Shard-local runtime-index primitive (SPMD model zoo)
# ---------------------------------------------------------------------------


def local_bcsr_matmul_t(values, rows, cols, x, mb: int):
    """y^T [mb*bm, T] = W_local @ x^T for one shard's blocks.

    values: [nnz, bm, bk]; rows/cols: [nnz] i32; x: [T, in] with in = kb*bk.
    Index arrays are runtime tensors (not static) so callers trace once
    under shard_map/pjit; the dataflow is the gather + micro-GEMM +
    segment-sum form of the BCSR kernel.

    Skinny batches (decode ticks: T <= the ambient ``spmv_threshold``)
    swap the per-block MXU micro-GEMM for the row-split
    multiply-accumulate of the ``spmv`` kernel family — the T dimension is
    static at trace time, so the serve decode step compiles the GEMV form
    while prefill keeps the einsum, and both land in the
    ``cache_stats()["spmv"]`` dispatch tallies.
    """
    from repro.ops.config import current_config
    from repro.ops.tiling import resolve_spmv_route

    nnz, bm, bk = values.shape
    t = x.shape[0]
    xt = x.T.reshape(-1, bk, t)  # [kb, bk, T]
    tiles = xt[cols]  # [nnz, bk, T]
    route = resolve_spmv_route(current_config().spmv_threshold, t)
    if route == "spmv":
        # product in the input dtype, f32 accumulation — matches the
        # einsum's preferred_element_type semantics
        part = jnp.sum(values[:, :, :, None] * tiles[:, None, :, :],
                       axis=2, dtype=jnp.float32)
    else:
        part = jnp.einsum(
            "nij,njt->nit", values, tiles, preferred_element_type=jnp.float32
        )
    y = jax.ops.segment_sum(part, rows, num_segments=mb)  # [mb, bm, T]
    return y.reshape(mb * bm, t)
