"""Cached execution plans: host-side planning done once per structure.

``make_plan(structure, n, cfg)`` bundles everything an spmm backend decides
on the host before launching a kernel:

* the output tile width ``bn`` (§IV-C selection via the tuning cache),
* for WCSR, the load-balancing task decomposition (§III-C) — the python
  loop over windows that used to re-run on every call — and the resolved
  §III-A gather-pipeline depth Q (explicit config, measured auto-tune
  winner, or the paper's serial default).

Plans are memoized per (structure, n, dtype, bn, chunks_per_task,
pipeline_depth, value_codec);
the task decomposition has its own cache keyed only by
(structure, chunks_per_task), so value swaps, dtype casts *and codec
flips* on the same ``SparseStructure`` never re-derive tasks — exactly the
per-step overhead a serving system handling repeated shapes must amortize
(the Acc-SpMM / cuTeSpMM preprocess-once pattern).

``make_partition(structure, num_shards)`` extends the same contract to the
mesh scale: the structure-aware shard split
(``repro.parallel.sparse.partition_structure``) is memoized per
(structure, num_shards), so sharded serving partitions each layer once and
swaps values forever.

``plan_cache_info()`` exposes hit/miss counters (plans, task
decompositions, partitions), so tests can prove planning runs once;
``partition_balance_report()`` lists per-shard load stats for dashboards.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.ops.config import OpConfig, current_config
from repro.ops.tiling import (count_codec_selection, resolve_bn,
                              resolve_pipeline_depth, tuned_entry,
                              tuning_cache_info)
from repro.sparse.structure import SparseStructure

__all__ = ["Plan", "make_plan", "make_partition", "plan_cache_info",
           "clear_plan_cache", "partition_balance_report", "PlanCacheInfo",
           "cache_stats", "codec_bytes_report"]


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """One memoized host-side plan for spmm over a fixed structure + n."""

    structure: SparseStructure
    n: int
    bn: int
    chunks_per_task: Optional[int]  # wcsr only
    tasks: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]  # wcsr only
    # resolved §III-A gather-pipeline depth Q (wcsr kernel path; None for
    # formats whose operand streams ride Mosaic's implicit pipeline)
    pipeline_depth: Optional[int] = None
    # resolved value codec of the operand this plan executes with
    # ("none" = raw dense-dtype values); part of the cache key, so a codec
    # flip re-plans cleanly while the structure-keyed task cache is shared
    value_codec: str = "none"
    # resolved skinny-N route ("spmm" = bn-wide tile kernels, "spmv" = the
    # GEMV family); part of the cache key so the same structure serving
    # prefill (wide N) and decode (N=1) holds two plans side by side
    route: str = "spmm"
    # resolved sharded-combine chunk count (repro.parallel.sparse chunked
    # compute/collective overlap); None for unsharded plans. Part of the
    # cache key: the chunk schedule pads task arrays per chunk, so a plan
    # reused under a different chunking would mis-shape the kernel launch.
    combine_chunks: Optional[int] = None

    @property
    def num_tasks(self) -> int:
        return 0 if self.tasks is None else len(self.tasks[0])


@dataclasses.dataclass
class PlanCacheInfo:
    hits: int
    misses: int
    task_decompositions: int
    size: int
    partition_hits: int = 0
    partition_misses: int = 0
    partitions: int = 0
    # structure-delta patching (repro.sparse.delta): cache entries derived
    # by patching the base structure's entry — neither a hit nor a full
    # rebuild miss
    plan_patched: int = 0
    partition_patched: int = 0


_PLANS: dict = {}
_TASKS: dict = {}
_PARTITIONS: dict = {}
_HITS = 0
_MISSES = 0
_DECOMPOSITIONS = 0
_P_HITS = 0
_P_MISSES = 0
_PLAN_PATCHED = 0
_PART_PATCHED = 0


def reset_patch_counters() -> None:
    """Zero the delta-patch counters (``clear_tuning_cache`` calls this)."""
    global _PLAN_PATCHED, _PART_PATCHED
    _PLAN_PATCHED = 0
    _PART_PATCHED = 0


def clear_plan_cache() -> None:
    """Drop all cached plans, task splits and partitions; zero counters."""
    global _HITS, _MISSES, _DECOMPOSITIONS, _P_HITS, _P_MISSES
    _PLANS.clear()
    _TASKS.clear()
    _PARTITIONS.clear()
    _HITS = 0
    _MISSES = 0
    _DECOMPOSITIONS = 0
    _P_HITS = 0
    _P_MISSES = 0
    reset_patch_counters()
    from repro.sparse.delta import reset_delta_stats

    reset_delta_stats()
    import sys

    ps = sys.modules.get("repro.parallel.sparse")
    if ps is not None:  # chunk-schedule arrays are partition-derived state
        ps.clear_combine_schedules()


def drop_auto_plans() -> None:
    """Drop cached plans built from ``"auto"`` knobs (post-autotune refresh).

    Only ``_PLANS`` entries whose config left ``bn`` on auto can have baked
    in a now-stale selection (tuned ``chunks_per_task`` / ``pipeline_depth``
    land in the cache *key*, so those re-resolve naturally). Task
    decompositions and mesh partitions are keyed purely by structure and
    are never invalidated by a tune — they, and all counters, stay intact
    so serving keeps its cross-tick amortization invariants.
    """
    for key in [k for k in _PLANS if k[3] in (None, "auto")]:
        del _PLANS[key]


def plan_cache_info() -> PlanCacheInfo:
    """Hit/miss/size counters for the plan, task and partition caches."""
    return PlanCacheInfo(hits=_HITS, misses=_MISSES,
                         task_decompositions=_DECOMPOSITIONS,
                         size=len(_PLANS),
                         partition_hits=_P_HITS, partition_misses=_P_MISSES,
                         partitions=len(_PARTITIONS),
                         plan_patched=_PLAN_PATCHED,
                         partition_patched=_PART_PATCHED)


def _as_structure(structure, caller: str) -> SparseStructure:
    """Unwrap a ``SparseStructure`` carrier (``SparseTensor`` & co.)."""
    if isinstance(structure, SparseStructure):
        return structure
    inner = getattr(structure, "structure", None)
    if not isinstance(inner, SparseStructure):
        raise TypeError(
            f"{caller}: expected SparseStructure (or SparseTensor), "
            f"got {type(structure).__name__}")
    return inner


def _tasks_for(structure: SparseStructure, chunks_per_task: int):
    """The §III-C decomposition, once per (structure, chunks_per_task)."""
    global _DECOMPOSITIONS
    key = (structure, chunks_per_task)
    tasks = _TASKS.get(key)
    if tasks is None:
        _DECOMPOSITIONS += 1
        tasks = structure.tasks(chunks_per_task)
        _TASKS[key] = tasks
    return tasks


def make_plan(structure, n: int, cfg: Optional[OpConfig] = None, *,
              dtype=None, codec: str = "none", route: str = "spmm",
              combine_chunks: Optional[int] = None) -> Plan:
    """Build (or fetch) the execution plan for ``spmm`` over ``structure``.

    ``structure`` may be a ``SparseStructure`` or anything carrying one
    (``SparseTensor`` — whose value dtype *and codec* are then the
    defaults). ``cfg`` defaults to the ambient ``current_config()``; only
    its ``bn`` / ``chunks_per_task`` planning-relevant fields key the
    cache. ``dtype`` is the stored-leaf dtype (tile selection is
    byte-width aware — a quantized operand plans with its payload bytes;
    bare-structure default: bfloat16); ``codec`` is the operand's resolved
    value codec and part of the cache key. Casts and codec flips re-plan
    ``bn`` cheaply but share the structure-keyed task cache. ``route`` is
    the resolved skinny-N dispatch ("spmm" | "spmv", also cache-keyed):
    the task split and depth resolution are route-invariant, but prefill
    and decode plans for the same structure must not collide.
    ``combine_chunks`` is the resolved sharded-combine chunk count (the
    chunked compute/collective overlap of ``repro.parallel.sparse``; None
    for unsharded plans) — cache-keyed like the route, since the chunk
    schedule shapes the per-shard task padding.
    """
    global _HITS, _MISSES
    if not isinstance(structure, SparseStructure):
        inner = _as_structure(structure, "make_plan")
        if dtype is None:
            dtype = getattr(structure, "dtype", None)
        if codec == "none":
            codec = getattr(structure, "codec", "none") or "none"
        structure = inner
    if dtype is None:
        dtype = jnp.bfloat16
    codec = str(codec)
    cfg = current_config() if cfg is None else cfg
    count_codec_selection(codec)
    bm, bk = structure.block
    if structure.fmt == "wcsr":
        tuned = tuned_entry("spmm", "wcsr", structure.shape, int(n),
                            structure.block, dtype)
        cpt = cfg.chunks_per_task or (tuned or {}).get("chunks_per_task") or 8
        # resolved here (cheap dict lookup) so the cache key — and thus the
        # plan a serving step reuses — is pinned to the depth the kernel
        # will actually run with, even if a later autotune re-tunes "auto"
        depth = resolve_pipeline_depth(
            cfg.pipeline_depth, default=1, op="spmm", fmt="wcsr",
            shape=structure.shape, n=int(n), block=structure.block,
            dtype=dtype, floor=1)
    else:
        cpt = None
        depth = None
    # route / combine_chunks appended last: drop_auto_plans /
    # _try_patch_plan index key[3] (cfg.bn) and key[1:] respectively, so
    # the layout stays stable
    cc = None if combine_chunks is None else int(combine_chunks)
    key = (structure, int(n), str(np.dtype(dtype)), cfg.bn, cpt, depth,
           codec, str(route), cc)
    plan = _PLANS.get(key)
    if plan is not None:
        _HITS += 1
        return plan
    plan = _try_patch_plan(structure, key, cpt)
    if plan is not None:
        global _PLAN_PATCHED
        _PLAN_PATCHED += 1
        _PLANS[key] = plan
        return plan
    _MISSES += 1
    bn = resolve_bn(cfg.bn, int(n), bm, bk, dtype, op="spmm",
                    fmt=structure.fmt, shape=structure.shape, impl="kernel")
    tasks = _tasks_for(structure, cpt) if structure.fmt == "wcsr" else None
    plan = Plan(structure=structure, n=int(n), bn=bn, chunks_per_task=cpt,
                tasks=tasks, pipeline_depth=depth, value_codec=codec,
                route=str(route), combine_chunks=cc)
    _PLANS[key] = plan
    return plan


def _try_patch_plan(structure: SparseStructure, key, cpt) -> Optional[Plan]:
    """Patch the base structure's plan across a registered delta.

    If ``structure`` was produced by ``repro.sparse.delta`` and its base
    was planned with the same (n, dtype, bn, chunks_per_task, depth,
    codec), reuse the base tile width verbatim and patch only the touched
    windows' tasks (``patch_tasks``) — O(touched + tasks-copy) instead of
    re-deriving everything. Counted as ``plan_patched``, not as a miss;
    the patched tasks land in ``_TASKS`` without bumping
    ``task_decompositions`` (the amortization counter serving CI watches).
    """
    from repro.sparse.delta import delta_of, patch_tasks

    d = delta_of(structure)
    if d is None:
        return None
    base_plan = _PLANS.get((d.base,) + key[1:])
    if base_plan is None:
        return None
    tasks = None
    if structure.fmt == "wcsr":
        tkey = (structure, cpt)
        tasks = _TASKS.get(tkey)
        if tasks is None:
            base_tasks = _TASKS.get((d.base, cpt), base_plan.tasks)
            if base_tasks is None:
                return None
            tasks = patch_tasks(d, base_tasks, cpt)
            _TASKS[tkey] = tasks
    return Plan(structure=structure, n=base_plan.n, bn=base_plan.bn,
                chunks_per_task=cpt, tasks=tasks,
                pipeline_depth=base_plan.pipeline_depth,
                value_codec=base_plan.value_codec,
                route=base_plan.route,
                combine_chunks=base_plan.combine_chunks)


def make_partition(structure, num_shards: int):
    """Build (or fetch) the device-mesh partition of ``structure``.

    The mesh-scale sibling of ``make_plan``: the structure-aware
    partitioner (``repro.parallel.sparse.partition_structure``) runs once
    per (structure, num_shards) and the resulting ``SparsePartition`` is
    reused across value swaps, dtype casts and every subsequent sharded
    spmm call — serving partitions each layer once. ``structure`` may be a
    ``SparseStructure`` or anything carrying one (``SparseTensor``).
    """
    global _P_HITS, _P_MISSES, _PART_PATCHED
    structure = _as_structure(structure, "make_partition")
    key = (structure, int(num_shards))
    part = _PARTITIONS.get(key)
    if part is not None:
        _P_HITS += 1
        return part
    from repro.sparse.delta import delta_of

    d = delta_of(structure)
    if d is not None:
        base_part = _PARTITIONS.get((d.base, int(num_shards)))
        if base_part is not None:
            from repro.parallel.sparse import patch_partition

            part = patch_partition(d, base_part)
            _PART_PATCHED += 1
            _PARTITIONS[key] = part
            return part
    _P_MISSES += 1
    from repro.parallel.sparse import partition_structure

    part = partition_structure(structure, int(num_shards))
    _PARTITIONS[key] = part
    return part


def partition_balance_report() -> list:
    """Shard-balance dicts for every cached partition (serving dashboards).

    Each entry is ``SparsePartition.balance()``: per-shard stored-element
    loads plus the worst/mean ratio — flat counters here across serve ticks
    are the mesh-scale amortization invariant.
    """
    return [p.balance() for p in _PARTITIONS.values()]


def cache_stats() -> dict:
    """One aggregator over every host-side cache counter, unified naming.

    PRs 2-4 grew three counter surfaces piecemeal (``plan_cache_info``,
    ``tuning_cache_info``, the partition fields bolted onto
    ``PlanCacheInfo``) with drifting key styles (``task_decompositions``
    vs ``partition_misses`` vs the ``pipeline_depths`` dict). This is the
    one dashboard-facing view — ``ServeEngine.stats()["cache_stats"]``
    consumes it — with a fixed shape::

        {"plan":      {"hits", "misses", "patched", "size"},
         "tasks":     {"decompositions"},
         "partition": {"hits", "misses", "patched", "size"},
         "tuning":    {"hits", "misses", "size", "autotuned"},
         "tune_db":   {"hits", "misses", "stale", "sweeps"},
         "selections": {"pipeline_depth": {Q: count},
                        "value_codec":   {name: count}},
         "spmv":      {"dispatched", "full_tile"},
         "combine":   {"chunked", "blocking", "chunks": {cc: count},
                       "schedules_built", "shard_chunks_built",
                       "shard_chunks_reused",
                       "hier_calls", "hier_fallback"},
         "delta":     {"appends", "retires", "plan_patched",
                       "partition_patched", "groups_reused",
                       "groups_requantized", "shards_reused",
                       "shards_reshipped"}}

    ``spmv`` is the skinny-N dispatch view (``tiling.spmv_dispatch_info``):
    route resolutions sent to the GEMV op family vs kept on the full-tile
    kernels. A decode loop at steady state shows ``dispatched`` advancing
    once per sparse layer per tick while prefill traffic lands in
    ``full_tile``.

    ``tune_db`` is the persistent tuning database (``repro.tune``) view:
    warm-start adoptions vs consults that fell back, plus in-process
    measured sweeps — ``hits > 0, sweeps == 0`` is the warm-started
    replica invariant CI asserts.

    ``combine`` is the chunked compute/collective overlap view
    (``repro.parallel.sparse`` sharded combine): resolutions that chose the
    overlapped multi-chunk pipeline vs the blocking whole-output
    collective (``tiling.combine_dispatch_info``), combine schedules built
    vs per-shard chunk arrays reused across structure deltas, and the
    ``hierarchical_psum`` call/fallback tallies (the ``reduce="hier"``
    degradation counter). The parallel-layer counters are probed via
    ``sys.modules`` — zeros when the parallel layer was never imported.

    ``delta`` is the dynamic-sparsity view (``repro.sparse.delta``):
    structure edits applied, plan/partition cache entries derived by
    patching instead of a full rebuild, codec value groups spliced bitwise
    vs requantized, and mesh shards reused vs reshipped. A growing-mask
    decode loop at steady state shows ``plan_patched`` advancing while
    ``plan.misses`` stays flat — the amortized-flat host-cost invariant
    (``ServeEngine.stats()["structure_deltas"]`` republishes this block).

    The legacy accessors stay (tests and external dashboards key on them);
    this aggregator is derived from the same counters, never a second set.
    """
    import sys

    from repro.ops.tiling import combine_dispatch_info, spmv_dispatch_info
    from repro.sparse.delta import delta_stats

    p = plan_cache_info()
    t = tuning_cache_info()
    delta = delta_stats()
    delta["plan_patched"] = p.plan_patched
    delta["partition_patched"] = p.partition_patched
    combine = combine_dispatch_info()
    combine.update({"schedules_built": 0, "shard_chunks_built": 0,
                    "shard_chunks_reused": 0,
                    "hier_calls": 0, "hier_fallback": 0})
    ps = sys.modules.get("repro.parallel.sparse")
    if ps is not None:
        combine.update(ps.combine_schedule_counters())
    pc = sys.modules.get("repro.parallel.collectives")
    if pc is not None:
        combine.update(pc.collective_counters())
    return {
        "plan": {"hits": p.hits, "misses": p.misses,
                 "patched": p.plan_patched, "size": p.size},
        "tasks": {"decompositions": p.task_decompositions},
        "partition": {"hits": p.partition_hits, "misses": p.partition_misses,
                      "patched": p.partition_patched, "size": p.partitions},
        "tuning": {"hits": t.hits, "misses": t.misses, "size": t.size,
                   "autotuned": t.autotuned},
        "tune_db": {"hits": t.db_hits, "misses": t.db_misses,
                    "stale": t.db_stale, "sweeps": t.sweeps},
        "selections": {"pipeline_depth": dict(t.pipeline_depths),
                       "value_codec": dict(t.value_codecs)},
        "spmv": spmv_dispatch_info(),
        "combine": combine,
        "delta": delta,
    }


def codec_bytes_report() -> list:
    """Modeled sparse-operand bytes-moved savings per quantized plan.

    One entry per cached (structure, codec) pair whose plan runs a value
    codec: baseline (f32 values, the dtype this repro's weights originate
    as) vs compressed (payload + one f32 scale per block/chunk group)
    traffic, from ``repro.sparse.codecs.modeled_value_bytes``. Surfaced by
    ``ServeEngine.stats()["codec_bytes"]`` — the serving dashboard's view
    of what the codec layer saves the Q-deep gather per step.
    """
    from repro.sparse.codecs import modeled_value_bytes

    seen = {}
    for plan in _PLANS.values():
        if plan.value_codec in (None, "none"):
            continue
        key = (plan.structure, plan.value_codec)
        if key in seen:
            continue
        g = plan.structure
        entry = modeled_value_bytes(
            g.stored_elements, g.block[0] * g.block[1], plan.value_codec)
        entry.update(fmt=g.fmt, shape=g.shape)
        seen[key] = entry
    return list(seen.values())
