"""Cached execution plans: host-side planning done once per structure.

``make_plan(structure, n, cfg)`` bundles everything an spmm backend decides
on the host before launching a kernel:

* the output tile width ``bn`` (§IV-C selection via the tuning cache), and
* for WCSR, the load-balancing task decomposition (§III-C) — the python
  loop over windows that used to re-run on every call.

Plans are memoized per (structure, n, dtype, impl, bn, chunks_per_task);
the task decomposition has its own cache keyed only by
(structure, chunks_per_task), so value swaps *and dtype casts* on the same
``SparseStructure`` never re-derive tasks — exactly the per-step overhead a
serving system handling repeated shapes must amortize (the Acc-SpMM /
cuTeSpMM preprocess-once pattern).

``plan_cache_info()`` exposes hit/miss counters plus the number of task
decompositions actually performed, so tests can prove planning runs once.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.ops.config import OpConfig, current_config
from repro.ops.tiling import resolve_bn
from repro.sparse.structure import SparseStructure

__all__ = ["Plan", "make_plan", "plan_cache_info", "clear_plan_cache",
           "PlanCacheInfo"]


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """One memoized host-side plan for spmm over a fixed structure + n."""

    structure: SparseStructure
    n: int
    bn: int
    chunks_per_task: Optional[int]  # wcsr only
    tasks: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]  # wcsr only

    @property
    def num_tasks(self) -> int:
        return 0 if self.tasks is None else len(self.tasks[0])


@dataclasses.dataclass
class PlanCacheInfo:
    hits: int
    misses: int
    task_decompositions: int
    size: int


_PLANS: dict = {}
_TASKS: dict = {}
_HITS = 0
_MISSES = 0
_DECOMPOSITIONS = 0


def clear_plan_cache() -> None:
    global _HITS, _MISSES, _DECOMPOSITIONS
    _PLANS.clear()
    _TASKS.clear()
    _HITS = 0
    _MISSES = 0
    _DECOMPOSITIONS = 0


def plan_cache_info() -> PlanCacheInfo:
    return PlanCacheInfo(hits=_HITS, misses=_MISSES,
                         task_decompositions=_DECOMPOSITIONS,
                         size=len(_PLANS))


def _tasks_for(structure: SparseStructure, chunks_per_task: int):
    """The §III-C decomposition, once per (structure, chunks_per_task)."""
    global _DECOMPOSITIONS
    key = (structure, chunks_per_task)
    tasks = _TASKS.get(key)
    if tasks is None:
        _DECOMPOSITIONS += 1
        tasks = structure.tasks(chunks_per_task)
        _TASKS[key] = tasks
    return tasks


def make_plan(structure, n: int, cfg: Optional[OpConfig] = None, *,
              dtype=None) -> Plan:
    """Build (or fetch) the execution plan for ``spmm`` over ``structure``.

    ``structure`` may be a ``SparseStructure`` or anything carrying one
    (``SparseTensor`` — whose value dtype is then the default ``dtype``).
    ``cfg`` defaults to the ambient ``current_config()``; only its ``bn`` /
    ``chunks_per_task`` planning-relevant fields key the cache. ``dtype``
    is the value dtype (tile selection is byte-width aware; bare-structure
    default: bfloat16); a cast re-plans ``bn`` cheaply but shares the task
    cache.
    """
    global _HITS, _MISSES
    if not isinstance(structure, SparseStructure):
        inner = getattr(structure, "structure", None)
        if not isinstance(inner, SparseStructure):
            raise TypeError(
                f"make_plan: expected SparseStructure (or SparseTensor), "
                f"got {type(structure).__name__}")
        if dtype is None:
            dtype = getattr(structure, "dtype", None)
        structure = inner
    if dtype is None:
        dtype = jnp.bfloat16
    cfg = current_config() if cfg is None else cfg
    cpt = (cfg.chunks_per_task or 8) if structure.fmt == "wcsr" else None
    key = (structure, int(n), str(np.dtype(dtype)), cfg.bn, cpt)
    plan = _PLANS.get(key)
    if plan is not None:
        _HITS += 1
        return plan
    _MISSES += 1
    bm, bk = structure.block
    bn = resolve_bn(cfg.bn, int(n), bm, bk, dtype, op="spmm",
                    fmt=structure.fmt, shape=structure.shape, impl="kernel")
    tasks = _tasks_for(structure, cpt) if structure.fmt == "wcsr" else None
    plan = Plan(structure=structure, n=int(n), bn=bn, chunks_per_task=cpt,
                tasks=tasks)
    _PLANS[key] = plan
    return plan
