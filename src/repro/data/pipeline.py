"""Host-side input pipeline: background prefetch thread + shard-aware
iteration. The prefetcher keeps ``depth`` batches ready so host data
generation overlaps device compute (the async input trick at scale)."""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

__all__ = ["Prefetcher", "make_train_iterator"]


class Prefetcher:
    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self._make(step)
            except Exception:  # surface errors on get()
                self._q.put(None)
                return
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self):
        item = self._q.get()
        if item is None:
            raise RuntimeError("data pipeline thread failed")
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_train_iterator(dataset, batch: int, seq: int, start_step: int = 0,
                        depth: int = 2) -> Prefetcher:
    return Prefetcher(
        lambda step: dataset.batch(step, batch, seq), start_step, depth
    )
