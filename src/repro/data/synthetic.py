"""Deterministic synthetic LM data: a Zipfian Markov stream that is cheap to
generate, reproducible per (seed, step, shard), and learnable (so the
training examples/tests can show loss decreasing)."""

from __future__ import annotations

import numpy as np

__all__ = ["SyntheticLM"]


class SyntheticLM:
    """Order-1 Markov chain with Zipf marginals over the vocab."""

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 16):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        # each token transitions to one of `branch` successors w/ Zipf weights
        self.succ = rng.integers(0, vocab_size, size=(vocab_size, branch))
        w = 1.0 / np.arange(1, branch + 1) ** 1.2
        self.w = w / w.sum()
        self.branch = branch

    def batch(self, step: int, batch: int, seq: int, shard: int = 0,
              num_shards: int = 1):
        """tokens/labels [batch, seq] for this (step, shard) — deterministic,
        disjoint across shards (shard-aware seeding)."""
        rng = np.random.default_rng(
            (step * 1_000_003 + shard) % (2**63)
        )
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            pick = rng.choice(self.branch, size=batch, p=self.w)
            toks[:, t + 1] = self.succ[toks[:, t], pick]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
