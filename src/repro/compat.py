"""jax API compatibility aliases.

The repo tracks current jax spellings; aliases here keep it running on the
0.4.x series too:

* ``jax.shard_map`` graduated from ``jax.experimental.shard_map`` and
  renamed its ``check_rep`` kwarg to ``check_vma``.
* ``jax.lax.axis_size`` is new; the classic spelling is a psum of 1 over
  the named axis (constant-folded, so still static).
"""

import jax

__all__ = ["shard_map", "axis_size"]

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:  # jax < 0.5
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, **kwargs)
