"""Training loop with production concerns:

* checkpoint/restart — resumes from the latest intact checkpoint (atomic
  writes mean a mid-write crash is invisible);
* elastic restart — restore() re-places arrays on the current mesh, so the
  same checkpoint resumes on a different device count;
* straggler watchdog — per-step wall-time EWMA; steps slower than
  ``straggler_factor`` x the EWMA are counted and logged (at real scale this
  signal feeds preemption/replacement; here it feeds metrics + tests);
* async checkpoint writes + host data prefetch overlap device compute.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import Checkpointer
from repro.train.step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup: int = 20
    clip_norm: float = 1.0
    microbatches: int = 1
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, model, tcfg: TrainerConfig, jit_kwargs: Optional[dict] = None):
        self.model = model
        self.tcfg = tcfg
        step_fn = make_train_step(
            model,
            optimizer=tcfg.optimizer,
            peak_lr=tcfg.peak_lr,
            warmup=tcfg.warmup,
            total_steps=tcfg.total_steps,
            clip_norm=tcfg.clip_norm,
            microbatches=tcfg.microbatches,
        )
        self.train_step = jax.jit(step_fn, donate_argnums=(0,), **(jit_kwargs or {}))
        self.ckpt = Checkpointer(tcfg.ckpt_dir, tcfg.keep_ckpts) if tcfg.ckpt_dir else None
        self.straggler_steps = 0
        self.history: list = []

    def init_or_restore(self, key, shardings=None) -> tuple:
        """Returns (state, start_step). Restores if a checkpoint exists."""
        params = self.model.init(key)
        state = init_train_state(params, self.tcfg.optimizer)
        start = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest()
            if latest is not None:
                state = self.ckpt.restore(latest, state, shardings)
                start = latest
        return state, start

    def run(self, state: TrainState, batch_iter: Callable[[int], dict],
            start_step: int = 0, on_step=None) -> TrainState:
        ewma = None
        for step in range(start_step, self.tcfg.total_steps):
            batch = batch_iter(step)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            loss = float(metrics["loss"])  # blocks; realizes step time
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.tcfg.straggler_factor * ewma and step > start_step + 3:
                self.straggler_steps += 1
            self.history.append({"step": step, "loss": loss, "dt": dt})
            if on_step is not None:
                on_step(step, metrics)
            if self.ckpt is not None and (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(step + 1, state,
                                     meta={"loss": loss})
        if self.ckpt is not None:
            self.ckpt.save_async(self.tcfg.total_steps, state, meta={})
            self.ckpt.wait()
        return state
