"""Train-step factory: mixed precision, grad accumulation (microbatching),
global-norm clipping, optimizer dispatch — all inside one jittable function
(the object the dry-run lowers).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import adafactor, adamw
from repro.optim.schedule import warmup_cosine


class TrainState(NamedTuple):
    params: dict
    opt: object  # AdamWState | AdafactorState


def init_train_state(params, optimizer: str = "adamw") -> TrainState:
    opt = (adamw if optimizer == "adamw" else adafactor).init(params)
    return TrainState(params=params, opt=opt)


def _global_norm(tree):
    def sq(x):
        # per-layer partial sums on stacked leaves: avoids materializing a
        # full f32 copy of multi-GB gradient leaves just to cast-and-square
        if x.ndim >= 3 and x.shape[0] >= 8:
            return jnp.sum(jax.lax.map(
                lambda s: jnp.sum(s.astype(jnp.float32) ** 2), x))
        return jnp.sum(x.astype(jnp.float32) ** 2)

    leaves = [
        x for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)
    ]
    return jnp.sqrt(sum(sq(x) for x in leaves))


def _clip_scale(tree, max_norm):
    """Global-norm clip as a scalar scale (folded into the optimizer update
    so a scaled copy of the gradient tree is never materialized)."""
    norm = _global_norm(tree)
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9)), norm


def make_train_step(
    model,
    *,
    optimizer: str = "adamw",
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    clip_norm: float = 1.0,
    microbatches: int = 1,
    weight_decay: float = 0.1,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    opt_mod = adamw if optimizer == "adamw" else adafactor
    loss_grad = jax.value_and_grad(model.loss, allow_int=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            return loss_grad(params, batch)
        # grad accumulation: split the batch along dim 0 and scan
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)

        def acc_fn(carry, mb_i):
            loss_acc, grad_acc = carry
            l, g = loss_grad(params, mb_i)
            grad_acc = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32)
                if jnp.issubdtype(b_.dtype, jnp.inexact) else a,
                grad_acc, g,
            )
            return (loss_acc + l, grad_acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32)
            if jnp.issubdtype(p.dtype, jnp.inexact) else jnp.zeros((), jnp.float32),
            params,
        )
        (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros(()), zeros), mb)
        scale = 1.0 / microbatches
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(state: TrainState, batch):
        loss, grads = compute_grads(state.params, batch)
        scale, gnorm = _clip_scale(grads, clip_norm)
        step = state.opt.step
        lr = warmup_cosine(step, peak_lr=peak_lr, warmup=warmup,
                           total=total_steps)
        kwargs = {"grad_scale": scale}
        if optimizer == "adamw":
            kwargs["weight_decay"] = weight_decay
        params, opt = opt_mod.apply(state.params, grads, state.opt, lr, **kwargs)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=params, opt=opt), metrics

    return train_step
