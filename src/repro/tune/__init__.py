"""``repro.tune`` — persistent tuning database + offline autotune farm.

The measured ``repro.ops.autotune_spmm`` sweep used to live and die with
the process; this package makes its winners durable and fleet-shareable:

  TuneDB            — on-disk JSON-lines store of autotune winners, keyed by
                      (op family, format, shape+N, block geometry, dtype)
                      with schema versioning, env fingerprinting and
                      corrupt-entry quarantine (db.py)
  run_farm/TuneJob  — the offline tune farm: a declarative job fleet swept
                      across a subprocess pool, winners merged into one DB
                      (farm.py; CLI: tools/tune_farm.py)

Warm-start wiring lives in ``repro.ops.tiling`` (``tuned_entry`` consults
the active DB, ``autotune_spmm`` records to it, ``set_tune_db`` /
``REPRO_TUNE_DB`` select it) and ``ServeEngine(tune_db=...)`` (preload at
construction + admission). docs/performance.md ("Persistent tuning") is
the user-facing story.
"""

from repro.tune.db import (ENV_DB_VAR, TUNE_DB_SCHEMA, TuneDB,
                           env_fingerprint, problem_key)
from repro.tune.farm import (TuneJob, default_fleet, load_fleet, run_farm,
                             run_job, smoke_fleet)

__all__ = ["TuneDB", "TUNE_DB_SCHEMA", "ENV_DB_VAR", "env_fingerprint",
           "problem_key", "TuneJob", "run_farm", "run_job", "load_fleet",
           "default_fleet", "smoke_fleet"]
