"""Offline autotune farm: sweep a declarative job fleet into a ``TuneDB``.

The serving story wants every measured ``(bn, chunks_per_task,
pipeline_depth, value_codec)`` sweep paid *offline, once per fleet* — not
per replica at startup. This module turns a declarative list of
``TuneJob``\\ s (shape, sparsity structure, format, codec set) into DB
records:

* each job synthesizes a deterministic operand (seeded sparsity pattern),
  runs the real measured ``repro.ops.autotune_spmm`` sweep with the DB
  consult *disabled* (a farm always re-measures), and commits the winner;
* jobs fan out over a subprocess pool (the Inductor
  ``compile_worker/subproc_pool`` pattern: isolated interpreters, each
  with its own jax runtime, so one wedged sweep can't take the farm down);
  every worker appends to the shared DB with atomic single-line writes —
  concurrent results merge without clobbering (``repro.tune.db``);
* the parent reloads + compacts the DB at the end and reports winners.

``tools/tune_farm.py`` is the CLI; ``workers=0`` runs jobs inline in the
calling process (tests / CI smoke / measurement on the actual serving
host). See docs/performance.md ("Persistent tuning").
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, List, Optional, Sequence, Tuple

__all__ = ["TuneJob", "run_farm", "run_job", "load_fleet", "default_fleet",
           "smoke_fleet"]


@dataclasses.dataclass(frozen=True)
class TuneJob:
    """One (structure, dense-operand) tuning problem.

    The synthesized operand is deterministic in the spec — the same job on
    any worker reproduces the same sparsity pattern, so its DB key (which
    covers the structure content digest) is stable across the fleet.
    """

    fmt: str = "bcsr"                 # "bcsr" | "wcsr"
    m: int = 256
    k: int = 256
    n: int = 128                      # dense-operand width (the key's N)
    block: Tuple[int, int] = (32, 32)
    sparsity: float = 0.75
    method: str = "random"            # sparsify block-mask method
    dtype: str = "float32"
    codecs: Sequence[str] = ("none",)
    seed: int = 0
    impl: Optional[str] = None        # backend override for the sweep

    @classmethod
    def from_dict(cls, d: dict) -> "TuneJob":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"TuneJob: unknown fields {sorted(unknown)}; "
                             f"accepted: {sorted(known)}")
        kw = dict(d)
        if "block" in kw:
            kw["block"] = (int(kw["block"][0]), int(kw["block"][1]))
        if "codecs" in kw:
            kw["codecs"] = tuple(kw["codecs"])
        return cls(**kw)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["block"] = list(d["block"])
        d["codecs"] = list(d["codecs"])
        return d


def default_fleet() -> List[TuneJob]:
    """A representative serving fleet: FFN-ish BCSR + attention-ish WCSR
    shapes across sparsities and codecs — prefill widths (n=128) plus the
    skinny decode widths (n in {1, 4, 16}) so the farm warms decode-path
    entries and the measured spmm-vs-spmv crossover route, not just the
    wide-N tiles the old fleet hardcoded."""
    jobs = []
    for fmt, block in (("bcsr", (32, 32)), ("wcsr", (32, 8))):
        for m, k in ((256, 256), (512, 256)):
            for sparsity in (0.5, 0.8):
                for n in (1, 4, 16, 128):
                    jobs.append(TuneJob(fmt=fmt, m=m, k=k, n=n, block=block,
                                        sparsity=sparsity,
                                        codecs=("none", "int8")))
    return jobs


def smoke_fleet() -> List[TuneJob]:
    """The CI-sized fleet: two tiny jobs, one per format."""
    return [
        TuneJob(fmt="bcsr", m=64, k=64, n=32, block=(16, 16), sparsity=0.5),
        TuneJob(fmt="wcsr", m=64, k=64, n=32, block=(16, 8), sparsity=0.5),
    ]


def load_fleet(path: str) -> List[TuneJob]:
    """Load a declarative fleet: a JSON list of ``TuneJob`` field dicts."""
    with open(path) as f:
        spec = json.load(f)
    if not isinstance(spec, list):
        raise ValueError(f"{path}: fleet spec must be a JSON list of job "
                         "objects")
    return [TuneJob.from_dict(d) for d in spec]


def _make_operands(job: TuneJob):
    """Synthesize the job's (SparseTensor, dense B) pair deterministically."""
    import numpy as np

    from repro.sparse import sparsify

    rng = np.random.default_rng(job.seed + 1)
    w = rng.normal(size=(job.m, job.k)).astype(job.dtype)
    st = sparsify(w, format=job.fmt, sparsity=job.sparsity,
                  method=job.method, block=job.block, seed=job.seed)
    b = np.asarray(rng.normal(size=(job.k, job.n)), job.dtype)
    return st, b


def run_job(job: TuneJob, db_path: Optional[str] = None) -> dict:
    """Run one measured sweep and (optionally) commit the winner.

    Returns ``{"job", "key", "winner"}``. With ``db_path`` the winner is
    appended to that DB (atomic, merge-safe — safe to call concurrently
    from many workers against one path).
    """
    import jax.numpy as jnp

    from repro.ops import autotune_spmm
    from repro.tune.db import TuneDB, problem_key

    st, b = _make_operands(job)
    b = jnp.asarray(b)
    winner = autotune_spmm(st, b, codecs=tuple(job.codecs), impl=job.impl,
                           use_db=False)
    key = problem_key("spmm", st.format, st.shape, job.n, st.block,
                      st.dtype)
    if db_path:
        TuneDB(db_path).record(
            key, winner, structure=st.structure.content_digest(),
            source="farm")
    winner = dict(winner)
    winner.pop("rejected_codecs", None)
    return {"job": job.to_dict(), "key": list(key[:2]) + [list(key[2]),
            list(key[3]), key[4]], "winner": winner}


def _pool_entry(job_dict: dict, db_path: Optional[str]) -> dict:
    """Top-level subprocess entry (must be importable under spawn)."""
    return run_job(TuneJob.from_dict(job_dict), db_path)


def run_farm(jobs: Iterable[TuneJob], db_path: str, *, workers: int = 0,
             compact: bool = True, timeout: Optional[float] = None
             ) -> dict:
    """Sweep ``jobs`` into the DB at ``db_path``; return a summary.

    ``workers > 0`` fans jobs out over a spawn-based subprocess pool
    (each worker owns a fresh jax runtime; results stream into the shared
    DB via atomic appends, so a crashed worker loses only its own jobs).
    ``workers=0`` runs inline. A job that raises is reported in
    ``"failed"`` — the farm commits every winner it got, it never gives
    up the fleet over one bad job.
    """
    jobs = list(jobs)
    results, failed = [], []
    if workers > 0:
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor

        ctx = mp.get_context("spawn")
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as pool:
            futs = {pool.submit(_pool_entry, j.to_dict(), db_path): j
                    for j in jobs}
            for fut, job in futs.items():
                try:
                    results.append(fut.result(timeout=timeout))
                except Exception as e:  # noqa: BLE001 — farm must survive
                    failed.append({"job": job.to_dict(), "error": repr(e)})
    else:
        for job in jobs:
            try:
                results.append(run_job(job, db_path))
            except Exception as e:  # noqa: BLE001
                failed.append({"job": job.to_dict(), "error": repr(e)})
    from repro.tune.db import TuneDB

    db = TuneDB(db_path)
    if compact and results:
        db.compact()
    return {"db": db.stats(), "jobs": len(jobs), "tuned": len(results),
            "failed": failed, "results": results,
            "workers": int(workers), "pid": os.getpid()}
