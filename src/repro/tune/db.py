"""``TuneDB`` — the persistent, append-merge-safe autotune database.

The measured ``repro.ops.autotune_spmm`` sweep is the expensive step that
makes the paper's kernels hit their numbers (the per-matrix adaptivity
Acc-SpMM and cuTeSpMM show is decisive), and until now its winners lived in
a per-process dict: every serving replica re-paid the full sweep per
structure on startup. ``TuneDB`` serializes those winners to disk so a
fleet tunes once — offline, in ``tools/tune_farm.py`` — and every engine
warm-starts from the file.

Design (the Inductor cache-entry playbook, adapted to JSON-lines):

* **One record per line**, appended with a single ``O_APPEND`` ``write()``
  — concurrent workers (the tune farm's subprocess pool, or several
  engines tuning live) interleave whole lines and never clobber each
  other. Merging is a pure read-side fold: for duplicate keys the record
  with the best (lowest) measured ``us`` wins, ties to the latest line.
* **Schema-versioned records** (``schema: "repro-tune/v1"``). A record
  with a different schema, an unparsable line, or a missing/malformed
  winner is *quarantined*: counted, skipped, and never fatal — a corrupt
  DB degrades to the in-process sweep, bitwise-identical to running with
  no DB at all.
* **Environment-fingerprinted entries**. Each record carries
  ``{"jax": jax.__version__, "backend": jax.default_backend()}``; an entry
  measured under a different jax or backend is *stale* — kept out of the
  live table (visible in ``stale_entries``) so a CPU-tuned DB never steers
  a TPU deployment, and a jax upgrade invalidates old timings.
* **Keys mirror the in-process tuning cache**: (op family, format,
  shape + N, block geometry, value dtype) — exactly
  ``repro.ops.tiling._tuned_key`` — plus the operand's structure content
  digest for provenance and per-structure preloads.

The ``repro.ops`` wiring (consult-on-miss, record-after-sweep, the
``db_hits``/``db_misses``/``db_stale``/``sweeps`` counters) lives in
``repro.ops.tiling``; ``ServeEngine(tune_db=...)`` preloads from here at
construction and admission time. See docs/performance.md
("Persistent tuning").
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List, Optional, Tuple

__all__ = ["TuneDB", "TUNE_DB_SCHEMA", "ENV_DB_VAR", "env_fingerprint",
           "problem_key", "key_to_record", "record_to_key"]

TUNE_DB_SCHEMA = "repro-tune/v1"

# Path of the process-wide default DB; repro.ops.tiling.active_tune_db()
# opens it lazily on first tuned-entry miss.
ENV_DB_VAR = "REPRO_TUNE_DB"

# required winner fields and their validators (bn must be a positive int;
# the others may be None for formats that don't tune them)
_WINNER_FIELDS = ("bn", "chunks_per_task", "pipeline_depth", "value_codec",
                  "us")


def env_fingerprint() -> Dict[str, str]:
    """The (jax version, backend platform) pair an entry was measured under.

    Timings (and even candidate availability — interpret-mode vs compiled
    kernels) are only comparable within one fingerprint; entries from any
    other are treated as stale at load time.
    """
    import jax

    return {"jax": str(jax.__version__),
            "backend": str(jax.default_backend())}


def problem_key(op: str, fmt: str, shape, n: int, block, dtype
                ) -> Tuple:
    """The canonical lookup key — mirrors ``repro.ops.tiling._tuned_key``."""
    import numpy as np

    return (str(op), str(fmt or ""), tuple(int(s) for s in shape) + (int(n),),
            (int(block[0]), int(block[1])), str(np.dtype(dtype)))


def key_to_record(key: Tuple) -> dict:
    """Serialize a problem key tuple into the record's ``"key"`` object."""
    op, fmt, shape_n, block, dtype = key
    return {"op": op, "fmt": fmt, "shape": list(shape_n[:-1]),
            "n": int(shape_n[-1]), "block": list(block), "dtype": dtype}


def record_to_key(k: dict) -> Tuple:
    """Inverse of ``key_to_record`` (raises on malformed input)."""
    return (str(k["op"]), str(k["fmt"]),
            tuple(int(s) for s in k["shape"]) + (int(k["n"]),),
            (int(k["block"][0]), int(k["block"][1])), str(k["dtype"]))


def _valid_winner(w) -> bool:
    if not isinstance(w, dict) or any(f not in w for f in _WINNER_FIELDS):
        return False
    try:
        return int(w["bn"]) > 0 and float(w["us"]) >= 0
    except (TypeError, ValueError):
        return False


class TuneDB:
    """On-disk autotune-winner store (JSON-lines, append-merge-safe).

    ``TuneDB(path)`` parses the file once (missing file = empty DB);
    ``reload()`` re-reads after external writers appended. All malformed
    input is counted, never raised — see the module docstring for the
    quarantine / staleness rules.

    Attributes after load:
      entries      {key_tuple: record} — env-valid winners, best ``us`` per key
      stale        {key_tuple: record} — env-mismatched entries (not served)
      quarantined  int — lines dropped as corrupt / wrong schema / malformed
    """

    def __init__(self, path: str, *, env: Optional[dict] = None):
        self.path = str(path)
        self.env = dict(env) if env is not None else env_fingerprint()
        self.entries: Dict[Tuple, dict] = {}
        self.stale: Dict[Tuple, dict] = {}
        self.quarantined = 0
        self.reload()

    def __len__(self) -> int:
        return len(self.entries)

    def __repr__(self) -> str:
        return (f"TuneDB({self.path!r}, entries={len(self.entries)}, "
                f"stale={len(self.stale)}, quarantined={self.quarantined})")

    # -- read side ----------------------------------------------------------
    def reload(self) -> "TuneDB":
        """(Re-)parse the file into the merged in-memory tables."""
        self.entries, self.stale, self.quarantined = {}, {}, 0
        try:
            with open(self.path, "rb") as f:
                raw = f.read()
        except (FileNotFoundError, IsADirectoryError, PermissionError,
                OSError):
            return self
        for line in raw.splitlines():
            line = line.strip()
            if not line:
                continue
            self._fold(line)
        return self

    def _fold(self, line: bytes) -> None:
        try:
            rec = json.loads(line)
        except (ValueError, UnicodeDecodeError):
            self.quarantined += 1
            return
        if not isinstance(rec, dict) or rec.get("schema") != TUNE_DB_SCHEMA:
            self.quarantined += 1
            return
        try:
            key = record_to_key(rec["key"])
        except (KeyError, TypeError, ValueError, IndexError):
            self.quarantined += 1
            return
        if not _valid_winner(rec.get("winner")):
            self.quarantined += 1
            return
        env = rec.get("env")
        table = self.entries if env == self.env else self.stale
        cur = table.get(key)
        # merge fold: best measured time wins, ties to the later line
        if cur is None or float(rec["winner"]["us"]) <= float(
                cur["winner"]["us"]):
            table[key] = rec

    def lookup(self, key: Tuple) -> Tuple[str, Optional[dict]]:
        """``("hit", winner)`` for an env-valid entry, ``("stale", None)``
        when only an env-mismatched entry exists, else ``("miss", None)``."""
        rec = self.entries.get(key)
        if rec is not None:
            return "hit", dict(rec["winner"])
        if key in self.stale:
            return "stale", None
        return "miss", None

    def match(self, *, op: Optional[str] = None, fmt: Optional[str] = None,
              shape=None, block=None,
              structure: Optional[str] = None) -> List[Tuple[Tuple, dict]]:
        """Env-valid ``(key, winner)`` pairs filtered by problem fields.

        ``shape`` matches the logical (m, k) prefix of the key (any N);
        ``structure`` matches the recorded content digest. This is the
        preload query ``ServeEngine`` runs per layer structure.
        """
        out = []
        want_shape = (tuple(int(s) for s in shape)
                      if shape is not None else None)
        want_block = ((int(block[0]), int(block[1]))
                      if block is not None else None)
        for key, rec in self.entries.items():
            k_op, k_fmt, k_shape_n, k_block, _ = key
            if op is not None and k_op != op:
                continue
            if fmt is not None and k_fmt != fmt:
                continue
            if want_shape is not None and k_shape_n[:-1] != want_shape:
                continue
            if want_block is not None and k_block != want_block:
                continue
            if structure is not None and rec.get("structure") != structure:
                continue
            out.append((key, dict(rec["winner"])))
        return out

    def winners(self) -> List[Tuple[Tuple, dict]]:
        """Every env-valid ``(key, winner)`` pair — the bulk warm-start
        feed for ``repro.ops.adopt_tuned_entries``."""
        return [(k, dict(r["winner"])) for k, r in self.entries.items()]

    # -- write side ---------------------------------------------------------
    def record(self, key: Tuple, winner: dict, *,
               structure: Optional[str] = None,
               source: str = "autotune") -> dict:
        """Append one winner (atomic single-line ``O_APPEND`` write).

        Also folds the record into the live tables, so a subsequent
        ``lookup`` in this process sees it without a ``reload()``.
        Returns the record written.
        """
        w = {f: winner.get(f) for f in _WINNER_FIELDS}
        w["bn"] = int(w["bn"])
        w["us"] = float(w["us"])
        rec = {
            "schema": TUNE_DB_SCHEMA,
            "key": key_to_record(key),
            "structure": structure,
            "env": dict(self.env),
            "winner": w,
            "meta": {"ts": time.time(), "pid": os.getpid(),
                     "source": str(source)},
        }
        line = (json.dumps(rec, sort_keys=True) + "\n").encode()
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd = os.open(self.path, os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                     0o644)
        try:
            os.write(fd, line)
        finally:
            os.close(fd)
        self._fold(line)
        return rec

    def compact(self) -> int:
        """Rewrite the file as one merged record per key (atomic replace).

        Drops quarantined lines and duplicate-key losers; keeps stale
        (env-mismatched) entries — another fingerprint's deployment may
        still want them. Returns the number of records written.
        """
        recs = [dict(r) for r in self.entries.values()]
        recs += [dict(r) for r in self.stale.values()]
        recs.sort(key=lambda r: json.dumps(r["key"], sort_keys=True))
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tunedb-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                for rec in recs:
                    f.write(json.dumps(rec, sort_keys=True) + "\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.quarantined = 0
        return len(recs)

    def stats(self) -> dict:
        """Dashboard summary: path + live/stale/quarantined entry counts."""
        return {"path": self.path, "entries": len(self.entries),
                "stale_entries": len(self.stale),
                "quarantined": self.quarantined, "env": dict(self.env)}
