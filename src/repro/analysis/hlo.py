"""Parse collective ops + bytes out of compiled HLO text.

``cost_analysis`` does not report collective traffic, so we regex the
post-SPMD module: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op line carries its (per-device) output
shape; we sum dtype-sized byte counts per collective kind.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL = r"all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute"

# e.g.:  %all-reduce.1 = f32[16,512]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^ ]*)\s+(" + _COLL + r")(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective kind (output-shape accounting)."""
    out: Dict[str, int] = defaultdict(int)
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        tuple_inner, dtype, dims, kind = m.groups()
        # avoid double counting start/done pairs: the -done op has the
        # same kind; count only lines not ending in -done
        line_start = hlo_text.rfind("\n", 0, m.start()) + 1
        line = hlo_text[line_start: hlo_text.find("(", m.end(4))]
        if f"{kind}-done" in line:
            continue
        if tuple_inner is not None:
            b = sum(
                _shape_bytes(dt, dm) for dt, dm in _SHAPE_RE.findall(tuple_inner)
            )
        else:
            b = _shape_bytes(dtype, dims)
        out[kind] += b
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text).values())
