"""Analytic MODEL_FLOPS (the 6·N·D convention) per (arch, shape)."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6 * N_active * tokens for train; 2 * N_active * tokens for inference
    (forward only), decode counts the single new token per sequence."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def attention_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Quadratic attention term (not in 6ND), useful-work convention
    (causal half), forward only; x3 for train (fwd+bwd)."""
    if cfg.attn_type == "none":
        return 0.0
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    s = shape.seq_len
    w = cfg.sliding_window
    span = min(w, s) if w else s
    if shape.kind == "decode":
        per_tok = 2 * 2 * h * hd * min(span, s)
        return per_tok * cfg.num_layers * shape.global_batch
    useful = s * span - (span * (span - 1)) // 2 if span < s else s * (s + 1) // 2
    per_seq = 2 * 2 * h * hd * useful
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * per_seq * cfg.num_layers * shape.global_batch
