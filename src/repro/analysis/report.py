"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun.jsonl.

Usage: PYTHONPATH=src python -m repro.analysis.report [results/dryrun.jsonl]
Writes results/roofline.md (pasted into EXPERIMENTS.md).
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            try:
                recs.append(json.loads(line))
            except Exception:
                pass
    # dedupe: keep the latest record per (arch, shape, mesh, sparse)
    by = {}
    for r in recs:
        by[(r["arch"], r["shape"], r["mesh"], round(r.get("sparse", 0), 4))] = r
    return list(by.values())


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def roofline_table(recs):
    rows = [
        "| arch | shape | mesh | compute | memory | collective | bottleneck "
        "| GB/chip | fits | useful | roofline |",
        "|---|---|---|---|---|---|---|---|---|---|---|"[:-4],
    ]
    rows[1] = "|---|---|---|---|---|---|---|---|---|---|"
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                         r["mesh"])):
        uf = r.get("useful_fraction")
        rf = r.get("roofline_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | {r['bottleneck']} "
            f"| {r['per_chip_bytes']/1e9:.1f} | {'Y' if r['fits'] else 'N'} "
            f"| {uf and f'{uf:.2f}' or '-'} "
            f"| {rf and f'{rf:.3f}' or '-'} |")
    return "\n".join(rows)


def summary(recs):
    single = [r for r in recs if r["mesh"].count("x") == 1]
    multi = [r for r in recs if r["mesh"].count("x") == 2]
    lines = [
        f"cells compiled: single-pod={len(single)} multi-pod={len(multi)}",
        f"fits (single-pod): {sum(r['fits'] for r in single)}/{len(single)}",
    ]
    by_bn = defaultdict(int)
    for r in single:
        by_bn[r["bottleneck"]] += 1
    lines.append(f"bottleneck split (single-pod): {dict(by_bn)}")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    recs = load(path)
    out = ["## Roofline table (single-pod 16x16 unless noted)\n",
           roofline_table(recs), "\n\n## Summary\n", summary(recs)]
    text = "\n".join(out)
    with open("results/roofline.md", "w") as f:
        f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
