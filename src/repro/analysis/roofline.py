"""Three-term roofline from a compiled dry-run artifact (see ROOFLINE
ANALYSIS spec). All quantities are per-device (the post-SPMD module is the
per-device program), so each term divided by per-chip peak gives seconds
directly — equivalent to the global-quantity / (chips x peak) formulation.

Hardware constants: TPU v5e.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import collective_bytes

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

__all__ = ["RooflineReport", "analyze_compiled", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]


@dataclasses.dataclass
class RooflineReport:
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: Optional[float] = None
    useful_fraction: Optional[float] = None  # MODEL_FLOPS / (HLO_FLOPs*chips)
    arg_bytes_per_device: Optional[float] = None
    temp_bytes_per_device: Optional[float] = None
    out_bytes_per_device: Optional[float] = None

    def dominant_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> Optional[float]:
        """Useful-compute fraction of peak at the modeled step time."""
        if self.model_flops_total is None:
            return None
        t = self.dominant_time()
        if t <= 0:
            return None
        return (self.model_flops_total / self.n_chips) / (t * PEAK_FLOPS)

    n_chips: int = 1

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["dominant_time_s"] = self.dominant_time()
        d["roofline_fraction"] = self.roofline_fraction()
        return d


def analyze_compiled(
    compiled,
    n_chips: int,
    model_flops_total: Optional[float] = None,
) -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0) or 0.0)
    hbm = sum(
        float(v) for k, v in ca.items() if k.startswith("bytes accessed")
    )
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    coll_total = float(sum(coll.values()))

    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    bottleneck = max(terms, key=terms.get)

    ma = compiled.memory_analysis()
    useful = None
    if model_flops_total is not None and flops > 0:
        useful = model_flops_total / (flops * n_chips)
    return RooflineReport(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        coll_bytes_per_device=coll_total,
        coll_breakdown=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_fraction=useful,
        arg_bytes_per_device=float(ma.argument_size_in_bytes),
        temp_bytes_per_device=float(ma.temp_size_in_bytes),
        out_bytes_per_device=float(ma.output_size_in_bytes),
        n_chips=n_chips,
    )
