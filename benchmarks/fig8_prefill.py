"""Paper Fig. 8 analogue: end-to-end prefill speedup on a scaled Qwen2.5
model under four configurations: dense, sparse-attention only (MInference
analogue), sparse-FFN only (BCSR), combined — across sequence lengths.

CPU measurement on a 4-layer h=448 scaled model; `derived` composes the
modeled v5e FFN/attention savings at the paper's full shapes (28L, h=3584,
90% FFN block sparsity), reproducing the paper's claim structure: FFN
sparsity dominates at short S, attention sparsity at long S, combined
multiplies (2.66x at 64K on H100)."""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import HBM_BW, PEAK_MXU, SMOKE, time_call
from repro.configs import ARCHS, reduced_config
from repro.core.sparse_attention import local_sink_mask
from repro.models.registry import build_model

SEQS = (128,) if SMOKE else (256, 512)
ATTN_BUDGET = 0.25
FFN_SPARSITY = 0.9


def _scaled_cfg(**over):
    return reduced_config(
        ARCHS["qwen2.5-7b"], num_layers=4, d_model=448, num_heads=8,
        num_kv_heads=4, head_dim=56, d_ff=1184, vocab_size=1024,
        sparse_block=(32, 32), **over)


def _modeled_full_speedup(seq: int):
    """Compose modeled v5e per-layer times at full Qwen scale."""
    h, f, L = 3584, 18944, 28
    # FFN: 3 projections, dense vs 10% blocks
    t_ffn_d = 3 * max(2.0 * h * f * seq / PEAK_MXU,
                      (h * f * 2 + seq * (h + f) * 2) / HBM_BW)
    t_ffn_s = 3 * max(2.0 * h * f * seq * (1 - FFN_SPARSITY) / PEAK_MXU,
                      (h * f * 2 * (1 - FFN_SPARSITY)
                       + seq * (h + f) * 2) / HBM_BW)
    # attention: causal half, dense vs block budget
    hd, nh = 128, 28
    t_att_d = 2 * 2 * nh * hd * seq * seq / 2 / PEAK_MXU
    t_att_s = t_att_d * ATTN_BUDGET
    qkvo = max(2.0 * 4 * h * h * seq / PEAK_MXU, 4 * h * h * 2 / HBM_BW)
    dense = t_ffn_d + t_att_d + qkvo
    return {
        "minference_only": dense / (t_ffn_d + t_att_s + qkvo),
        "bcsr_only": dense / (t_ffn_s + t_att_d + qkvo),
        "combined": dense / (t_ffn_s + t_att_s + qkvo),
    }


def run(csv_rows):
    rng = np.random.default_rng(0)
    for seq in SEQS:
        nqb = seq // 32
        block_mask = np.broadcast_to(
            local_sink_mask(nqb, nqb, window_blocks=max(1, int(ATTN_BUDGET * nqb)),
                            sink_blocks=1), (8, nqb, nqb)).copy()
        variants = {
            "dense": dict(cfg=_scaled_cfg(), mask=None),
            "minference_only": dict(cfg=_scaled_cfg(), mask=block_mask),
            "bcsr_only": dict(cfg=_scaled_cfg(ffn_sparsity=FFN_SPARSITY),
                              mask=None),
            "combined": dict(cfg=_scaled_cfg(ffn_sparsity=FFN_SPARSITY),
                             mask=block_mask),
        }
        toks = jnp.asarray(rng.integers(0, 1024, (1, seq)), jnp.int32)
        us = {}
        for name, v in variants.items():
            m = build_model(v["cfg"], block_mask=v["mask"])
            params = m.init(jax.random.PRNGKey(0))
            fwd = jax.jit(lambda p, b, m=m: m.forward(p, b)[0])
            us[name] = time_call(fwd, params, {"tokens": toks},
                                 warmup=1, iters=3)
        modeled = _modeled_full_speedup(seq * 128)  # scale to 32K-64K regime
        for name in variants:
            sp_meas = us["dense"] / us[name]
            sp_model = modeled.get(name, 1.0)
            csv_rows.append((f"fig8/S{seq}_{name}", us[name],
                             f"meas={sp_meas:.2f}x_model@{seq*128}={sp_model:.2f}x"))
    return csv_rows
