"""Paper Table III analogue: Qwen2.5-7B gate_proj latency, dense vs BCSR,
across block sparsity {80, 90, 95, 99}% and sequence length.

CPU measurement uses a 1/8-scaled gate_proj (2368 x 448) with 64x64 blocks;
`derived` reports the modeled full-size (18944 x 3584, 128x128 blocks) v5e
latency and speedup — the paper's headline is the monotone speedup growth
with sparsity (1.58x at 90% -> 3.19x at 99% on H100).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (HBM_BW, PEAK_MXU, SMOKE, model_bcsr_time,
                               time_call, time_spmm)
from repro.ops import auto_bn
from repro.sparse import sparsify

M_S, K_S = 18944 // 8, 3584 // 8  # scaled CPU shapes
M_F, K_F = 18944, 3584
SPARSITIES = (0.9,) if SMOKE else (0.8, 0.9, 0.95, 0.99)
SEQS = (1024,) if SMOKE else (1024, 4096)


def _dense_time_full(n):
    flops = 2.0 * M_F * K_F * n
    bytes_ = (M_F * K_F + K_F * n + M_F * n) * 2
    return max(flops / PEAK_MXU, bytes_ / HBM_BW)


def run(csv_rows):
    rng = np.random.default_rng(0)
    w_s = rng.normal(size=(M_S, K_S)).astype(np.float32)
    for n in SEQS:
        n_s = max(n // 8, 128)
        x_s = jnp.asarray(rng.normal(size=(K_S, n_s)).astype(np.float32))
        f_dense = jax.jit(
            lambda xx, ww=jnp.asarray(w_s): ww @ xx)
        us_dense = time_call(f_dense, x_s)
        t_dense_full = _dense_time_full(n)
        csv_rows.append((f"table3/gateproj_N{n}_dense", us_dense,
                         f"{t_dense_full*1e3:.3f}ms_v5e"))
        for sp in SPARSITIES:
            # format-agnostic sparsify -> SparseTensor (plans once per layer)
            a = sparsify(w_s, format="bcsr", block=(64, 64), sparsity=sp,
                         method="random", seed=1)
            us_sp = time_spmm(a, x_s, warmup=2, iters=5)
            # full-size model: nnz blocks at this sparsity, 128x128 blocks
            nnzb = int(round((1 - sp) * (M_F // 128) * (K_F // 128)))
            bn = auto_bn(n, 128, 128, op="table3", shape=(M_F, K_F))
            t_sp = model_bcsr_time(nnzb, 128, 128, n, bn, k=K_F)
            csv_rows.append((
                f"table3/gateproj_N{n}_sparse{int(sp*100)}", us_sp,
                f"{t_sp*1e3:.3f}ms_v5e({t_dense_full/t_sp:.2f}x)"))
    return csv_rows
