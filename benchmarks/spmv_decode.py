"""Skinny-N decode hot loop: GEMV fast path vs full-tile SpMM at N=1.

The ``spmv/decode`` row measures exactly what PR 9's dispatch buys: the
same sparse operand multiplied against a one-column RHS through the
full-tile kernels (``spmv_threshold=0`` pins the wide path) and through
the GEMV family (``spmv_threshold=1`` guarantees the skinny route). Both
timings go through ``spmm`` so the numbers include the dispatch layer the
decode loop actually pays, and ``benchmarks.common.time_spmm`` jits over
the same plan/backends the serving engine uses.

The module is also an acceptance guard, not just a number: it asserts the
GEMV path beats the full-tile path at N=1 for *both* formats — on TPU
because a b_col-wide row gather replaces full-width tile DMAs, and in
interpret mode because the GEMV grids issue far fewer DMAs/grid steps —
and that the dispatch counter actually observed the skinny route (so the
measurement can't silently compare full-tile against itself).

Standalone:  PYTHONPATH=src python benchmarks/spmv_decode.py --smoke
Harness:     python benchmarks/run.py spmv [--smoke]
"""

from __future__ import annotations

import os
import pathlib
import sys

if __package__ in (None, ""):  # standalone: mirror run.py's bootstrap
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

import numpy as np
import jax.numpy as jnp

from benchmarks.common import JSON_EXTRAS, SMOKE, time_spmm
from repro.ops import spmv_dispatch_info
from repro.sparse import SparseTensor

# smoke: small operands so CI finishes in seconds under interpret-mode
# kernels — but not so small that the full-tile grid degenerates to a
# couple of steps (at 64x64 the crossover inverts); full: FFN-decode-ish.
_M, _K = (128, 128) if SMOKE else (512, 512)
_BLOCKS = {"wcsr": (16, 8), "bcsr": (16, 16)} if SMOKE else \
          {"wcsr": (32, 8), "bcsr": (32, 32)}
_DENSITY = 0.4
_WARMUP, _ITERS = (1, 2) if SMOKE else (2, 5)


def _operand(rng, fmt):
    d = rng.normal(size=(_M, _K)).astype(np.float32)
    d *= rng.random(d.shape) < _DENSITY
    return SparseTensor.from_dense(d, fmt, block=_BLOCKS[fmt])


def run(csv_rows):
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=(_K, 1)).astype(np.float32))

    extras = {"m": _M, "k": _K, "n": 1}
    before = spmv_dispatch_info()["dispatched"]
    for fmt in ("wcsr", "bcsr"):
        st = _operand(rng, fmt)
        full_us = time_spmm(st, b, warmup=_WARMUP, iters=_ITERS,
                            spmv_threshold=0)   # pin the full-tile path
        gemv_us = time_spmm(st, b, warmup=_WARMUP, iters=_ITERS,
                            spmv_threshold=1)   # pin the GEMV family
        extras[f"{fmt}_full_us"] = full_us
        extras[f"{fmt}_gemv_us"] = gemv_us
        extras[f"{fmt}_speedup"] = full_us / gemv_us
    extras["dispatched"] = spmv_dispatch_info()["dispatched"] - before

    # acceptance: the decode fast path must actually be fast, and the
    # dispatch counter must prove the skinny route ran at all
    assert extras["dispatched"] > 0, extras
    for fmt in ("wcsr", "bcsr"):
        assert extras[f"{fmt}_gemv_us"] < extras[f"{fmt}_full_us"], extras

    csv_rows.append((
        "spmv/decode", extras["wcsr_gemv_us"],
        f"wcsr_speedup={extras['wcsr_speedup']:.2f}x"
        f"_bcsr_speedup={extras['bcsr_speedup']:.2f}x"))
    JSON_EXTRAS["spmv/decode"] = extras
    return csv_rows


def main() -> None:
    rows = []
    run(rows)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    print("spmv_decode: OK", file=sys.stderr)


if __name__ == "__main__":
    main()
