"""Paper Fig. 7 analogue: output-tile width (bn ~ BN = 2*WGMMA_N) sweep at
N=1024 — larger tiles amortize per-step overhead, non-divisors pay padding
waste, VMEM caps the top end (paper §IV-C)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import (SMOKE, SUITE, geomean, model_bcsr_time,
                               suite_matrix, tflops, time_call)
from repro.kernels.bcsr.kernel import run_bcsr_spmm
from repro.kernels.tuning import padding_waste, vmem_usage
from repro.sparse import convert

M = K = 512 if SMOKE else 1024
N = 1024
BM = BK = 64
BNS = (64, 256) if SMOKE else (16, 64, 128, 176 * 2, 256, 496, 512, 1024)


def run(csv_rows):
    mats = []
    for i, (kind, density) in enumerate(SUITE[:2] if SMOKE else SUITE[:4]):
        d = suite_matrix(kind, M, K, density, seed=200 + i)
        mats.append((convert(d, "bcsr", block=(BM, BK)), int((d != 0).sum())))
    best = None
    for bn in BNS:
        if vmem_usage(BM, BK, bn) > 16 * 1024 * 1024:
            csv_rows.append((f"fig7/bn{bn}", 0.0, "exceeds_vmem"))
            continue
        waste = padding_waste(N, bn)
        tf = []
        for a, nnz in mats:
            n_eff = -(-N // bn) * bn  # padded width actually computed
            t = model_bcsr_time(a.nnz_blocks, BM, BK, n_eff, bn, k=K)
            tf.append(tflops(nnz, N, t))  # useful-N throughput convention
        gm = geomean(tf)
        csv_rows.append((f"fig7/bn{bn}", 0.0,
                         f"{gm:.2f}TFLOPS(waste={waste:.2f})"))
        if best is None or gm > best[1]:
            best = (bn, gm)
    # one measured interpret run at the selected bn
    a, nnz = mats[0]
    b = jnp.asarray(np.random.default_rng(0).normal(
        size=(K, 256)).astype(np.float32))
    us = time_call(lambda bb: run_bcsr_spmm(a, bb, bn=min(best[0], 256)),
                   b, warmup=1, iters=2)
    csv_rows.append((f"fig7/selected_bn{best[0]}", us, f"{best[1]:.2f}TFLOPS"))
    return csv_rows
