"""Persistent-tuning warm-start: farm once, restart with zero sweeps.

The row CI diffs (``tune/warmstart``) measures what the TuneDB buys a
restarting replica: the cost of resolving a tuned configuration from the
farm-produced DB (``warm``) vs re-paying the full measured
``autotune_spmm`` sweep in-process (``cold``). The module also re-runs the
acceptance invariant end-to-end — a ``ServeEngine`` cold-started against
the farm DB must reach steady state with ``db_hits > 0`` and
``sweeps == 0`` in ``stats()["tune_db"]`` — so the benchmark fails loudly
if the warm-start wiring regresses, not just slowly.

Everything runs against a throwaway DB under ``results/`` — the harness
never touches a deployment's ``REPRO_TUNE_DB``.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np
import jax

from benchmarks.common import JSON_EXTRAS
from repro.configs import ARCHS, reduced_config
from repro.models.registry import build_model
from repro.ops import (autotune_spmm, clear_tuning_cache, set_tune_db,
                       tuning_cache_info)
from repro.serve.engine import Request, ServeEngine
from repro.tune import TuneDB, run_farm, smoke_fleet
from repro.tune.farm import _make_operands


def _trace_engine(db_path, cfg, m, params, rng):
    """Cold-process simulation: fresh tuned cache, engine owns the DB."""
    clear_tuning_cache()
    eng = ServeEngine(m, params, slots=2, max_len=64, page_size=16,
                      chunk=32, prefill_block_q=16, tune_db=db_path)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (12,)),
                    max_new_tokens=3) for i in range(3)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    return eng.stats()["tune_db"]


def run(csv_rows):
    rng = np.random.default_rng(0)
    fleet = smoke_fleet()  # CI-sized even off-smoke: the row is a guard
    db_path = os.path.join(tempfile.mkdtemp(prefix="repro-tune-"),
                           "tune.jsonl")

    import repro.ops.tiling as _tiling
    prior_db = _tiling._TUNE_DB  # restore after: don't leak into modules
    set_tune_db(None)
    clear_tuning_cache()
    try:
        t0 = time.perf_counter()
        farm = run_farm(fleet, db_path, workers=0)
        farm_s = time.perf_counter() - t0
        assert not farm["failed"], farm["failed"]

        # the fleet's first problem, re-synthesized deterministically
        import jax.numpy as jnp
        st, b = _make_operands(fleet[0])
        b = jnp.asarray(b)

        # cold: no DB — the replica pays the measured sweep
        clear_tuning_cache()
        t0 = time.perf_counter()
        cold = autotune_spmm(st, b, codecs=tuple(fleet[0].codecs),
                             use_db=False)
        cold_us = (time.perf_counter() - t0) * 1e6

        # warm: same problem resolved from the farm DB — no sweep
        clear_tuning_cache()
        set_tune_db(TuneDB(db_path))
        t0 = time.perf_counter()
        warm = autotune_spmm(st, b, codecs=tuple(fleet[0].codecs))
        warm_us = (time.perf_counter() - t0) * 1e6
        ti = tuning_cache_info()
        assert ti.sweeps == 0 and ti.db_hits > 0, ti
        assert warm["bn"] == cold["bn"], (warm, cold)

        # acceptance invariant: engine restart against the farm DB
        cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=1,
                             vocab_size=512)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng_db = _trace_engine(db_path, cfg, m, params, rng)
        assert eng_db["db_hits"] > 0 and eng_db["sweeps"] == 0, eng_db

        speedup = cold_us / max(warm_us, 1e-9)
        csv_rows.append((
            "tune/warmstart", warm_us,
            f"cold_sweep_us={cold_us:.0f}_speedup={speedup:.0f}x"
            f"_db_hits={eng_db['db_hits']}_sweeps={eng_db['sweeps']}"))
        JSON_EXTRAS["tune/warmstart"] = {
            "farm_jobs": farm["jobs"],
            "farm_s": farm_s,
            "cold_sweep_us": cold_us,
            "warm_lookup_us": warm_us,
            "warm_speedup": speedup,
            "db_entries": eng_db["entries"],
            "db_hits": eng_db["db_hits"],
            "db_misses": eng_db["db_misses"],
            "db_stale": eng_db["db_stale"],
            "sweeps": eng_db["sweeps"],
        }
    finally:
        set_tune_db(prior_db)
        clear_tuning_cache()
    return csv_rows
