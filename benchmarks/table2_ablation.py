"""Paper Table II / Fig. 6 analogue: incremental async-feature ablation,
mapped to TPU mechanisms (DESIGN.md §2):

  opt0  scalar (VPU, no MXU, no overlap)         ~ CUDA-core baseline
  opt1  +MXU micro-GEMMs, serialized loads       ~ +WGMMA
  opt2  +BlockSpec double-buffered DMA (overlap) ~ +TMA
  opt3  +multi-stage revisit pipeline            ~ +warp specialization
  opt4  +halved grid-step issue overhead         ~ +raw mbarrier
  opt5  +accumulator zero-elision                ~ +ScaleD=0
  opt6  +static persistent traversal             ~ persistent kernel (REGRESSES)
  opt7  +cluster A-multicast w/ sync overhead    ~ TMA multicast (REGRESSES)

`us_per_call` times the interpret-mode Pallas BCSR kernel once (the real
kernel implements opt5 semantics); `derived` is the modeled v5e TFLOP/s per
stage on the suite geomean.

The `table2/pipeline_qQ` rows reproduce the paper's async-pipeline ablation
directly on the WCSR gather path: the same kernel run at §III-A depth
Q ∈ {1, 2, 3} through `OpConfig.pipeline_depth` (1 = serial gather,
2 = double buffer, 3 = the paper's circular buffer). `us_per_call` is the
measured interpret-mode sweep (plumbing guard); `derived` models the v5e
steady state (`model_wcsr_chunk_time`): each extra slot hides one more
chunk's worth of the gather's HBM round-trip latency, with the paper's
diminishing returns past the point where Q-1 in-flight chunks cover it.

The `table2/codec_*` rows extend the same ablation to the value-codec
layer (Acc-SpMM's bit-compression knob): the quantized kernel path timed
at depth 2, with `derived` reporting the modeled sparse-operand
bytes-moved reduction (payload + per-chunk f32 scales vs the f32
baseline) — the headroom the compression hands back to the latency-hiding
pipeline. Structured extras (bytes breakdown) land in BENCH_spmm.json via
`benchmarks.common.JSON_EXTRAS`.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import (GRID_STEP_NS, HBM_BW, JSON_EXTRAS, PEAK_MXU,
                               SMOKE, SUITE, geomean, model_bcsr_time,
                               suite_matrix, tflops, time_call, time_spmm)
from repro.kernels.bcsr.kernel import run_bcsr_spmm
from repro.sparse import SparseTensor, convert, registered_value_codecs
from repro.sparse.codecs import modeled_value_bytes

M = K = 512 if SMOKE else 1024
N = 1024
BM = BK = 64
BN = 256
SUITE2 = SUITE[:2] if SMOKE else SUITE
# WCSR pipeline-depth sweep shape (kept small: interpret-mode measurement)
QN = 256
Q_BROW, Q_BCOL = 64, 8


DMA_LATENCY_NS = 600.0  # HBM round-trip latency of one gathered row burst


def model_wcsr_chunk_time(b_col: int, b_row: int, bn: int, depth: int,
                          dtype_bytes: int = 2) -> float:
    """Modeled v5e seconds per WCSR chunk at §III-A pipeline depth Q.

    What a Q-deep circular buffer buys on this kernel is *latency hiding*:
    the scalar core's DMA issue + the MXU work of Q-1 in-flight chunks
    overlap the HBM round trip of the chunk being gathered. Each extra slot
    hides one more `busy` period of the latency; returns diminish once
    (Q-1)*busy covers it — the paper's Table 2 shape.
    """
    issue = b_col * 30e-9  # ~30ns scalar-core issue per row DMA
    stream = (b_col * bn + b_row * b_col) * dtype_bytes / HBM_BW
    tc = 2.0 * b_row * b_col * bn / PEAK_MXU
    busy = issue + max(stream, tc)  # occupancy per chunk once data arrived
    exposed = max(0.0, DMA_LATENCY_NS * 1e-9 - (depth - 1) * busy)
    return busy + exposed + GRID_STEP_NS * 1e-9


def _pipeline_rows(csv_rows):
    d = suite_matrix("uniform", M, K, 0.02, seed=11)
    w = SparseTensor.wrap(convert(d, "wcsr", b_row=Q_BROW, b_col=Q_BCOL))
    nnz = int((d != 0).sum())
    b = jnp.asarray(np.random.default_rng(1).normal(
        size=(K, QN)).astype(np.float32))
    nchunks = w.structure.nnz // Q_BCOL  # packed chunks across all windows
    base = None
    for q in (1, 2, 3):
        us = time_spmm(w, b, warmup=1, iters=2, impl="kernel_interpret",
                       bn=128, pipeline_depth=q)
        t = nchunks * (QN // 128) * model_wcsr_chunk_time(
            Q_BCOL, Q_BROW, 128, q)
        tf = tflops(nnz, QN, t)
        base = base or tf
        csv_rows.append((f"table2/pipeline_q{q}", us,
                         f"{tf:.3f}TFLOPS({tf / base:.2f}x)"))
    return _codec_rows(csv_rows, w, b)


def _codec_rows(csv_rows, w, b):
    """Value-codec ablation on the WCSR gather path (guarded like the
    ``pipeline_q{1,2,3}`` rows by the CI smoke step).

    `us_per_call` times the interpret-mode kernel consuming the compressed
    payload with fused in-register dequant (plumbing guard: the quantized
    path must run at every depth the CI smoke sweeps); `derived` is the
    modeled sparse-operand bytes-moved reduction vs the f32 baseline —
    payload bytes + one f32 scale per [b_row, b_col] chunk
    (``repro.sparse.codecs.modeled_value_bytes``), the traffic the §III-A
    gather actually issues per serving step.
    """
    stored = w.structure.stored_elements
    group = Q_BROW * Q_BCOL
    for codec in ("int8", "fp8_e4m3"):
        if codec not in registered_value_codecs():
            continue  # fp8 is gated on the jax build exposing the dtype
        wq = w.quantize(codec)
        us = time_spmm(wq, b, warmup=1, iters=2, impl="kernel_interpret",
                       bn=128, pipeline_depth=2)
        m = modeled_value_bytes(stored, group, codec)
        name = f"table2/codec_{codec}"
        csv_rows.append((name, us, f"{m['reduction']:.2f}x_bytes"))
        JSON_EXTRAS[name] = {
            "baseline_bytes": m["baseline_bytes"],
            "compressed_bytes": m["compressed_bytes"],
            "scale_bytes": m["scale_bytes"],
            "reduction": m["reduction"],
        }
    return csv_rows


def _stage_time(a, nnz, row_imbalance, stage: str) -> float:
    # per-step issue overhead shrinks through the pipeline stages:
    # sync barriers (4x) -> single-stage async wait (2x) -> multi-stage
    # circular buffer (1x) -> raw-mbarrier analogue (0.5x)
    kw = dict(dtype_bytes=2, k=K)
    if stage == "opt0":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=False,
                               mxu=False, c_zero_pass=True,
                               grid_ns=4 * GRID_STEP_NS, **kw)
    if stage == "opt1":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=False,
                               mxu=True, c_zero_pass=True,
                               grid_ns=4 * GRID_STEP_NS, **kw)
    if stage == "opt2":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=True,
                               grid_ns=2 * GRID_STEP_NS, **kw)
    if stage == "opt3":  # multi-stage pipeline also hides most issue latency
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=True,
                               grid_ns=0.6 * GRID_STEP_NS, **kw)
    if stage == "opt4":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=True,
                               grid_ns=0.5 * GRID_STEP_NS, **kw)
    if stage == "opt5":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=False,
                               grid_ns=0.5 * GRID_STEP_NS, **kw)
    if stage == "opt6":  # persistent static assignment: load imbalance
        t = model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                            mxu=True, c_zero_pass=False,
                            grid_ns=0.5 * GRID_STEP_NS, **kw)
        return t * row_imbalance
    if stage == "opt7":  # multicast: A fetched once per block (not per n-tile)
        t5 = model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                             mxu=True, c_zero_pass=False,
                             grid_ns=0.5 * GRID_STEP_NS, **kw)
        saved_a = a.nnz_blocks * BM * BK * 2 * (N // BN - 1) / 819e9
        sync = a.nnz_blocks * (N // BN) * 2 * GRID_STEP_NS * 1e-9  # x-CTA brr
        return t5 - saved_a + sync
    raise ValueError(stage)


def run(csv_rows):
    stages = [f"opt{i}" for i in range(8)]
    per_stage = {s: [] for s in stages}
    kernel_us = None
    for i, (kind, density) in enumerate(SUITE2):
        d = suite_matrix(kind, M, K, density, seed=100 + i)
        a = convert(d, "bcsr", block=(BM, BK))
        nnz = int((d != 0).sum())
        rows = np.asarray(a.block_rows)[: a.nnz_blocks]
        counts = np.bincount(rows, minlength=M // BM).astype(float)
        imb = counts.max() / max(counts.mean(), 1e-9)
        for s in stages:
            per_stage[s].append(tflops(nnz, N, _stage_time(a, nnz, imb, s)))
        if kernel_us is None:  # one interpret-mode run of the real kernel
            b = jnp.asarray(np.random.default_rng(0).normal(
                size=(K, 256)).astype(np.float32))
            kernel_us = time_call(
                lambda bb: run_bcsr_spmm(a, bb, bn=256), b, warmup=1, iters=2)
    base = geomean(per_stage["opt0"])
    for s in stages:
        gm = geomean(per_stage[s])
        us = kernel_us if s == "opt5" else 0.0
        csv_rows.append((f"table2/{s}", us, f"{gm:.2f}TFLOPS({gm/base:.1f}x)"))
    # paper claim: opt1..opt3 contribute ~98% of the total opt0->opt5 gain
    g = {s: geomean(per_stage[s]) for s in stages}
    frac = (g["opt3"] - g["opt0"]) / max(g["opt5"] - g["opt0"], 1e-9)
    csv_rows.append(("table2/async_features_fraction_of_gain", 0.0,
                     f"{frac:.2f}"))
    csv_rows.append(("table2/opt6_regresses", 0.0,
                     str(bool(g["opt6"] < g["opt5"]))))
    csv_rows.append(("table2/opt7_regresses", 0.0,
                     str(bool(g["opt7"] < g["opt5"]))))
    return _pipeline_rows(csv_rows)
