"""Paper Table II / Fig. 6 analogue: incremental async-feature ablation,
mapped to TPU mechanisms (DESIGN.md §2):

  opt0  scalar (VPU, no MXU, no overlap)         ~ CUDA-core baseline
  opt1  +MXU micro-GEMMs, serialized loads       ~ +WGMMA
  opt2  +BlockSpec double-buffered DMA (overlap) ~ +TMA
  opt3  +multi-stage revisit pipeline            ~ +warp specialization
  opt4  +halved grid-step issue overhead         ~ +raw mbarrier
  opt5  +accumulator zero-elision                ~ +ScaleD=0
  opt6  +static persistent traversal             ~ persistent kernel (REGRESSES)
  opt7  +cluster A-multicast w/ sync overhead    ~ TMA multicast (REGRESSES)

`us_per_call` times the interpret-mode Pallas BCSR kernel once (the real
kernel implements opt5 semantics); `derived` is the modeled v5e TFLOP/s per
stage on the suite geomean.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import (GRID_STEP_NS, SMOKE, SUITE, geomean,
                               model_bcsr_time, suite_matrix, tflops,
                               time_call)
from repro.kernels.bcsr.kernel import run_bcsr_spmm
from repro.sparse import convert

M = K = 512 if SMOKE else 1024
N = 1024
BM = BK = 64
BN = 256
SUITE2 = SUITE[:2] if SMOKE else SUITE


def _stage_time(a, nnz, row_imbalance, stage: str) -> float:
    # per-step issue overhead shrinks through the pipeline stages:
    # sync barriers (4x) -> single-stage async wait (2x) -> multi-stage
    # circular buffer (1x) -> raw-mbarrier analogue (0.5x)
    kw = dict(dtype_bytes=2, k=K)
    if stage == "opt0":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=False,
                               mxu=False, c_zero_pass=True,
                               grid_ns=4 * GRID_STEP_NS, **kw)
    if stage == "opt1":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=False,
                               mxu=True, c_zero_pass=True,
                               grid_ns=4 * GRID_STEP_NS, **kw)
    if stage == "opt2":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=True,
                               grid_ns=2 * GRID_STEP_NS, **kw)
    if stage == "opt3":  # multi-stage pipeline also hides most issue latency
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=True,
                               grid_ns=0.6 * GRID_STEP_NS, **kw)
    if stage == "opt4":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=True,
                               grid_ns=0.5 * GRID_STEP_NS, **kw)
    if stage == "opt5":
        return model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                               mxu=True, c_zero_pass=False,
                               grid_ns=0.5 * GRID_STEP_NS, **kw)
    if stage == "opt6":  # persistent static assignment: load imbalance
        t = model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                            mxu=True, c_zero_pass=False,
                            grid_ns=0.5 * GRID_STEP_NS, **kw)
        return t * row_imbalance
    if stage == "opt7":  # multicast: A fetched once per block (not per n-tile)
        t5 = model_bcsr_time(a.nnz_blocks, BM, BK, N, BN, overlap=True,
                             mxu=True, c_zero_pass=False,
                             grid_ns=0.5 * GRID_STEP_NS, **kw)
        saved_a = a.nnz_blocks * BM * BK * 2 * (N // BN - 1) / 819e9
        sync = a.nnz_blocks * (N // BN) * 2 * GRID_STEP_NS * 1e-9  # x-CTA brr
        return t5 - saved_a + sync
    raise ValueError(stage)


def run(csv_rows):
    stages = [f"opt{i}" for i in range(8)]
    per_stage = {s: [] for s in stages}
    kernel_us = None
    for i, (kind, density) in enumerate(SUITE2):
        d = suite_matrix(kind, M, K, density, seed=100 + i)
        a = convert(d, "bcsr", block=(BM, BK))
        nnz = int((d != 0).sum())
        rows = np.asarray(a.block_rows)[: a.nnz_blocks]
        counts = np.bincount(rows, minlength=M // BM).astype(float)
        imb = counts.max() / max(counts.mean(), 1e-9)
        for s in stages:
            per_stage[s].append(tflops(nnz, N, _stage_time(a, nnz, imb, s)))
        if kernel_us is None:  # one interpret-mode run of the real kernel
            b = jnp.asarray(np.random.default_rng(0).normal(
                size=(K, 256)).astype(np.float32))
            kernel_us = time_call(
                lambda bb: run_bcsr_spmm(a, bb, bn=256), b, warmup=1, iters=2)
    base = geomean(per_stage["opt0"])
    for s in stages:
        gm = geomean(per_stage[s])
        us = kernel_us if s == "opt5" else 0.0
        csv_rows.append((f"table2/{s}", us, f"{gm:.2f}TFLOPS({gm/base:.1f}x)"))
    # paper claim: opt1..opt3 contribute ~98% of the total opt0->opt5 gain
    g = {s: geomean(per_stage[s]) for s in stages}
    frac = (g["opt3"] - g["opt0"]) / max(g["opt5"] - g["opt0"], 1e-9)
    csv_rows.append(("table2/async_features_fraction_of_gain", 0.0,
                     f"{frac:.2f}"))
    csv_rows.append(("table2/opt6_regresses", 0.0,
                     str(bool(g["opt6"] < g["opt5"]))))
    csv_rows.append(("table2/opt7_regresses", 0.0,
                     str(bool(g["opt7"] < g["opt5"]))))
    return csv_rows
