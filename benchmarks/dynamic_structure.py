"""Dynamic structure: delta-patch vs full-rebuild host cost per mask edit.

The ``dyn/append`` row measures what ``repro.sparse.delta`` buys a serving
loop whose sparsity mask grows online (speculative block promotion, KV-mask
growth): the per-step *host* cost of ``append_window_chunks`` +
``make_plan`` — which splices the cached base plan through the registered
``StructureDelta`` — against the naive path that rebuilds the grown
structure with ``wcsr_from_dense`` and re-plans it from scratch every step.
Both loops time structure + planning only (the host work the delta layer
amortizes); the on-device value splice is correctness-checked untimed,
because its wall time on this CPU container is dominated by per-shape XLA
scatter compiles that say nothing about the host planning story.

The module is also an acceptance guard, not just a number: it asserts that
every growth step was served by a plan *patch* (``cache_stats()["plan"]
["patched"] == steps`` with zero full re-plans after the warmup miss), that
the patched path beats the rebuild path in wall time, and that
``ServeEngine.stats()`` surfaces the ``structure_deltas`` counter block —
so the amortization story regresses loudly.

Standalone:  PYTHONPATH=src python benchmarks/dynamic_structure.py --smoke
Harness:     python benchmarks/run.py dyn [--smoke]
"""

from __future__ import annotations

import os
import pathlib
import sys
import time

if __package__ in (None, ""):  # standalone: mirror run.py's bootstrap
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

import numpy as np

from benchmarks.common import JSON_EXTRAS, SMOKE
from repro.ops import cache_stats, clear_plan_cache, make_plan
from repro.sparse import (SparseTensor, append_window_chunks, structure_of,
                          wcsr_from_dense)

# smoke: tiny growth trace so CI finishes in seconds; full: enough windows
# and steps that the O(nnz) rebuild visibly dwarfs the O(edit) patch.
_M, _K = (128, 128) if SMOKE else (512, 512)
_BLOCK = (16, 8) if SMOKE else (32, 8)
_N = 32
_STEPS = 4 if SMOKE else 16


def _base(rng):
    d = rng.normal(size=(_M, _K)).astype(np.float32)
    d *= rng.random(d.shape) < 0.15
    return d


def _growth_trace(structure, rng, steps):
    """(window, col) edits that never collide with stored columns."""
    b_row, b_col = structure.block
    windows = _M // b_row
    ptrs = structure.ptrs
    cols_by_w = [set(int(c) for c in
                     structure.indices[0][int(ptrs[w]):int(ptrs[w + 1])]
                     if int(c) >= 0) for w in range(windows)]
    trace = []
    for s in range(steps):
        w = s % windows
        free = [c for c in range(_K) if c not in cols_by_w[w]]
        col = int(free[int(rng.integers(0, len(free)))])
        cols_by_w[w].add(col)
        trace.append((w, col))
    return trace


def run(csv_rows):
    rng = np.random.default_rng(0)
    d = _base(rng)
    b_row, _ = _BLOCK
    base = SparseTensor.from_dense(d, "wcsr", block=_BLOCK)
    trace = _growth_trace(base.structure, rng, _STEPS)
    step_vals = [rng.normal(size=(b_row, 1)).astype(np.float32)
                 for _ in trace]

    # --- naive path: densify-edit, rebuild structure, plan from scratch --
    d_cur = d.copy()
    rebuild_ts = []
    for (w, col), vals in zip(trace, step_vals):
        d_cur[w * b_row:(w + 1) * b_row, col:col + 1] = vals
        t0 = time.perf_counter()
        clear_plan_cache()  # a from-scratch planner has no base to reuse
        g_rb = structure_of(wcsr_from_dense(d_cur, *_BLOCK))
        make_plan(g_rb, _N)
        rebuild_ts.append((time.perf_counter() - t0) * 1e6)
    rebuild_us = float(np.median(rebuild_ts))

    # --- delta path: structure edit + patched plan, warm caches ----------
    clear_plan_cache()
    g = base.structure
    make_plan(g, _N)  # the one legitimate full plan (warmup)
    patch_ts = []
    for (w, col), _vals in zip(trace, step_vals):
        t0 = time.perf_counter()
        g, _ = append_window_chunks(g, w, [col])
        make_plan(g, _N)
        patch_ts.append((time.perf_counter() - t0) * 1e6)
    patch_us = float(np.median(patch_ts))

    cs = cache_stats()
    patched = cs["plan"]["patched"]
    full_replans = cs["plan"]["misses"] - 1  # minus the warmup
    assert patched == _STEPS, cs["plan"]
    assert full_replans == 0, cs["plan"]
    assert patch_us < rebuild_us, (patch_us, rebuild_us)

    # value splice (untimed): the tensor-level chain must land on exactly
    # the matrix the naive densify-edit loop produced
    st = base
    for (w, col), vals in zip(trace, step_vals):
        st = st.append_window_chunks(w, [col], vals)
    assert st.structure == g, "tensor chain diverged from structure chain"
    np.testing.assert_allclose(np.asarray(st.todense()), d_cur,
                               rtol=0, atol=0)

    # the serving runtime surfaces the same counters
    import jax
    from repro.configs import ARCHS, reduced_config
    from repro.models.registry import build_model
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=1, vocab_size=512)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, slots=2, max_len=64, page_size=16,
                      chunk=32, prefill_block_q=16)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (8,)),
                    max_new_tokens=2) for i in range(2)]
    eng.run(reqs)
    sd = eng.stats()["structure_deltas"]
    assert "plan_patched" in sd and "appends" in sd, sd

    speedup = rebuild_us / max(patch_us, 1e-9)
    csv_rows.append((
        "dyn/append", patch_us,
        f"rebuild_us={rebuild_us:.0f}_speedup={speedup:.1f}x"
        f"_patched={patched}_full_replans={full_replans}"))
    JSON_EXTRAS["dyn/append"] = {
        "steps": _STEPS,
        "patch_us": patch_us,
        "rebuild_us": rebuild_us,
        "patch_speedup": speedup,
        "plan_patched": patched,
        "full_replans_growth": full_replans,
    }
    return csv_rows


def main() -> None:
    rows = []
    run(rows)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    print("dynamic_structure: OK", file=sys.stderr)


if __name__ == "__main__":
    main()
