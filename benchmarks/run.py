"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a copy under results/).

``--smoke`` shrinks every suite/shape (see benchmarks.common.SMOKE) so CI
can run the whole harness under interpret-mode kernels on CPU:

    REPRO_SPARSE_IMPL=kernel_interpret python benchmarks/run.py --smoke

``--json`` additionally writes ``BENCH_spmm.json`` — the machine-readable
per-benchmark latency/bytes summary (schema: ``benchmarks.common.
BENCH_JSON_SCHEMA``) that CI emits and uploads, so the perf trajectory
across PRs is diffable by tooling instead of by eyeballing CSV.
"""

import json
import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

JSON_PATH = "BENCH_spmm.json"


def main() -> None:
    flags = {a for a in sys.argv[1:] if a.startswith("--")}
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    if "--smoke" in flags:
        # must be set before the benchmark modules (and their module-level
        # suite constants) are imported below
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    only = args[0] if args else None
    from benchmarks import (dist_scaling, dynamic_structure, fig7_tilewidth,
                            fig8_prefill, serve_throughput, spmv_decode,
                            table1_suitesparse, table2_ablation,
                            table3_gateproj, tune_warmstart)
    from benchmarks.common import bench_json_payload

    modules = {
        "table1": table1_suitesparse,
        "table2": table2_ablation,
        "table3": table3_gateproj,
        "fig7": fig7_tilewidth,
        "fig8": fig8_prefill,
        # serving runtime: chunked prefill vs legacy + arrival-trace TTFT
        "serve": serve_throughput,
        # multi-device scaling smoke (forced host mesh in a child process)
        "dist": dist_scaling,
        # persistent-tuning warm-start: farm -> restart with zero sweeps
        "tune": tune_warmstart,
        # dynamic structure: delta-patch vs full-rebuild host cost
        "dyn": dynamic_structure,
        # skinny-N decode: GEMV fast path vs full-tile SpMM at N=1
        "spmv": spmv_decode,
    }
    rows = [("name", "us_per_call", "derived")]
    for name, mod in modules.items():
        if only and name != only:
            continue
        mod.run(rows)
    out = "\n".join(f"{n},{u if isinstance(u, str) else f'{u:.1f}'},{d}"
                    for n, u, d in rows)
    print(out)
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.csv", "w") as f:
        f.write(out + "\n")
    if "--json" in flags:
        with open(JSON_PATH, "w") as f:
            json.dump(bench_json_payload(rows), f, indent=2, sort_keys=True)
        print(f"wrote {JSON_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()
