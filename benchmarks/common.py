"""Shared benchmark utilities: timing, synthetic SuiteSparse-style matrices,
and the v5e kernel cost model used for `derived` columns.

This container is CPU-only, so every row reports BOTH:
  * ``us_per_call`` — measured wall time of the jitted CPU implementation
    (relative comparisons only), and
  * ``derived``     — modeled TPU v5e execution from the roofline cost model
    (bytes/flops of the kernel dataflow; this is the number the paper's
    tables are reproduced against).
"""

from __future__ import annotations

import os
import time
from typing import Callable

import numpy as np
import jax

# CI smoke mode (benchmarks/run.py --smoke): shrink suites/shapes so the
# harness runs end-to-end in seconds under interpret-mode kernels on CPU —
# the point is that examples and the benchmark plumbing can't silently rot,
# not that the numbers mean anything.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"

# v5e constants (same as analysis/roofline.py)
PEAK_MXU = 197e12  # bf16 FLOP/s
PEAK_VPU = 3.2e12  # f32 vector FLOP/s (CUDA-core analogue)
HBM_BW = 819e9  # B/s
GRID_STEP_NS = 100.0  # per-grid-step scalar/DMA issue overhead (modeled)
VMEM_RESIDENT_BYTES = 8 * 1024 * 1024  # B-slice VMEM residency budget


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time in microseconds (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def time_spmm(a, b, warmup: int = 1, iters: int = 3, **config) -> float:
    """Time ``repro.ops.spmm(a, b)`` jitted, under the ambient op config.

    ``a`` may be a raw format or a ``repro.sparse.SparseTensor`` — the
    latter carries its pre-extracted structure, so host-side planning (tile
    selection, WCSR task split) hits the ``make_plan`` cache instead of
    re-deriving per call: the serving-style amortized measurement.
    ``config`` keywords (impl, bn, ...) apply to this measurement only; with
    none given the registry/auto-tiling defaults are measured — i.e. exactly
    what a caller of the public API gets.
    """
    from repro.ops import spmm

    f = jax.jit(lambda b_: spmm(a, b_, **config))
    return time_call(f, b, warmup=warmup, iters=iters)


def geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], np.float64)
    return float(np.exp(np.log(xs).mean())) if len(xs) else 0.0


# ---------------------------------------------------------------------------
# Machine-readable benchmark output (BENCH_spmm.json)
# ---------------------------------------------------------------------------

# Schema contract for benchmarks/run.py --json. Bump on breaking changes so
# trajectory tooling can dispatch on it:
#   {"schema": BENCH_JSON_SCHEMA,
#    "smoke": bool,                       # CI-sized run: numbers not meaningful
#    "rows": [{"name": "table2/opt5",     # one entry per CSV row
#              "us_per_call": float|None, # measured CPU wall time (None = n/a)
#              "derived": str,            # modeled column, verbatim
#              ...extras}],               # e.g. codec rows: baseline_bytes,
#                                         # compressed_bytes, reduction
#    "summaries": {module: {"rows": int, "us_geomean": float}}}
BENCH_JSON_SCHEMA = "repro-bench/v1"

# Benchmark modules attach per-row structured extras here (keyed by row
# name); bench_json_payload merges them into the row objects. The codec
# ablation rows use it for their bytes-moved breakdown.
JSON_EXTRAS: dict = {}


def bench_json_payload(rows) -> dict:
    """Build the ``BENCH_spmm.json`` payload from the harness CSV rows.

    ``rows`` is the run.py accumulator including the header row. Latency
    summaries are per benchmark module (the ``name`` prefix before ``/``);
    bytes summaries ride on the rows that registered ``JSON_EXTRAS``.
    """
    header, *data = rows
    out_rows = []
    groups: dict = {}
    for name, us, derived in data:
        entry = {
            "name": name,
            "us_per_call": None if isinstance(us, str) else float(us),
            "derived": str(derived),
        }
        entry.update(JSON_EXTRAS.get(name, {}))
        out_rows.append(entry)
        groups.setdefault(name.split("/")[0], []).append(entry)
    summaries = {
        mod: {
            "rows": len(entries),
            "us_geomean": geomean([e["us_per_call"] for e in entries
                                   if e["us_per_call"]]),
        }
        for mod, entries in groups.items()
    }
    return {"schema": BENCH_JSON_SCHEMA, "smoke": SMOKE,
            "rows": out_rows, "summaries": summaries}


# ---------------------------------------------------------------------------
# Synthetic many-user arrival trace (serve benchmark / CI smoke)
# ---------------------------------------------------------------------------


def arrival_trace(n_requests: int, *, mean_interarrival_ticks: float = 2.0,
                  prompt_lens=(8, 64), max_new: int = 8, seed: int = 0):
    """Deterministic synthetic serving workload.

    Poisson-ish arrivals (geometric inter-arrival gaps in engine ticks) with
    uniformly drawn prompt lengths — the many-user trace behind the ``serve``
    benchmark row. Returns a list of dicts sorted by ``arrive_tick``:
    ``{"rid", "arrive_tick", "prompt_len", "max_new"}``.
    """
    rng = np.random.default_rng(seed)
    lo, hi = prompt_lens
    tick, out = 0, []
    for rid in range(n_requests):
        out.append({
            "rid": rid,
            "arrive_tick": tick,
            "prompt_len": int(rng.integers(lo, hi + 1)),
            "max_new": max_new,
        })
        tick += int(rng.geometric(1.0 / mean_interarrival_ticks))
    return out


# ---------------------------------------------------------------------------
# Synthetic SuiteSparse-style matrices (banded / power-law / uniform)
# ---------------------------------------------------------------------------


def suite_matrix(kind: str, m: int, k: int, density: float, seed: int
                 ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = np.zeros((m, k), np.float32)
    nnz = int(density * m * k)
    if kind == "uniform":
        idx = rng.choice(m * k, size=nnz, replace=False)
        a.flat[idx] = rng.normal(size=nnz).astype(np.float32)
    elif kind == "banded":
        bw = max(1, int(density * k))
        for i in range(m):
            c0 = int(i * k / m)
            lo, hi = max(0, c0 - bw), min(k, c0 + bw)
            a[i, lo:hi] = rng.normal(size=hi - lo)
    elif kind == "powerlaw":
        # a few dense rows, long sparse tail (degree-skewed graphs)
        row_nnz = (k * density * (np.arange(1, m + 1) ** -0.8))
        row_nnz = np.maximum(1, (row_nnz * m / row_nnz.sum() * k * density)
                             ).astype(int)
        rng.shuffle(row_nnz)
        for i in range(m):
            n_i = min(int(row_nnz[i]), k)
            cols = rng.choice(k, size=n_i, replace=False)
            a[i, cols] = rng.normal(size=n_i)
    else:
        raise ValueError(kind)
    return a


SUITE = [
    ("uniform", 0.005), ("uniform", 0.02), ("uniform", 0.05),
    ("banded", 0.01), ("banded", 0.05), ("banded", 0.1),
    ("powerlaw", 0.005), ("powerlaw", 0.02), ("powerlaw", 0.05),
]


# ---------------------------------------------------------------------------
# v5e kernel cost model
# ---------------------------------------------------------------------------


def model_bcsr_time(nnz_blocks: int, bm: int, bk: int, n: int, bn: int,
                    dtype_bytes: int = 2, *, k: int | None = None,
                    overlap: bool = True, mxu: bool = True,
                    grid_ns: float = GRID_STEP_NS,
                    c_zero_pass: bool = False, row_lengths=None) -> float:
    """Modeled seconds for the BCSR kernel's dataflow on one v5e core.

    B traffic: if the [K, bn] dense column slice fits the VMEM residency
    budget it is read once per n-tile (VMEM residency — the TPU analogue of
    the H100's 50MB L2 holding B, which is what makes the paper's sparse
    kernels win on small/medium K); otherwise every block re-fetches its
    [bk, bn] tile from HBM.
    """
    n_tiles = -(-n // bn)
    steps = nnz_blocks * n_tiles
    flops = 2.0 * nnz_blocks * bm * bk * n_tiles * bn
    bytes_a = nnz_blocks * bm * bk * dtype_bytes * n_tiles
    refetch = nnz_blocks * bk * bn * dtype_bytes * n_tiles
    if k is not None and k * bn * dtype_bytes <= VMEM_RESIDENT_BYTES:
        bytes_b = min(refetch, k * bn * dtype_bytes * n_tiles)
    else:
        bytes_b = refetch
    # C written once per (row, n) tile; estimate rows from nnz (>=1 block/row)
    bytes_c = (row_lengths is not None and len(row_lengths) or nnz_blocks) \
        * bm * bn * dtype_bytes
    if c_zero_pass:
        bytes_c *= 2  # explicit zero-init pass (removed by ScaleD=0 analogue)
    t_comp = flops / (PEAK_MXU if mxu else PEAK_VPU)
    t_mem = (bytes_a + bytes_b + bytes_c) / HBM_BW
    t_grid = steps * grid_ns * 1e-9
    if overlap:
        return max(t_comp, t_mem) + t_grid
    return t_comp + t_mem + t_grid


def tflops(nnz: int, n: int, seconds: float) -> float:
    """Paper's throughput convention: (2 * nnz * N) / t."""
    if seconds <= 0:
        return 0.0
    return 2.0 * nnz * n / seconds / 1e12
