"""Paper Table I analogue: geomean SpMM TFLOP/s on a SuiteSparse-style suite,
stratified by density and dense width N: WCSR / BCSR vs the two baselines the
paper compares against — BELL (cuSPARSE Blocked-ELLPACK analogue: block rows
padded to the max row length, i.e. compute wasted on padding blocks) and a
dense GEMM (cuBLAS analogue). Matrices are RCM-preprocessed like the paper.

us_per_call measures the jitted CPU reference dataflow (at N=256 only);
`derived` is modeled v5e TFLOP/s with the paper's convention 2*nnz*N/t.
Derived-only rows (bell, geomeans, speedups, and the N != 256 strata) have
no measurement: their us column is empty in the CSV and null in
BENCH_spmm.json rather than a misleading 0.0.

`wcsr` models the paper-faithful kernel (synchronous per-iteration gather,
§III-C); `wcsr_opt` adds the beyond-paper double-buffered gather (8
outstanding row DMAs overlapped with the MXU) — see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import (HBM_BW, PEAK_MXU, SMOKE, geomean,
                               model_bcsr_time, suite_matrix, tflops,
                               time_spmm)
from repro.ops import auto_bn
from repro.sparse import SparseTensor, rcm_permutation

M = K = 512 if SMOKE else 2048  # scaled-down suite (CPU container)
NS = (256,) if SMOKE else (256, 1024)
N_MEASURE = 256
B_ROW = 64  # scaled block (full TPU config uses 128; see DESIGN.md)
DMA_ISSUE_NS = 30.0

SUITE1 = [
    ("uniform", 0.002), ("uniform", 0.005),
    ("banded", 0.002), ("banded", 0.01), ("banded", 0.03),
    ("powerlaw", 0.002), ("powerlaw", 0.005), ("powerlaw", 0.02),
]
if SMOKE:
    SUITE1 = SUITE1[:2]


def _model_wcsr_time(w, n, bn, overlap_gather: bool = False):
    n_tiles = -(-n // bn)
    flops = 2.0 * w.padded_cols * w.b_row * n_tiles * bn
    bytes_a = w.padded_cols * w.b_row * 2 * n_tiles
    bytes_b = w.padded_cols * bn * 2 * n_tiles  # indirect gather, no reuse
    bytes_c = w.num_windows * w.b_row * n_tiles * bn * 4
    t_comp = flops / PEAK_MXU
    t_mem = (bytes_a + bytes_b + bytes_c) / HBM_BW
    # scalar-core row-DMA issue (the cooperative-gather analogue)
    t_issue = w.padded_cols * n_tiles * DMA_ISSUE_NS * 1e-9
    if overlap_gather:  # double-buffered gather, 8 outstanding DMAs
        return max(t_comp, t_mem, t_issue / 8.0)
    return max(t_comp, t_mem) + t_issue


def _bell_blocks(a) -> int:
    """Blocked-ELLPACK pads every block-row to the max row length."""
    rows = np.asarray(a.block_rows)[: a.nnz_blocks]
    counts = np.bincount(rows, minlength=a.shape[0] // a.block[0])
    return int(counts.max()) * (a.shape[0] // a.block[0])


def run(csv_rows):
    mats = []
    for i, (kind, density) in enumerate(SUITE1):
        d = suite_matrix(kind, M, K, density, seed=i)
        perm = rcm_permutation(d)  # paper's preprocessing step
        d = d[np.ix_(perm, perm)] if d.shape[0] == d.shape[1] else d[perm]
        nnz = int((d != 0).sum())
        # format-agnostic layer: structure extracted once per matrix, so the
        # repeated time_spmm calls below plan once (make_plan cache)
        a = SparseTensor.from_dense(d, "bcsr", block=(B_ROW, B_ROW))
        w = SparseTensor.from_dense(d, "wcsr", block=(B_ROW, 8))
        mats.append((kind, density, d, nnz, a, w))

    for n in NS:
        per_fmt = {"wcsr": [], "wcsr_opt": [], "bcsr": [], "bell": [],
                   "dense": []}
        for kind, density, d, nnz, a, w in mats:
            # ops-layer §IV-C auto-tiling (tuning-cached), same policy the
            # public spmm() applies by default
            bn = auto_bn(n, B_ROW, B_ROW, op="table1", shape=(M, K))
            t_b = model_bcsr_time(a.raw.nnz_blocks, B_ROW, B_ROW, n, bn, k=K)
            t_bell = model_bcsr_time(_bell_blocks(a.raw), B_ROW, B_ROW, n, bn,
                                     k=K)
            t_w = _model_wcsr_time(w.raw, n, bn)
            t_wo = _model_wcsr_time(w.raw, n, bn, overlap_gather=True)
            t_d = max(2.0 * M * K * n / PEAK_MXU,
                      (M * K + K * n + M * n) * 2 / HBM_BW)
            per_fmt["bcsr"].append(tflops(nnz, n, t_b))
            per_fmt["bell"].append(tflops(nnz, n, t_bell))
            per_fmt["wcsr"].append(tflops(nnz, n, t_w))
            per_fmt["wcsr_opt"].append(tflops(nnz, n, t_wo))
            per_fmt["dense"].append(tflops(nnz, n, t_d))

            # derived-only rows carry "" (JSON us_per_call: null) — a 0.0
            # would read as a measured zero-microsecond call downstream
            us_b = us_w = ""
            if n == N_MEASURE:
                b = jnp.asarray(np.random.default_rng(1).normal(
                    size=(K, n)).astype(np.float32))
                # unified API with bn="auto" defaults (format-polymorphic)
                us_b = time_spmm(a, b)
                us_w = time_spmm(w, b)
            csv_rows.append((f"table1/{kind}_d{density}_N{n}_wcsr", us_w,
                             f"{per_fmt['wcsr'][-1]:.2f}TFLOPS"))
            csv_rows.append((f"table1/{kind}_d{density}_N{n}_bcsr", us_b,
                             f"{per_fmt['bcsr'][-1]:.2f}TFLOPS"))
            csv_rows.append((f"table1/{kind}_d{density}_N{n}_bell", "",
                             f"{per_fmt['bell'][-1]:.2f}TFLOPS"))
        for fmt in per_fmt:
            gm = geomean(per_fmt[fmt])
            csv_rows.append((f"table1/geomean_N{n}_{fmt}", "",
                             f"{gm:.2f}TFLOPS"))
        for base in ("bell", "dense"):
            for fmt in ("wcsr", "wcsr_opt", "bcsr"):
                sp = geomean(per_fmt[fmt]) / max(geomean(per_fmt[base]), 1e-9)
                csv_rows.append((f"table1/speedup_{fmt}_over_{base}_N{n}",
                                 "", f"{sp:.2f}x"))
    return csv_rows
