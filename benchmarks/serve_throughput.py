"""Serving-runtime throughput under a synthetic many-user arrival trace.

Two measurements on a tiny CPU-runnable model:

1. **Prefill throughput** — one long prompt through the chunked block-sparse
   prefill (the §IV-D path: one ``sparse_attention`` dispatch per layer per
   chunk) vs the legacy token-at-a-time decode loop. The acceptance
   invariant is ``chunked prefill tok/s > legacy prefill tok/s`` — CI
   asserts it from the JSON extras.
2. **Continuous-batching trace** — the ``benchmarks.common.arrival_trace``
   workload driven tick-by-tick through the paged engine: generated-token
   throughput, p50/p95 TTFT, and the amortization guard
   (``plan_cache.task_decompositions`` flat across ticks once the first
   request has traced). The trace runs twice — once with the skinny-N
   GEMV dispatch at its default ``spmv_threshold="auto"`` and once pinned
   to full-tile (``spmv_threshold=0``) — so the JSON row carries decode
   tok/s on both sides of the crossover plus the dispatch count.

Both engines warm up on a throwaway request first so compile time doesn't
pollute TTFT.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import JSON_EXTRAS, SMOKE, arrival_trace
from repro.configs import ARCHS, reduced_config
from repro.ops import DEFAULT_SPMV_THRESHOLD, OpConfig, spmv_dispatch_info
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine

PROMPT = 64 if SMOKE else 256
CHUNK = 32 if SMOKE else 64
PAGE = 16 if SMOKE else 32
MAX_LEN = 2 * PROMPT
N_REQS = 4 if SMOKE else 10
TRACE_LENS = (8, 24) if SMOKE else (16, 64)


def _engine(m, params, *, legacy, slots=2, op_config=None):
    return ServeEngine(m, params, slots=slots, max_len=MAX_LEN,
                       page_size=PAGE, chunk=CHUNK, prefill_block_q=16,
                       legacy_prefill=legacy, op_config=op_config)


def _warmup(eng, rng, cfg):
    # longer than one chunk so both prefill variants (mid-prompt and final
    # with-logits chunk) compile before anything is timed
    eng.run([Request(rid=-1,
                     prompt=rng.integers(0, cfg.vocab_size, (CHUNK + 5,)),
                     max_new_tokens=2)])
    eng.telemetry.records.clear()  # keep compile out of the percentiles


def _prefill_tok_s(eng, rng, cfg) -> float:
    """Tokens/s of prompt ingestion = prompt_len / time-to-first-token."""
    req = Request(rid=1000, prompt=rng.integers(0, cfg.vocab_size, (PROMPT,)),
                  max_new_tokens=2)
    eng.run([req])
    ttft = eng.telemetry.records[1000].ttft_seconds
    return PROMPT / ttft


def _run_trace(eng, rng, cfg):
    trace = [dict(t) for t in arrival_trace(
        N_REQS, prompt_lens=TRACE_LENS, max_new=4, seed=1)]
    reqs = {t["rid"]: Request(
        rid=t["rid"], prompt=rng.integers(0, cfg.vocab_size,
                                          (t["prompt_len"],)),
        max_new_tokens=t["max_new"]) for t in trace}
    from repro.ops import plan_cache_info

    base_tick = eng.ticks
    i = 0
    decomp_after_first = None
    t0 = time.perf_counter()
    while i < len(trace) or len(eng.queue) or any(
            a is not None for a in eng.active):
        while i < len(trace) and trace[i]["arrive_tick"] <= eng.ticks - base_tick:
            eng.submit(reqs[trace[i]["rid"]])
            i += 1
        eng.tick()
        if decomp_after_first is None:
            decomp_after_first = plan_cache_info().task_decompositions
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs.values())
    s = eng.stats()
    gen = sum(len(r.out_tokens) for r in reqs.values())
    return {
        "wall_s": wall,
        "gen_tok_s": gen / wall,
        "ttft_p50_s": s["ttft"]["p50_s"],
        "ttft_p95_s": s["ttft"]["p95_s"],
        "ttft_p50_ticks": s["ttft"]["p50_ticks"],
        "ttft_p95_ticks": s["ttft"]["p95_ticks"],
        "task_decomp_first_tick": decomp_after_first,
        "task_decomp_last_tick": plan_cache_info().task_decompositions,
    }


def run(csv_rows):
    rng = np.random.default_rng(0)
    # sparse FFN so decode ticks actually exercise the sparse matmuls the
    # skinny-N dispatch routes (a dense FFN never touches the spmv family)
    cfg = reduced_config(ARCHS["granite-3-2b"], num_layers=2, vocab_size=512,
                         ffn_sparsity=0.75)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    tok_s = {}
    for mode, legacy in (("chunked", False), ("legacy", True)):
        eng = _engine(m, params, legacy=legacy)
        _warmup(eng, rng, cfg)
        tok_s[mode] = _prefill_tok_s(eng, rng, cfg)
        csv_rows.append((f"serve/{mode}_prefill", 1e6 * PROMPT / tok_s[mode],
                         f"prefill_tok_s={tok_s[mode]:.0f}"))
    speedup = tok_s["chunked"] / tok_s["legacy"]
    JSON_EXTRAS["serve/chunked_prefill"] = {
        "prefill_tok_s": tok_s["chunked"],
        "legacy_prefill_tok_s": tok_s["legacy"],
        "prefill_speedup": speedup,
    }

    # the same trace twice: default decode (skinny-N GEMV dispatch on,
    # OpConfig.spmv_threshold="auto") vs pinned full-tile — so the JSON
    # surfaces the decode tok/s on each side of the crossover
    trace, spmv_hits = {}, 0
    for mode, op_cfg in (("spmv", None),
                         ("full_tile", OpConfig(spmv_threshold=0))):
        # dispatch decisions are made at trace time, so snapshot the
        # counter around warmup+trace, not just the timed run
        before = spmv_dispatch_info()["dispatched"]
        eng = _engine(m, params, legacy=False, op_config=op_cfg)
        _warmup(eng, rng, cfg)
        _run_trace(eng, rng, cfg)  # warm process-global plan/tuning caches
        trace[mode] = _run_trace(eng, rng, cfg)
        if mode == "spmv":
            spmv_hits = spmv_dispatch_info()["dispatched"] - before
    t = trace["spmv"]
    t["decode_tok_s_spmv"] = trace["spmv"]["gen_tok_s"]
    t["decode_tok_s_full_tile"] = trace["full_tile"]["gen_tok_s"]
    t["spmv_dispatched"] = spmv_hits
    t["spmv_crossover_n"] = DEFAULT_SPMV_THRESHOLD
    csv_rows.append((
        "serve/trace_continuous_batching", 1e6 * t["wall_s"],
        f"gen_tok_s={t['gen_tok_s']:.0f}_ttft_p50={t['ttft_p50_ticks']:.0f}t"
        f"_p95={t['ttft_p95_ticks']:.0f}t"))
    JSON_EXTRAS["serve/trace_continuous_batching"] = t
    return csv_rows
