"""Multi-device SpMM scaling smoke: sharded vs single-device on a host mesh.

Device count must be fixed before jax initializes, so the measurement runs
in a child process launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; the parent (the
``benchmarks/run.py`` harness) only parses its CSV. On a CPU container the
"devices" are host threads sharing one socket, so ``us_per_call`` is a
plumbing smoke (does the sharded path run, does it stay numerically sane),
not a speedup claim — the ``derived`` column reports the partitioner's
worst/mean shard-balance ratio, which *is* meaningful at any scale.

The ``dist/overlap`` row exercises the chunked compute/collective overlap
(``combine_chunks``): both combines are measured on the host mesh (plumbing
smoke; their outputs must match bitwise-tight), and the ``derived`` column
reports *modeled v5e* throughput — blocking = ``t_comp + t_coll`` vs
overlapped = ``max(t_comp, t_coll) + min(t_comp, t_coll)/chunks`` — which
is what the CI guard checks (overlapped >= blocking by construction of the
overlap; the measured host numbers ride along in the JSON extras).

Standalone: ``python benchmarks/dist_scaling.py`` (add ``--devices 8``,
``--smoke``, or ``--topology 2x2`` for a 2-D ``(data, model)`` mesh with
the ``reduce="hier"`` combine).
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

_SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
_DEVICES = 4
_TOPOLOGY = None  # (rows, cols) -> 2-D (data, model) mesh in the child

# v5e inter-chip (ICI) bandwidth per chip, one direction — the collective
# cost model for the overlap row (HBM/MXU peaks live in benchmarks.common)
ICI_BW = 4.5e10


def _child() -> None:
    """Runs inside the forced multi-device process; prints CSV rows."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import model_bcsr_time, suite_matrix, tflops, time_call

    from repro.ops import DEFAULT_COMBINE_CHUNKS, auto_bn, make_partition, spmm
    from repro.sparse import SparseTensor

    ndev = len(jax.devices())
    if _TOPOLOGY is not None:
        r, c = _TOPOLOGY
        mesh = jax.make_mesh((r, c), ("data", "model"))
        axes = ("data", "model")
    else:
        mesh = jax.make_mesh((ndev,), ("data",))
        axes = "data"
    m, k, n = (256, 256, 64) if _SMOKE else (1024, 1024, 256)
    d = suite_matrix("powerlaw", m, k, 0.05, seed=0)
    b = jnp.asarray(np.random.default_rng(1).normal(size=(k, n)),
                    jnp.float32)
    tag = (f"{_TOPOLOGY[0]}x{_TOPOLOGY[1]}" if _TOPOLOGY is not None
           else f"x{ndev}")
    for fmt, block in [("bcsr", (32, 32)), ("wcsr", (32, 8))]:
        st = SparseTensor.from_dense(d, fmt, block=block)
        ratio = make_partition(st.structure, ndev).balance()["ratio"]
        f1 = jax.jit(lambda x: spmm(st, x))
        us1 = time_call(f1, b)
        sst = st.shard(mesh, axes)
        fs = jax.jit(lambda x: spmm(sst, x))
        uss = time_call(fs, b)
        # sanity: the two paths agree before either time means anything
        np.testing.assert_allclose(np.asarray(fs(b)), np.asarray(f1(b)),
                                   atol=2e-3, rtol=1e-3)
        if _TOPOLOGY is not None:
            # hierarchical combine must match the flat two-axis psum
            fh = jax.jit(lambda x: spmm(sst, x, reduce="hier"))
            np.testing.assert_allclose(np.asarray(fh(b)),
                                       np.asarray(fs(b)),
                                       atol=1e-5, rtol=1e-5)
        print(f"dist/{fmt}/single,{us1:.1f},devices=1")
        print(f"dist/{fmt}/sharded_{tag},{uss:.1f},"
              f"balance_ratio={ratio:.3f}")

    # -- chunked compute/collective overlap (combine_chunks) ---------------
    st = SparseTensor.from_dense(d, "bcsr", block=(32, 32))
    sst = st.shard(mesh, axes)
    cc = DEFAULT_COMBINE_CHUNKS
    f_block = jax.jit(lambda x: spmm(sst, x, combine_chunks=1))
    f_over = jax.jit(lambda x: spmm(sst, x, combine_chunks=cc))
    us_block = time_call(f_block, b)
    us_over = time_call(f_over, b)
    # the chunked combine is a row-partition of the same math: outputs
    # must match the blocking combine to float tolerance, not just "close"
    np.testing.assert_allclose(np.asarray(f_over(b)),
                               np.asarray(f_block(b)),
                               atol=1e-5, rtol=1e-5)
    nnz = int(st.structure.nnz) * 32 * 32
    bn = auto_bn(n, 32, 32, op="dist", shape=(m, k))
    nnz_blocks = int(st.structure.nnz)
    t_comp = model_bcsr_time(max(nnz_blocks // ndev, 1), 32, 32, n, bn, k=k)
    t_coll = 2.0 * (ndev - 1) / ndev * (m * n * 4) / ICI_BW
    t_blocking = t_comp + t_coll
    t_overlap = max(t_comp, t_coll) + min(t_comp, t_coll) / cc
    tp_b = tflops(nnz, n, t_blocking)
    tp_o = tflops(nnz, n, t_overlap)
    print(f"dist/overlap,{us_over:.1f},"
          f"modeled_v5e={tp_o:.2f}vs{tp_b:.2f}TFLOPS cc={cc}")
    print("dist-extras/overlap," + json.dumps({
        "combine_chunks": cc, "devices": ndev,
        "blocking_us": round(us_block, 1),
        "overlapped_us": round(us_over, 1),
        "modeled_blocking_tflops": round(tp_b, 3),
        "modeled_overlapped_tflops": round(tp_o, 3),
    }))


def run(rows) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_DEVICES}")
    if _SMOKE:
        env["REPRO_BENCH_SMOKE"] = "1"
    repo = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    argv = [sys.executable, __file__, "--child"]
    if _TOPOLOGY is not None:
        argv += ["--topology", f"{_TOPOLOGY[0]}x{_TOPOLOGY[1]}"]
    p = subprocess.run(
        argv, capture_output=True, text=True, env=env, timeout=900)
    if p.returncode != 0:
        raise RuntimeError(
            f"dist_scaling child failed:\n{p.stdout}\n{p.stderr}")
    sys.path.insert(0, str(repo))  # standalone runs: make benchmarks importable
    from benchmarks.common import JSON_EXTRAS

    for line in p.stdout.splitlines():
        if line.startswith("dist-extras/"):
            name, payload = line.split(",", 1)
            JSON_EXTRAS["dist/" + name.split("/", 1)[1]] = json.loads(payload)
        elif line.startswith("dist/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))


def main() -> None:
    global _SMOKE, _DEVICES, _TOPOLOGY
    if "--smoke" in sys.argv:
        _SMOKE = True
    if "--devices" in sys.argv:
        _DEVICES = int(sys.argv[sys.argv.index("--devices") + 1])
    if "--topology" in sys.argv:
        r, c = sys.argv[sys.argv.index("--topology") + 1].split("x")
        _TOPOLOGY = (int(r), int(c))
        _DEVICES = _TOPOLOGY[0] * _TOPOLOGY[1]
    if "--child" in sys.argv:
        _child()
        return
    rows = []
    run(rows)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
