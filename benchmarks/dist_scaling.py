"""Multi-device SpMM scaling smoke: sharded vs single-device on a host mesh.

Device count must be fixed before jax initializes, so the measurement runs
in a child process launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; the parent (the
``benchmarks/run.py`` harness) only parses its CSV. On a CPU container the
"devices" are host threads sharing one socket, so ``us_per_call`` is a
plumbing smoke (does the sharded path run, does it stay numerically sane),
not a speedup claim — the ``derived`` column reports the partitioner's
worst/mean shard-balance ratio, which *is* meaningful at any scale.

Standalone: ``python benchmarks/dist_scaling.py`` (add ``--devices 8`` or
``--smoke``).
"""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys

_SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
_DEVICES = 4


def _child() -> None:
    """Runs inside the forced multi-device process; prints CSV rows."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import suite_matrix, time_call

    from repro.ops import make_partition, spmm
    from repro.sparse import SparseTensor

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    m, k, n = (256, 256, 64) if _SMOKE else (1024, 1024, 256)
    d = suite_matrix("powerlaw", m, k, 0.05, seed=0)
    b = jnp.asarray(np.random.default_rng(1).normal(size=(k, n)),
                    jnp.float32)
    for fmt, block in [("bcsr", (32, 32)), ("wcsr", (32, 8))]:
        st = SparseTensor.from_dense(d, fmt, block=block)
        ratio = make_partition(st.structure, ndev).balance()["ratio"]
        f1 = jax.jit(lambda x: spmm(st, x))
        us1 = time_call(f1, b)
        sst = st.shard(mesh, "data")
        fs = jax.jit(lambda x: spmm(sst, x))
        uss = time_call(fs, b)
        # sanity: the two paths agree before either time means anything
        np.testing.assert_allclose(np.asarray(fs(b)), np.asarray(f1(b)),
                                   atol=2e-3, rtol=1e-3)
        print(f"dist/{fmt}/single,{us1:.1f},devices=1")
        print(f"dist/{fmt}/sharded_x{ndev},{uss:.1f},"
              f"balance_ratio={ratio:.3f}")


def run(rows) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_DEVICES}")
    if _SMOKE:
        env["REPRO_BENCH_SMOKE"] = "1"
    repo = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    p = subprocess.run(
        [sys.executable, __file__, "--child"],
        capture_output=True, text=True, env=env, timeout=900)
    if p.returncode != 0:
        raise RuntimeError(
            f"dist_scaling child failed:\n{p.stdout}\n{p.stderr}")
    for line in p.stdout.splitlines():
        if line.startswith("dist/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))


def main() -> None:
    global _SMOKE, _DEVICES
    if "--smoke" in sys.argv:
        _SMOKE = True
    if "--devices" in sys.argv:
        _DEVICES = int(sys.argv[sys.argv.index("--devices") + 1])
    if "--child" in sys.argv:
        _child()
        return
    rows = []
    run(rows)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
